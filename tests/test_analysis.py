"""Tests for repro.analysis — the static invariant-verification layer.

Two families:

* **Seeded-mutation golden diagnostics** — copy ``src/`` into a tmp
  tree, inject one defect of a pass's target class, and assert the pass
  reports *exactly* the expected error code (and stays silent on the
  adjacent clean constructs).  This is the proof each pass catches its
  defect class, per ISSUE 7's acceptance criteria.
* **Repo self-cleanliness** — every pass runs clean on the real tree
  (the property the blocking ``analysis`` CI job gates), and the
  model-plane corpus (presets × builders, all configs, golden trace
  fixtures) validates with zero diagnostics.
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (AnalysisError, Severity, preflight, run_passes,
                            validate)
from repro.analysis.framework import PassContext, get_pass
from repro.analysis.__main__ import main as analysis_main
from repro.configs import all_configs
from repro.core.flexblock import row_block
from repro.core.mapping import MappingSpec, ReshapeSpec
from repro.core.presets import PRESET_ARCHS
from repro.core.workload import (MODEL_BUILDERS, OpNode, Workload,
                                 lm_workload)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = sorted((REPO / "tests" / "fixtures" / "trace").glob("*.json"))


def _codes(diags, *, errors_only: bool = True):
    return sorted({d.code for d in diags
                   if not d.suppressed
                   and (not errors_only or d.severity == Severity.ERROR)})


def _mutated_tree(tmp_path: Path) -> Path:
    """A throwaway copy of src/ to inject defects into."""
    root = tmp_path / "tree"
    shutil.copytree(REPO / "src", root / "src")
    return root


def _run(pass_name: str, root: Path):
    return get_pass(pass_name).run(PassContext(root=root))


def _append(root: Path, rel: str, text: str) -> None:
    p = root / "src" / "repro" / rel
    p.write_text(p.read_text() + text)


def _sub(root: Path, rel: str, old: str, new: str) -> None:
    p = root / "src" / "repro" / rel
    text = p.read_text()
    assert old in text, f"mutation anchor {old!r} missing from {rel}"
    p.write_text(text.replace(old, new))


# ---------------------------------------------------------------------------
# repo self-cleanliness (what the CI `analysis` job gates)
# ---------------------------------------------------------------------------

def test_repo_is_clean_under_all_passes():
    diags = [d for d in run_passes(None, root=REPO) if not d.suppressed]
    assert not [d for d in diags if d.severity == Severity.ERROR], \
        [f"{d.code} {d.location}: {d.message}" for d in diags]
    assert not [d for d in diags if d.severity == Severity.WARNING], \
        [f"{d.code} {d.location}: {d.message}" for d in diags]


def test_cli_all_json_exits_zero(capsys):
    rc = analysis_main(["--all", "--format", "json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert payload["ok"] is True
    assert payload["counts"]["error"] == 0
    assert set(payload["passes"]) == {"import-boundary", "cache-key",
                                      "model-plane", "determinism"}


def test_cli_runs_without_jax(tmp_path):
    """The entire checker must work on a jax-free interpreter."""
    nojax = tmp_path / "nojax"
    nojax.mkdir()
    (nojax / "jax.py").write_text("raise ImportError('no jax here')\n")
    env_path = f"{nojax}:{REPO / 'src'}"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--all"],
        env={"PATH": "/usr/bin:/bin", "PYTHONPATH": env_path},
        capture_output=True, text=True, cwd=str(REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_rejects_unknown_pass():
    with pytest.raises(SystemExit) as ei:
        analysis_main(["--pass", "nonsense"])
    assert ei.value.code == 2


def test_cli_list(capsys):
    assert analysis_main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in ("import-boundary", "cache-key", "model-plane",
                 "determinism"):
        assert name in out


# ---------------------------------------------------------------------------
# pass 1: import-boundary (seeded mutations)
# ---------------------------------------------------------------------------

def test_boundary_clean_tree_has_no_findings(tmp_path):
    assert _run("import-boundary", _mutated_tree(tmp_path)) == []


def test_boundary_catches_toplevel_jax_import(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "core/flexblock.py", "\nimport jax\n")
    diags = _run("import-boundary", root)
    assert _codes(diags) == ["CIM101", "CIM102"]
    cim101 = [d for d in diags if d.code == "CIM101"]
    assert len(cim101) == 1
    assert cim101[0].file.endswith("core/flexblock.py")
    # the taint propagates to eager importers of flexblock
    assert any(d.code == "CIM102" for d in diags)


def test_boundary_catches_transitive_jax_reach(tmp_path):
    root = _mutated_tree(tmp_path)
    # serve.engine imports jax eagerly (execution plane, legal there);
    # pulling it into the explore plane must flag the importing edge
    _append(root, "explore/job.py", "\nfrom ..serve import engine\n")
    diags = _run("import-boundary", root)
    assert "CIM102" in _codes(diags)
    assert any(d.file.endswith("explore/job.py") for d in diags)


def test_boundary_catches_plane_crossing_without_jax(tmp_path):
    root = _mutated_tree(tmp_path)
    # a brand-new, import-free execution-plane module: crossing into it
    # is still a layering violation (CIM103), even with no jax anywhere
    (root / "src" / "repro" / "launch" / "_stub.py").write_text("X = 1\n")
    _append(root, "core/workload.py", "\nfrom ..launch import _stub\n")
    diags = _run("import-boundary", root)
    assert _codes(diags) == ["CIM103"]


def test_boundary_allows_lazy_and_type_checking_imports(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "core/flexblock.py", (
        "\nfrom typing import TYPE_CHECKING\n"
        "if TYPE_CHECKING:\n    import jax\n"
        "def _lazy_site():\n    import jax.numpy as jnp\n"
        "    return jnp\n"))
    assert _run("import-boundary", root) == []


def test_boundary_suppression_marker(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "core/flexblock.py",
            "\nimport jax  # ciminus: ignore[*] -- test waiver\n")
    diags = run_passes(["import-boundary"], root=root)
    assert all(d.suppressed for d in diags if d.code == "CIM101")


# ---------------------------------------------------------------------------
# pass 2: cache-key completeness (seeded mutations)
# ---------------------------------------------------------------------------

def test_cachekey_clean_tree_has_no_findings(tmp_path):
    assert _run("cache-key", _mutated_tree(tmp_path)) == []


def test_cachekey_catches_new_simulate_kwarg(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "core/costmodel.py",
         "def simulate(",
         "def simulate(*, _rounding_mode: str = 'even'):\n    pass\n"
         "def _old_simulate(")
    diags = _run("cache-key", root)
    assert "CIM201" in _codes(diags)
    assert any("_rounding_mode" in d.message for d in diags)


def test_cachekey_catches_unforwarded_job_field(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/runner.py", "profile=job.profile,", "profile=None,")
    diags = _run("cache-key", root)
    assert _codes(diags) == ["CIM202"]
    assert any("'profile'" in d.message for d in diags)


def test_cachekey_catches_hand_listed_canonical(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/job.py",
         "_sorted_field_names(type(obj))", "('arch', 'workload')")
    diags = _run("cache-key", root)
    assert "CIM203" in _codes(diags)


def test_cachekey_catches_schema_bump_without_history(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/job.py", "CACHE_SCHEMA = 5", "CACHE_SCHEMA = 6")
    diags = _run("cache-key", root)
    assert _codes(diags) == ["CIM204"]


def test_cachekey_anchors_present_or_cim200(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/runner.py", "def evaluate_job(", "def eval_job_v2(")
    diags = _run("cache-key", root)
    assert "CIM200" in _codes(diags)


def test_cachekey_catches_obs_named_job_field(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/job.py",
         "kind: str                                   # 'simulate' | 'dense'",
         "kind: str                                   # 'simulate' | 'dense'"
         "\n    obs_tag: str = 'x'")
    diags = _run("cache-key", root)
    assert "CIM205" in _codes(diags)
    assert any("obs_tag" in d.message for d in diags)


def test_cachekey_catches_obs_named_simulate_param(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "core/costmodel.py",
         "def simulate(",
         "def simulate(*, obs_sink=None):\n    pass\n"
         "def _old_simulate(")
    assert "CIM205" in _codes(_run("cache-key", root))


def test_cachekey_catches_obs_import_in_job_module(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "explore/job.py", "\nfrom .. import obs  # noqa\n")
    diags = _run("cache-key", root)
    assert _codes(diags) == ["CIM205"]
    assert any("obs" in d.message for d in diags)


def test_cachekey_catches_fault_named_job_field(tmp_path):
    """CIM206: retry/timeout/fault knobs are runner-level — a
    fault-named ExploreJob field is a cache-key contract breach."""
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/job.py",
         "kind: str                                   # 'simulate' | 'dense'",
         "kind: str                                   # 'simulate' | 'dense'"
         "\n    retry_budget: int = 2")
    diags = _run("cache-key", root)
    assert "CIM206" in _codes(diags)
    assert any("retry_budget" in d.message for d in diags)


def test_cachekey_catches_fault_named_simulate_param(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "core/costmodel.py",
         "def simulate(",
         "def simulate(*, timeout_s=None):\n    pass\n"
         "def _old_simulate(")
    diags = _run("cache-key", root)
    assert "CIM206" in _codes(diags)
    assert any("timeout_s" in d.message for d in diags)


def test_cachekey_catches_faults_import_in_job_module(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "explore/job.py", "\nfrom . import faults  # noqa\n")
    diags = _run("cache-key", root)
    assert _codes(diags) == ["CIM206"]
    assert any("faults" in d.message for d in diags)


def test_cachekey_catches_batch_named_job_field(tmp_path):
    """CIM207: batching is an execution knob — results are bit-identical
    by contract, so a batch-named ExploreJob field would fracture the
    store namespace for no semantic reason."""
    root = _mutated_tree(tmp_path)
    _sub(root, "explore/job.py",
         "kind: str                                   # 'simulate' | 'dense'",
         "kind: str                                   # 'simulate' | 'dense'"
         "\n    batch_size: int = 0")
    diags = _run("cache-key", root)
    assert "CIM207" in _codes(diags)
    assert any("batch_size" in d.message for d in diags)


def test_cachekey_catches_search_named_simulate_param(tmp_path):
    root = _mutated_tree(tmp_path)
    _sub(root, "core/costmodel.py",
         "def simulate(",
         "def simulate(*, search_budget=None):\n    pass\n"
         "def _old_simulate(")
    diags = _run("cache-key", root)
    assert "CIM207" in _codes(diags)
    assert any("search_budget" in d.message for d in diags)


def test_cachekey_catches_batch_import_in_job_module(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "explore/job.py", "\nfrom . import batch  # noqa\n")
    diags = _run("cache-key", root)
    assert _codes(diags) == ["CIM207"]
    assert any("batch" in d.message for d in diags)


def test_cachekey_catches_search_import_in_job_module(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "explore/job.py",
            "\nfrom .search import SearchPolicy  # noqa\n")
    diags = _run("cache-key", root)
    assert _codes(diags) == ["CIM207"]


# ---------------------------------------------------------------------------
# pass 3: model-plane validation (live-object goldens)
# ---------------------------------------------------------------------------

def _splice(w: Workload, key: str, node: OpNode) -> None:
    w.nodes[key] = node     # bypass add(): the hazard validate() targets


def test_modelplane_dangling_edge():
    w = Workload("t")
    w.fc("a", 16, 16)
    _splice(w, "b", OpNode(name="b", kind="add", inputs=("ghost",),
                           elements=4))
    codes = _codes(validate(w))
    assert codes == ["CIM301"]


def test_modelplane_name_mismatch_and_cycle():
    w = Workload("t")
    w.fc("a", 16, 16)
    _splice(w, "b", OpNode(name="zzz", kind="fc", K=4, N=4, V=1))
    _splice(w, "c", OpNode(name="c", kind="fc", inputs=("d",),
                           K=4, N=4, V=1))
    _splice(w, "d", OpNode(name="d", kind="fc", inputs=("c",),
                           K=4, N=4, V=1))
    codes = _codes(validate(w))
    assert codes == ["CIM302", "CIM303"]


def test_modelplane_isolated_op_is_warning_only():
    w = Workload("t")
    w.fc("a", 16, 16)
    w.fc("b", 16, 16, inputs=("a",))
    w.fc("loner", 8, 8)
    diags = validate(w)
    assert _codes(diags) == []                       # no errors
    assert _codes(diags, errors_only=False) == ["CIM304"]


def test_modelplane_bad_dims():
    w = Workload("t")
    _splice(w, "a", OpNode(name="a", kind="conv", K=0, N=-3, V=1))
    assert _codes(validate(w)) == ["CIM305"]


def test_modelplane_incompatible_sparsity():
    w = Workload("t")
    w.fc("a", 16, 16)
    w.nodes["a"].sparsity = row_block(0.5, width=10 ** 6)  # block >> matrix
    assert _codes(validate(w)) == ["CIM306"]


def test_modelplane_index_capacity_infeasible():
    arch = PRESET_ARCHS["mars"]()
    tiny = dataclasses.replace(arch.mem("index_mem"), capacity_bytes=1)
    arch = arch.replace(
        memory_units={**arch.memory_units, "index_mem": tiny})
    w = Workload("t")
    w.fc("a", 4096, 4096)
    w.nodes["a"].sparsity = row_block(0.5, width=16)
    assert "CIM307" in _codes(validate(w, arch))


def test_modelplane_arch_contract():
    arch = PRESET_ARCHS["mars"]()
    broken = arch.replace(compute_units={
        k: v for k, v in arch.compute_units.items() if k != "adder_tree"})
    w = Workload("t")
    w.fc("a", 16, 16)
    assert "CIM309" in _codes(validate(w, broken))


def test_modelplane_mapping_contract():
    w = Workload("t")
    w.fc("a", 16, 16)
    mapping = MappingSpec(
        reshape=ReshapeSpec(rearrange="slice", slice_size=0),
        strategy="bogus")
    codes = _codes(validate(w, None, mapping))
    assert codes == ["CIM310"]
    assert len([d for d in validate(w, None, mapping)
                if d.code == "CIM310"]) == 2          # strategy + slice


def test_workload_validate_reports_everything_at_once():
    w = Workload("t")
    w.fc("a", 16, 16)
    _splice(w, "b", OpNode(name="b", kind="add", inputs=("ghost",),
                           elements=4))
    _splice(w, "c", OpNode(name="wrong", kind="fc", K=4, N=4, V=1))
    _splice(w, "d", OpNode(name="d", kind="fc", inputs=("e",),
                           K=4, N=4, V=1))
    _splice(w, "e", OpNode(name="e", kind="fc", inputs=("d",),
                           K=4, N=4, V=1))
    kinds = {i.kind for i in w.validate()}
    assert {"dangling-edge", "name-mismatch", "cycle",
            "isolated"} <= kinds
    # topo_order still raises (legacy contract unchanged)
    with pytest.raises(ValueError):
        w.topo_order()


# ---------------------------------------------------------------------------
# preflight policy (strict vs warn-only)
# ---------------------------------------------------------------------------

def _broken_workload() -> Workload:
    w = Workload("broken")
    _splice(w, "a", OpNode(name="a", kind="add", inputs=("ghost",),
                           elements=4))
    return w


def test_preflight_strict_raises():
    with pytest.raises(AnalysisError) as ei:
        preflight(_broken_workload(), strict=True, where="unit-test")
    assert "CIM301" in str(ei.value)


def test_preflight_warn_only_warns():
    with pytest.warns(RuntimeWarning, match="CIM301"):
        preflight(_broken_workload(), strict=False,
                  where="unit-test-warn")


def test_preflight_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_ANALYSIS_PREFLIGHT", "0")
    assert preflight(_broken_workload(), strict=True) == []


# ---------------------------------------------------------------------------
# pass 4: determinism lint (seeded mutations)
# ---------------------------------------------------------------------------

def test_determinism_clean_tree_has_no_findings(tmp_path):
    assert _run("determinism", _mutated_tree(tmp_path)) == []


@pytest.mark.parametrize("snippet,code", [
    ("x = np.random.rand(3)", "CIM401"),
    ("g = np.random.default_rng()", "CIM401"),
    ("import random\nr = random.random()", "CIM401"),
    ("import time\nt = time.time()", "CIM402"),
    ("h = hash((1, 2))", "CIM403"),
    ("import os\nfiles = os.listdir('.')", "CIM404"),
])
def test_determinism_catches(tmp_path, snippet, code):
    root = _mutated_tree(tmp_path)
    body = "\n".join("    " + line for line in snippet.splitlines())
    _append(root, "core/flexblock.py", f"\ndef _mutant():\n{body}\n")
    assert _codes(_run("determinism", root)) == [code]


@pytest.mark.parametrize("snippet", [
    "g = np.random.default_rng(42)",                  # seeded
    "import time\nt = time.perf_counter()",           # monotonic
    "import os\nfiles = sorted(os.listdir('.'))",     # sorted enumeration
])
def test_determinism_allows_clean_idioms(tmp_path, snippet):
    root = _mutated_tree(tmp_path)
    body = "\n".join("    " + line for line in snippet.splitlines())
    _append(root, "core/flexblock.py", f"\ndef _mutant():\n{body}\n")
    assert _run("determinism", root) == []


def test_determinism_wall_clock_waived_inside_obs_only(tmp_path):
    """CIM402 is sanctioned under repro.obs (telemetry stamps), nowhere
    else — the same mutant fails in core/."""
    root = _mutated_tree(tmp_path)
    mutant = "\ndef _mutant():\n    import time\n    t = time.time()\n"
    _append(root, "obs/core.py", mutant)
    assert _run("determinism", root) == []
    _append(root, "core/flexblock.py", mutant)
    assert _codes(_run("determinism", root)) == ["CIM402"]


def test_determinism_other_codes_not_waived_in_obs(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "obs/core.py",
            "\ndef _mutant():\n    h = hash((1, 2))\n")
    assert _codes(_run("determinism", root)) == ["CIM403"]


def test_boundary_protects_obs(tmp_path):
    root = _mutated_tree(tmp_path)
    _append(root, "obs/core.py", "\nimport jax\n")
    diags = _run("import-boundary", root)
    codes = _codes(diags)
    # CIM101 on obs/core itself; the taint then propagates CIM102 to
    # every protected module that eagerly imports repro.obs
    assert "CIM101" in codes
    assert any("repro.obs.core" in d.message and d.code == "CIM101"
               for d in diags)


# ---------------------------------------------------------------------------
# corpus property: presets x models, all configs, golden fixtures clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", sorted(PRESET_ARCHS))
@pytest.mark.parametrize("model", sorted(MODEL_BUILDERS))
def test_corpus_models_validate_clean(preset, model):
    diags = validate(MODEL_BUILDERS[model](), PRESET_ARCHS[preset]())
    assert diags == [], [d.message for d in diags]


@pytest.mark.parametrize("cfg_name", sorted(all_configs()))
def test_corpus_configs_validate_clean(cfg_name):
    w = lm_workload(all_configs()[cfg_name], seq_len=32)
    diags = validate(w, PRESET_ARCHS["mars"]())
    assert diags == [], [d.message for d in diags]


@pytest.mark.parametrize("fixture", FIXTURES, ids=lambda p: p.stem)
def test_corpus_trace_fixtures_validate_clean(fixture):
    from repro.trace.ir import TraceGraph
    from repro.trace.lower import lower_graph
    w = lower_graph(TraceGraph.load(fixture))
    diags = validate(w)
    assert diags == [], [d.message for d in diags]


def test_golden_fixture_count_still_five():
    assert len(FIXTURES) == 5
