"""Guided-search tests: determinism, budgets, resumability, streaming.

Search policies choose *which* points evaluate, never *what* a point
computes — so every policy must be deterministic (same policy → same
trajectory), budget-bounded, and fully resumable through a PR 9 run
directory (second run → zero evaluations).
"""
import numpy as np
import pytest

from repro.core import FlexBlockSpec, FullBlock, default_mapping, usecase_arch
from repro.core.schedule import SchedulePolicy
from repro.core.workload import Workload
from repro.explore import (ExploreJob, PointSpace, ResultCache, SearchPolicy,
                           SweepRunner, estimate_job, run_search)
from repro.explore.sweeps import GridPoint, run_grid, stream_grid

RATIOS = (0.3, 0.45, 0.6, 0.75, 0.9)
STRATEGIES = ("spatial", "duplicate")
POLICIES = ("monolithic", "partitioned")
SHAPE = (len(RATIOS), len(STRATEGIES), len(POLICIES))


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


def _wl():
    w = Workload("searchy")
    w.fc("fc1", 64, 64)
    w.fc("fc2", 64, 32, inputs=("fc1",))
    return w


@pytest.fixture(scope="module")
def space(arch4):
    mappings = {s: default_mapping(arch4, s) for s in STRATEGIES}
    scheds = {p: SchedulePolicy(policy=p) for p in POLICIES}

    def factory(i):
        ri, si, pi = np.unravel_index(i, SHAPE)
        ratio = RATIOS[ri]
        strat, pol = STRATEGIES[si], POLICIES[pi]
        spec = FlexBlockSpec((FullBlock(16, 16, ratio),), name="b")
        job = ExploreJob.simulate(arch4, _wl().set_sparsity(spec),
                                  mappings[strat], schedule=scheds[pol])
        dense = ExploreJob.dense(arch4, _wl(), mappings[strat],
                                 schedule=scheds[pol])
        return GridPoint(job, dense, meta=(("pattern", "b"),
                                           ("ratio", ratio),
                                           ("schedule", pol)))

    return PointSpace(int(np.prod(SHAPE)), factory, SHAPE)


@pytest.fixture(scope="module")
def exhaustive_rows(space):
    points = [space.factory(i) for i in range(space.size)]
    return run_grid(points, runner=SweepRunner(workers=1)).rows


# ---------------------------------------------------------------------------
# PointSpace / SearchPolicy surface
# ---------------------------------------------------------------------------

def test_point_space_coords_roundtrip(space):
    for i in (0, 1, 7, space.size - 1):
        assert space.index(space.coords(i)) == i
    assert space.coords(0) == (0, 0, 0)


def test_point_space_shape_mismatch_rejected():
    with pytest.raises(ValueError):
        PointSpace(10, lambda i: None, (3, 3))


def test_search_policy_validation():
    with pytest.raises(ValueError):
        SearchPolicy(kind="annealing")
    with pytest.raises(ValueError):
        SearchPolicy(budget=0)
    with pytest.raises(ValueError):
        SearchPolicy(eta=1)
    with pytest.raises(ValueError):
        SearchPolicy(direction="up")


def test_estimate_job_deterministic_and_finite(space):
    jobs = [space.factory(i).job for i in range(4)]
    ests = [estimate_job(j) for j in jobs]
    assert all(np.isfinite(e) and e > 0 for e in ests)
    assert ests == [estimate_job(j) for j in jobs]


def test_estimate_jobs_bit_identical_to_per_job(arch4, space):
    """Batch estimation shares one costing pass per variant group but
    must reproduce estimate_job's floats exactly — including under a
    calibration profile, whose efficiency division is replayed per op."""
    from repro.calibrate.profile import resolve_profile
    from repro.explore import estimate_jobs

    jobs = [space.factory(i).job for i in range(space.size)]
    prof = resolve_profile("default")
    jobs += [ExploreJob.simulate(j.arch, j.workload, j.mapping,
                                 profile=prof, schedule=j.schedule)
             for j in jobs[:6]]
    assert estimate_jobs(jobs) == [estimate_job(j) for j in jobs]


# ---------------------------------------------------------------------------
# stream_grid
# ---------------------------------------------------------------------------

def test_stream_grid_matches_run_grid(space, exhaustive_rows, tmp_path):
    csv_path = tmp_path / "rows.csv"
    sr = stream_grid((space.factory(i) for i in range(space.size)),
                     runner=SweepRunner(workers=1, batch_size=8),
                     chunk=6, keep_rows=True, csv_path=csv_path,
                     total=space.size)
    assert sr.rows == exhaustive_rows
    ref = run_grid([space.factory(i) for i in range(space.size)],
                   runner=SweepRunner(workers=1))
    assert sr.front_rows == ref.pareto()
    assert sr.topk_rows == ref.top_k("latency_ms", 5)
    assert sr.points == space.size
    import csv
    with open(csv_path) as f:
        assert len(list(csv.DictReader(f))) == space.size


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_exhaustive_covers_space(space, exhaustive_rows):
    res = run_search(space, SearchPolicy(kind="exhaustive"),
                     runner=SweepRunner(workers=1, batch_size=8),
                     keep_rows=True)
    assert res.rows == exhaustive_rows
    assert res.points == space.size and res.estimated == 0


def test_halving_promotes_best_estimates_in_grid_order(space):
    budget = 5
    ests = [estimate_job(space.factory(i).job) for i in range(space.size)]
    survivors = sorted(sorted(range(space.size),
                              key=lambda i: (ests[i], i))[:budget])
    expect = run_grid([space.factory(i) for i in survivors],
                      runner=SweepRunner(workers=1)).rows
    res = run_search(space, SearchPolicy(kind="halving", budget=budget),
                     runner=SweepRunner(workers=1, batch_size=8),
                     keep_rows=True)
    assert res.rows == expect
    assert res.points == budget and res.estimated == space.size


def test_halving_default_budget_is_size_over_eta(space):
    res = run_search(space, SearchPolicy(kind="halving", eta=4),
                     runner=SweepRunner(workers=1), keep_rows=True)
    assert res.points == space.size // 4


def test_evolve_deterministic_and_budget_bounded(space):
    pol = SearchPolicy(kind="evolve", budget=10, seed=5, population=4)
    runs = [run_search(space, pol, runner=SweepRunner(workers=1,
                                                      batch_size=8),
                       keep_rows=True) for _ in range(2)]
    assert runs[0].rows == runs[1].rows            # same trajectory
    assert runs[0].points == runs[1].points <= 10
    assert all("space_index" in r for r in runs[0].rows)
    seen = [r["space_index"] for r in runs[0].rows]
    assert len(set(seen)) == len(seen)             # never re-evaluates


def test_evolve_different_seeds_diverge(space):
    mk = lambda s: run_search(  # noqa: E731
        space, SearchPolicy(kind="evolve", budget=12, seed=s, population=4),
        runner=SweepRunner(workers=1), keep_rows=True)
    a, b = mk(1), mk(2)
    assert [r["space_index"] for r in a.rows] \
        != [r["space_index"] for r in b.rows]


def test_search_resumes_with_zero_evaluations(space, tmp_path):
    pol = SearchPolicy(kind="halving", budget=5)
    first = run_search(space, pol, runner=SweepRunner(
        workers=1, batch_size=8, cache=ResultCache(tmp_path)),
        keep_rows=True)
    assert first.stats.evaluated > 0
    second = run_search(space, pol, runner=SweepRunner(
        workers=1, batch_size=8, cache=ResultCache(tmp_path)),
        keep_rows=True)
    assert second.rows == first.rows
    assert second.stats.evaluated == 0             # all served by store


def test_search_rows_shared_across_policies(space, tmp_path):
    """Search is an execution knob (CIM207): a point evaluated under
    halving serves the same store entry to an exhaustive replay."""
    run_search(space, SearchPolicy(kind="halving", budget=5),
               runner=SweepRunner(workers=1,
                                  cache=ResultCache(tmp_path)))
    replay = run_search(space, SearchPolicy(kind="exhaustive"),
                        runner=SweepRunner(workers=1,
                                           cache=ResultCache(tmp_path)),
                        keep_rows=True)
    assert replay.stats.disk_hits > 0
