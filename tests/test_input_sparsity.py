"""Input-sparsity profiling (paper §IV-B pre-simulation analysis)."""
import numpy as np

import jax.numpy as jnp

from repro.core.input_sparsity import (analytic_skip_ratio, quantize_int8,
                                       skippable_bit_ratio)


def test_quantize_symmetric():
    x = jnp.asarray([-1.0, 0.0, 0.5, 1.0])
    q = np.asarray(quantize_int8(x))
    assert q.dtype == np.int8
    assert q[0] == -127 and q[3] == 127 and q[1] == 0


def test_all_zero_activations_fully_skippable():
    q = jnp.zeros((4, 64), jnp.int8)
    assert skippable_bit_ratio(q, 16) == 1.0


def test_dense_activations_not_skippable():
    q = jnp.full((4, 64), 127, jnp.int8)   # all bits set
    r = skippable_bit_ratio(q, 16, n_bits=7)
    assert r == 0.0


def test_ratio_decreases_with_group_size():
    rng = np.random.default_rng(0)
    acts = rng.normal(size=(32, 256)) * (rng.random((32, 256)) > 0.5)
    q = quantize_int8(jnp.asarray(acts))
    r_small = skippable_bit_ratio(q, 8)
    r_large = skippable_bit_ratio(q, 64)
    # larger broadcast groups make all-zero planes rarer (§III-B)
    assert r_large <= r_small


def test_analytic_estimate_behaviour():
    lo = analytic_skip_ratio(0.3, 32)
    hi = analytic_skip_ratio(0.9, 32)
    assert 0.0 <= lo < hi <= 1.0
    # more rows to agree → lower skip probability
    assert analytic_skip_ratio(0.5, 64) <= analytic_skip_ratio(0.5, 8)
