"""FlexBlock abstraction: unit + hypothesis property tests (paper §III)."""
import math

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core.flexblock import (FlexBlockSpec, FullBlock, IntraBlock,
                                  TABLE_II_PATTERNS, column_block,
                                  column_wise, dense_spec, hybrid, row_block,
                                  row_wise)


# ---------------------------------------------------------------------------
# Definition conformance
# ---------------------------------------------------------------------------

def test_fullblock_phi_formula():
    fb = FullBlock(2, 4, 0.7)
    # Φ = ⌊(1-r)·(M/m)·(N/n)⌋ (Def. III.2)
    assert fb.nonzero_blocks((16, 16)) == math.floor(0.3 * 8 * 4)


def test_intrablock_phi_formula():
    ib = IntraBlock(4, 1, 0.5)
    assert ib.phi == math.floor(0.5 * 4)


def test_intrablock_requires_column_blocks():
    with pytest.raises(ValueError):
        IntraBlock(2, 2, 0.5)


def test_intrablock_rejects_empty_blocks():
    with pytest.raises(ValueError):
        IntraBlock(2, 1, 0.9)  # φ = 0


def test_composition_limit():
    with pytest.raises(ValueError):
        FlexBlockSpec((IntraBlock(2, 1, 0.5), FullBlock(2, 16, 0.5),
                       FullBlock(4, 16, 0.5)))


def test_composition_order_enforced():
    # FullBlock + FullBlock is a subset of the finer pattern (§III-D)
    with pytest.raises(ValueError):
        FlexBlockSpec((FullBlock(2, 16, 0.5), FullBlock(4, 16, 0.5)))


def test_integral_multiple_constraint():
    with pytest.raises(ValueError):
        FlexBlockSpec((IntraBlock(2, 1, 0.5), FullBlock(3, 16, 0.5)))


def test_pattern_set_validation():
    with pytest.raises(ValueError):
        IntraBlock(2, 1, 0.5, pattern_set=((1, 1),))  # keeps 2 ≠ φ=1
    ib = IntraBlock(2, 1, 0.5, pattern_set=((1, 0), (0, 1)))
    assert len(ib.patterns()) == 2


def test_default_pattern_set_is_exhaustive():
    ib = IntraBlock(4, 1, 0.5)
    assert len(ib.default_patterns()) == math.comb(4, 2)


def test_hybrid_ratio_derivation():
    spec = hybrid(2, 16, 0.8)
    # overall density = intra density × fullblock density
    d = spec.overall_density((1024, 512))
    assert abs(d - 0.2) < 0.01


def test_hybrid_unreachable_ratio():
    with pytest.raises(ValueError):
        hybrid(2, 16, 0.3)   # 1:2 alone already gives density 0.5


def test_table_ii_patterns_exist():
    pats = TABLE_II_PATTERNS(0.8)
    for name in ("row-wise", "row-block", "column-wise", "channel-wise",
                 "column-block", "1:2+row-block", "1:2+row-wise",
                 "1:4+row-block"):
        assert name in pats, name


# ---------------------------------------------------------------------------
# Properties (hypothesis)
# ---------------------------------------------------------------------------

@given(m=st.integers(1, 8), n=st.integers(1, 8),
       r=st.floats(0.05, 0.95),
       gm=st.integers(1, 8), gn=st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_fullblock_density_bounds(m, n, r, gm, gn):
    if m * n <= 1:
        return
    fb = FullBlock(m, n, r)
    shape = (m * gm, n * gn)
    d = FlexBlockSpec((fb,)).overall_density(shape)
    assert 0.0 <= d <= 1.0
    # Φ blocks of m·n elements each
    assert abs(d - fb.nonzero_blocks(shape) / (gm * gn)) < 1e-9


@given(m=st.integers(2, 8), r=st.floats(0.05, 0.9),
       shape=st.tuples(st.integers(2, 6), st.integers(1, 64)))
@settings(max_examples=60, deadline=None)
def test_index_bits_nonnegative_and_monotone_in_size(m, r, shape):
    if math.floor((1.0 - r) * m) < 1:
        return  # φ = 0 is rejected by the constructor (by design)
    ib = IntraBlock(m, 1, r)
    spec = FlexBlockSpec((ib,))
    small = spec.index_storage_bits((m * shape[0], shape[1]))
    large = spec.index_storage_bits((m * shape[0] * 2, shape[1]))
    assert 0 <= small <= large


@given(r=st.floats(0.05, 0.95))
@settings(max_examples=30, deadline=None)
def test_dense_spec_identity(r):
    assert dense_spec().overall_density((64, 64)) == 1.0
    assert dense_spec().index_storage_bits((64, 64)) == 0


@given(width=st.sampled_from([8, 16, 32]), r=st.floats(0.1, 0.9))
@settings(max_examples=30, deadline=None)
def test_named_patterns_bind(width, r):
    for spec in (row_wise(r), row_block(r, width), column_wise(r),
                 column_block(r, width)):
        b = spec.bind((128, 128))
        b.validate_for((128, 128))
        assert 0.0 <= b.overall_density((128, 128)) <= 1.0
