"""Multi-macro DAG scheduler tests (``repro.core.schedule``).

The tentpole acceptance sweep lives here: the ``"monolithic"`` policy
must reproduce the retained pre-scheduler simulator
(:func:`repro.core.costmodel.simulate_reference`) **bit-for-bit** across
sparsity patterns × mapping strategies × workloads, proving the
scheduling refactor behavior-preserving before the new policies open new
design space.  The ``"partitioned"`` policy must beat monolithic on
workloads with independent branches while leaving dynamic energy within
the accounting identity (same access counts, reshuffled in time), and
``"resident"`` must amortise weight loading across invocations — with a
bit-identical monolithic fallback when the workload does not fit.

Also covered: the new :meth:`Workload.topo_order` / :meth:`levels` DAG
utilities (diamond / fan-out shapes, cycle rejection), the exploration
plumbing (job keys, ``schedule_sweep``, the ``--schedule`` CLI), and the
perf gate's informational handling of baseline-less suites.
"""
import dataclasses
import importlib
import sys
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core import (OpNode, SchedulePolicy, Workload, build_schedule,
                        critical_path, default_mapping, dense_baseline,
                        hybrid, lm_workload, mars_arch, resnet18, row_block,
                        simulate, simulate_reference, usecase_arch)
from repro.core.schedule import OpExec, POLICIES
from repro.explore import CACHE_SCHEMA, ExploreJob, schedule_sweep


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


@pytest.fixture(scope="module")
def arch16():
    return usecase_arch(16)


def _mlp_stack(depth=3, width=512, v=64):
    """Band-fitting fc stack (resident's home turf on a 16-macro org)."""
    wl = Workload(f"mlp{depth}x{width}")
    prev = ()
    for i in range(depth):
        wl.add(OpNode(name=f"fc{i}", kind="fc", K=width, N=width, V=v,
                      c_in=width, inputs=prev,
                      sparsity=row_block(0.8, 16)))
        prev = (f"fc{i}",)
    return wl


# ---------------------------------------------------------------------------
# Workload DAG utilities: topo_order / levels / successors.
# ---------------------------------------------------------------------------

def _diamond():
    """The ResNet-shortcut shape: a → (b, c) → d."""
    wl = Workload("diamond")
    wl.simple("a", "act", 4)
    wl.simple("b", "act", 4, inputs=("a",))
    wl.simple("c", "act", 4, inputs=("a",))
    wl.simple("d", "add", 4, inputs=("b", "c"))
    return wl


def _fanout():
    """The attention Q/K/V shape: x → (q, k, v) → s(q,k) → o(s,v)."""
    wl = Workload("qkv")
    wl.simple("x", "act", 4)
    for n in ("q", "k", "v"):
        wl.fc(n, 8, 8, inputs=("x",))
    wl.simple("s", "add", 4, inputs=("q", "k"))
    wl.simple("o", "add", 4, inputs=("s", "v"))
    return wl


def test_topo_order_respects_dependencies():
    for wl in (_diamond(), _fanout(), resnet18(32)):
        order = wl.topo_order()
        assert sorted(order) == sorted(wl.nodes)
        pos = {n: i for i, n in enumerate(order)}
        for node in wl.nodes.values():
            for inp in node.inputs:
                assert pos[inp] < pos[node.name], (node.name, inp)


def test_topo_order_stable_wrt_insertion():
    # the public API forbids forward references, so insertion order is
    # already topological and Kahn must preserve it exactly
    wl = _fanout()
    assert wl.topo_order() == list(wl.nodes)


def test_levels_diamond_and_fanout():
    assert _diamond().levels() == [["a"], ["b", "c"], ["d"]]
    assert _fanout().levels() == [["x"], ["q", "k", "v"], ["s"], ["o"]]


def test_levels_resnet_shortcut_is_concurrent():
    wl = resnet18(32)
    lvl = {name: i for i, level in enumerate(wl.levels())
           for name in level}
    # the stage-1 shortcut conv reads the same input as the block's c1:
    # same level → the partitioned scheduler may overlap them
    assert lvl["s1b0_sc"] == lvl["s1b0_c1"]
    assert lvl["s1b0_add"] > lvl["s1b0_c2"]


def test_cycle_rejected():
    wl = _diamond()
    # splice a back-edge in behind the API (add() forbids forward refs)
    wl.nodes["a"] = dataclasses.replace(wl.nodes["a"], inputs=("d",))
    with pytest.raises(ValueError, match="cycle"):
        wl.topo_order()
    with pytest.raises(ValueError, match="cycle"):
        wl.levels()


def test_unknown_input_rejected():
    wl = _diamond()
    wl.nodes["ghost-user"] = OpNode(name="ghost-user", kind="act",
                                    elements=1, inputs=("ghost",))
    with pytest.raises(ValueError, match="unknown input"):
        wl.topo_order()


def test_critical_path_picks_longest_chain():
    wl = _diamond()
    path, cycles = critical_path(wl, {"a": 1.0, "b": 5.0, "c": 2.0,
                                      "d": 1.0})
    assert path == ["a", "b", "d"] and cycles == 7.0


# ---------------------------------------------------------------------------
# SchedulePolicy validation.
# ---------------------------------------------------------------------------

def test_schedule_policy_validation():
    with pytest.raises(ValueError, match="unknown schedule policy"):
        SchedulePolicy(policy="speculative")
    with pytest.raises(ValueError, match="invocations"):
        SchedulePolicy(invocations=0)
    assert SchedulePolicy().policy == "monolithic"


# ---------------------------------------------------------------------------
# Tentpole equivalence sweep: monolithic == pre-scheduler, bit for bit.
# ---------------------------------------------------------------------------

def _assert_reports_identical(ref, rep, ctx):
    assert ref.latency_cycles == rep.latency_cycles, ctx
    assert ref.latency_ms == rep.latency_ms, ctx
    assert ref.energy_pj == rep.energy_pj, ctx          # exact, per unit
    assert ref.total_energy_uj == rep.total_energy_uj, ctx
    assert ref.utilization == rep.utilization, ctx
    assert ref.index_storage_bits == rep.index_storage_bits, ctx
    assert ref.index_capacity_ok == rep.index_capacity_ok, ctx
    assert len(ref.op_costs) == len(rep.op_costs), ctx
    for a, b in zip(ref.op_costs, rep.op_costs):
        assert a == b, (ctx, a.name)                    # incl. start/end


_WORKLOADS = {
    "resnet18": lambda: resnet18(32),
    "lm-whisper": lambda: lm_workload(get_config("whisper-medium"),
                                      seq_len=16),
}


@pytest.mark.parametrize("wl_name", sorted(_WORKLOADS))
@pytest.mark.parametrize("spec_name,spec", [
    ("row-block", row_block(0.8, 16)),
    ("hybrid-1:2", hybrid(2, 16, 0.8)),
    ("dense", None),
])
@pytest.mark.parametrize("strategy", ["spatial", "duplicate"])
def test_monolithic_matches_pre_scheduler_bit_for_bit(arch4, wl_name,
                                                      spec_name, spec,
                                                      strategy):
    m = default_mapping(arch4, strategy)

    def wl():
        w = _WORKLOADS[wl_name]()
        return w.set_sparsity(spec) if spec is not None else w

    ref = simulate_reference(arch4, wl(), m)
    for sched in (None, SchedulePolicy()):
        rep = simulate(arch4, wl(), m, schedule=sched)
        _assert_reports_identical(ref, rep, (wl_name, spec_name, strategy))
        assert rep.schedule is not None
        assert rep.schedule.policy == "monolithic"
        assert rep.schedule.makespan_cycles == rep.latency_cycles
        assert rep.schedule.concurrency == 1.0
    assert ref.schedule is None                # the reference builds none


def test_monolithic_matches_reference_conv_only_scope():
    """eval_scope='conv_only' ops are dependency-only in the schedule."""
    arch = mars_arch()
    m = default_mapping(arch, "duplicate")
    wl_fn = lambda: resnet18(32).set_sparsity(row_block(0.75, 16))  # noqa: E731
    ref = simulate_reference(arch, wl_fn(), m)
    rep = simulate(arch, wl_fn(), m)
    _assert_reports_identical(ref, rep, "conv_only")


def test_monolithic_serial_placement(arch4):
    rep = simulate(arch4, resnet18(32).set_sparsity(row_block(0.8, 16)),
                   default_mapping(arch4))
    cum = 0.0
    for oc in rep.op_costs:
        assert oc.start_cycle == cum
        cum = cum + oc.latency_cycles
        assert oc.end_cycle == cum
    assert cum == rep.latency_cycles


# ---------------------------------------------------------------------------
# Partitioned: strictly faster on branchy DAGs, dynamic energy identical.
# ---------------------------------------------------------------------------

def _dyn(rep):
    return {k: v for k, v in rep.energy_pj.items() if k != "static"}


def _assert_valid_schedule(wl, sched, n_macros):
    placed = {s.name: s for s in sched.ops}
    assert sorted(placed) == sorted(wl.nodes)
    for node in wl.nodes.values():
        for inp in node.inputs:            # data deps respected
            assert placed[node.name].start >= placed[inp].end
    events = []
    for s in sched.ops:                    # macro capacity respected
        if s.macros and s.end > s.start:
            events.append((s.start, s.macros))
            events.append((s.end, -s.macros))
    in_use = 0
    for _, delta in sorted(events):        # releases sort before acquires
        in_use += delta
        assert in_use <= n_macros


@pytest.mark.parametrize("wl_name,n_macros", [
    ("resnet18", 4),        # shortcut convs overlap on the 4-macro org
    ("lm-whisper", 16),     # Q/K/V are half-org ops on the 16-macro org
])
def test_partitioned_faster_same_dynamic_energy(wl_name, n_macros):
    arch = usecase_arch(n_macros)
    wl_fn = lambda: _WORKLOADS[wl_name]().set_sparsity(row_block(0.8, 16))  # noqa: E731
    m = default_mapping(arch, "spatial")
    mono = simulate(arch, wl_fn(), m)
    part = simulate(arch, wl_fn(), m,
                    schedule=SchedulePolicy("partitioned"))
    # strictly lower total latency (independent branches overlap) ...
    assert part.latency_cycles < mono.latency_cycles, wl_name
    # ... within the accounting identity: same access counts, reshuffled
    # in time — every dynamic-energy entry is bit-identical, and static
    # energy shrinks with the shorter schedule
    assert _dyn(part) == _dyn(mono), wl_name
    assert part.energy_pj["static"] < mono.energy_pj["static"]
    assert part.utilization == mono.utilization
    assert [a.latency_cycles for a in part.op_costs] == \
        [b.latency_cycles for b in mono.op_costs]
    s = part.schedule
    assert s.concurrency > 1.0
    assert s.makespan_cycles >= s.critical_path_cycles > 0.0
    assert s.critical_path
    _assert_valid_schedule(wl_fn(), s, arch.n_macros)


def test_partitioned_overlaps_qkv(arch16):
    """Whisper-scale attention projections are half-org ops: Q and K run
    concurrently in the partitioned schedule."""
    wl = lm_workload(get_config("whisper-medium"), seq_len=16)
    wl.set_sparsity(row_block(0.8, 16))
    rep = simulate(arch16, wl, default_mapping(arch16, "spatial"),
                   schedule=SchedulePolicy("partitioned"))
    s = rep.schedule
    q, k = s.op("attn_q"), s.op("attn_k")
    assert q.start == k.start                 # same ready time, both fit
    assert q.macros + k.macros <= arch16.n_macros
    assert 0.0 < q.macro_share < 1.0


def test_partitioned_chain_degenerates_to_monolithic(arch16):
    """A pure chain has no branch to overlap: same makespan as serial."""
    wl = _mlp_stack()
    m = default_mapping(arch16, "spatial")
    mono = simulate(arch16, wl, m)
    part = simulate(arch16, wl, m, schedule=SchedulePolicy("partitioned"))
    assert part.latency_cycles == mono.latency_cycles


# ---------------------------------------------------------------------------
# Resident: preload hoisting + invocation amortisation, safe fallback.
# ---------------------------------------------------------------------------

def test_resident_fits_and_amortises(arch16):
    m = default_mapping(arch16, "spatial")
    mono1 = simulate(arch16, _mlp_stack(), m,
                     schedule=SchedulePolicy("monolithic", invocations=1))
    res1 = simulate(arch16, _mlp_stack(), m,
                    schedule=SchedulePolicy("resident", invocations=1))
    assert res1.schedule.resident
    assert res1.schedule.preload_cycles > 0.0
    # one invocation: the hoisted preload exactly offsets the per-op
    # load stages — no gain, no loss
    assert res1.latency_cycles == pytest.approx(mono1.latency_cycles,
                                                rel=1e-12)
    mono8 = simulate(arch16, _mlp_stack(), m,
                     schedule=SchedulePolicy("monolithic", invocations=8))
    res8 = simulate(arch16, _mlp_stack(), m,
                    schedule=SchedulePolicy("resident", invocations=8))
    # invocations scale the monolithic walk linearly ...
    assert mono8.latency_cycles == pytest.approx(8 * mono1.latency_cycles,
                                                 rel=1e-12)
    # ... while resident pays the load waves once and pulls ahead
    assert res8.latency_cycles < mono8.latency_cycles
    # weight traffic pinned: first invocation's cost, not 8x
    assert res8.energy_pj["weight_buf"] == res1.energy_pj["weight_buf"]
    assert mono8.energy_pj["weight_buf"] == pytest.approx(
        8 * mono1.energy_pj["weight_buf"], rel=1e-12)
    # stored-once index metadata is pinned too (streams still recur)
    assert res8.energy_pj["index_mem"] < mono8.energy_pj["index_mem"]
    # compute recurs every invocation regardless of residency
    assert res8.energy_pj["cim_array"] == mono8.energy_pj["cim_array"]


def test_resident_pins_only_weight_traffic_on_unified_buffer():
    """MARS routes weights AND activations through one ``global_buf``:
    the resident pin must amortise only the weight fill/loads, never the
    per-invocation input reads / output writes / partial-sum spills that
    share the buffer's name."""
    arch = mars_arch()                        # unified ping-pong global_buf
    m = default_mapping(arch, "spatial")

    def wl_fn():
        wl = Workload("convchain")
        prev, hw = (), 8
        for i in range(3):
            node, hw = wl.conv(f"c{i}", 16 if i == 0 else 64, 64, hw,
                               k=3, inputs=prev)
            prev = (node.name,)
        return wl.set_sparsity(row_block(0.75, 16))

    res1 = simulate(arch, wl_fn(), m,
                    schedule=SchedulePolicy("resident", invocations=1))
    res8 = simulate(arch, wl_fn(), m,
                    schedule=SchedulePolicy("resident", invocations=8))
    mono8 = simulate(arch, wl_fn(), m,
                     schedule=SchedulePolicy("monolithic", invocations=8))
    assert res1.schedule.resident and res8.schedule.resident
    # activation traffic recurs every invocation ...
    assert res8.energy_pj["global_buf"] > res1.energy_pj["global_buf"]
    # ... while the weight portion is paid once, so resident stays
    # strictly below monolithic's reload-every-pass total
    assert res8.energy_pj["global_buf"] < mono8.energy_pj["global_buf"]


def test_resident_falls_back_bit_for_bit(arch4):
    """resnet18's aggregate band demand exceeds a 4-macro org: resident
    must degrade to exactly the monolithic numbers, flagged."""
    wl_fn = lambda: resnet18(32).set_sparsity(row_block(0.8, 16))  # noqa: E731
    m = default_mapping(arch4, "spatial")
    mono = simulate(arch4, wl_fn(), m)
    res = simulate(arch4, wl_fn(), m, schedule=SchedulePolicy("resident"))
    assert res.schedule.resident is False
    assert res.schedule.preload_cycles == 0.0
    _assert_reports_identical(mono, res, "resident-fallback")


def test_invocations_scale_dense_comparisons(arch16):
    """Speedup vs a same-policy dense baseline stays meaningful at any
    invocation count."""
    wl = _mlp_stack()
    m = default_mapping(arch16, "spatial")
    sched = SchedulePolicy("resident", invocations=4)
    rep = simulate(arch16, wl, m, schedule=sched)
    dense = dense_baseline(arch16, wl, m, schedule=sched)
    assert dense.latency_cycles > rep.latency_cycles


# ---------------------------------------------------------------------------
# build_schedule guard rails.
# ---------------------------------------------------------------------------

def test_build_schedule_empty_workload():
    wl = Workload("empty")
    res = build_schedule(wl, SchedulePolicy("partitioned"), {},
                         n_macros=4, band_slots=128)
    assert res.makespan_cycles == 0.0 and res.ops == []


def test_build_schedule_zero_duration_ops_keep_order():
    wl = _diamond()
    execs = {n: OpExec(name=n, duration=0.0) for n in wl.nodes}
    res = build_schedule(wl, SchedulePolicy("partitioned"), execs,
                         n_macros=4, band_slots=128)
    assert res.makespan_cycles == 0.0
    assert {s.name for s in res.ops} == set(wl.nodes)


# ---------------------------------------------------------------------------
# Exploration plumbing: job keys, schedule sweep, CLI.
# ---------------------------------------------------------------------------

def test_cache_schema_bumped_for_schedule_field():
    # the schedule field landed in schema 4; later changes bump further
    # (5: Workload.source_digest + the attn_ctx hand-DAG node)
    assert CACHE_SCHEMA >= 4


def test_job_key_includes_schedule_policy(arch4):
    m = default_mapping(arch4)
    wl = resnet18(32).set_sparsity(row_block(0.8, 16))
    j0 = ExploreJob.simulate(arch4, wl, m)
    j1 = ExploreJob.simulate(arch4, wl, m,
                             schedule=SchedulePolicy("partitioned"))
    j2 = ExploreJob.simulate(arch4, wl, m,
                             schedule=SchedulePolicy("partitioned",
                                                     invocations=2))
    assert len({j0.key, j1.key, j2.key}) == 3
    # the explicit default normalises onto the None spelling
    j3 = ExploreJob.simulate(arch4, wl, m, schedule=SchedulePolicy())
    assert j3.schedule is None and j3.key == j0.key
    d0 = ExploreJob.dense(arch4, wl, m)
    d1 = ExploreJob.dense(arch4, wl, m, schedule=SchedulePolicy())
    d2 = ExploreJob.dense(arch4, wl, m,
                          schedule=SchedulePolicy("partitioned"))
    assert d0.key == d1.key != d2.key


def test_schedule_sweep_rows(arch4):
    res = schedule_sweep(arch4, lambda: resnet18(32), row_block(0.8, 16),
                         policies=("monolithic", "partitioned"),
                         workers=1)
    assert len(res.rows) == 2
    by = {r["schedule"]: r for r in res.rows}
    assert set(by) == {"monolithic", "partitioned"}
    assert by["partitioned"]["latency_ms"] < by["monolithic"]["latency_ms"]
    assert by["monolithic"]["invocations"] == 1


def test_explore_cli_schedule_axis(arch4, capsys):
    from repro.explore.__main__ import main
    rc = main(["sparsity", "--model", "resnet18", "--ratios", "0.8",
               "--workers", "1", "--schedule", "monolithic,partitioned"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "schedule" in out and "partitioned" in out


def test_explore_cli_rejects_unknown_policy():
    from repro.explore.__main__ import main
    with pytest.raises(SystemExit):
        main(["sparsity", "--model", "resnet18", "--ratios", "0.8",
              "--schedule", "bogus"])


# ---------------------------------------------------------------------------
# Perf gate: suites absent from the baseline are informational.
# ---------------------------------------------------------------------------

def _load_compare():
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        return importlib.import_module("benchmarks.compare")
    finally:
        sys.path.pop(0)


def test_compare_new_suite_is_informational():
    cmp_mod = _load_compare()
    base = {"suites": {"a": {"ok": True, "wall_s": 1.0}}}
    cur = {"suites": {"a": {"ok": True, "wall_s": 1.1},
                      "schedule": {"ok": True, "wall_s": 99.0}}}
    failures, rows = cmp_mod.compare_summaries(base, cur)
    assert failures == []                     # +10% within budget; new
    new = next(r for r in rows if r["suite"] == "schedule")
    assert "informational" in new["delta"]
    total = next(r for r in rows if r["suite"] == "TOTAL")
    assert total["current_s"] == pytest.approx(1.1)   # new suite excluded


def test_compare_existing_thresholds_not_weakened():
    cmp_mod = _load_compare()
    base = {"suites": {"a": {"ok": True, "wall_s": 1.0}}}
    cur = {"suites": {"a": {"ok": True, "wall_s": 2.0},
                      "schedule": {"ok": True, "wall_s": 0.1}}}
    failures, _ = cmp_mod.compare_summaries(base, cur)
    assert any("regressed" in f for f in failures)
