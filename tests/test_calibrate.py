"""Calibration subsystem: profile schema, bounded fit, harvesting, the
CLI, and end-to-end application through the cost model and the
exploration engine."""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.calibrate import (CalibrationProfile, FitError, ProfileError,
                             Sample, default_profile, fit_profile,
                             from_ledger, record_to_sample, resolve_profile,
                             write_samples)
from repro.calibrate.fit import bounded_lsq

FIXTURE_LEDGER = Path(__file__).parent / "fixtures" / "calibration_ledger.jsonl"

# The ground truth the fixture ledger was generated from (see the
# fixture's per-class efficiencies: matmul 0.80, attention 0.90,
# steps 0.95, ±1% noise).
TRUE_PEAKS = {"peak_flops": 165e12, "hbm_bw": 750e9, "ici_bw": 42e9}


# ---------------------------------------------------------------------------
# Profile schema
# ---------------------------------------------------------------------------

def test_default_profile_matches_legacy_constants():
    p = default_profile()
    assert (p.peak_flops, p.hbm_bw, p.ici_bw) == (197e12, 819e9, 50e9)
    assert p.is_analytic_default()
    assert p.efficiency_for("anything") == 1.0
    # and it is what the roofline module aliases
    from repro.launch import roofline
    assert (roofline.PEAK_FLOPS, roofline.HBM_BW, roofline.ICI_BW) == \
        (p.peak_flops, p.hbm_bw, p.ici_bw)


def test_profile_round_trip_and_content_hash(tmp_path):
    p = CalibrationProfile(name="t", device="d", peak_flops=1e14,
                           hbm_bw=5e11, ici_bw=4e10,
                           efficiency={"matmul": 0.8},
                           provenance={"n_samples": 3},
                           residuals={"rel_rmse": 0.01})
    path = p.save(tmp_path / "p.json")
    q = CalibrationProfile.load(path)
    assert q == p
    assert q.content_hash() == p.content_hash()
    # name/device/provenance/residuals are metadata: two fits agreeing
    # on the physics share an address (and sweep-cache keys)
    r = dataclasses.replace(p, name="other", device="elsewhere",
                            provenance={}, residuals={})
    assert r.content_hash() == p.content_hash()
    # physical content does move it
    s = dataclasses.replace(p, efficiency={"matmul": 0.9})
    assert s.content_hash() != p.content_hash()


def test_save_addressed_filename_embeds_hash(tmp_path):
    p = CalibrationProfile(name="dev", device="d")
    path = p.save_addressed(tmp_path)
    assert path.name == f"dev-{p.content_hash()[:12]}.json"
    assert CalibrationProfile.load(path) == p


@pytest.mark.parametrize("doc,msg", [
    ({"device": "d"}, "missing required"),
    ({"name": "x", "device": "d", "schema_version": 99}, "schema_version"),
    ({"name": "x", "device": "d", "peak_flops": -1.0}, "peak_flops"),
    ({"name": "x", "device": "d", "hbm_bw": 0}, "hbm_bw"),
    ({"name": "x", "device": "d", "efficiency": {"m": 9.0}}, "implausible"),
    ({"name": "x", "device": "d", "bogus_field": 1}, "unknown"),
])
def test_profile_validation_rejects(doc, msg):
    with pytest.raises(ProfileError, match=msg):
        CalibrationProfile.from_dict(doc)


def test_resolve_profile(tmp_path):
    assert resolve_profile(None) == default_profile()
    assert resolve_profile("default") == default_profile()
    p = CalibrationProfile(name="x", device="d")
    assert resolve_profile(p) is p
    path = p.save(tmp_path / "x.json")
    assert resolve_profile(str(path)) == p
    with pytest.raises(ProfileError):
        resolve_profile(str(tmp_path / "missing.json"))


# ---------------------------------------------------------------------------
# Harvest
# ---------------------------------------------------------------------------

def test_record_to_sample_both_formats():
    s = record_to_sample({"op_class": "matmul", "flops": 1e9, "bytes": 1e6,
                          "coll_bytes": 0.0, "time_s": 1e-3})
    assert s.op_class == "matmul" and s.time_s == 1e-3
    s = record_to_sample({"arch": "a", "cell": "c", "kind": "decode",
                          "flops": 1e9, "bytes_accessed": 1e6,
                          "collective_bytes": {"all-reduce": 5.0, "count": 1},
                          "wall_s": 2e-3})
    assert s.op_class == "step:decode" and s.coll_bytes == 5.0
    assert record_to_sample({"flops": 1e9, "bytes_accessed": 1e6}) is None
    assert record_to_sample({"error": "boom", "wall_s": 1.0}) is None
    assert record_to_sample({"op_class": "m", "flops": 1e9, "bytes": 0,
                             "time_s": -1.0}) is None


def test_from_ledger_fixture_accounting():
    rep = from_ledger(FIXTURE_LEDGER)
    assert len(rep.samples) == 21
    assert rep.skipped_untimed == 1       # characterisation-only record
    assert rep.skipped_malformed == 1     # truncated JSON line
    classes = {s.op_class for s in rep.samples}
    # executed dry-run cells (dryrun --execute) land as step:<kind>
    assert {"matmul", "attention",
            "step:train", "step:prefill", "step:decode"} <= classes


def test_executed_dryrun_cells_harvest_with_time_s():
    """`dryrun --execute` records (executed: true, time_s) harvest into
    per-kind step samples, preferring the measured time_s field."""
    rep = from_ledger(FIXTURE_LEDGER)
    executed = [s for s in rep.samples
                if dict(s.meta).get("tag") == "exec"]
    assert len(executed) == 3
    by_class = {s.op_class: s for s in executed}
    assert by_class["step:train"].time_s == 3.8812
    assert by_class["step:prefill"].time_s == 2.4106
    assert by_class["step:decode"].time_s == 3.2095
    assert all(s.flops > 0 and s.bytes > 0 for s in executed)


def test_write_samples_round_trip(tmp_path):
    samples = [Sample("matmul", 1e9, 1e6, 0.0, 1e-3,
                      meta=(("device", "t"),))]
    path = write_samples(samples, tmp_path / "s.jsonl")
    rep = from_ledger(path)
    assert rep.samples == samples


# ---------------------------------------------------------------------------
# Fit
# ---------------------------------------------------------------------------

def _synthetic(n=24, with_coll=True, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        f = float(rng.uniform(1e12, 8e13))
        b = float(rng.uniform(1e9, 6e10))
        c = float(rng.uniform(1e8, 3e9)) if with_coll else 0.0
        t = (f / TRUE_PEAKS["peak_flops"] + b / TRUE_PEAKS["hbm_bw"]
             + c / TRUE_PEAKS["ici_bw"])
        out.append(Sample("matmul" if i % 2 else "attention", f, b, c, t))
    return out


@pytest.mark.parametrize("solver", ["scipy", "numpy"])
def test_fit_recovers_known_peaks(solver):
    if solver == "scipy":
        pytest.importorskip("scipy")
    prof = fit_profile(_synthetic(), name="t", solver=solver)
    assert prof.provenance["solver"] == solver
    for key, true in TRUE_PEAKS.items():
        assert getattr(prof, key) == pytest.approx(true, rel=1e-3), key
    assert all(e == pytest.approx(1.0, rel=1e-3)
               for e in prof.efficiency.values())
    assert prof.residuals["rel_rmse"] < 1e-6


def test_fit_keeps_prior_for_unidentified_peak():
    prof = fit_profile(_synthetic(with_coll=False), name="t")
    assert prof.ici_bw == default_profile().ici_bw
    assert "ici_bw" not in prof.provenance["identified"]
    assert prof.peak_flops == pytest.approx(TRUE_PEAKS["peak_flops"],
                                            rel=1e-3)


def test_fit_rejects_empty():
    with pytest.raises(FitError):
        fit_profile([], name="t")
    with pytest.raises(FitError):
        fit_profile([Sample("m", 0.0, 0.0, 0.0, 1.0)], name="t")


def test_fit_fixture_ledger_end_to_end():
    rep = from_ledger(FIXTURE_LEDGER)
    prof = fit_profile(rep.samples, name="fixture-fit")
    prof.validate()
    # the global fit absorbs the mean inefficiency into the peaks, so
    # recovery is within the spread of the per-class factors (0.80–0.95)
    assert prof.peak_flops == pytest.approx(TRUE_PEAKS["peak_flops"],
                                            rel=0.35)
    assert prof.hbm_bw == pytest.approx(TRUE_PEAKS["hbm_bw"], rel=0.35)
    # matmul runs furthest below the fixture's roofline → lowest factor
    assert prof.efficiency["matmul"] < prof.efficiency["attention"]
    assert prof.residuals["rel_rmse"] < 0.05
    # round-trips the schema
    assert CalibrationProfile.from_dict(
        json.loads(prof.to_json())) == prof


@pytest.mark.parametrize("solver", ["numpy", "scipy"])
def test_bounded_lsq_respects_bounds(solver):
    if solver == "scipy":
        pytest.importorskip("scipy")
    A = np.array([[1.0], [1.0]])
    y = np.array([10.0, 12.0])
    lb, ub = np.array([0.0]), np.array([5.0])
    x, _ = bounded_lsq(A, y, lb, ub, solver=solver)
    assert x[0] == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# Application: cost model + exploration engine
# ---------------------------------------------------------------------------

def _sim_setup():
    from repro.core import usecase_arch
    from repro.core.mapping import default_mapping
    from repro.core.workload import resnet18

    arch = usecase_arch(4)
    return arch, resnet18(32), default_mapping(arch)


def test_op_class_mapping():
    from repro.core.costmodel import op_class
    from repro.core.workload import OpNode

    assert op_class(OpNode(name="fc1", kind="fc")) == "matmul"
    assert op_class(OpNode(name="attn_scores", kind="matmul")) == "attention"
    # attn_{q,k,v,o} projections are plain GEMMs, not flash attention
    assert op_class(OpNode(name="attn_q", kind="fc")) == "matmul"
    assert op_class(OpNode(name="relu1", kind="act")) == "post_proc"


def test_simulate_default_profile_is_identity():
    from repro.core.costmodel import simulate

    arch, wl, mp = _sim_setup()
    r0 = simulate(arch, wl, mp)
    r1 = simulate(arch, wl, mp, profile=default_profile())
    assert r0.latency_cycles == r1.latency_cycles
    assert r0.energy_pj == r1.energy_pj


def test_simulate_profile_scales_latency_and_static_energy():
    from repro.core.costmodel import op_class, simulate

    arch, wl, mp = _sim_setup()
    prof = CalibrationProfile(name="half", device="t",
                              efficiency={"matmul": 0.5})
    r0 = simulate(arch, wl, mp)
    r1 = simulate(arch, wl, mp, profile=prof)
    # every matmul-class op exactly doubles; others untouched
    for a, b in zip(r0.op_costs, r1.op_costs):
        scale = 2.0 if op_class(wl.nodes[a.name]) == "matmul" else 1.0
        assert b.latency_cycles == pytest.approx(scale * a.latency_cycles)
    assert r1.latency_cycles > r0.latency_cycles
    # static energy follows the stretched schedule; dynamic terms do not
    assert r1.energy_pj["static"] > r0.energy_pj["static"]
    assert r1.energy_pj["cim_array"] == r0.energy_pj["cim_array"]


def test_explore_job_key_includes_profile():
    from repro.explore import ExploreJob

    arch, wl, mp = _sim_setup()
    prof = CalibrationProfile(name="p", device="t",
                              efficiency={"matmul": 0.5})
    j0 = ExploreJob.simulate(arch, wl, mp)
    j1 = ExploreJob.simulate(arch, wl, mp, profile=prof)
    j2 = ExploreJob.simulate(arch, wl, mp, profile=default_profile())
    assert len({j0.key, j1.key, j2.key}) == 3
    # same profile content → same key (content-addressed, not identity)
    j3 = ExploreJob.simulate(arch, wl, mp, profile=CalibrationProfile(
        name="p", device="t", efficiency={"matmul": 0.5}))
    assert j3.key == j1.key
    # provenance/residuals are metadata: physically identical profiles
    # from different fits must hit the same cache entries
    j4 = ExploreJob.simulate(arch, wl, mp, profile=dataclasses.replace(
        prof, provenance={"sources": ["elsewhere.jsonl"]},
        residuals={"rel_rmse": 0.123}))
    assert j4.key == j1.key


def test_sparsity_sweep_calibrated_rows_differ_only_by_profile():
    from repro.core import TABLE_II_PATTERNS, usecase_arch
    from repro.core.workload import resnet18
    from repro.explore import sparsity_sweep

    arch = usecase_arch(4)
    wl_fn = lambda: resnet18(32)  # noqa: E731
    kw = dict(ratios=(0.8,), workers=1,
              pattern_factory=lambda r: TABLE_II_PATTERNS(r, c_in=16))
    analytic = sparsity_sweep(arch, wl_fn, {}, **kw)
    prof = CalibrationProfile(name="p", device="t",
                              efficiency={"matmul": 0.8, "post_proc": 0.9})
    calibrated = sparsity_sweep(arch, wl_fn, {}, profile=prof, **kw)

    assert len(analytic.rows) == len(calibrated.rows) > 0
    for a, c in zip(analytic.rows, calibrated.rows):
        # identity columns match row for row
        for col in ("pattern", "ratio", "mapping", "utilization",
                    "index_kib"):
            assert a[col] == c[col]
        assert c["latency_ms"] > a["latency_ms"]
    # the bundled default profile is a no-op end to end
    default_rows = sparsity_sweep(arch, wl_fn, {},
                                  profile=default_profile(), **kw).rows
    assert default_rows == analytic.rows


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_fit_show_diff(tmp_path, capsys):
    from repro.calibrate.__main__ import main

    out = tmp_path / "prof.json"
    assert main(["fit", "--ledger", str(FIXTURE_LEDGER),
                 "--name", "fixture-fit", "--out", str(out),
                 "--profiles-dir", str(tmp_path / "profiles")]) == 0
    err = capsys.readouterr().err
    assert "skipped 1 untimed and 1 malformed" in err

    prof = CalibrationProfile.load(out)
    addressed = list((tmp_path / "profiles").glob("*.json"))
    assert len(addressed) == 1
    assert prof.content_hash()[:12] in addressed[0].name

    assert main(["show", str(out), "--check"]) == 0
    assert "OK: schema-valid" in capsys.readouterr().out
    assert main(["show", str(out), "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["name"] == "fixture-fit"
    assert main(["diff", str(out), "default"]) == 0
    assert "profiles differ" in capsys.readouterr().out
    assert main(["diff", "default", "default"]) == 0
    assert "identical physical content" in capsys.readouterr().out


def test_cli_fit_refuses_untimed_only(tmp_path, capsys):
    from repro.calibrate.__main__ import main

    ledger = tmp_path / "l.jsonl"
    ledger.write_text(json.dumps({"arch": "a", "flops": 1e9,
                                  "bytes_accessed": 1e6,
                                  "collective_bytes": {}}) + "\n")
    assert main(["fit", "--ledger", str(ledger)]) == 1
    assert "fit failed" in capsys.readouterr().err


def test_cli_show_rejects_bad_profile(tmp_path, capsys):
    from repro.calibrate.__main__ import main

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"name": "x", "device": "d",
                               "schema_version": 99}))
    assert main(["show", str(bad)]) == 1
    assert "schema_version" in capsys.readouterr().err


def test_explore_cli_profile_mode(tmp_path, capsys):
    from repro.explore.__main__ import main as explore_main

    prof = CalibrationProfile(
        name="p", device="t",
        efficiency={"matmul": 0.8, "attention": 0.8, "post_proc": 0.8})
    path = prof.save(tmp_path / "p.json")
    rc = explore_main(["sparsity", "--model", "resnet18", "--ratios", "0.8",
                       "--workers", "1", "--profile", str(path),
                       "--diff-analytic"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "calibrated mode: profile 'p'" in out
    assert "calibrated vs analytic" in out
    assert "1.250" in out         # every class at 0.8 → 1/0.8 latency ratio


# ---------------------------------------------------------------------------
# Microbenchmark harvest (CPU-friendly: dispatches to the jnp oracles)
# ---------------------------------------------------------------------------

def test_microbench_kernels_smoke():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.calibrate import microbench_kernels

    rep = microbench_kernels(sizes=(64,), repeats=1)
    classes = {s.op_class for s in rep.samples}
    assert {"attention", "matmul", "intrablock"} <= classes
    for s in rep.samples:
        assert s.time_s > 0 and s.flops > 0 and s.bytes > 0
        assert dict(s.meta)["impl"] in ("ref", "pallas")
    # samples feed straight into a fit
    prof = fit_profile(rep.samples, name="smoke")
    prof.validate()
