"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and finiteness (assignment requirement)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import all_configs, cells_for, get_config, list_archs
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, prefill)
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step

ARCHS = list_archs()


def _batch_for(cfg, B=2, S=16):
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.prefix_len:
        batch["prefix_embed"] = 0.02 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))
    if cfg.enc_dec:
        batch["enc_embed"] = 0.02 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _batch_for(cfg)
    kwargs = {k: batch[k] for k in ("prefix_embed", "enc_embed")
              if k in batch}
    logits = forward(params, batch["tokens"], cfg, **kwargs)
    S_out = 16 + (cfg.prefix_len or 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    batch = _batch_for(cfg)
    p1, o1, m1 = step(params, opt, batch)
    assert np.isfinite(float(m1["loss"]))
    assert int(o1["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p1))
    assert delta > 0


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-9b", "mamba2-130m",
                                  "hymba-1.5b", "whisper-medium",
                                  "qwen3-moe-30b-a3b", "paligemma-3b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, dtype=jnp.float32)
    B, S = 2, 12
    batch = _batch_for(cfg, B, S + 1)
    kwargs = {k: batch[k] for k in ("prefix_embed", "enc_embed")
              if k in batch}
    full = forward(params, batch["tokens"], cfg, **kwargs)
    _, cache = prefill(params, batch["tokens"][:, :S], cfg, **kwargs)
    if "k" in cache:
        cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 4),
                                          (0, 0), (0, 0)))
        cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 4),
                                          (0, 0), (0, 0)))
    lg, _ = decode_step(params, batch["tokens"][:, S], cfg, cache)
    pfx = cfg.prefix_len or 0
    ref = full[:, pfx + S, :]
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                               atol=5e-4, rtol=1e-3)


def test_gemma2_local_global_flags():
    from repro.models.transformer import layer_flags
    cfg = get_config("gemma2-9b")
    flags = np.asarray(layer_flags(cfg))
    assert flags.shape == (42,)
    assert flags[1] and not flags[0]       # alternating local/global


def test_sliding_window_masks_old_tokens():
    """A token outside the window must not influence attention."""
    cfg = get_config("hymba-1.5b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, window=4, ssm_state=0, family="dense",
                              attention="sliding")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    t = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0, cfg.vocab_size)
    base = forward(params, t, cfg)
    t2 = t.at[0, 0].set((int(t[0, 0]) + 1) % cfg.vocab_size)
    pert = forward(params, t2, cfg)
    # last position is > window away from position 0
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(pert[0, -1]), atol=1e-5)


def test_long_500k_eligibility():
    eligible = {a for a, c in all_configs().items()
                if "long_500k" in cells_for(c)}
    assert eligible == {"mamba2-130m", "hymba-1.5b", "gemma2-9b"}


def test_param_counts_near_nameplate():
    """Parameter counts should be in the ballpark of the model names."""
    expect = {"llama3-8b": 8.0e9, "gemma-7b": 8.5e9, "qwen3-4b": 4.0e9,
              "gemma2-9b": 9.2e9, "dbrx-132b": 132e9, "mamba2-130m": 0.13e9,
              "hymba-1.5b": 1.5e9, "qwen3-moe-30b-a3b": 30.5e9}
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.55 * target < n < 1.45 * target, (arch, n, target)
