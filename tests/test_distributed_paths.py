"""Multi-device distributed paths (4 virtual CPU devices, subprocess):

* EP shard_map MoE ≡ the global-dispatch oracle (dropless capacity),
  gradients finite;
* sequence-parallel SWA attention ≡ the fallback path incl. gradients.

Each test runs in its own interpreter because jax locks the device
count at first init (the main pytest process runs with 1 device).
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH="src")


def _run(script: str, timeout: int = 480):
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


EP_MOE = """
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.layers import _moe_block_global, moe_block
from repro.runtime import compat
cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                          n_experts=8, top_k=2, capacity_factor=8.0)
D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff
ks = [jax.random.PRNGKey(i) for i in range(5)]
p = {"w_router": jax.random.normal(ks[0], (D, E)) * 0.1,
     "w_up": jax.random.normal(ks[1], (E, D, F)) * 0.05,
     "w_gate": jax.random.normal(ks[2], (E, D, F)) * 0.05,
     "w_down": jax.random.normal(ks[3], (E, F, D)) * 0.05}
x = jax.random.normal(ks[4], (4, 16, D))
mesh = jax.make_mesh((2, 2), ("data", "model"))
with compat.set_mesh(mesh):
    y_ep = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
    g = jax.jit(jax.grad(lambda p, x: moe_block(x, p, cfg).sum()))(p, x)
y_ref = _moe_block_global(x, p, cfg)
assert float(jnp.abs(y_ep - y_ref).max()) < 2e-4
assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
print("EP-MOE-OK")
"""

SWA_SEQPAR = """
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.layers import attention_block
from repro.runtime import compat
cfg = dataclasses.replace(get_config("hymba-1.5b"), d_model=80, n_heads=5,
                          n_kv_heads=5, head_dim=16, window=64)
D, Hq, hd = 80, 5, 16
p = {k: jax.random.normal(jax.random.PRNGKey(i), s) * 0.1
     for i, (k, s) in enumerate({"wq": (D, Hq, hd), "wk": (D, Hq, hd),
                                 "wv": (D, Hq, hd), "wo": (Hq, hd, D)}.items())}
B, S = 2, 2048
x = jax.random.normal(jax.random.PRNGKey(9), (B, S, D))
pos = jnp.broadcast_to(jnp.arange(S), (B, S))
f = lambda x, p: attention_block(x, p, cfg, positions=pos, causal=True,
                                 window=cfg.window)
y_ref, (k_ref, v_ref) = f(x, p)
g_ref = jax.grad(lambda p, x: f(x, p)[0].sum())(p, x)
mesh = jax.make_mesh((2, 2), ("data", "model"))
with compat.set_mesh(mesh):
    y_sp, (k_sp, v_sp) = jax.jit(f)(x, p)
    g_sp = jax.jit(jax.grad(lambda p, x: f(x, p)[0].sum()))(p, x)
assert float(jnp.abs(y_sp - y_ref).max()) < 2e-5
assert float(jnp.abs(k_sp - k_ref).max()) < 2e-5
for k in g_ref:
    assert float(jnp.abs(g_sp[k] - g_ref[k]).max()) < 2e-3, k
print("SWA-SEQPAR-OK")
"""


@pytest.mark.slow
def test_ep_moe_matches_global_dispatch():
    assert "EP-MOE-OK" in _run(EP_MOE)


@pytest.mark.slow
def test_swa_seqpar_matches_fallback():
    assert "SWA-SEQPAR-OK" in _run(SWA_SEQPAR)
