"""Training substrate: loop, checkpoint/restart, fault tolerance,
data-pipeline determinism, gradient compression numerics."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.distributed.compress import (compress_decompress_grads,
                                        dequantize_int8,
                                        quantize_int8_stochastic)
from repro.train.checkpoint import (latest_step, list_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.trainer import Trainer, TrainerConfig


CFG = get_config("llama3-8b").reduced()


def _pipeline(steps=0, seq_len=16, global_batch=4):
    pcfg = PipelineConfig(vocab_size=CFG.vocab_size, seq_len=seq_len,
                          global_batch=global_batch, seed=3)
    return TokenPipeline(pcfg, start_step=steps)


def test_pipeline_determinism_and_state():
    p1, p2 = _pipeline(), _pipeline()
    b1, b2 = p1.next_batch(), p2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # restart from state reproduces the stream
    state = p1.state()
    nxt = p1.next_batch()
    p3 = TokenPipeline.from_state(p2.cfg, state)
    np.testing.assert_array_equal(p3.next_batch()["tokens"], nxt["tokens"])


def test_pipeline_host_sharding():
    cfg0 = PipelineConfig(vocab_size=100, seq_len=8, global_batch=4,
                          seed=1, host_id=0, n_hosts=2)
    cfg1 = PipelineConfig(vocab_size=100, seq_len=8, global_batch=4,
                          seed=1, host_id=1, n_hosts=2)
    b0 = TokenPipeline(cfg0).next_batch()
    b1 = TokenPipeline(cfg1).next_batch()
    assert b0["tokens"].shape == (2, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    b = _pipeline().next_batch()
    # labels[t] == tokens[t+1] by construction of the stream
    assert b["tokens"].shape == b["labels"].shape


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([2.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      clip_norm=0.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_trainer_loss_decreases(tmp_path):
    tcfg = TrainerConfig(steps=10, ckpt_every=5, ckpt_dir=str(tmp_path),
                         log_every=1)
    tr = Trainer(CFG, AdamWConfig(lr=5e-3, warmup_steps=2), tcfg,
                 _pipeline(seq_len=32, global_batch=8))
    log = tr.train()
    losses = [m["loss"] for m in log]
    assert len(losses) == 10
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), 7, params, opt,
                    data_state={"step": 7, "seed": 0, "host_id": 0})
    p2, o2, meta = restore_checkpoint(str(tmp_path), params, opt)
    assert meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(params["a"]))
    assert p2["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_rotation(tmp_path):
    params = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, params, keep=2)
    assert list_checkpoints(str(tmp_path)) == [4, 5]


def test_checkpoint_restore_validates_shapes(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros((3, 3))})


def test_trainer_failure_recovery(tmp_path):
    """A step that raises mid-run must resume from the last checkpoint
    and complete training."""
    tcfg = TrainerConfig(steps=6, ckpt_every=2, ckpt_dir=str(tmp_path))
    tr = Trainer(CFG, AdamWConfig(lr=1e-3), tcfg, _pipeline())
    fired = {"n": 0}

    def fault_hook(step):
        if step == 4 and fired["n"] == 0:
            fired["n"] = 1
            raise RuntimeError("injected node failure")

    log = tr.train(fault_hook=fault_hook)
    assert fired["n"] == 1
    assert latest_step(str(tmp_path)) == 6
    steps_seen = [m["step"] for m in log]
    assert steps_seen[-1] == 5  # completed through the end


def test_trainer_aborts_after_max_retries(tmp_path):
    tcfg = TrainerConfig(steps=4, ckpt_every=2, ckpt_dir=str(tmp_path),
                         max_retries=2)
    tr = Trainer(CFG, AdamWConfig(), tcfg, _pipeline())

    def always_fail(step):
        raise ValueError("persistent failure")

    with pytest.raises(RuntimeError, match="aborting") as excinfo:
        tr.train(fault_hook=always_fail)
    # the abort chains the root cause and names it in the message
    assert isinstance(excinfo.value.__cause__, ValueError)
    assert "ValueError: persistent failure" in str(excinfo.value)


def test_gradient_compression_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(333,)) * 1e-3,
                    jnp.float32)
    q, s, shape, pad = quantize_int8_stochastic(x, jax.random.PRNGKey(0))
    y = dequantize_int8(q, s, shape, pad)
    assert y.shape == x.shape
    # block-wise int8: relative error bounded by ~1/127 of block max
    err = float(jnp.abs(y - x).max())
    assert err <= float(jnp.abs(x).max()) / 127 * 1.01


def test_compressed_grads_preserve_training_signal():
    grads = {"w": jnp.asarray([[0.1, -0.2], [0.3, -0.4]], jnp.float32)}
    out = compress_decompress_grads(grads)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=0.4 / 127 * 2)
