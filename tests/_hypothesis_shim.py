"""Hypothesis import shim for the property-based tests.

When hypothesis is installed (the ``[test]`` extra), re-exports the real
``given`` / ``settings`` / ``st``.  When it's absent, exports stand-ins
that mark just the decorated property tests as skipped — so the plain
unit tests in the same modules keep running (the seed guarded the whole
module with ``pytest.importorskip``, which silently dropped them too).
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    settings = given

    class _Strategies:
        """Accepts any ``st.<name>(...)`` call; values are never used."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
