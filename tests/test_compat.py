"""Unit tests for the jax version-compat shim (repro.runtime.compat).

Exercises mesh discovery, mesh-scoped sharding construction, and
shard_map on whatever jax line is installed — the shim is the single
point every execution-plane call site routes through, so these tests
pin its contract independent of the model code.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.runtime import compat


# ---------------------------------------------------------------------------
# version guard
# ---------------------------------------------------------------------------

def test_version_parse_and_guard():
    assert compat._parse_version("0.4.37") == (0, 4, 37)
    assert compat._parse_version("0.5.0") == (0, 5, 0)
    assert compat._parse_version("0.5.0rc1") == (0, 5, 0)
    # the installed jax made it through the import-time guard
    assert compat._SUPPORTED[0] <= compat.JAX_VERSION < compat._SUPPORTED[1]


def test_supported_range_matches_pyproject():
    import os
    import re
    root = os.path.join(os.path.dirname(__file__), "..")
    with open(os.path.join(root, "pyproject.toml")) as f:
        m = re.search(r'"jax>=([\d.]+),<([\d.]+)"', f.read())
    assert m, "pyproject [jax] extra must pin a jax range"
    assert compat._parse_version(m.group(1)) == compat._SUPPORTED[0]
    assert compat._parse_version(m.group(2)) == compat._SUPPORTED[1]


# ---------------------------------------------------------------------------
# mesh discovery
# ---------------------------------------------------------------------------

def _local_mesh():
    return jax.make_mesh((jax.device_count(), 1), ("data", "model"))


def test_no_mesh_is_empty():
    m = compat.get_abstract_mesh()
    assert m.empty
    assert tuple(m.axis_names) == ()


def test_set_mesh_discovery_and_restore():
    mesh = _local_mesh()
    assert compat.get_abstract_mesh().empty
    with compat.set_mesh(mesh):
        active = compat.get_abstract_mesh()
        assert not active.empty
        assert tuple(active.axis_names) == ("data", "model")
        assert active.shape["model"] == 1
        assert active.shape["data"] == jax.device_count()
    assert compat.get_abstract_mesh().empty


def test_set_mesh_restores_on_exception():
    mesh = _local_mesh()
    with pytest.raises(RuntimeError, match="boom"):
        with compat.set_mesh(mesh):
            raise RuntimeError("boom")
    assert compat.get_abstract_mesh().empty


def test_set_mesh_nesting():
    m1 = _local_mesh()
    m2 = jax.make_mesh((1, jax.device_count()), ("pod", "model"))
    with compat.set_mesh(m1):
        with compat.set_mesh(m2):
            assert tuple(compat.get_abstract_mesh().axis_names) == \
                ("pod", "model")
        assert tuple(compat.get_abstract_mesh().axis_names) == \
            ("data", "model")


# ---------------------------------------------------------------------------
# sharding construction on the active mesh
# ---------------------------------------------------------------------------

def test_filter_spec_tracks_active_mesh():
    from repro.distributed.sharding import filter_spec
    spec = P(("pod", "data"), None, "model")
    assert filter_spec(spec) is None              # no mesh → no-op marker
    with compat.set_mesh(_local_mesh()):
        assert filter_spec(spec) == P(("data",), None, "model")


def test_maybe_shard_inside_jit_under_mesh():
    """with_sharding_constraint with a bare PartitionSpec must resolve
    against the compat-activated mesh on every supported jax line."""
    from repro.distributed.sharding import maybe_shard

    x = jnp.arange(8.0).reshape(4, 2)
    f = jax.jit(lambda x: maybe_shard(x * 2, P("data", None)))
    with compat.set_mesh(_local_mesh()):
        y = f(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x) * 2)
    # and off-mesh it is an identity wrapper
    np.testing.assert_array_equal(
        np.asarray(jax.jit(lambda x: maybe_shard(x, P("data", None)))(x)),
        np.asarray(x))


def test_tree_shardings_lower_with_in_shardings():
    from repro.distributed.sharding import tree_shardings
    mesh = _local_mesh()
    tree = {"wq": jnp.zeros((2, 8, 16, 8)), "b": jnp.zeros((3,))}
    shardings = tree_shardings(mesh, tree)
    assert all(isinstance(s, NamedSharding)
               for s in jax.tree.leaves(shardings))
    with compat.set_mesh(mesh):
        lowered = jax.jit(
            lambda t: jax.tree.map(lambda l: l + 1, t),
            in_shardings=(shardings,),
        ).lower(tree)
    assert lowered.compile() is not None


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def test_shard_map_runs_on_installed_jax():
    mesh = _local_mesh()
    n = jax.device_count()
    x = jnp.arange(4 * n, dtype=jnp.float32).reshape(n, 4)

    def body(xl):
        i = jax.lax.axis_index("data")
        return xl + i.astype(jnp.float32)

    with compat.set_mesh(mesh):
        y = compat.shard_map(
            body, mesh=compat.get_abstract_mesh(),
            in_specs=(P("data", None),), out_specs=P("data", None),
            check_vma=False,
        )(x)
    expect = np.asarray(x) + np.arange(n)[:, None]
    np.testing.assert_array_equal(np.asarray(y), expect)


def test_shard_map_collective():
    mesh = _local_mesh()
    n = jax.device_count()
    x = jnp.ones((n, 2), jnp.float32)

    def body(xl):
        return jax.lax.psum(xl, "data")

    with compat.set_mesh(mesh):
        y = compat.shard_map(
            body, mesh=compat.get_abstract_mesh(),
            in_specs=(P("data", None),), out_specs=P("data", None),
            check_vma=False,
        )(x)
    np.testing.assert_array_equal(np.asarray(y), np.full((n, 2), n))
