"""Pruning workflow tests: Eq. 1 / Eq. 2 semantics (paper §IV-D)."""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

import jax.numpy as jnp

from repro.core.flexblock import FlexBlockSpec, FullBlock, IntraBlock, hybrid
from repro.core.pruning import (block_losses, flexblock_mask, fullblock_mask,
                                intrablock_mask, prune_matrix)

RNG = np.random.default_rng(42)


def test_block_losses_eq1():
    w = RNG.normal(size=(8, 8)).astype(np.float32)
    losses = np.asarray(block_losses(jnp.asarray(w), 2, 4, "l1"))
    expect = np.abs(w).reshape(4, 2, 2, 4).sum(axis=(1, 3))
    np.testing.assert_allclose(losses, expect, rtol=1e-5)


def test_fullblock_keeps_highest_loss_blocks():
    w = np.zeros((4, 4), np.float32)
    w[0:2, 0:2] = 10.0      # block (0,0) most important
    w[2:4, 2:4] = 5.0       # block (1,1) second
    mask = fullblock_mask(jnp.asarray(w), FullBlock(2, 2, 0.5), "l1")
    assert mask[0:2, 0:2].all() and mask[2:4, 2:4].all()
    assert not mask[0:2, 2:4].any() and not mask[2:4, 0:2].any()


def test_fullblock_exact_block_count():
    w = RNG.normal(size=(32, 32)).astype(np.float32)
    fb = FullBlock(4, 4, 0.75)
    mask = fullblock_mask(jnp.asarray(w), fb, "l2")
    blocks = mask.reshape(8, 4, 8, 4).sum(axis=(1, 3))
    n_kept = (blocks > 0).sum()
    assert n_kept == fb.nonzero_blocks((32, 32))
    # kept blocks are fully kept
    assert set(np.unique(blocks)) <= {0, 16}


def test_intrablock_topk_per_block():
    w = np.arange(8, dtype=np.float32).reshape(8, 1)  # increasing magnitude
    mask = intrablock_mask(jnp.asarray(w), IntraBlock(4, 1, 0.5))
    # each 4-block keeps its top-2 magnitudes
    np.testing.assert_array_equal(mask[:, 0], [0, 0, 1, 1, 0, 0, 1, 1])


def test_intrablock_pattern_set_restriction():
    # only pattern (1,0) allowed: always keep first element, even when the
    # second is larger
    w = np.array([[1.0], [100.0]], np.float32)
    ib = IntraBlock(2, 1, 0.5, pattern_set=((1, 0),))
    mask = intrablock_mask(jnp.asarray(w), ib)
    np.testing.assert_array_equal(mask[:, 0], [1, 0])


def test_intrablock_align_cols_produces_aligned_mask():
    w = RNG.normal(size=(16, 8)).astype(np.float32)
    mask = intrablock_mask(jnp.asarray(w), IntraBlock(4, 1, 0.75),
                           align_cols=True)
    mb = mask.reshape(4, 4, 8)
    assert (mb == mb[:, :, :1]).all()


@given(ratio=st.floats(0.1, 0.9), m=st.sampled_from([2, 4, 8]))
@settings(max_examples=25, deadline=None)
def test_intrablock_density_matches_phi(ratio, m):
    import math
    if math.floor((1.0 - ratio) * m) < 1:
        return  # φ = 0 rejected by the constructor
    ib = IntraBlock(m, 1, ratio)
    w = RNG.normal(size=(m * 8, 16)).astype(np.float32)
    mask = intrablock_mask(jnp.asarray(w), ib)
    assert abs(mask.mean() - ib.phi / m) < 1e-9


def test_hybrid_mask_density():
    w = RNG.normal(size=(64, 64)).astype(np.float32)
    spec = hybrid(2, 16, 0.8)
    res = prune_matrix(jnp.asarray(w), spec)
    assert abs(res.density - 0.2) < 0.05
    # pruned weights exactly zero after apply
    pruned = np.asarray(res.apply(jnp.asarray(w)))
    assert (pruned[res.mask == 0] == 0).all()


def test_padding_never_protects_blocks():
    # ragged matrix: padded region has zero importance
    w = np.ones((5, 5), np.float32)
    mask = fullblock_mask(jnp.asarray(w), FullBlock(2, 2, 0.5), "l1")
    assert mask.shape == (5, 5)


@given(ratio=st.floats(0.2, 0.8))
@settings(max_examples=20, deadline=None)
def test_l1_vs_l2_both_valid(ratio):
    w = RNG.normal(size=(32, 32)).astype(np.float32)
    for crit in ("l1", "l2"):
        m = flexblock_mask(jnp.asarray(w),
                           FlexBlockSpec((FullBlock(4, 4, ratio),)), crit)
        assert m.shape == (32, 32)
        assert 0 < m.mean() < 1
