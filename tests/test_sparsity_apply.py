"""FlexBlock ↔ execution-plane integration: live-param pruning, sparse
fine-tuning invariants, modeling-plane round trip."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import hybrid, row_block, usecase_arch
from repro.models.transformer import init_params
from repro.sparsity.apply import (cim_cost_of_model, prune_params,
                                  sparsity_report)
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step

CFG = get_config("llama3-8b").reduced()


@pytest.fixture(scope="module")
def pruned():
    params = init_params(CFG, jax.random.PRNGKey(0), dtype=jnp.float32)
    return prune_params(params, row_block(0.75, 16))


def test_prune_density(pruned):
    params, masks = pruned
    rep = sparsity_report(params, masks)
    assert abs(rep["overall_density"] - 0.25) < 0.08, rep
    # pruned weights are exactly zero
    for name, m in masks["layers"].items():
        if m is None:
            continue
        w = np.asarray(params["layers"][name])
        assert (w[np.asarray(m) == 0] == 0).all()


def test_sparse_finetune_keeps_zeros(pruned):
    params, masks = pruned
    opt = adamw_init(params)
    step = jax.jit(make_train_step(CFG, AdamWConfig(lr=1e-2), masks=masks))
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, CFG.vocab_size),
        "labels": jax.random.randint(key, (2, 16), 0, CFG.vocab_size),
    }
    p1, o1, m1 = step(params, opt, batch)
    assert np.isfinite(float(m1["loss"]))
    for name, m in masks["layers"].items():
        if m is None:
            continue
        w = np.asarray(p1["layers"][name])
        assert (w[np.asarray(m) == 0] == 0).all(), name
        # surviving weights did move
        moved = np.abs(w - np.asarray(params["layers"][name]))[
            np.asarray(m) == 1].sum()
        assert moved > 0, name


def test_cim_cost_round_trip():
    arch = usecase_arch(16)
    rep, cmp = cim_cost_of_model(get_config("qwen3-4b"), arch,
                                 hybrid(2, 16, 0.8), seq_len=32)
    assert rep.latency_cycles > 0
    assert cmp["speedup"] >= 1.0
    assert cmp["energy_saving"] > 1.0
