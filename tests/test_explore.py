"""Exploration-engine tests: job keying, cache accounting, parallel
equivalence, Pareto correctness, and legacy-wrapper compatibility."""
import pytest

from repro.core import (TABLE_II_PATTERNS, compare, default_mapping,
                        dense_baseline, hybrid, resnet18, row_block,
                        row_wise, simulate, sweep_mappings, sweep_sparsity,
                        usecase_arch, vgg16)
from repro.explore import (ExploreJob, ResultCache, SweepRunner, content_key,
                           mapping_sweep, pareto_front, sparsity_sweep, top_k)

RATIOS = (0.7, 0.8)


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


def _pattern_factory(r):
    return TABLE_II_PATTERNS(r, c_in=16)


# ---------------------------------------------------------------------------
# Job keying
# ---------------------------------------------------------------------------

def test_job_key_content_addressed(arch4):
    m = default_mapping(arch4)
    j1 = ExploreJob.simulate(arch4, resnet18(32).set_sparsity(row_wise(0.8)), m)
    j2 = ExploreJob.simulate(arch4, resnet18(32).set_sparsity(row_wise(0.8)), m)
    j3 = ExploreJob.simulate(arch4, resnet18(32).set_sparsity(row_wise(0.7)), m)
    assert j1.key == j2.key and j1 == j2          # same content, new objects
    assert j1.key != j3.key                       # ratio differs
    assert len({j1, j2, j3}) == 2                 # hashable, set-deduplicable


def test_dense_jobs_share_key_across_patterns(arch4):
    """Every pattern's baseline maps to ONE cache entry."""
    m = default_mapping(arch4)
    d1 = ExploreJob.dense(arch4, resnet18(32).set_sparsity(row_wise(0.8)), m)
    d2 = ExploreJob.dense(arch4, resnet18(32).set_sparsity(row_block(0.7)), m)
    assert d1.key == d2.key


def test_content_key_rejects_unknown_types():
    with pytest.raises(TypeError):
        content_key(object())


# ---------------------------------------------------------------------------
# Cache hit/miss accounting
# ---------------------------------------------------------------------------

def test_cache_accounting_within_run(arch4):
    m = default_mapping(arch4)
    wl_fn = lambda: resnet18(32)  # noqa: E731
    res = sparsity_sweep(arch4, wl_fn, {}, ratios=RATIOS, mapping=m,
                         pattern_factory=_pattern_factory, workers=1)
    n_points = len(res.rows)
    s = res.stats
    # every point requests (sparse job, dense job); dense dedups to 1
    assert s.requested == 2 * n_points
    assert s.unique == n_points + 1
    assert s.evaluated == s.unique                  # cold cache
    assert s.cache_hits == s.requested - s.evaluated == n_points - 1


def test_cache_accounting_across_runs(arch4):
    m = default_mapping(arch4)
    wl_fn = lambda: resnet18(32)  # noqa: E731
    runner = SweepRunner(workers=1)
    first = sparsity_sweep(arch4, wl_fn, {}, ratios=RATIOS, mapping=m,
                           pattern_factory=_pattern_factory, runner=runner)
    second = sparsity_sweep(arch4, wl_fn, {}, ratios=RATIOS, mapping=m,
                            pattern_factory=_pattern_factory, runner=runner)
    assert second.stats.evaluated == 0
    assert second.stats.memory_hits == second.stats.unique
    assert second.rows == first.rows


def test_disk_cache_roundtrip(arch4, tmp_path):
    m = default_mapping(arch4)
    wl_fn = lambda: resnet18(32)  # noqa: E731
    cold = sparsity_sweep(arch4, wl_fn, {}, ratios=(0.8,), mapping=m,
                          pattern_factory=_pattern_factory, workers=1,
                          cache=ResultCache(tmp_path / "cache"))
    assert cold.stats.evaluated > 0
    warm = sparsity_sweep(arch4, wl_fn, {}, ratios=(0.8,), mapping=m,
                          pattern_factory=_pattern_factory, workers=1,
                          cache=ResultCache(tmp_path / "cache"))
    assert warm.stats.evaluated == 0
    assert warm.stats.disk_hits == warm.stats.unique
    assert warm.rows == cold.rows


# ---------------------------------------------------------------------------
# Parallel vs sequential row equivalence
# ---------------------------------------------------------------------------

def test_parallel_rows_match_sequential(arch4):
    m = default_mapping(arch4, "duplicate")
    wl_fn = lambda: resnet18(32)  # noqa: E731
    seq = sparsity_sweep(arch4, wl_fn, {}, ratios=RATIOS, mapping=m,
                         pattern_factory=_pattern_factory, workers=1)
    with SweepRunner(workers=2) as runner:
        par = sparsity_sweep(arch4, wl_fn, {}, ratios=RATIOS, mapping=m,
                             pattern_factory=_pattern_factory, runner=runner)
    assert par.stats.workers == 2
    assert par.rows == seq.rows                    # bit-identical, same order


def test_parallel_matches_handrolled_legacy_loop(arch4):
    """The engine reproduces the pre-engine sequential sweep exactly."""
    m = default_mapping(arch4, "duplicate")
    wl_fn = lambda: resnet18(32)  # noqa: E731
    dense = dense_baseline(arch4, wl_fn(), m)
    legacy = []
    for ratio in RATIOS:
        for name, spec in _pattern_factory(ratio).items():
            rep = simulate(arch4, wl_fn().set_sparsity(spec), m)
            c = compare(rep, dense)
            legacy.append((name, ratio, rep.latency_ms, rep.total_energy_uj,
                           c["speedup"], c["energy_saving"]))
    with SweepRunner(workers=2) as runner:
        par = sparsity_sweep(arch4, wl_fn, {}, ratios=RATIOS, mapping=m,
                             pattern_factory=_pattern_factory, runner=runner)
    engine = [(r["pattern"], r["ratio"], r["latency_ms"], r["energy_uj"],
               r["speedup"], r["energy_saving"]) for r in par.rows]
    assert engine == legacy


# ---------------------------------------------------------------------------
# Legacy wrapper compatibility
# ---------------------------------------------------------------------------

def test_sweep_sparsity_wrapper_schema(arch4):
    rows = sweep_sparsity(arch4, lambda: resnet18(32), {}, ratios=(0.8,),
                          pattern_factory=_pattern_factory)
    assert rows and set(rows[0]) == {
        "arch", "workload", "pattern", "ratio", "mapping", "latency_ms",
        "energy_uj", "utilization", "speedup", "energy_saving", "index_kib"}


def test_sweep_mappings_wrapper_schema():
    rows = sweep_mappings(lambda org: usecase_arch(16, org),
                          lambda: vgg16(32), hybrid(2, 16, 0.8),
                          orgs=((4, 4),), strategies=("spatial",))
    assert rows and {"org", "rearrange", "speedup"} <= set(rows[0])
    assert rows[0]["org"] == "4x4" and rows[0]["ratio"] is None


def test_mapping_sweep_engine_matches_wrapper():
    kw = dict(orgs=((4, 4), (2, 8)), strategies=("spatial", "duplicate"))
    wrapper = sweep_mappings(lambda org: usecase_arch(16, org),
                             lambda: resnet18(32), hybrid(2, 16, 0.8), **kw)
    engine = mapping_sweep(lambda org: usecase_arch(16, org),
                           lambda: resnet18(32), hybrid(2, 16, 0.8),
                           workers=1, **kw)
    assert wrapper == engine.rows


# ---------------------------------------------------------------------------
# Pareto frontier / top-k
# ---------------------------------------------------------------------------

def test_pareto_hand_checked_three_points():
    rows = [
        {"name": "a", "latency_ms": 1.0, "energy_uj": 3.0},
        {"name": "b", "latency_ms": 2.0, "energy_uj": 1.0},
        {"name": "c", "latency_ms": 3.0, "energy_uj": 2.0},   # dominated by b
    ]
    objs = (("latency_ms", "min"), ("energy_uj", "min"))
    front = pareto_front(rows, objs)
    assert [r["name"] for r in front] == ["a", "b"]


def test_pareto_direction_and_missing_columns():
    rows = [
        {"name": "a", "latency_ms": 1.0, "speedup": 2.0},
        {"name": "b", "latency_ms": 1.0, "speedup": 3.0},     # dominates a
        {"name": "derived"},                                   # no objectives
    ]
    objs = (("latency_ms", "min"), ("speedup", "max"))
    front = pareto_front(rows, objs)
    assert [r["name"] for r in front] == ["b"]


def test_pareto_keeps_duplicates_and_order():
    rows = [{"latency_ms": 1.0, "energy_uj": 1.0, "id": i} for i in range(3)]
    front = pareto_front(rows, (("latency_ms", "min"), ("energy_uj", "min")))
    assert [r["id"] for r in front] == [0, 1, 2]


def test_top_k():
    rows = [{"m": v} for v in (3.0, 1.0, 2.0)]
    assert [r["m"] for r in top_k(rows, "m", 2)] == [1.0, 2.0]
    assert [r["m"] for r in top_k(rows, "m", 2, direction="max")] == [3.0, 2.0]


def test_sweep_result_export(arch4, tmp_path):
    m = default_mapping(arch4)
    res = sparsity_sweep(arch4, lambda: resnet18(32), {}, ratios=(0.8,),
                         mapping=m, pattern_factory=_pattern_factory,
                         workers=1)
    csv_path, json_path = tmp_path / "r.csv", tmp_path / "r.json"
    res.to_csv(csv_path)
    res.to_json(json_path)
    assert csv_path.read_text().startswith("arch,workload,pattern")
    assert "\"stats\"" in json_path.read_text()
    front = res.pareto()
    assert front and all(r in res.rows for r in front)
