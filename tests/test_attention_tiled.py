"""Statically tiled attention path: exactness against a dense oracle
across mask configurations (causal / sliding window / prefix-LM /
non-divisible chunks), plus the bf16-scores knob's error bound."""
from __future__ import annotations

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.layers import chunked_attention, set_scores_dtype


def ref_attn(q, k, v, causal=True, window=None, prefix=0):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    s = np.einsum("bqhd,bkhd->bhqk", q.astype(np.float32),
                  np.repeat(k.astype(np.float32), G, axis=2)) / math.sqrt(hd)
    qi = np.arange(Sq)[:, None]
    ki = np.arange(Skv)[None, :]
    ok = np.ones((Sq, Skv), bool)
    if causal:
        cm = ki <= qi
        if prefix > 0:
            cm |= (qi < prefix) & (ki < prefix)
        ok &= cm
    if window is not None:
        ok &= ki > qi - window
    s = np.where(ok[None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p,
                     np.repeat(v.astype(np.float32), G, axis=2))


def _qkv(B=2, S=512, Hq=4, Hkv=2, hd=16, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    return q, k, v


@pytest.mark.parametrize("window,prefix,chunk", [
    (None, 0, 128),      # causal triangular tiling
    (64, 0, 128),        # static window skip
    (200, 0, 96),        # window not a chunk multiple
    (None, 100, 128),    # prefix-LM bidirectional prefix
    (None, 0, 512),      # single tile (Sq == chunk boundary)
])
def test_tiled_matches_dense(window, prefix, chunk):
    q, k, v = _qkv()
    out = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=True, window=window, prefix=prefix, chunk=chunk))
    ref = ref_attn(q, k, v, True, window, prefix)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=2e-4)


def test_generic_scan_path_matches_dense():
    # Sq != Skv via kv_len/cache shape forces the scan path
    q, k, v = _qkv(S=256)
    out = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=False, chunk=64))
    s = np.einsum("bqhd,bkhd->bhqk",
                  q.astype(np.float32),
                  np.repeat(k.astype(np.float32), 2, axis=2)) / 4.0
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bhqk,bkhd->bqhd", p,
                    np.repeat(v.astype(np.float32), 2, axis=2))
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=2e-4)


def test_bf16_scores_bounded_error():
    q, k, v = _qkv()
    ref = ref_attn(q, k, v, True, None, 0)
    try:
        set_scores_dtype(jnp.bfloat16)
        out = np.asarray(chunked_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            causal=True, chunk=128)).astype(np.float32)
    finally:
        set_scores_dtype(jnp.float32)
    # bf16 softmax chain: ~1% relative error bound on outputs
    err = np.abs(out - ref).max() / max(np.abs(ref).max(), 1e-9)
    assert err < 0.05, err
