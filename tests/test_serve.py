"""Serving engine: slot management, per-slot positions, determinism."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import decode_step, init_params, prefill
from repro.serve.engine import Request, ServeEngine

CFG = get_config("llama3-8b").reduced()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0), dtype=jnp.float32)


def _greedy_reference(params, prompt, n_new):
    """Sequential batch-1 reference decode."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = prefill(params, toks, CFG)
    # pad cache to engine max_len
    pad = 64 - cache["k"].shape[2]
    cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        lg, cache = decode_step(params, jnp.asarray([out[-1]], jnp.int32),
                                CFG, cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_engine_matches_reference(params):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in (5, 9)]
    engine = ServeEngine(CFG, params, slots=2, max_len=64)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.output) == 6
        ref = _greedy_reference(params, p, 6)
        assert r.output == ref, (r.output, ref)


def test_more_requests_than_slots(params):
    rng = np.random.default_rng(1)
    engine = ServeEngine(CFG, params, slots=2, max_len=48)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=3) for _ in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done and len(r.output) == 3 for r in reqs)


def test_heterogeneous_prompt_lengths(params):
    """Slots at different positions must decode independently."""
    rng = np.random.default_rng(2)
    pa = rng.integers(0, CFG.vocab_size, size=3).astype(np.int32)
    pb = rng.integers(0, CFG.vocab_size, size=17).astype(np.int32)
    engine = ServeEngine(CFG, params, slots=2, max_len=64)
    ra, rb = Request(prompt=pa, max_new_tokens=5), Request(prompt=pb,
                                                           max_new_tokens=5)
    engine.submit(ra)
    engine.submit(rb)
    engine.run()
    assert ra.output == _greedy_reference(params, pa, 5)
    assert rb.output == _greedy_reference(params, pb, 5)


def test_engine_metrics_cumulative_vs_last_stats(params):
    """metrics accumulates across run() calls; last_stats is per-call."""
    rng = np.random.default_rng(3)

    def _submit(engine, n, toks):
        reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=4)
                        .astype(np.int32), max_new_tokens=toks)
                for _ in range(n)]
        for r in reqs:
            engine.submit(r)
        return reqs

    engine = ServeEngine(CFG, params, slots=2, max_len=48)
    _submit(engine, 3, 4)
    engine.run()
    first = dict(engine.last_stats)
    assert first["requests_completed"] == 3
    assert first["tokens_generated"] == 3 * 4     # prefill token + decodes
    assert first["steps"] > 0 and first["wall_s"] > 0
    snap1 = engine.stats_snapshot()
    assert snap1["requests"] == {"submitted": 3, "completed": 3,
                                 "queue_depth": 0}
    assert snap1["ttft_s"]["count"] == 3
    assert snap1["token_latency_s"]["count"] > 0

    _submit(engine, 2, 3)
    engine.run()
    # last_stats covers only the second call...
    assert engine.last_stats["requests_completed"] == 2
    assert engine.last_stats["tokens_generated"] == 2 * 3
    # ...while the engine-lifetime metrics keep cumulating
    snap2 = engine.stats_snapshot()
    assert snap2["requests"] == {"submitted": 5, "completed": 5,
                                 "queue_depth": 0}
    assert snap2["tokens_generated"] == 3 * 4 + 2 * 3
    assert snap2["steps"] == first["steps"] + engine.last_stats["steps"]
    assert snap2["ttft_s"]["count"] == 5
    text = engine.stats_text()
    assert "serve.requests submitted=5 completed=5" in text
    assert "p99" in text


def test_engine_metrics_do_not_change_outputs(params):
    """Instrumented engine output still matches the batch-1 reference."""
    rng = np.random.default_rng(4)
    p = rng.integers(0, CFG.vocab_size, size=6).astype(np.int32)
    engine = ServeEngine(CFG, params, slots=1, max_len=48)
    r = Request(prompt=p, max_new_tokens=4)
    engine.submit(r)
    engine.run()
    assert r.output == _greedy_reference(params, p, 4)
    snap = engine.stats_snapshot()
    assert snap["ttft_s"]["p50"] > 0
    assert snap["tokens_per_s"] > 0


def test_queue_full_backpressure(params):
    """Bounded admission: submits past max_queue are rejected with a
    structured reason and never perturb the admitted requests."""
    rng = np.random.default_rng(7)
    engine = ServeEngine(CFG, params, slots=1, max_len=48, max_queue=2)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=3) for _ in range(3)]
    assert engine.submit(reqs[0]) is True
    assert engine.submit(reqs[1]) is True
    assert engine.submit(reqs[2]) is False
    assert reqs[2].reject_reason == "queue_full"
    assert reqs[2].output is None and not reqs[2].done
    assert engine.metrics.requests_rejected == 1
    assert engine.metrics.queue_depth == 2   # rejected one never entered

    engine.run()
    for r in reqs[:2]:
        assert r.done
        assert r.output == _greedy_reference(params, r.prompt, 3)
    snap = engine.stats_snapshot()
    assert snap["requests"]["submitted"] == 2
    assert snap["requests"]["completed"] == 2
    assert snap["failures"] == {"rejected": 1, "expired": 0}
    assert "rejected=1 expired=0" in engine.stats_text()


def test_deadline_drops_queued_request(params):
    """A request whose deadline lapses while queued is dropped before
    prefill; requests ahead of it are unaffected."""
    rng = np.random.default_rng(8)
    engine = ServeEngine(CFG, params, slots=1, max_len=48)
    ok = Request(prompt=rng.integers(0, CFG.vocab_size, size=4)
                 .astype(np.int32), max_new_tokens=3)
    late = Request(prompt=rng.integers(0, CFG.vocab_size, size=4)
                   .astype(np.int32), max_new_tokens=3, deadline_s=0.0)
    assert engine.submit(ok) and engine.submit(late)
    engine.run()

    assert ok.done
    assert ok.output == _greedy_reference(params, ok.prompt, 3)
    assert not late.done
    assert late.reject_reason == "deadline"
    assert late.output == []                 # admitted but never prefilled
    assert engine.metrics.requests_expired == 1
    snap = engine.stats_snapshot()
    assert snap["requests"]["queue_depth"] == 0
    assert snap["failures"] == {"rejected": 0, "expired": 1}


def test_deadline_cuts_off_mid_decode(params):
    """A deadline crossed mid-decode keeps the partial output, frees the
    slot, and counts as expired — the engine keeps draining."""
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, CFG.vocab_size, size=4).astype(np.int32)
    engine = ServeEngine(CFG, params, slots=1, max_len=48)
    req = Request(prompt=prompt, max_new_tokens=20, deadline_s=5.0)
    assert engine.submit(req)
    engine.step()                            # prefill + first decode step
    assert len(req.output) == 2
    req.submit_t -= 10.0                     # force the deadline to lapse
    engine.step()

    assert not req.done
    assert req.reject_reason == "deadline"
    assert req.output == _greedy_reference(params, prompt, 3)  # partial
    assert all(r is None for r in engine.slot_req)
    assert engine.metrics.requests_expired == 1
    assert engine.metrics.requests_completed == 0
    engine.run()                             # nothing left; terminates
    assert engine.last_stats["steps"] == 0
    snap = engine.stats_snapshot()
    assert snap["requests"]["queue_depth"] == 0
    assert snap["failures"] == {"rejected": 0, "expired": 1}
