"""Serving engine: slot management, per-slot positions, determinism."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import decode_step, init_params, prefill
from repro.serve.engine import Request, ServeEngine

CFG = get_config("llama3-8b").reduced()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0), dtype=jnp.float32)


def _greedy_reference(params, prompt, n_new):
    """Sequential batch-1 reference decode."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = prefill(params, toks, CFG)
    # pad cache to engine max_len
    pad = 64 - cache["k"].shape[2]
    cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        lg, cache = decode_step(params, jnp.asarray([out[-1]], jnp.int32),
                                CFG, cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_engine_matches_reference(params):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in (5, 9)]
    engine = ServeEngine(CFG, params, slots=2, max_len=64)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.output) == 6
        ref = _greedy_reference(params, p, 6)
        assert r.output == ref, (r.output, ref)


def test_more_requests_than_slots(params):
    rng = np.random.default_rng(1)
    engine = ServeEngine(CFG, params, slots=2, max_len=48)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=3) for _ in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done and len(r.output) == 3 for r in reqs)


def test_heterogeneous_prompt_lengths(params):
    """Slots at different positions must decode independently."""
    rng = np.random.default_rng(2)
    pa = rng.integers(0, CFG.vocab_size, size=3).astype(np.int32)
    pb = rng.integers(0, CFG.vocab_size, size=17).astype(np.int32)
    engine = ServeEngine(CFG, params, slots=2, max_len=64)
    ra, rb = Request(prompt=pa, max_new_tokens=5), Request(prompt=pb,
                                                           max_new_tokens=5)
    engine.submit(ra)
    engine.submit(rb)
    engine.run()
    assert ra.output == _greedy_reference(params, pa, 5)
    assert rb.output == _greedy_reference(params, pb, 5)
