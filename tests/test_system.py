"""End-to-end behaviour tests for the paper's system.

The full CIMinus story in one place: describe hardware + workload +
mapping → prune with FlexBlock → profile input sparsity → simulate →
validate the headline claims (sparsity speedups, mapping trade-offs,
index overhead), and the execution plane: the same masks train a live
JAX model whose pruned weights stay zero.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (TABLE_II_PATTERNS, compare, default_mapping,
                        dense_baseline, hybrid, mars_arch, resnet50,
                        row_block, sdp_arch, simulate, sweep_mappings,
                        usecase_arch, vgg16)
from repro.core.input_sparsity import analytic_skip_ratio
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.transformer import init_params
from repro.sparsity.apply import prune_params, sparsity_report
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_paper_pipeline_end_to_end():
    """§VII-B style: one architecture, several patterns, consistent
    efficiency ordering (coarse ≥ fine) and valid reports."""
    arch = usecase_arch(4, input_sparsity=True)
    m = default_mapping(arch, "duplicate")
    wl_fn = lambda: resnet50(32)
    dense = dense_baseline(arch, wl_fn(), m)

    results = {}
    for name, spec in TABLE_II_PATTERNS(0.8, c_in=16).items():
        wl = wl_fn().set_sparsity(spec)
        skip = {op.name: analytic_skip_ratio(0.5, arch.macro.sub_rows)
                for op in wl.mvm_ops()}
        rep = simulate(arch, wl, m, input_sparsity=skip)
        results[name] = compare(rep, dense)

    # every sparse config at least matches dense
    for name, c in results.items():
        assert c["speedup"] >= 0.99, (name, c)
    # coarse row-wise is at least as fast as the hybrid fine pattern
    assert results["row-wise"]["speedup"] >= \
        results["1:2+row-block"]["speedup"] * 0.95


def test_mapping_exploration_story():
    """§VII-C: duplication lifts utilization dramatically for conv-heavy
    models (paper reports up to 7.7×)."""
    rows = sweep_mappings(
        lambda org: usecase_arch(16, org),
        lambda: resnet50(32).set_sparsity(hybrid(2, 16, 0.8)),
        hybrid(2, 16, 0.8), orgs=((8, 2), (4, 4), (2, 8)))
    sp = {r["org"]: r for r in rows if r["mapping"] == "spatial"}
    dp = {r["org"]: r for r in rows if r["mapping"] == "duplicate"}
    gains = [dp[o]["utilization"] / max(sp[o]["utilization"], 1e-9)
             for o in sp]
    assert max(gains) > 2.0


def test_validation_architectures_build():
    for arch in (mars_arch(), sdp_arch()):
        arch.validate()
        assert arch.n_macros >= 8


def test_execution_plane_round_trip(tmp_path):
    """Prune a live model with the paper's workflow, fine-tune 4 steps,
    verify loss is finite, decreasing, and zeros stay zero."""
    cfg = get_config("qwen3-4b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    pruned, masks = prune_params(params, row_block(0.6, 16))
    rep = sparsity_report(pruned, masks)
    assert abs(rep["overall_density"] - 0.4) < 0.1

    pcfg = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=0)
    tcfg = TrainerConfig(steps=4, ckpt_every=4, ckpt_dir=str(tmp_path))
    tr = Trainer(cfg, AdamWConfig(lr=2e-3, warmup_steps=1), tcfg,
                 TokenPipeline(pcfg), masks=masks)
    tr.params = pruned
    log = tr.train()
    assert all(np.isfinite(m["loss"]) for m in log)
    for name, m in masks["layers"].items():
        if m is None:
            continue
        w = np.asarray(tr.params["layers"][name])
        assert (w[np.asarray(m) == 0] == 0).all()


def test_index_memory_accounting():
    """Eq. 8: hybrid patterns need both block and element indices; the
    fine pattern costs more index bits than the coarse one."""
    from repro.core.flexblock import FlexBlockSpec, FullBlock
    shape = (1024, 512)
    coarse = FlexBlockSpec((FullBlock(16, 16, 0.8),)).index_storage_bits(shape)
    fine = hybrid(2, 16, 0.8).index_storage_bits(shape)
    assert fine > coarse > 0
