"""Vectorized-hot-path equivalence + tile-grid memoisation tests.

The cost model's per-tile loops were rewritten as vectorized
``np.add.reduceat`` reductions with a content-addressed ``TileGrid``
memo (see ``repro.core.mapping``).  The scalar-loop reference
implementations are retained and replayed here via
``mapping.reference_loops()``: every simulated ``CostReport`` —
latency, the full energy breakdown, utilisation, index bits, per-op
costs — must be **bit-for-bit identical** between the two paths, across
sparsity patterns × rearrangement × mapping strategies, on ragged,
IntraBlock, and rearranged grids alike.
"""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import (TABLE_II_PATTERNS, OpNode, Workload, default_mapping,
                        hybrid, resnet18, row_block, row_wise, simulate,
                        usecase_arch)
from repro.core import mapping as M
from repro.core.flexblock import column_wise
from repro.core.mapping import (TileGridCache, _band_stats_loop,
                                _band_stats_vectorized, _occupancy_loop,
                                _occupancy_vectorized, reshape_and_compress)


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


def _assert_reports_identical(ref, vec, ctx):
    assert ref.latency_cycles == vec.latency_cycles, ctx
    assert ref.latency_ms == vec.latency_ms, ctx
    assert ref.energy_pj == vec.energy_pj, ctx          # exact, per unit
    assert ref.total_energy_uj == vec.total_energy_uj, ctx
    assert ref.utilization == vec.utilization, ctx
    assert ref.index_storage_bits == vec.index_storage_bits, ctx
    assert len(ref.op_costs) == len(vec.op_costs), ctx
    for a, b in zip(ref.op_costs, vec.op_costs):
        assert a == b, (ctx, a.name)
    assert ref == vec, ctx


# ---------------------------------------------------------------------------
# Full-simulation equivalence: the tentpole acceptance check.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_name,spec", [
    ("row-wise", row_wise(0.8)),                  # ragged FullBlock(1,N)
    ("row-block", row_block(0.8, 16)),            # ragged FullBlock(1,16)
    ("column-wise", column_wise(0.7)),            # col-orient compression
    ("hybrid-1:2", hybrid(2, 16, 0.8)),           # IntraBlock + FullBlock
    ("dense", None),
])
@pytest.mark.parametrize("strategy", ["spatial", "duplicate"])
@pytest.mark.parametrize("rearrange", [None, "slice", "pad"])
def test_loop_and_vectorized_costreports_identical(arch4, spec_name, spec,
                                                   strategy, rearrange):
    m = default_mapping(arch4, strategy, rearrange=rearrange,
                        slice_size=32 if rearrange == "slice" else 0)

    def wl():
        w = resnet18(32)
        return w.set_sparsity(spec) if spec is not None else w

    with M.reference_loops():
        ref = simulate(arch4, wl(), m)
    vec = simulate(arch4, wl(), m)
    _assert_reports_identical(ref, vec, (spec_name, strategy, rearrange))


def test_equivalence_with_input_sparsity_and_masks(arch4):
    arch = arch4.replace(input_sparsity_support=True)
    wl_fn = lambda: resnet18(32).set_sparsity(row_block(0.75, 16))  # noqa: E731
    m = default_mapping(arch, "duplicate")
    skip = {op.name: 0.3 for op in wl_fn().mvm_ops()}
    # explicit pruning-workflow mask for one op exercises the mask-digest
    # cache key path
    op = wl_fn().mvm_ops()[0]
    f = row_block(0.75, 16).bind((op.K, op.N)).full
    gm, gn = f.grid((op.K, op.N))
    rng = np.random.default_rng(7)
    keep = rng.random((gm, gn)) < 0.4
    keep[0, :] = True
    masks = {op.name: keep}
    with M.reference_loops():
        ref = simulate(arch, wl_fn(), m, input_sparsity=skip, masks=masks)
    vec = simulate(arch, wl_fn(), m, input_sparsity=skip, masks=masks)
    _assert_reports_identical(ref, vec, "input-sparsity+masks")


# ---------------------------------------------------------------------------
# Property tests: vectorized reductions == loop reference on random
# ragged profiles (hypothesis when installed, via the repo shim).
# ---------------------------------------------------------------------------

def _random_profile(rng, n, lo=0, hi=200):
    return rng.integers(lo, hi, size=n).astype(np.int64)


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n=st.integers(min_value=0, max_value=300),
       tile_k=st.sampled_from([8, 32, 64, 1024]),
       tile_n=st.sampled_from([4, 16, 32]))
@settings(max_examples=60, deadline=None)
def test_occupancy_property_random_ragged(seed, n, tile_k, tile_n):
    rng = np.random.default_rng(seed)
    k_cols = _random_profile(rng, n)
    k_base = int(rng.integers(1, 256))
    loop = _occupancy_loop(k_cols, k_base, tile_k, tile_n)
    vec = _occupancy_vectorized(k_cols, k_base, tile_k, tile_n)
    assert loop.shape == vec.shape
    assert np.array_equal(loop, vec)      # bit-identical, not allclose


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n=st.integers(min_value=0, max_value=300),
       tile_n=st.sampled_from([4, 16, 32]),
       sub_rows=st.sampled_from([1, 8, 32]))
@settings(max_examples=60, deadline=None)
def test_band_stats_property_random_ragged(seed, n, tile_n, sub_rows):
    rng = np.random.default_rng(seed)
    k_cols = _random_profile(rng, n)
    K = int(rng.integers(1, 512))
    loop = _band_stats_loop(k_cols, K, tile_n, sub_rows)
    vec = _band_stats_vectorized(k_cols, K, tile_n, sub_rows)
    assert loop == vec                    # (bands, tiles, row_demand, ragged)


def test_occupancy_and_band_stats_edge_profiles():
    """Deterministic edge cases the random sweep may miss."""
    cases = [
        np.array([], dtype=np.int64),            # empty profile
        np.zeros(40, dtype=np.int64),            # all-zero columns
        np.array([5], dtype=np.int64),           # single column
        np.full(64, 17, dtype=np.int64),         # uniform (not ragged)
        np.array([0, 0, 9, 0], dtype=np.int64),  # zero tiles mixed in
    ]
    for k_cols in cases:
        assert np.array_equal(_occupancy_loop(k_cols, 3, 32, 16),
                              _occupancy_vectorized(k_cols, 3, 32, 16))
        assert _band_stats_loop(k_cols, 7, 16, 8) == \
            _band_stats_vectorized(k_cols, 7, 16, 8)


# ---------------------------------------------------------------------------
# Utilisation regression pin (the `rows_used` → `row_demand` satellite).
# ---------------------------------------------------------------------------

def test_utilization_pinned_for_known_ragged_grid(arch4):
    """Hand-computed utilisation for an explicit ragged keep-grid.

    row_demand is the Σ over N-tiles of the tile's mean real rows per
    column (NOT a global mean): tile0 holds column groups of 40 and 10
    rows → mean 25; tile1 holds 20 and 30 → mean 25; total demand 50
    rows.  Spatial mapping, 1 wave, no duplication: provisioned rows =
    4 macros × 32 bands × 32 sub_rows = 4096, so utilisation must be
    exactly 50/4096.
    """
    wl = Workload("pin")
    wl.add(OpNode(name="fc", kind="fc", K=64, N=64, V=1,
                  sparsity=row_block(0.5, 16)))
    keep = np.zeros((64, 4), dtype=bool)     # FullBlock(1,16) grid on 64×64
    keep[:40, 0] = True
    keep[:10, 1] = True
    keep[:20, 2] = True
    keep[:30, 3] = True
    rep = simulate(arch4, wl, default_mapping(arch4, "spatial"),
                   masks={"fc": keep})
    assert rep.op_costs[0].utilization == 50.0 / 4096.0
    assert rep.op_costs[0].tiles == 2
    # the same grid through the reference loop agrees
    with M.reference_loops():
        ref = simulate(arch4, wl, default_mapping(arch4, "spatial"),
                       masks={"fc": keep})
    assert ref.op_costs[0].utilization == rep.op_costs[0].utilization


# ---------------------------------------------------------------------------
# TileGrid memoisation semantics.
# ---------------------------------------------------------------------------

def _op(name, K, N, spec):
    return OpNode(name=name, kind="fc", K=K, N=N, V=4, sparsity=spec)


def test_tile_grid_shared_across_same_shape_ops(arch4):
    """Same (K, N, spec, tile): one grid computation serves every op —
    repeated layer shapes are the transformer/CNN common case."""
    cache = TileGridCache()
    spec = row_block(0.8, 16)
    m = default_mapping(arch4).reshape
    g1 = reshape_and_compress(_op("a", 256, 128, spec), arch4, m, cache=cache)
    g2 = reshape_and_compress(_op("b", 256, 128, spec), arch4, m, cache=cache)
    assert g1 is g2                       # the memoised instance itself
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


def test_tile_grid_cache_distinguishes_content(arch4):
    cache = TileGridCache()
    m = default_mapping(arch4).reshape
    spec = row_block(0.8, 16)
    base = reshape_and_compress(_op("a", 256, 128, spec), arch4, m, cache=cache)
    for other in (_op("b", 256, 256, spec),              # shape differs
                  _op("c", 256, 128, row_block(0.7, 16)),  # ratio differs
                  _op("d", 256, 128, row_wise(0.8))):    # pattern differs
        g = reshape_and_compress(other, arch4, m, cache=cache)
        assert g is not base
    assert cache.stats()["misses"] == 4 and cache.stats()["hits"] == 0


def test_tile_grid_cache_mask_digest_key(arch4):
    """Supplied pruning masks key by content: equal-content arrays hit,
    different content misses."""
    cache = TileGridCache()
    m = default_mapping(arch4).reshape
    spec = row_block(0.5, 16)
    rng = np.random.default_rng(0)
    keep = rng.random((64, 8)) < 0.5
    op = _op("a", 64, 128, spec)
    g1 = reshape_and_compress(op, arch4, m, block_keep=keep, cache=cache)
    g2 = reshape_and_compress(op, arch4, m, block_keep=keep.copy(),
                              cache=cache)
    assert g1 is g2
    other = keep.copy()
    other[0, 0] = not other[0, 0]
    g3 = reshape_and_compress(op, arch4, m, block_keep=other, cache=cache)
    assert g3 is not g1


def test_tile_grid_cache_lru_eviction(arch4):
    cache = TileGridCache(capacity=2)
    m = default_mapping(arch4).reshape
    spec = row_block(0.8, 16)
    for i, n in enumerate((64, 128, 192)):
        reshape_and_compress(_op(f"o{i}", 256, n, spec), arch4, m,
                             cache=cache)
    assert len(cache) == 2 and cache.stats()["evictions"] == 1
    # capacity 0 disables storage entirely
    off = TileGridCache(capacity=0)
    reshape_and_compress(_op("x", 64, 64, spec), arch4, m, cache=off)
    assert len(off) == 0


def test_reference_mode_bypasses_cache(arch4):
    cache = TileGridCache()
    m = default_mapping(arch4).reshape
    op = _op("a", 256, 128, row_block(0.8, 16))
    with M.reference_loops():
        reshape_and_compress(op, arch4, m, cache=cache)
    assert len(cache) == 0 and cache.stats()["misses"] == 0


def test_cached_grids_are_read_only(arch4):
    g = reshape_and_compress(_op("a", 128, 64, row_block(0.8, 16)), arch4,
                             default_mapping(arch4).reshape,
                             cache=TileGridCache())
    with pytest.raises(ValueError):
        g.occupancy[0, 0] = 1.0
    with pytest.raises(ValueError):
        g.k_eff[0] = 1
