"""Chaos-recovery tests: deterministic fault injection proving the
fault-tolerance layer (jax-free).

The contract under test: a sweep that loses workers, hangs, hits
transient exceptions, or reads corrupted store entries must finish with
every *surviving* row bit-identical to a fault-free run — and a
SIGKILLed sweep must resume re-evaluating only the missing points.
"""
import os
import re
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.core import TABLE_II_PATTERNS, default_mapping, resnet18, usecase_arch
from repro.explore import (ExploreJob, FaultError, FaultPlan, KeyJournal,
                           ResultCache, ResultStore, RunStats, StoreError,
                           SweepFailure, SweepRunner, faults,
                           parse_fault_spec, sparsity_sweep)
from repro.explore.__main__ import main as explore_main

RATIOS = (0.7, 0.8)


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


def _pattern_factory(r):
    return TABLE_II_PATTERNS(r, c_in=16)


def _sweep(runner, arch):
    return sparsity_sweep(arch, lambda: resnet18(32), {}, ratios=RATIOS,
                          mapping=default_mapping(arch),
                          pattern_factory=_pattern_factory, runner=runner)


@pytest.fixture(scope="module")
def baseline(arch4):
    """Fault-free parallel run: (rows, all job keys, the dense key)."""
    runner = SweepRunner(workers=2)
    res = _sweep(runner, arch4)
    runner.close()
    dense = ExploreJob.dense(arch4, resnet18(32),
                             default_mapping(arch4)).key
    return res.rows, sorted(runner._seen_keys), dense


def _seed_selecting(kind, keys, rate, want=1):
    """A seed whose plan selects >= ``want`` of ``keys`` — keeps the
    rate-based tests independent of incidental key churn."""
    for seed in range(200):
        plan = FaultPlan(**{"seed": seed, kind: rate})
        if sum(plan.selected(kind, k) for k in keys) >= want:
            return seed
    raise AssertionError(f"no seed selects {want} keys for {kind}")


# ---------------------------------------------------------------------------
# FaultPlan / spec grammar
# ---------------------------------------------------------------------------

def test_fault_spec_roundtrip():
    plan = FaultPlan(seed=7, crash=0.25, exc=0.5, times=float("inf"),
                     hang_s=12.0, match="ab12")
    assert parse_fault_spec(plan.spec()) == plan
    assert parse_fault_spec("seed=3,hang=1.0") == FaultPlan(seed=3, hang=1.0)


@pytest.mark.parametrize("bad", ["frobnicate=1", "crash", "crash=",
                                 "crash=2.0", "times=-1", "seed=x"])
def test_fault_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_fault_spec(bad)


def test_fault_selection_deterministic_and_matched():
    plan = FaultPlan(seed=9, crash=0.5, match="ab")
    keys = [f"{p}{i:02d}" + "0" * 60 for p in ("ab", "cd") for i in range(20)]
    first = [plan.selected("crash", k) for k in keys]
    assert first == [plan.selected("crash", k) for k in keys]  # pure
    assert not any(sel for k, sel in zip(keys, first) if k.startswith("cd"))
    assert any(sel for k, sel in zip(keys, first) if k.startswith("ab"))
    # times bounds the attempts a selected fault fires on
    victim = next(k for k, sel in zip(keys, first) if sel)
    assert plan.should("crash", victim, attempt=0)
    assert not plan.should("crash", victim, attempt=1)
    forever = FaultPlan(seed=9, crash=0.5, match="ab", times=float("inf"))
    assert forever.should("crash", victim, attempt=10 ** 6)


def test_crash_fault_degrades_to_exception_in_parent():
    """Outside a pool worker an injected crash must not kill the
    process — it raises FaultError so sequential paths stay testable."""
    faults.install(FaultPlan(crash=1.0), export_env=False)
    assert not faults.in_worker()
    with pytest.raises(FaultError):
        faults.maybe_fail("deadbeef" * 8)


def test_env_spec_install_uninstall(monkeypatch):
    faults.install("seed=5,exc=0.5")
    assert os.environ[faults._ENV_VAR] == "seed=5,exc=0.5"
    assert faults.active_plan() == FaultPlan(seed=5, exc=0.5)
    faults.uninstall()
    assert faults._ENV_VAR not in os.environ
    assert faults.active_plan() is None


def test_corrupt_payload_deterministic():
    plan = FaultPlan(seed=2, corrupt=1.0)
    faults.install(plan, export_env=False)
    key, payload = "ab" * 32, b"x" * 300
    garbled = faults.corrupt_payload(key, payload)
    assert garbled != payload
    assert garbled == faults.corrupt_payload(key, payload)  # reproducible
    faults.uninstall()
    assert faults.corrupt_payload(key, payload) == payload  # disabled: no-op


# ---------------------------------------------------------------------------
# Store corruption tolerance
# ---------------------------------------------------------------------------

def _garble(store, key):
    if store.backend == "json":
        store._entry_path(key).write_bytes(b"\x00torn")
    else:
        con = store._connect()
        with con:
            con.execute("INSERT OR REPLACE INTO results VALUES (?, ?)",
                        (key, b"\x00torn"))


@pytest.mark.parametrize("backend", ["sqlite", "json"])
def test_corrupt_entry_is_miss_removed_and_counted(tmp_path, backend, arch4):
    store = ResultStore(tmp_path / "s", backend=backend)
    runner = SweepRunner(workers=1, cache=ResultCache(store=store))
    res = _sweep(runner, arch4)
    victim = sorted(runner._seen_keys)[0]
    _garble(store, victim)

    fresh = ResultStore(tmp_path / "s", backend=backend)
    assert fresh.get(victim) is None            # miss, not an exception
    assert fresh.corrupt_entries == 1
    assert victim not in fresh.keys()           # bad entry removed
    _garble(fresh, victim)                      # re-damage for the sweep

    # a sweep over the damaged store re-evaluates just the victim and
    # produces bit-identical rows
    cache2 = ResultCache(store=ResultStore(tmp_path / "s", backend=backend))
    runner2 = SweepRunner(workers=1, cache=cache2)
    res2 = _sweep(runner2, arch4)
    assert res2.rows == res.rows
    assert res2.stats.evaluated == 1
    assert res2.stats.corrupt_entries == 1
    assert cache2.stats.corrupt_entries == 1


@pytest.mark.parametrize("backend", ["sqlite", "json"])
def test_store_schema_mismatch_is_hard_error(tmp_path, backend):
    store = ResultStore(tmp_path / "s", backend=backend)
    if backend == "json":
        (tmp_path / "s" / "store_meta.json").write_text(
            '{"store_schema": 999}')
    else:
        con = store._connect()
        with con:
            con.execute("UPDATE meta SET v='999' WHERE k='store_schema'")
    store.close()
    with pytest.raises(StoreError):
        ResultStore(tmp_path / "s", backend=backend)


def test_corrupt_fault_injection_end_to_end(tmp_path, arch4, baseline):
    """A ``corrupt`` fault garbles entries on *write*; the read path
    must absorb them: rows stay correct, damage is counted."""
    rows0, keys, dense = baseline
    victim = next(k for k in keys if k != dense)
    faults.install(FaultPlan(seed=1, corrupt=1.0, match=victim[:16]),
                   export_env=False)
    cache = ResultCache(tmp_path / "run")
    res = _sweep(SweepRunner(workers=1, cache=cache), arch4)
    faults.uninstall()
    assert res.rows == rows0                    # in-memory results unharmed

    cache2 = ResultCache(tmp_path / "run")
    runner2 = SweepRunner(workers=1, cache=cache2)
    res2 = _sweep(runner2, arch4)
    assert res2.rows == rows0
    assert res2.stats.corrupt_entries == 1      # garbled entry dropped
    assert res2.stats.evaluated == 1            # only the victim re-ran


def test_journal_drops_torn_tail(tmp_path):
    j = KeyJournal(tmp_path / "journal.txt")
    a, b = "ab" * 32, "cd" * 32
    j.record(a)
    j.record(b)
    j.close()
    with open(tmp_path / "journal.txt", "a") as f:
        f.write("ef" * 10)                      # torn final line, no newline
    assert KeyJournal(tmp_path / "journal.txt").keys() == {a, b}


# ---------------------------------------------------------------------------
# Chaos sweeps: surviving rows bit-identical to the fault-free run
# ---------------------------------------------------------------------------

def test_transient_exceptions_recovered_bitwise(arch4, baseline):
    rows0, keys, _ = baseline
    seed = _seed_selecting("exc", keys, 0.3, want=2)
    faults.install(FaultPlan(seed=seed, exc=0.3, times=1))
    runner = SweepRunner(workers=2, backoff_s=0.01)
    res = _sweep(runner, arch4)
    runner.close()
    assert res.rows == rows0
    assert res.stats.retried >= 2 and res.stats.failed == 0


def test_worker_crash_recovered_bitwise(arch4, baseline):
    """Mid-flight worker kills: the pool self-heals, suspects re-run
    solo, and every row matches the fault-free run bit for bit."""
    rows0, keys, _ = baseline
    seed = _seed_selecting("crash", keys, 0.3, want=2)
    faults.install(FaultPlan(seed=seed, crash=0.3, times=1))
    runner = SweepRunner(workers=2, backoff_s=0.01)
    res = _sweep(runner, arch4)
    runner.close()
    assert res.rows == rows0
    assert res.stats.retried >= 2 and res.stats.failed == 0


def test_hung_worker_recovered_by_timeout(arch4, baseline):
    rows0, keys, dense = baseline
    victim = next(k for k in keys if k != dense)
    faults.install(FaultPlan(seed=3, hang=1.0, hang_s=60.0, times=1,
                             match=victim[:16]))
    runner = SweepRunner(workers=2, timeout_s=2.0, backoff_s=0.01)
    res = _sweep(runner, arch4)
    runner.close()
    assert res.rows == rows0
    assert res.stats.timed_out >= 1 and res.stats.failed == 0


def test_poison_job_quarantined_strict_and_degrade(arch4, baseline):
    rows0, keys, dense = baseline
    victim = next(k for k in keys if k != dense)
    plan = FaultPlan(seed=3, crash=1.0, times=float("inf"),
                     match=victim[:16])

    faults.install(plan)
    runner = SweepRunner(workers=2, backoff_s=0.01)
    with pytest.raises(SweepFailure) as ei:
        _sweep(runner, arch4)
    runner.close()
    assert [f.key for f in ei.value.failures] == [victim]
    assert ei.value.failures[0].reason == "crash"
    # partial results delivered alongside the failure: exactly the
    # poison job's slot is None, everything else survived
    assert sum(r is None for r in ei.value.results) == 1

    faults.install(plan)
    runner = SweepRunner(workers=2, backoff_s=0.01, failure_mode="degrade")
    res = _sweep(runner, arch4)
    runner.close()
    failed = [r for r in res.rows if r.get("failed")]
    ok = [r for r in res.rows if not r.get("failed")]
    assert len(failed) == 1 and failed[0]["workload"] == "resnet18-32"
    assert all(r in rows0 for r in ok)          # survivors bit-identical
    assert res.stats.failed == 1


# ---------------------------------------------------------------------------
# SIGKILL + resume: only the missing points re-evaluate
# ---------------------------------------------------------------------------

_ENGINE_RE = re.compile(r"engine: .*?(\d+) evaluated")


def _evaluated_from(output: str) -> int:
    m = _ENGINE_RE.search(output)
    assert m, f"no engine line in output:\n{output}"
    return int(m.group(1))


def _cli(run_dir, extra=()):
    return ["sparsity", "--model", "resnet18", "--img", "32",
            "--ratios", "0.7,0.8", "--workers", "2",
            "--run-dir", str(run_dir), *extra]


def test_sigkill_then_resume_evaluates_only_missing(tmp_path, capsys):
    run_dir = tmp_path / "run"
    env = dict(os.environ,
               PYTHONPATH=str(Path(__file__).resolve().parent.parent / "src"),
               # every job sleeps 0.4s on every attempt: pure latency,
               # no retries — guarantees we can SIGKILL mid-sweep
               REPRO_FAULTS="seed=1,hang=1.0,hang_s=0.4,times=1000000")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.explore", *_cli(run_dir)],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        start_new_session=True)
    journal = KeyJournal(run_dir / "journal.txt")
    deadline = time.monotonic() + 120
    try:
        while len(journal.keys()) < 3:
            assert proc.poll() is None, "sweep finished before the kill"
            assert time.monotonic() < deadline, "no progress before kill"
            time.sleep(0.05)
    finally:
        if proc.poll() is None:
            os.killpg(proc.pid, signal.SIGKILL)
        proc.wait()

    # the store survived the SIGKILL intact: every journaled key is
    # present and readable (WAL / atomic rename — no torn entries)
    store = ResultStore(run_dir)
    journaled = journal.keys()
    assert len(journaled) >= 3
    check = store.self_check()
    assert check.ok and journaled <= store.keys()
    store.close()

    # resume replays the recorded invocation; only missing points run
    assert explore_main(["--resume", str(run_dir)]) == 0
    out = capsys.readouterr().out
    total_unique = len(KeyJournal(run_dir / "journal.txt").keys())
    assert _evaluated_from(out) == total_unique - len(journaled)
    assert total_unique > len(journaled)        # the kill left work behind

    # a second resume is a pure cache replay
    assert explore_main(["--resume", str(run_dir)]) == 0
    assert _evaluated_from(capsys.readouterr().out) == 0

    # and the audited run directory is consistent
    assert explore_main(["--check-store", str(run_dir)]) == 0


def test_cli_run_dir_resume_and_check_store(tmp_path, capsys):
    run_dir = tmp_path / "run"
    assert explore_main(_cli(run_dir, ("--workers", "1"))) == 0
    first = _evaluated_from(capsys.readouterr().out)
    assert first > 0
    assert explore_main(["--resume", str(run_dir)]) == 0
    assert _evaluated_from(capsys.readouterr().out) == 0
    assert explore_main(["--check-store", str(run_dir)]) == 0
    assert "store check: ok" in capsys.readouterr().out


def test_cli_strict_failure_exit_code_and_resume_hint(tmp_path, capsys):
    run_dir = tmp_path / "run"
    # a sweep-wide transient that outlasts the retry budget on one key:
    # first learn a real key, then poison it permanently
    assert explore_main(_cli(run_dir, ("--workers", "1"))) == 0
    capsys.readouterr()
    keys = sorted(KeyJournal(run_dir / "journal.txt").keys())
    faults.install(FaultPlan(crash=1.0, times=float("inf"),
                             match=keys[0][:16]))
    run2 = tmp_path / "run2"
    rc = explore_main(_cli(run2, ("--workers", "1", "--backoff", "0.01")))
    err = capsys.readouterr().err
    assert rc == 3
    assert "failed after retries" in err and "--resume" in err
    # degrade mode keeps going and exits 0, marking the row failed
    faults.install(FaultPlan(crash=1.0, times=float("inf"),
                             match=keys[0][:16]))
    run3 = tmp_path / "run3"
    rc = explore_main(_cli(run3, ("--workers", "1", "--backoff", "0.01",
                                  "--degrade")))
    assert rc == 0


def test_check_store_flags_corruption(tmp_path, capsys):
    run_dir = tmp_path / "run"
    assert explore_main(_cli(run_dir, ("--workers", "1"))) == 0
    capsys.readouterr()
    store = ResultStore(run_dir)
    victim = sorted(store.keys())[0]
    _garble(store, victim)
    store.close()
    assert explore_main(["--check-store", str(run_dir)]) == 1
    out = capsys.readouterr()
    assert "1 corrupt" in out.out
    # the check dropped the bad entry; resume heals the run directory
    assert explore_main(["--resume", str(run_dir)]) == 0
    assert _evaluated_from(capsys.readouterr().out) == 1
    assert explore_main(["--check-store", str(run_dir)]) == 0


# ---------------------------------------------------------------------------
# RunStats fault counters
# ---------------------------------------------------------------------------

def test_runstats_fault_counters_merge_and_text():
    a = RunStats(requested=4, unique=3, evaluated=3, failed=1, retried=2,
                 timed_out=1, corrupt_entries=1)
    b = RunStats(requested=2, unique=2, evaluated=2)
    m = a.merge(b)
    assert (m.failed, m.retried, m.timed_out, m.corrupt_entries) \
        == (1, 2, 1, 1)
    assert m.as_dict()["failed"] == 1
    assert "faults: 1 failed, 2 retried, 1 timed out" in a.stats_text()
    assert "faults:" not in b.stats_text()      # quiet when clean
    # failed jobs are not cache hits
    assert a.cache_hits == 4 - 3 - 1
