"""Tests for repro.obs — the observability plane.

Four families:

* **Core recording** — spans/counters/events/heartbeats land in the
  trace directory, worker processes merge into the parent run, the
  disabled path is a shared no-op object.
* **Faces** — Chrome-trace timeline export (schema + disjoint-lane
  invariants), per-component energy attribution (shares sum to 1,
  groups partition the component set), serve-metrics histograms.
* **Observational-only contract** — an obs-enabled sweep produces
  byte-identical CostReports, identical rows, and identical cache keys
  vs the same sweep with obs disabled.
* **Stats semantics** — ``RunStats.merge`` arithmetic and the
  cumulative-vs-``last_stats`` split across repeated ``run()`` calls.
"""
from __future__ import annotations

import json
import math
import os
from pathlib import Path

import pytest

from repro import obs
from repro.core import (TABLE_II_PATTERNS, default_mapping, resnet18,
                        row_wise, simulate, usecase_arch)
from repro.core.report import CostReport
from repro.core.schedule import SchedulePolicy
from repro.explore import ExploreJob, SweepRunner, sparsity_sweep
from repro.explore.runner import RunStats
from repro.obs.energy import (append_energy_csv, component_group,
                              component_rows, energy_table)
from repro.obs.metrics import ServeMetrics, StreamingHistogram
from repro.obs.timeline import (chrome_trace, check_chrome_trace,
                                write_chrome_trace)

RATIOS = (0.7, 0.8)


def _pattern_factory(r):
    return TABLE_II_PATTERNS(r, c_in=16)


@pytest.fixture(scope="module")
def arch16():
    return usecase_arch(16)


@pytest.fixture(scope="module")
def partitioned_report(arch16):
    return simulate(arch16, resnet18(32), default_mapping(arch16),
                    schedule=SchedulePolicy(policy="partitioned"))


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with recording disabled."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# core recording
# ---------------------------------------------------------------------------

def test_disabled_entry_points_are_shared_noops():
    assert obs.get_observer() is None or True   # env may differ; force off
    obs.disable()
    assert not obs.is_enabled()
    s1, s2 = obs.span("a"), obs.span("b", k=1)
    assert s1 is s2                              # one shared null object
    with s1:
        s1.set(x=1)
    assert obs.heartbeat("h", total=3) is s1
    obs.counter("c")                             # returns None, no write
    obs.event("e", k="v")


def test_enable_disable_roundtrip(tmp_path):
    o = obs.enable(tmp_path / "t", run_id="test-run")
    assert obs.is_enabled() and obs.get_observer() is o
    assert os.environ.get("REPRO_OBS_DIR") == str(o.dir)
    obs.disable()
    assert not obs.is_enabled()
    assert "REPRO_OBS_DIR" not in os.environ
    manifest = obs.read_manifest(tmp_path / "t")
    assert manifest["run_id"] == "test-run"
    assert manifest["obs_schema"] == obs.OBS_SCHEMA


def test_span_counter_event_recorded(tmp_path):
    with obs.enabled(tmp_path / "t"):
        with obs.span("work.block", stage="x") as sp:
            sp.set(items=3)
        obs.counter("work.count", 7, kind="unit")
        obs.event("work.done", ok=True)
    recs = obs.read_events(tmp_path / "t")
    by_name = {r["name"]: r for r in recs}
    assert by_name["work.block"]["type"] == "span"
    assert by_name["work.block"]["dur_s"] >= 0
    assert by_name["work.block"]["attrs"] == {"stage": "x", "items": 3}
    assert by_name["work.count"]["value"] == 7
    assert by_name["work.done"]["attrs"] == {"ok": True}
    # monotonic ordering of the merged stream
    ts = [r["t"] for r in recs]
    assert ts == sorted(ts)


def test_span_records_exception(tmp_path):
    with obs.enabled(tmp_path / "t"):
        with pytest.raises(ValueError):
            with obs.span("boom"):
                raise ValueError("x")
    (rec,) = obs.read_events(tmp_path / "t", name="boom")
    assert rec["error"] == "ValueError"


def test_heartbeat_rate_limited_but_final_tick_always(tmp_path):
    with obs.enabled(tmp_path / "t"):
        hb = obs.heartbeat("loop", total=1000, min_interval_s=3600)
        for i in range(1000):
            hb.tick(i + 1)
    beats = obs.read_events(tmp_path / "t", name="loop.heartbeat")
    # first beat (interval forced on the first call) + the final one
    assert 1 <= len(beats) <= 2
    last = beats[-1]["attrs"]
    assert last["done"] == last["total"] == 1000
    assert last["points_per_s"] > 0


def test_env_auto_enable(tmp_path, monkeypatch):
    import repro.obs.core as core
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path / "envrun"))
    monkeypatch.setattr(core, "_OBSERVER", None)
    monkeypatch.setattr(core, "_ENV_CHECKED", False)
    assert obs.is_enabled()
    obs.event("from.env")
    obs.disable()
    assert [r["name"] for r in obs.read_events(tmp_path / "envrun")] == \
        ["from.env"]


# ---------------------------------------------------------------------------
# timeline export
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_and_tracks(partitioned_report, tmp_path):
    doc = chrome_trace(partitioned_report)
    assert check_chrome_trace(doc) == []
    meta = doc["otherData"]
    assert meta["n_macros"] == 16
    assert meta["policy"] == "partitioned"
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"
         and e["cat"] == "op"]
    # ops land on distinct macro tracks (the acceptance criterion)
    assert len({e["tid"] for e in x}) > 1
    # critical-path lane present and consistent with the schedule
    cp = [e for e in doc["traceEvents"] if e.get("cat") == "critical-path"]
    assert {e["name"] for e in cp} == \
        set(partitioned_report.schedule.critical_path) & \
        {o.name for o in partitioned_report.schedule.ops
         if o.end > o.start}
    out = write_chrome_trace(partitioned_report, tmp_path / "t.json")
    assert check_chrome_trace(json.loads(out.read_text())) == []


def test_chrome_trace_lanes_never_overlap(partitioned_report):
    """The lane replay must put at most one op on a macro at a time."""
    doc = chrome_trace(partitioned_report)
    per_lane = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X" and e.get("cat") == "op":
            per_lane.setdefault(e["tid"], []).append((e["ts"],
                                                      e["ts"] + e["dur"]))
    post_tid = doc["otherData"]["n_macros"]
    for tid, spans in per_lane.items():
        if tid == post_tid:
            continue                    # post unit serialises by scheduler
        spans.sort()
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-9, f"lane {tid}: [{s0},{e0}) vs [{s1},{e1})"


def test_chrome_trace_requires_schedule(arch16):
    from repro.core.costmodel import simulate_reference
    rep = simulate_reference(arch16, resnet18(32), default_mapping(arch16))
    with pytest.raises(ValueError):
        chrome_trace(rep)


def test_check_chrome_trace_flags_bad_docs():
    assert check_chrome_trace({}) != []
    assert check_chrome_trace({"traceEvents": []}) != []
    bad = {"traceEvents": [{"ph": "X", "name": "op"}]}   # missing ts/dur
    assert any("missing" in p for p in check_chrome_trace(bad))


# ---------------------------------------------------------------------------
# energy attribution (+ the satellite invariants on CostReport views)
# ---------------------------------------------------------------------------

def test_energy_shares_sum_to_one(partitioned_report):
    shares = partitioned_report.energy_shares()
    assert shares                                  # non-degenerate report
    assert all(v > 0 for v in shares.values())
    assert math.isclose(sum(shares.values()), 1.0, rel_tol=1e-9)


def test_grouped_energy_partitions_components(partitioned_report):
    rep = partitioned_report
    groups = rep.grouped_energy()
    # groups partition the ledger: totals match exactly...
    assert math.isclose(sum(groups.values()), sum(rep.energy_pj.values()),
                        rel_tol=1e-12)
    # ...and every component is claimed by exactly one group, the same
    # one repro.obs.energy reports
    for comp, pj in rep.energy_pj.items():
        g = component_group(comp)
        assert g in groups, f"{comp} classified into unknown group {g}"


def test_component_rows_align_with_report(partitioned_report):
    rows = component_rows(partitioned_report, meta={"pattern": "dense"})
    assert {r["component"] for r in rows} == set(
        partitioned_report.energy_pj)
    assert math.isclose(sum(r["share"] for r in rows), 1.0, rel_tol=1e-9)
    assert all(r["pattern"] == "dense" for r in rows)
    assert "cim_array" in energy_table(partitioned_report)


def test_append_energy_csv_accumulates(tmp_path, partitioned_report):
    path = tmp_path / "e.csv"
    rows = component_rows(partitioned_report)
    append_energy_csv(rows, path)
    append_energy_csv(rows, path)
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 1 + 2 * len(rows)         # one header only


# ---------------------------------------------------------------------------
# report/schedule satellites
# ---------------------------------------------------------------------------

def test_summary_includes_schedule_line(partitioned_report):
    s = partitioned_report.summary()
    assert "schedule[partitioned]" in s
    assert "critical-path=" in s and "macro-util=" in s


def test_summary_without_schedule_has_no_schedule_line(arch16):
    from repro.core.costmodel import simulate_reference
    rep = simulate_reference(arch16, resnet18(32), default_mapping(arch16))
    assert "schedule[" not in rep.summary()


def test_report_from_dict_roundtrip(partitioned_report):
    clone = CostReport.from_dict(
        json.loads(partitioned_report.to_json()))
    assert clone.to_json() == partitioned_report.to_json()
    assert clone.schedule.policy == "partitioned"
    assert clone.op_costs[0].name == partitioned_report.op_costs[0].name


def test_macro_time_utilization_bounds(partitioned_report, arch16):
    sched = partitioned_report.schedule
    u = sched.macro_time_utilization()
    assert 0.0 < u <= 1.0
    # a zero-length schedule reports 0, not a division error
    import dataclasses
    empty = dataclasses.replace(sched, makespan_cycles=0.0, ops=[])
    assert empty.macro_time_utilization() == 0.0


# ---------------------------------------------------------------------------
# serve metrics accumulators
# ---------------------------------------------------------------------------

def test_streaming_histogram_percentiles():
    h = StreamingHistogram()
    for v in (0.001, 0.002, 0.003, 0.004, 0.100):
        h.observe(v)
    assert h.count == 5
    assert h.min == 0.001 and h.max == 0.100
    assert math.isclose(h.mean, 0.022, rel_tol=1e-9)
    assert 0.001 <= h.percentile(50) <= 0.004
    assert h.percentile(99) <= 0.100 + 1e-9
    assert h.percentile(0) == 0.001 and h.percentile(100) == 0.100
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["p99"] >= snap["p50"]


def test_streaming_histogram_empty_and_single():
    h = StreamingHistogram()
    assert h.percentile(50) == 0.0 and h.snapshot()["count"] == 0
    h.observe(0.5)
    for p in (0, 50, 99, 100):
        assert math.isclose(h.percentile(p), 0.5, rel_tol=1e-6)


def test_serve_metrics_lifecycle():
    m = ServeMetrics()
    for _ in range(3):
        m.on_submit()
    assert m.queue_depth == 3
    for _ in range(3):
        m.on_scheduled()
        m.on_first_token(0.05)
    for _ in range(4):                   # 4 steps × 3 active slots
        m.on_step(3, 0.01)
        m.on_tokens(3, 0.01)
    for _ in range(3):
        m.on_complete()
    snap = m.snapshot()
    assert snap["requests"] == {"submitted": 3, "completed": 3,
                                "queue_depth": 0}
    assert snap["tokens_generated"] == 12
    assert snap["ttft_s"]["count"] == 3
    assert snap["token_latency_s"]["count"] == 12
    assert math.isclose(snap["tokens_per_s"], 12 / 0.04, rel_tol=1e-6)
    text = m.render_text()
    assert "serve.tokens 12" in text and "p99" in text
    json.loads(m.render_json())          # valid JSON exposition


# ---------------------------------------------------------------------------
# RunStats semantics
# ---------------------------------------------------------------------------

def test_runstats_merge_arithmetic():
    a = RunStats(requested=10, unique=6, memory_hits=2, disk_hits=1,
                 evaluated=3, workers=2, wall_s=1.5, tile_grid_hits=4,
                 tile_grid_misses=2)
    b = RunStats(requested=4, unique=2, memory_hits=2, disk_hits=0,
                 evaluated=0, workers=4, wall_s=0.5, tile_grid_hits=1,
                 tile_grid_misses=0)
    m = a.merge(b)
    assert (m.requested, m.unique, m.evaluated) == (14, 8, 3)
    assert (m.memory_hits, m.disk_hits) == (4, 1)
    assert m.workers == 4                          # max, not sum
    assert math.isclose(m.wall_s, 2.0)
    assert (m.tile_grid_hits, m.tile_grid_misses) == (5, 2)
    assert m.cache_hits == 14 - 3
    assert a.merge(RunStats()).requested == a.requested   # identity-ish


def test_runstats_cumulative_vs_last_stats():
    arch = usecase_arch(4)
    runner = SweepRunner(workers=1)
    wl_fn = lambda: resnet18(32)  # noqa: E731
    m = default_mapping(arch)
    sparsity_sweep(arch, wl_fn, {}, ratios=RATIOS, mapping=m,
                   pattern_factory=_pattern_factory, runner=runner)
    first_total = runner.stats.requested
    first_unique = runner.stats.unique
    sparsity_sweep(arch, wl_fn, {}, ratios=RATIOS, mapping=m,
                   pattern_factory=_pattern_factory, runner=runner)
    # last_stats covers only the second call; stats keeps accumulating
    assert runner.last_stats.requested == first_total
    assert runner.last_stats.evaluated == 0        # all served from cache
    assert runner.stats.requested == 2 * first_total
    # cumulative unique counts distinct keys over the runner's lifetime
    assert runner.stats.unique == first_unique


# ---------------------------------------------------------------------------
# the observational-only contract: obs on == obs off, bit for bit
# ---------------------------------------------------------------------------

def test_obs_enabled_sweep_bit_identical_and_artifacts(tmp_path):
    arch = usecase_arch(4)
    m = default_mapping(arch)
    wl_fn = lambda: resnet18(32)  # noqa: E731

    off = sparsity_sweep(arch, wl_fn, {}, ratios=RATIOS, mapping=m,
                         pattern_factory=_pattern_factory, workers=1)
    with obs.enabled(tmp_path / "run"):
        on = sparsity_sweep(arch, wl_fn, {}, ratios=RATIOS, mapping=m,
                            pattern_factory=_pattern_factory, workers=1)

    assert on.rows == off.rows                     # bit-identical rows
    # cache keys are obs-independent
    j_off = ExploreJob.simulate(arch, wl_fn().set_sparsity(row_wise(0.8)), m)
    with obs.enabled(tmp_path / "run2"):
        j_on = ExploreJob.simulate(arch,
                                   wl_fn().set_sparsity(row_wise(0.8)), m)
    assert j_on.key == j_off.key
    # byte-identical CostReports
    rep_off = simulate(arch, wl_fn().set_sparsity(row_wise(0.8)), m)
    with obs.enabled(tmp_path / "run3"):
        rep_on = simulate(arch, wl_fn().set_sparsity(row_wise(0.8)), m)
    assert rep_on.to_json() == rep_off.to_json()

    # the recorded run produced the promised artifacts
    run_dir = tmp_path / "run"
    assert (run_dir / "manifest.json").exists()
    runs = list(obs.core.iter_runs(run_dir)) if hasattr(obs, "core") else []
    ecsv = run_dir / "energy_components.csv"
    assert ecsv.exists()
    header = ecsv.read_text().splitlines()[0]
    assert "component" in header and "energy_pj" in header
    spans = obs.read_events(run_dir, name="explore.evaluate_job")
    assert len(spans) == len(on.rows) + 1          # points + shared dense
    beats = obs.read_events(run_dir, name="explore.run.heartbeat")
    assert beats and beats[-1]["attrs"]["done"] == len(on.rows) + 1


def test_worker_processes_merge_into_parent_run(tmp_path):
    """Parallel evaluation lands worker events in the same trace dir."""
    arch = usecase_arch(4)
    m = default_mapping(arch)
    wl_fn = lambda: resnet18(32)  # noqa: E731
    with obs.enabled(tmp_path / "prun"):
        res = sparsity_sweep(arch, wl_fn, {}, ratios=RATIOS, mapping=m,
                             pattern_factory=_pattern_factory, workers=2)
    spans = obs.read_events(tmp_path / "prun", name="explore.evaluate_job")
    assert len(spans) == len(res.rows) + 1
    assert len({r["pid"] for r in spans}) >= 2     # >1 process recorded
    # sequential twin matches row for row (telemetry changed nothing)
    seq = sparsity_sweep(arch, wl_fn, {}, ratios=RATIOS, mapping=m,
                         pattern_factory=_pattern_factory, workers=1)
    assert res.rows == seq.rows


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_obs_cli_timeline_and_check(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main
    out = tmp_path / "trace.json"
    rc = obs_main(["timeline", "--model", "resnet18", "--policy",
                   "partitioned", "--out", str(out)])
    assert rc == 0
    assert obs_main(["check", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["otherData"]["policy"] == "partitioned"
    # corrupt it -> check fails
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": "nope"}))
    assert obs_main(["check", str(bad)]) == 1


def test_obs_cli_energy_and_report(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main
    csv_out = tmp_path / "energy.csv"
    rc = obs_main(["energy", "--model", "resnet18", "--ratio", "0.8",
                   "--csv", str(csv_out)])
    assert rc == 0
    assert csv_out.exists()
    capsys.readouterr()
    with obs.enabled(tmp_path / "rrun"):
        obs.event("x.y", n=1)
    assert obs_main(["report", str(tmp_path / "rrun")]) == 0
    out = capsys.readouterr().out
    assert "x.y" in out
    assert obs_main(["report", str(tmp_path / "missing")]) == 1
