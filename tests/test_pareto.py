"""Pareto/top-k tests: edge cases and the streaming-equivalence pin.

``ParetoFront`` / ``StreamingTopK`` exist so million-point sweeps never
hold all rows; their contract is exact equality with the one-shot
``pareto_front`` / ``top_k`` over the same stream.
"""
import math
import random

import pytest

from repro.explore import (ParetoFront, StreamingTopK, pareto_front, top_k)

OBJS = (("latency_ms", "min"), ("energy_uj", "min"))


def _row(lat, en, tag=None):
    r = {"latency_ms": lat, "energy_uj": en}
    if tag is not None:
        r["tag"] = tag
    return r


# ---------------------------------------------------------------------------
# one-shot edge cases
# ---------------------------------------------------------------------------

def test_front_empty_input():
    assert pareto_front([], OBJS) == []
    assert top_k([], "latency_ms") == []


def test_front_single_point():
    rows = [_row(1.0, 2.0)]
    assert pareto_front(rows, OBJS) == rows
    assert top_k(rows, "latency_ms") == rows


def test_front_ties_on_all_objectives_all_survive():
    rows = [_row(1.0, 2.0, t) for t in ("a", "b", "c")]
    assert pareto_front(rows, OBJS) == rows        # nobody dominates


def test_front_nan_rows_excluded():
    good = _row(1.0, 1.0, "good")
    rows = [_row(float("nan"), 0.5, "n1"), good,
            _row(0.1, float("nan"), "n2")]
    assert pareto_front(rows, OBJS) == [good]
    # top_k on latency: the NaN-latency row drops, the NaN-energy row
    # (finite latency 0.1) stays and sorts first
    assert top_k(rows, "latency_ms", 5) == [rows[2], good]


def test_front_inf_participates_normally():
    rows = [_row(float("inf"), 0.5, "i"), _row(1.0, 1.0, "f"),
            _row(float("inf"), 2.0, "dom")]
    # inf/0.5 survives (best energy); inf/2.0 is dominated by both
    assert pareto_front(rows, OBJS) == rows[:2]


def test_front_none_and_missing_excluded():
    good = _row(1.0, 1.0)
    rows = [{"latency_ms": None, "energy_uj": 0.1},
            {"energy_uj": 0.1}, good]
    assert pareto_front(rows, OBJS) == [good]


def test_front_max_direction():
    # with energy MAXimised, (1.0, 5.0) dominates (2.0, 3.0) outright
    rows = [_row(1.0, 5.0), _row(2.0, 3.0), _row(1.5, 1.0)]
    objs = (("latency_ms", "min"), ("energy_uj", "max"))
    assert pareto_front(rows, objs) == [rows[0]]


# ---------------------------------------------------------------------------
# streaming equivalence
# ---------------------------------------------------------------------------

def _random_rows(n, rng):
    rows = []
    for i in range(n):
        roll = rng.random()
        if roll < 0.05:
            rows.append(_row(float("nan"), rng.random(), i))
        elif roll < 0.08:
            rows.append(_row(rng.random(), None, i))
        elif roll < 0.11:
            rows.append({"energy_uj": rng.random(), "tag": i})
        elif roll < 0.16:
            rows.append(_row(float("inf"), rng.random(), i))
        elif roll < 0.30:
            rows.append(_row(0.5, 0.5, i))         # heavy duplicates
        else:
            rows.append(_row(round(rng.random(), 2),
                             round(rng.random(), 2), i))
    return rows


def test_streaming_front_equals_one_shot():
    rng = random.Random(7)
    rows = _random_rows(800, rng)
    inc = ParetoFront(OBJS)
    inc.extend(rows)
    assert inc.front() == pareto_front(rows, OBJS)
    assert len(inc) == len(pareto_front(rows, OBJS))
    assert inc.seen + inc.skipped == len(rows)


def test_streaming_front_chunked_feeding():
    rng = random.Random(11)
    rows = _random_rows(500, rng)
    inc = ParetoFront(OBJS)
    for i in range(0, len(rows), 37):
        inc.extend(rows[i:i + 37])
    assert inc.front() == pareto_front(rows, OBJS)


def test_streaming_front_add_return_value():
    inc = ParetoFront(OBJS)
    assert inc.add(_row(1.0, 1.0)) is True
    assert inc.add(_row(2.0, 2.0)) is False        # dominated
    assert inc.add(_row(0.5, 2.0)) is True         # trade-off
    assert inc.add(_row(float("nan"), 0.0)) is False


@pytest.mark.parametrize("direction", ["min", "max"])
@pytest.mark.parametrize("k", [0, 1, 5, 17])
def test_streaming_topk_equals_one_shot(direction, k):
    rng = random.Random(13)
    rows = _random_rows(600, rng)
    inc = StreamingTopK("latency_ms", k, direction=direction)
    inc.extend(rows)
    assert inc.best() == top_k(rows, "latency_ms", k, direction=direction)


def test_streaming_topk_tie_order_matches_stable_sort():
    rows = [_row(1.0, 0.0, t) for t in range(6)]
    for direction in ("min", "max"):
        inc = StreamingTopK("latency_ms", 3, direction=direction)
        inc.extend(rows)
        assert inc.best() == top_k(rows, "latency_ms", 3,
                                   direction=direction) == rows[:3]


def test_streaming_topk_rejects_bad_direction():
    with pytest.raises(ValueError):
        StreamingTopK("latency_ms", 3, direction="sideways")
