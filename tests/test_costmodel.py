"""Cost-model behaviour tests (paper §V + Table I validation setups)."""
import dataclasses

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import (OpNode, Workload, compare, default_mapping,
                        dense_baseline, hybrid, lm_workload, mars_arch,
                        resnet18, resnet50, row_block, row_wise, sdp_arch,
                        simulate, usecase_arch, vgg16)
from repro.core.flexblock import column_wise
from repro.core.workload import mobilenet_v2


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


def test_report_fields(arch4):
    wl = resnet18(32).set_sparsity(row_block(0.8))
    rep = simulate(arch4, wl, default_mapping(arch4))
    assert rep.latency_cycles > 0
    assert rep.total_energy_uj > 0
    assert 0.0 <= rep.utilization <= 1.0
    assert rep.index_storage_bits > 0
    assert set(rep.grouped_energy()) == {
        "cim_macro", "buffers", "pre_post", "sparsity", "static"}


def test_sparse_never_slower_than_dense(arch4):
    m = default_mapping(arch4, "duplicate")
    for pat in (row_wise(0.8), row_block(0.8), hybrid(2, 16, 0.8)):
        wl = resnet50(32).set_sparsity(pat)
        rep = simulate(arch4, wl, m)
        dense = dense_baseline(arch4, wl, m)
        c = compare(rep, dense)
        assert c["speedup"] >= 0.99, (pat.name, c)
        assert c["energy_saving"] >= 1.0, (pat.name, c)


@given(r=st.sampled_from([0.5, 0.6, 0.7, 0.8, 0.9]))
@settings(max_examples=5, deadline=None)
def test_energy_monotone_in_ratio(r):
    arch = usecase_arch(4)
    m = default_mapping(arch, "duplicate")
    wl_lo = resnet18(32).set_sparsity(row_wise(max(r - 0.2, 0.3)))
    wl_hi = resnet18(32).set_sparsity(row_wise(r))
    e_lo = simulate(arch, wl_lo, m).total_energy_uj
    e_hi = simulate(arch, wl_hi, m).total_energy_uj
    assert e_hi <= e_lo * 1.02


def test_input_sparsity_reduces_latency(arch4):
    arch = arch4.replace(input_sparsity_support=True)
    wl = resnet18(32).set_sparsity(row_wise(0.8))
    m = default_mapping(arch)
    base = simulate(arch, wl, m)
    skipped = simulate(arch, wl, m,
                       input_sparsity={op.name: 0.3 for op in wl.mvm_ops()})
    assert skipped.latency_cycles < base.latency_cycles


def test_duplication_improves_utilization(arch4):
    wl_fn = lambda: resnet50(32).set_sparsity(hybrid(2, 16, 0.8))
    sp = simulate(arch4, wl_fn(), default_mapping(arch4, "spatial"))
    dp = simulate(arch4, wl_fn(), default_mapping(arch4, "duplicate"))
    assert dp.utilization > sp.utilization


def test_rearrangement_improves_utilization():
    arch = usecase_arch(16)
    wl_fn = lambda: resnet50(32).set_sparsity(hybrid(2, 16, 0.8))
    m0 = default_mapping(arch, "spatial")
    m1 = default_mapping(arch, "spatial", rearrange="slice", slice_size=32)
    r0 = simulate(arch, wl_fn(), m0)
    r1 = simulate(arch, wl_fn(), m1)
    assert r1.utilization >= r0.utilization * 0.999


def test_mars_table1_setup():
    """MARS: conv-only scope, FullBlock(1,16), VGG/ResNet CIFAR."""
    arch = mars_arch()
    assert arch.macro.rows == 1024 and arch.macro.cols == 64
    assert arch.macro.sub_rows == 64 and arch.n_macros == 8
    m = default_mapping(arch, "duplicate")
    for wl_fn in (vgg16, resnet18):
        wl = wl_fn(32).set_sparsity(row_block(0.75, 16))
        rep = simulate(arch, wl, m)
        c = compare(rep, dense_baseline(arch, wl, m))
        # MARS reports ~2-4x speedup / ~2.5-4x energy saving at this
        # sparsity; the model must land in that regime
        assert 1.5 < c["speedup"] < 6.0, c
        assert 1.5 < c["energy_saving"] < 6.0, c


def test_sdp_table1_setup():
    """SDP: full-NN scope, Intra(2,1)+Full(2,8), ImageNet models."""
    arch = sdp_arch()
    assert arch.macro.sub_rows == 1 and arch.n_macros == 512
    assert arch.input_sparsity_support
    m = default_mapping(arch, "duplicate")
    wl = resnet18(224, 1000).set_sparsity(hybrid(2, 8, 0.75))
    rep = simulate(arch, wl, m)
    c = compare(rep, dense_baseline(arch, wl, m))
    assert 1.3 < c["speedup"] < 6.0, c
    assert 1.3 < c["energy_saving"] < 8.0, c


def test_index_capacity_flag(arch4):
    wl = vgg16(224, 1000).set_sparsity(hybrid(2, 16, 0.8))
    rep = simulate(arch4, wl, default_mapping(arch4))
    assert isinstance(rep.index_capacity_ok, bool)


def test_index_capacity_checked_in_bits(arch4):
    """``index_capacity_bits()`` already returns bits: the flag compares
    Eq. 8 storage against it directly.  The historical check multiplied
    the capacity by an unexplained 64, silently passing workloads up to
    64x over capacity — this pin would have caught it.
    """
    cap = arch4.index_capacity_bits()
    assert cap == 32 * 1024 * 8              # 32 KiB index memory

    # resnet50's row-block index stream lands between cap and 64*cap:
    # exactly the regime the old slack waved through
    over = simulate(arch4, resnet50(32).set_sparsity(row_block(0.8, 16)),
                    default_mapping(arch4))
    assert cap < over.index_storage_bits <= cap * 64
    assert over.index_capacity_ok is False

    # a genuinely small workload stays within capacity
    small = Workload("tiny")
    small.add(OpNode(name="fc", kind="fc", K=64, N=64, V=1, c_in=64,
                     sparsity=row_block(0.5, 16)))
    under = simulate(arch4, small, default_mapping(arch4))
    assert 0 < under.index_storage_bits <= cap
    assert under.index_capacity_ok is True

    # arches without an index memory never flag
    no_idx = arch4.replace(
        weight_sparsity_support=False,
        memory_units={k: v for k, v in arch4.memory_units.items()
                      if k != "index_mem"})
    rep = simulate(no_idx, resnet50(32).set_sparsity(row_block(0.8, 16)),
                   default_mapping(no_idx))
    assert rep.index_capacity_ok is True


def test_post_proc_traffic_scales_with_input_bits(arch4):
    """_other_op_cost buffer traffic is priced at macro.input_bits, so a
    4-bit arch moves half the post-proc bits of the 8-bit default."""
    wl_fn = lambda: Workload("act-only")  # noqa: E731
    wl8, wl4 = wl_fn(), wl_fn()
    for wl in (wl8, wl4):
        wl.add(OpNode(name="a", kind="act", elements=4096))
        wl.add(OpNode(name="e", kind="embed", elements=4096, inputs=("a",),
                      weight_count=0))
    arch8 = arch4
    arch4b = arch4.replace(
        macro=dataclasses.replace(arch4.macro, input_bits=4))
    m = default_mapping(arch8)
    r8 = simulate(arch8, wl8, m)
    r4 = simulate(arch4b, wl4, m)
    for buf in ("input_buf", "output_buf", "weight_buf"):
        assert r4.energy_pj[buf] == r8.energy_pj[buf] / 2, buf
    # latency is element-count-bound on the post-proc SIMD width: unchanged
    assert r4.latency_cycles == r8.latency_cycles


def test_attn_scores_v_formula_explicit():
    """Hand-computed regression for the lm_workload score-matmul volume:
    per head × layer × batch element, seq_len query vectors stream
    against K^T — V must be exactly heads × layers × batch × seq_len
    for every LM config, including odd batch × head counts."""
    from repro.configs import get_config, list_archs
    cases = [(128, 1), (48, 3), (17, 5)]
    for name in list_archs():
        cfg = get_config(name)
        if cfg.attention == "none":
            continue
        for seq_len, batch in cases:
            wl = lm_workload(cfg, seq_len=seq_len, batch=batch)
            sc = wl.nodes["attn_scores"]
            assert sc.K == cfg.head_dim
            assert sc.N == seq_len
            assert sc.V == cfg.n_heads * cfg.n_layers * batch * seq_len, \
                (name, seq_len, batch)
            # and the projections feed it: q/k inputs, per-token volume
            assert sc.inputs == ("attn_q", "attn_k")
            assert wl.nodes["attn_q"].V == seq_len * batch * cfg.n_layers


def test_lm_workload_lowering():
    from repro.configs import get_config
    cfg = get_config("llama3-8b")
    wl = lm_workload(cfg, seq_len=64, batch=1)
    names = set(wl.nodes)
    assert {"attn_q", "attn_o", "mlp_up", "mlp_down", "lm_head"} <= names
    assert wl.total_macs() > 0
    arch = usecase_arch(16)
    wl.set_sparsity(row_block(0.8))
    rep = simulate(arch, wl, default_mapping(arch, "duplicate"))
    assert rep.latency_cycles > 0


def test_moe_lm_workload():
    from repro.configs import get_config
    cfg = get_config("dbrx-132b")
    wl = lm_workload(cfg, seq_len=16, batch=1)
    up = wl.nodes["expert_up"]
    # weights stored for all experts, compute scaled by top_k
    assert up.weights == cfg.d_model * cfg.d_ff * 2 * cfg.n_experts
    assert up.V == 16 * cfg.n_layers * cfg.top_k


def test_depthwise_not_pruned():
    wl = mobilenet_v2(32).set_sparsity(row_wise(0.8))
    dw = [n for n in wl.nodes.values() if n.kind == "dwconv"]
    assert dw and all(n.sparsity.is_dense for n in dw)
