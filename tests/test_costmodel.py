"""Cost-model behaviour tests (paper §V + Table I validation setups)."""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import (compare, default_mapping, dense_baseline, hybrid,
                        lm_workload, mars_arch, resnet18, resnet50, row_block,
                        row_wise, sdp_arch, simulate, usecase_arch, vgg16)
from repro.core.flexblock import column_wise
from repro.core.workload import mobilenet_v2


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


def test_report_fields(arch4):
    wl = resnet18(32).set_sparsity(row_block(0.8))
    rep = simulate(arch4, wl, default_mapping(arch4))
    assert rep.latency_cycles > 0
    assert rep.total_energy_uj > 0
    assert 0.0 <= rep.utilization <= 1.0
    assert rep.index_storage_bits > 0
    assert set(rep.grouped_energy()) == {
        "cim_macro", "buffers", "pre_post", "sparsity", "static"}


def test_sparse_never_slower_than_dense(arch4):
    m = default_mapping(arch4, "duplicate")
    for pat in (row_wise(0.8), row_block(0.8), hybrid(2, 16, 0.8)):
        wl = resnet50(32).set_sparsity(pat)
        rep = simulate(arch4, wl, m)
        dense = dense_baseline(arch4, wl, m)
        c = compare(rep, dense)
        assert c["speedup"] >= 0.99, (pat.name, c)
        assert c["energy_saving"] >= 1.0, (pat.name, c)


@given(r=st.sampled_from([0.5, 0.6, 0.7, 0.8, 0.9]))
@settings(max_examples=5, deadline=None)
def test_energy_monotone_in_ratio(r):
    arch = usecase_arch(4)
    m = default_mapping(arch, "duplicate")
    wl_lo = resnet18(32).set_sparsity(row_wise(max(r - 0.2, 0.3)))
    wl_hi = resnet18(32).set_sparsity(row_wise(r))
    e_lo = simulate(arch, wl_lo, m).total_energy_uj
    e_hi = simulate(arch, wl_hi, m).total_energy_uj
    assert e_hi <= e_lo * 1.02


def test_input_sparsity_reduces_latency(arch4):
    arch = arch4.replace(input_sparsity_support=True)
    wl = resnet18(32).set_sparsity(row_wise(0.8))
    m = default_mapping(arch)
    base = simulate(arch, wl, m)
    skipped = simulate(arch, wl, m,
                       input_sparsity={op.name: 0.3 for op in wl.mvm_ops()})
    assert skipped.latency_cycles < base.latency_cycles


def test_duplication_improves_utilization(arch4):
    wl_fn = lambda: resnet50(32).set_sparsity(hybrid(2, 16, 0.8))
    sp = simulate(arch4, wl_fn(), default_mapping(arch4, "spatial"))
    dp = simulate(arch4, wl_fn(), default_mapping(arch4, "duplicate"))
    assert dp.utilization > sp.utilization


def test_rearrangement_improves_utilization():
    arch = usecase_arch(16)
    wl_fn = lambda: resnet50(32).set_sparsity(hybrid(2, 16, 0.8))
    m0 = default_mapping(arch, "spatial")
    m1 = default_mapping(arch, "spatial", rearrange="slice", slice_size=32)
    r0 = simulate(arch, wl_fn(), m0)
    r1 = simulate(arch, wl_fn(), m1)
    assert r1.utilization >= r0.utilization * 0.999


def test_mars_table1_setup():
    """MARS: conv-only scope, FullBlock(1,16), VGG/ResNet CIFAR."""
    arch = mars_arch()
    assert arch.macro.rows == 1024 and arch.macro.cols == 64
    assert arch.macro.sub_rows == 64 and arch.n_macros == 8
    m = default_mapping(arch, "duplicate")
    for wl_fn in (vgg16, resnet18):
        wl = wl_fn(32).set_sparsity(row_block(0.75, 16))
        rep = simulate(arch, wl, m)
        c = compare(rep, dense_baseline(arch, wl, m))
        # MARS reports ~2-4x speedup / ~2.5-4x energy saving at this
        # sparsity; the model must land in that regime
        assert 1.5 < c["speedup"] < 6.0, c
        assert 1.5 < c["energy_saving"] < 6.0, c


def test_sdp_table1_setup():
    """SDP: full-NN scope, Intra(2,1)+Full(2,8), ImageNet models."""
    arch = sdp_arch()
    assert arch.macro.sub_rows == 1 and arch.n_macros == 512
    assert arch.input_sparsity_support
    m = default_mapping(arch, "duplicate")
    wl = resnet18(224, 1000).set_sparsity(hybrid(2, 8, 0.75))
    rep = simulate(arch, wl, m)
    c = compare(rep, dense_baseline(arch, wl, m))
    assert 1.3 < c["speedup"] < 6.0, c
    assert 1.3 < c["energy_saving"] < 8.0, c


def test_index_capacity_flag(arch4):
    wl = vgg16(224, 1000).set_sparsity(hybrid(2, 16, 0.8))
    rep = simulate(arch4, wl, default_mapping(arch4))
    assert isinstance(rep.index_capacity_ok, bool)


def test_lm_workload_lowering():
    from repro.configs import get_config
    cfg = get_config("llama3-8b")
    wl = lm_workload(cfg, seq_len=64, batch=1)
    names = set(wl.nodes)
    assert {"attn_q", "attn_o", "mlp_up", "mlp_down", "lm_head"} <= names
    assert wl.total_macs() > 0
    arch = usecase_arch(16)
    wl.set_sparsity(row_block(0.8))
    rep = simulate(arch, wl, default_mapping(arch, "duplicate"))
    assert rep.latency_cycles > 0


def test_moe_lm_workload():
    from repro.configs import get_config
    cfg = get_config("dbrx-132b")
    wl = lm_workload(cfg, seq_len=16, batch=1)
    up = wl.nodes["expert_up"]
    # weights stored for all experts, compute scaled by top_k
    assert up.weights == cfg.d_model * cfg.d_ff * 2 * cfg.n_experts
    assert up.V == 16 * cfg.n_layers * cfg.top_k


def test_depthwise_not_pruned():
    wl = mobilenet_v2(32).set_sparsity(row_wise(0.8))
    dw = [n for n in wl.nodes.values() if n.kind == "dwconv"]
    assert dw and all(n.sparsity.is_dense for n in dw)
