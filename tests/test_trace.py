"""Differential tests for the trace→Workload lowering (repro.trace).

Three tiers:

* Golden-fixture replay (jax-free): the committed TraceGraph JSONs under
  ``tests/fixtures/trace/`` lower to Workloads that are bit-exact on MVM
  totals against the hand-built sibling DAGs, with the elementwise
  surplus pinned to an explicit constant so drift is visible.
* Live capture (needs jax): every LM config in :mod:`repro.configs` and
  the CNN references trace → lower → diff bit-exact, and the captured
  graph digest reproduces the committed fixture's.
* Property tests (hypothesis, via the shim): randomly shaped
  weight-chain graphs lower to DAGs whose MVM totals match the analytic
  closed form, topo-sort cleanly, and simulate under all three schedule
  policies with non-negative costs.
"""
import os
import warnings

import pytest

from _hypothesis_shim import given, settings, st

from repro.configs import get_config, list_archs
from repro.core import (OpNode, SchedulePolicy, Workload, default_mapping,
                        lm_workload, simulate, usecase_arch)
from repro.core.costmodel import op_class
from repro.core.schedule import POLICIES
from repro.core.workload import MODEL_BUILDERS
from repro.core import workload as workload_mod
from repro.trace import (TraceEqn, TraceGraph, TraceVar, diff_workloads,
                         lower_graph, summarize)

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures", "trace")

# digest prefixes of the committed golden graphs: capture determinism is
# part of the contract (same program + shapes → same content key)
FIXTURE_DIGESTS = {
    "lm_llama3-8b_forward.json": "c812a051528c1135",
    "lm_llama3-8b_prefill.json": "540084b77134e6a1",
    "lm_llama3-8b_decode.json": "30fef909e3451db8",
    "lm_dbrx-132b_forward.json": "3c6916efbbbd1f50",
    "cnn_resnet18_32.json": "4b1d3b0245052cc8",
}


def _fixture(name: str) -> TraceGraph:
    return TraceGraph.load(os.path.join(FIXTURE_DIR, name))


def _hand_for(graph: TraceGraph) -> Workload:
    meta = graph.meta
    if "config" in meta:
        return lm_workload(get_config(meta["config"]),
                           seq_len=int(meta["seq_len"]),
                           batch=int(meta["batch"]))
    return MODEL_BUILDERS[meta["model"]](int(meta["img"]),
                                         int(meta["num_classes"]))


# ---------------------------------------------------------------------------
# Golden-fixture replay (jax-free)
# ---------------------------------------------------------------------------

def test_fixture_set_is_committed():
    missing = [n for n in FIXTURE_DIGESTS if not
               os.path.exists(os.path.join(FIXTURE_DIR, n))]
    assert not missing, f"golden fixtures missing: {missing}"


@pytest.mark.parametrize("name", sorted(FIXTURE_DIGESTS))
def test_fixture_digest_stable(name):
    g = _fixture(name)
    assert g.digest().startswith(FIXTURE_DIGESTS[name])
    # serialisation round-trips content-identically
    assert TraceGraph.from_dict(g.to_dict()).digest() == g.digest()


@pytest.mark.parametrize("name", [n for n in sorted(FIXTURE_DIGESTS)
                                  if "decode" not in n])
def test_fixture_differential(name):
    traced = lower_graph(_fixture(name))
    d = diff_workloads(traced, _hand_for(_fixture(name)))
    assert d["mvm_match"], d
    assert d["total_weights_equal"], d


def test_llama3_forward_fixture_pinned():
    """The flagship fixture's totals, as explicit numbers: MVM macs and
    weights bit-exact vs the hand DAG, elementwise surplus pinned so a
    lowering change shows up as a diff of THIS constant."""
    traced = lower_graph(_fixture("lm_llama3-8b_forward.json"))
    hand = lm_workload(get_config("llama3-8b"), seq_len=8, batch=1)
    d = diff_workloads(traced, hand)
    assert d["traced"]["mvm_macs"] == 60_054_044_672
    assert d["traced"]["mvm_macs"] == d["hand"]["mvm_macs"]
    assert d["traced"]["mvm_weights"] == 743_440_384
    assert d["traced"]["mvm_weights"] == d["hand"]["mvm_weights"]
    assert d["elementwise_surplus"] == 13_459_520
    assert traced.source_digest.startswith("c812a051528c1135")


def test_dbrx_moe_fixture_pinned():
    d = diff_workloads(lower_graph(_fixture("lm_dbrx-132b_forward.json")),
                       _hand_for(_fixture("lm_dbrx-132b_forward.json")))
    assert d["traced"]["mvm_macs"] == 286_852_644_864
    assert d["mvm_match"] and d["total_weights_equal"]
    assert d["elementwise_surplus"] == 55_711_488


def test_resnet18_fixture_pinned():
    d = diff_workloads(lower_graph(_fixture("cnn_resnet18_32.json")),
                       _hand_for(_fixture("cnn_resnet18_32.json")))
    assert d["traced"]["mvm_macs"] == 555_468_800
    assert d["mvm_match"] and d["total_weights_equal"]
    assert d["elementwise_surplus"] == 492_032


def test_decode_fixture_lowers_and_orders():
    """Decode has no hand sibling (lm_workload models a full sequence);
    the contract is that it lowers, topo-sorts, and carries the KV-cache
    attention matmuls."""
    w = lower_graph(_fixture("lm_llama3-8b_decode.json"))
    order = w.topo_order()
    assert sorted(order) == sorted(w.nodes)
    assert w.levels()
    s = summarize(w)
    assert s["n_mvm"] > 0 and s["mvm_macs"] > 0
    kinds = {n.kind for n in w.nodes.values()}
    assert "matmul" in kinds and "fc" in kinds


@pytest.mark.parametrize("policy", POLICIES)
def test_traced_fixtures_simulate_under_every_policy(policy):
    """Traced DAGs run through the unmodified scheduler: every committed
    fixture simulates under all three policies with no warnings and
    strictly positive cost."""
    arch = usecase_arch(16)
    mapping = default_mapping(arch, "spatial")
    for name in sorted(FIXTURE_DIGESTS):
        w = lower_graph(_fixture(name))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            rep = simulate(arch, w, mapping, schedule=SchedulePolicy(policy))
        assert rep.latency_cycles > 0
        assert rep.total_energy_uj > 0


def test_partitioned_beats_monolithic_on_traced_cnn():
    """The traced resnet18 DAG has real branch concurrency the scheduler
    can exploit — partitioned must not be slower than monolithic."""
    arch = usecase_arch(16)
    mapping = default_mapping(arch, "spatial")
    lat = {}
    for pol in ("monolithic", "partitioned"):
        w = lower_graph(_fixture("cnn_resnet18_32.json"))
        lat[pol] = simulate(arch, w, mapping,
                            schedule=SchedulePolicy(pol)).latency_cycles
    assert lat["partitioned"] <= lat["monolithic"]


def test_source_digest_keys_the_explore_cache():
    from repro.explore.job import content_key

    g = _fixture("lm_llama3-8b_forward.json")
    w1, w2 = lower_graph(g), lower_graph(g)
    assert w1.source_digest == g.digest()
    assert content_key(w1) == content_key(w2)
    w2.source_digest = "0" * 64
    assert content_key(w1) != content_key(w2)
    # hand-built workloads (source_digest=None) still canonicalise
    hand = lm_workload(get_config("llama3-8b"), seq_len=8)
    assert hand.source_digest is None
    assert content_key(hand) != content_key(w1)


# ---------------------------------------------------------------------------
# Unknown-kind fallback (satellite regression)
# ---------------------------------------------------------------------------

def _one_off_workload(kind: str) -> Workload:
    w = Workload(f"oneoff-{kind}")
    w.fc("fc", 64, 64)
    w.simple("tail", kind, 4096, inputs=("fc",))
    return w


def test_unknown_kind_warns_once_and_prices_as_elementwise():
    arch = usecase_arch(4)
    mapping = default_mapping(arch, "spatial")
    workload_mod._warned_kinds.discard("frobnicate")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        odd = simulate(arch, _one_off_workload("frobnicate"), mapping)
    msgs = [str(x.message) for x in rec
            if issubclass(x.category, RuntimeWarning)]
    assert any("frobnicate" in m for m in msgs), msgs
    # an unknown kind is priced exactly like a known elementwise op of
    # the same element count — never silently free, never a crash
    act = simulate(arch, _one_off_workload("act"), mapping)
    assert odd.latency_cycles == act.latency_cycles
    assert odd.total_energy_uj == act.total_energy_uj
    # the warning fires once per kind per process
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        simulate(arch, _one_off_workload("frobnicate"), mapping)
    assert not [x for x in rec2 if "frobnicate" in str(x.message)]


def test_known_kinds_never_warn():
    arch = usecase_arch(4)
    mapping = default_mapping(arch, "spatial")
    for kind in sorted(workload_mod.OTHER_KINDS):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            simulate(arch, _one_off_workload(kind), mapping)


def test_weight_free_matmul_classes_as_attention():
    ctx = OpNode(name="ctx", kind="matmul", K=8, N=8, V=64,
                 prunable=False, weight_count=0)
    assert op_class(ctx) == "attention"
    mm = OpNode(name="proj", kind="matmul", K=8, N=8, V=64)
    assert op_class(mm) == "matmul"


# ---------------------------------------------------------------------------
# Live capture (needs jax)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("step", ["forward", "prefill"])
@pytest.mark.parametrize("config", list_archs())
def test_live_lm_differential(config, step):
    """Every LM config, traced live: MVM totals bit-exact vs the hand
    DAG — the acceptance criterion of the tracer."""
    pytest.importorskip("jax", exc_type=ImportError)
    from repro.trace import traced_workload

    traced = traced_workload(config, step=step, seq_len=8, batch=1)
    hand = lm_workload(get_config(config), seq_len=8, batch=1)
    d = diff_workloads(traced, hand)
    assert d["mvm_match"], (config, step, d)
    assert d["total_weights_equal"], (config, step, d)


@pytest.mark.parametrize("config", list_archs())
def test_live_decode_lowers_and_simulates(config):
    pytest.importorskip("jax", exc_type=ImportError)
    from repro.trace import traced_workload

    w = traced_workload(config, step="decode", seq_len=8, batch=1)
    assert sorted(w.topo_order()) == sorted(w.nodes)
    rep = simulate(usecase_arch(16), w,
                   default_mapping(usecase_arch(16), "spatial"))
    assert rep.latency_cycles > 0


@pytest.mark.parametrize("model", ["vgg16", "resnet18"])
def test_live_cnn_differential(model):
    pytest.importorskip("jax", exc_type=ImportError)
    from repro.trace import traced_cnn

    traced = traced_cnn(model, 32, 100)
    d = diff_workloads(traced, MODEL_BUILDERS[model](32, 100))
    assert d["mvm_match"], (model, d)
    assert d["total_weights_equal"], (model, d)
    if model == "vgg16":
        # the straight-line VGG reference folds perfectly: zero surplus
        assert d["elementwise_surplus"] == 0


def test_live_capture_reproduces_committed_digest():
    pytest.importorskip("jax", exc_type=ImportError)
    from repro.trace.capture import trace_model

    g = trace_model(get_config("llama3-8b"), step="forward",
                    seq_len=8, batch=1)
    assert g.digest() == _fixture("lm_llama3-8b_forward.json").digest()


def test_live_model_source_captures():
    """source='model' traces the real execution-plane transformer; the
    diff is informational (flash tiling reshapes the arithmetic), but the
    lowering itself must hold: MVM macs within a few percent of hand."""
    pytest.importorskip("jax", exc_type=ImportError)
    from repro.trace import traced_workload

    traced = traced_workload("llama3-8b", step="forward", seq_len=8,
                             batch=1, source="model")
    hand = lm_workload(get_config("llama3-8b"), seq_len=8, batch=1)
    ratio = traced.total_macs() / hand.total_macs()
    assert 0.9 < ratio < 1.2, ratio


# ---------------------------------------------------------------------------
# Property tests: random weight-chain graphs (hypothesis via the shim)
# ---------------------------------------------------------------------------

_EW_PRIMS = ("exp", "tanh", "logistic", "neg", "sqrt", "abs")


def _chain_graph(n_layers, d, seq, ew_tail):
    """A jaxpr-shaped graph: x(1,seq,d) through n_layers of
    dot_general(·, w_i(d,d)) each followed by ``ew_tail`` unary
    elementwise ops.  Closed-form totals: macs = n_layers·d²·seq,
    weights = n_layers·d²."""
    vars_ = {"x": TraceVar((1, seq, d), "float32")}
    weights, eqns, invars = {}, [], ["x"]
    cur = "x"
    for i in range(n_layers):
        wv = f"w{i}"
        vars_[wv] = TraceVar((d, d), "float32")
        weights[wv] = f"layer{i}/w"
        invars.append(wv)
        out = f"y{i}"
        vars_[out] = TraceVar((1, seq, d), "float32")
        eqns.append(TraceEqn("dot_general", [cur, wv], [out], params={
            "dimension_numbers": [[[2], [0]], [[], []]]}))
        cur = out
        for j, prim in enumerate(ew_tail):
            nxt = f"e{i}_{j}"
            vars_[nxt] = TraceVar((1, seq, d), "float32")
            eqns.append(TraceEqn(prim, [cur], [nxt]))
            cur = nxt
    return TraceGraph(name="prop-chain", invars=invars, outvars=[cur],
                      vars=vars_, eqns=eqns, weights=weights)


@given(n_layers=st.integers(1, 4), d=st.integers(4, 48),
       seq=st.integers(1, 16),
       ew_tail=st.lists(st.sampled_from(_EW_PRIMS), max_size=4))
@settings(max_examples=40, deadline=None)
def test_random_chain_lowers_to_closed_form(n_layers, d, seq, ew_tail):
    w = lower_graph(_chain_graph(n_layers, d, seq, tuple(ew_tail)))
    assert w.total_macs() == n_layers * d * d * seq
    assert w.total_weights() == n_layers * d * d
    assert sorted(w.topo_order()) == sorted(w.nodes)
    # folding preserves the elementwise volume exactly
    unfolded = lower_graph(_chain_graph(n_layers, d, seq, tuple(ew_tail)),
                           fold=False)
    assert (sum(n.elements for n in w.other_ops())
            == sum(n.elements for n in unfolded.other_ops())
            == n_layers * len(ew_tail) * seq * d)
    assert len(w.other_ops()) <= len(unfolded.other_ops())


@given(n_layers=st.integers(1, 3), d=st.integers(4, 32),
       seq=st.integers(1, 8),
       ew_tail=st.lists(st.sampled_from(_EW_PRIMS), max_size=2))
@settings(max_examples=12, deadline=None)
def test_random_chain_simulates_under_every_policy(n_layers, d, seq, ew_tail):
    arch = usecase_arch(4)
    mapping = default_mapping(arch, "spatial")
    for pol in POLICIES:
        w = lower_graph(_chain_graph(n_layers, d, seq, tuple(ew_tail)))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            rep = simulate(arch, w, mapping, schedule=SchedulePolicy(pol))
        assert rep.latency_cycles >= 0
        assert rep.total_energy_uj >= 0
        for oc in rep.op_costs:
            assert oc.latency_cycles >= 0 and oc.macs >= 0
