"""Row-serial macro cost model (SDP-style row-granular digital CIM).

Invariants (§Validation modeling findings):
* a workload that fits in ONE wave gets NO latency benefit from row
  pruning on a row-PARALLEL macro, but a proportional one on a
  row-SERIAL macro;
* IntraBlock compression on a row-serial macro saves energy but not
  time (double-broadcast streams both candidates);
* energy savings are row-count proportional in both modes.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.core import (Workload, compare, default_mapping, dense_baseline,
                        hybrid, row_wise, simulate)
from repro.core.hardware import CIMArch, MacroSpec
from repro.core.presets import default_compute_units, default_memory_units


def _arch(row_serial: bool) -> CIMArch:
    macro = MacroSpec(rows=32, cols=64, sub_rows=1, sub_cols=64,
                      load_rows_per_cycle=2, row_serial=row_serial)
    a = CIMArch(name=f"rs-{row_serial}", macro=macro, org=(4, 8),
                compute_units=default_compute_units(macro),
                memory_units=default_memory_units(
                    weight_kb=64, unified=True, ping_pong=True),
                clock_ghz=0.5, weight_sparsity_support=True,
                input_sparsity_support=False, eval_scope="all")
    a.validate()
    return a


def _small_fc() -> Workload:
    wl = Workload("one-wave-fc")
    # dense band demand = ceil(256/64 cols)·256 rows = 1024 = exactly the
    # 32 macros × 32 bands capacity → ONE wave even dense
    wl.fc("fc1", 256, 256, v=64)
    return wl


@pytest.mark.parametrize("row_serial,min_speedup,max_speedup", [
    (False, 0.95, 1.3),     # row-parallel: ~no latency benefit, one wave
    (True, 2.0, 5.0),       # row-serial: resident-row proportional
])
def test_row_pruning_speedup_regimes(row_serial, min_speedup, max_speedup):
    arch = _arch(row_serial)
    mapping = default_mapping(arch, "spatial")
    wl = _small_fc().set_sparsity(row_wise(0.75))
    rep = simulate(arch, wl, mapping)
    c = compare(rep, dense_baseline(arch, wl, mapping))
    assert min_speedup <= c["speedup"] <= max_speedup, c
    # energy always tracks the pruned row count (≈4× fewer MAC rows)
    assert c["energy_saving"] > 1.5


def test_intrablock_saves_energy_not_time_when_row_serial():
    arch = _arch(True)
    mapping = default_mapping(arch, "spatial")
    # pure 1:2 IntraBlock (no FullBlock component): rows halve, but the
    # double broadcast streams both candidates → latency ≈ dense
    from repro.core.flexblock import FlexBlockSpec, IntraBlock
    spec = FlexBlockSpec(patterns=(IntraBlock(2, 1, 0.5),))
    wl = _small_fc().set_sparsity(spec)
    rep = simulate(arch, wl, mapping)
    c = compare(rep, dense_baseline(arch, wl, mapping))
    assert c["speedup"] < 1.4, c                  # no real time win
    assert c["energy_saving"] > 1.3, c            # but real energy win
