"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.flexblock import IntraBlock
from repro.core.pruning import intrablock_mask
from repro.kernels import (bitserial_zero_profile, block_importance,
                           block_sparse_matmul, compress_fullblock,
                           compress_intrablock, decompress_intrablock,
                           intrablock_gather_matmul)
from repro.kernels import ref as R

RNG = np.random.default_rng(7)


def _random_fullblock(K, N, bm, bn, keep_frac=0.5):
    w = RNG.normal(size=(K, N)).astype(np.float32)
    keep = RNG.random((K // bm, N // bn)) < keep_frac
    keep[0, :] = True  # at least one block per column group
    mask = np.repeat(np.repeat(keep, bm, 0), bn, 1)
    return w, keep, (w * mask)


@pytest.mark.parametrize("K,N,bm,bn,B", [
    (128, 64, 32, 32, 8),
    (256, 128, 64, 64, 32),
    (512, 256, 128, 128, 16),
    (384, 128, 128, 64, 5),     # B not a multiple of the tile
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_block_sparse_matmul_sweep(K, N, bm, bn, B, dtype):
    w, keep, wm = _random_fullblock(K, N, bm, bn)
    w = np.asarray(jnp.asarray(w, dtype))
    wm = np.asarray(jnp.asarray(wm, dtype=jnp.float32))
    wc, idx = compress_fullblock(np.asarray(jnp.asarray(w, dtype)), keep, bm, bn)
    x = jnp.asarray(RNG.normal(size=(B, K)), dtype)
    dense = np.asarray(jnp.asarray(x, jnp.float32)) @ (
        np.asarray(jnp.asarray(w, jnp.float32))
        * np.repeat(np.repeat(keep, bm, 0), bn, 1))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    y_ref = R.block_sparse_matmul_ref(x, jnp.asarray(wc), jnp.asarray(idx))
    y_pal = block_sparse_matmul(x, jnp.asarray(wc), jnp.asarray(idx),
                                impl="pallas_interpret", tile_b=8)
    scale = max(np.abs(dense).max(), 1.0)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32) / scale,
                               dense / scale, atol=tol)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32) / scale,
                               dense / scale, atol=tol)


@pytest.mark.parametrize("K,N,m,B", [
    (64, 32, 2, 8), (128, 64, 4, 16), (256, 128, 8, 7),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_intrablock_gather_matmul_sweep(K, N, m, B, dtype):
    w = RNG.normal(size=(K, N)).astype(np.float32)
    ratio = (m - 1) / m
    mask = intrablock_mask(jnp.asarray(w), IntraBlock(m, 1, ratio),
                           align_cols=True)
    wc, ridx = compress_intrablock(w, mask, m)
    x = jnp.asarray(RNG.normal(size=(B, K)), dtype)
    dense = np.asarray(jnp.asarray(x, jnp.float32)) @ (w * mask)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    y_ref = R.intrablock_gather_matmul_ref(
        x, jnp.asarray(wc, dtype), jnp.asarray(ridx))
    y_pal = intrablock_gather_matmul(
        x, jnp.asarray(wc, dtype), jnp.asarray(ridx),
        impl="pallas_interpret", tile_b=8, tile_n=32)
    scale = max(np.abs(dense).max(), 1.0)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32) / scale,
                               dense / scale, atol=tol)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32) / scale,
                               dense / scale, atol=tol)


def test_compress_intrablock_rejects_unaligned():
    w = RNG.normal(size=(8, 4)).astype(np.float32)
    mask = intrablock_mask(jnp.asarray(w), IntraBlock(2, 1, 0.5))
    if np.all(mask.reshape(4, 2, 4) == mask.reshape(4, 2, 4)[:, :, :1]):
        pytest.skip("mask happened to be aligned")
    with pytest.raises(ValueError):
        compress_intrablock(w, mask, 2)
    # general path: masked-dense decompression is exact
    np.testing.assert_array_equal(decompress_intrablock(w, mask), w * mask)


@pytest.mark.parametrize("M,N,bm,bn", [
    (64, 64, 8, 8), (128, 256, 32, 16), (256, 128, 64, 128),
])
@pytest.mark.parametrize("crit", ["l1", "l2"])
def test_block_importance_sweep(M, N, bm, bn, crit):
    w = jnp.asarray(RNG.normal(size=(M, N)), jnp.float32)
    ref = R.block_importance_ref(w, bm, bn, crit)
    pal = block_importance(w, bm, bn, crit, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("V,K,g", [(16, 64, 16), (100, 96, 32), (128, 256, 64)])
def test_bitserial_profile_sweep(V, K, g):
    q = jnp.asarray(RNG.integers(-40, 40, size=(V, K)), jnp.int8)
    ref = np.asarray(R.bitserial_zero_profile_ref(q, g))
    pal = np.asarray(bitserial_zero_profile(q, g, impl="pallas_interpret"))
    np.testing.assert_array_equal(pal, ref)
    skippable, total = ref
    assert 0 <= skippable <= total


def test_bitserial_all_zero_input():
    q = jnp.zeros((8, 32), jnp.int8)
    s, t = np.asarray(R.bitserial_zero_profile_ref(q, 8))
    assert s == t  # everything skippable


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Sq,Skv,hd,tq,tk,causal,window", [
    (128, 128, 32, 32, 32, True, None),    # causal triangular skip
    (256, 256, 64, 64, 64, True, 64),      # sliding-window skip
    (128, 256, 32, 32, 64, False, None),   # cross/bidirectional
    (256, 256, 16, 128, 128, True, None),  # MXU-sized q tiles
    (128, 128, 32, 32, 32, False, 64),     # non-causal + window: completes
    (128, 128, 32, 32, 32, True, 64),      # the causal × window × dtype
                                           # regression cross vs ref.py
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(Sq, Skv, hd, tq, tk, causal, window, dtype):
    from repro.kernels import flash_attention
    from repro.kernels.ref import flash_attention_ref
    B, Hq, Hkv = 2, 4, 2
    q = jnp.asarray(RNG.normal(size=(B, Sq, Hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    y = flash_attention(q, k, v, causal=causal, window=window,
                        impl="pallas_interpret", tile_q=tq, tile_k=tk)
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * Hq, Skv, hd)
    vf = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * Hq, Skv, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    ref = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    ref = ref.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_matches_model_attention():
    """Kernel ≡ the execution-plane chunked_attention (same math)."""
    from repro.kernels import flash_attention
    from repro.models.layers import chunked_attention
    B, S, Hq, Hkv, hd = 2, 256, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    y_kernel = flash_attention(q, k, v, causal=True, window=64,
                               impl="pallas_interpret", tile_q=64, tile_k=64)
    y_model = chunked_attention(q, k, v, causal=True, window=64, chunk=64)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=5e-5)
