"""Elastic scaling: checkpoint/restore across different host topologies.

Checkpoints are host-side full-array snapshots, so a run may resume on a
different host count (elastic scale-up/down): the data pipeline reshards
its global batch by host id, and params/opt state are resharded by jit
on restore.  These tests verify (a) bitwise stream continuity of the
pipeline across host regrouping, and (b) loss-trajectory continuity of
a trainer restarted with a different pipeline sharding.
"""
from __future__ import annotations

import tempfile

import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def _cfg():
    return ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=128,
                      gated_mlp=True, attention="global")


def test_pipeline_host_streams_restartable_and_disjoint():
    """Each (seed, step, host) stream is bitwise restart-stable, and
    different hosts draw independent (non-identical) shards."""
    mk = lambda h, start=0: TokenPipeline(
        PipelineConfig(vocab_size=64, seq_len=16, global_batch=8, seed=3,
                       host_id=h, n_hosts=2), start_step=start)
    a0, a1 = mk(0), mk(1)
    b0_first = [a0.next_batch()["tokens"] for _ in range(3)]
    b1_first = [a1.next_batch()["tokens"] for _ in range(3)]
    # restart host 0 at step 1: bitwise identical continuation
    r0 = mk(0, start=1)
    np.testing.assert_array_equal(b0_first[1], r0.next_batch()["tokens"])
    np.testing.assert_array_equal(b0_first[2], r0.next_batch()["tokens"])
    # hosts are independent streams
    assert any(not np.array_equal(x, y) for x, y in zip(b0_first, b1_first))


def test_elastic_restart_changes_host_count():
    """Train 4 steps on a 1-host layout, resume on a 2-host layout from
    the checkpoint: training continues from the same step with finite,
    comparable losses (params restored exactly)."""
    cfg = _cfg()
    pcfg = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=0)
    with tempfile.TemporaryDirectory() as d:
        t1 = Trainer(cfg, AdamWConfig(lr=1e-3),
                     TrainerConfig(steps=4, ckpt_every=2, ckpt_dir=d,
                                   seed=0),
                     TokenPipeline(pcfg))
        log1 = t1.train()
        assert len(log1) == 4

        # "scale out": same global batch, now sharded as host 0 of 2
        pcfg2 = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4, seed=0, host_id=0, n_hosts=2)
        t2 = Trainer(cfg, AdamWConfig(lr=1e-3),
                     TrainerConfig(steps=6, ckpt_every=2, ckpt_dir=d,
                                   seed=0),
                     TokenPipeline(pcfg2))
        assert t2.start_step == 4                      # resumed
        # restored params match the step-4 snapshot exactly
        w1 = np.asarray(t1.params["layers"]["w_up"])
        w2 = np.asarray(t2.params["layers"]["w_up"])
        np.testing.assert_array_equal(w1, w2)
        log2 = t2.train()
        assert len(log2) == 2 and all(np.isfinite(m["loss"]) for m in log2)
