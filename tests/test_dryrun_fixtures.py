"""Dry-run HLO parsing against saved HLO-text fixtures, plus the full
ledger → ``load_ledger`` → ``analyze`` round trip.  Pure text + JSON —
no TPU/GPU (or even a working jax device) required."""
import json
from pathlib import Path

import pytest

FIXTURES = Path(__file__).parent / "fixtures"


def _hlo(name: str) -> str:
    return (FIXTURES / "hlo" / name).read_text()


def test_collective_bytes_mixed_fixture():
    from repro.launch.dryrun import collective_bytes

    out = collective_bytes(_hlo("collectives_mixed.txt"))
    # -done ops are counted at their -start; fusion over a collective-
    # named operand is not a collective.
    assert out["all-gather"] == 2048 * 512 * 2 + 8 * 128 * 2
    assert out["all-reduce"] == 4096 * 4 + 1000 * 4
    assert out["reduce-scatter"] == 512 * 128 * 4
    assert out["all-to-all"] == 2 * 256 * 4
    assert out["collective-permute"] == 4096 * 4
    assert out["count"] == 7


def test_collective_bytes_no_collectives_fixture():
    from repro.launch.dryrun import collective_bytes

    out = collective_bytes(_hlo("no_collectives.txt"))
    assert out["count"] == 0
    assert all(v == 0 for k, v in out.items() if k != "count")


@pytest.mark.parametrize("sig,expected", [
    ("bf16[8,128]{1,0}", 8 * 128 * 2),
    ("f32[4096]{0}", 4096 * 4),
    ("(f32[16]{0}, f32[16]{0})", 2 * 16 * 4),
    ("(bf16[2,3]{1,0}, s32[5]{0}, pred[7]{0})", 2 * 3 * 2 + 5 * 4 + 7),
    ("f8e4m3fn[10]{0}", 10),
    ("pred[]", 1),
    ("c64[4]{0}", 0),            # unknown dtype: skipped, not crashed
    ("token[]", 0),
    ("u64[3,3]{1,0}", 9 * 8),
])
def test_shape_bytes(sig, expected):
    from repro.launch.dryrun import _shape_bytes

    assert _shape_bytes(sig) == expected


def _ledger_record(**over):
    rec = {
        "arch": "llama3-8b", "cell": "train_4k", "mesh": "single",
        "tag": "", "chips": 256, "kind": "train", "seq_len": 4096,
        "global_batch": 256, "flops": 1.97e14, "bytes_accessed": 8.19e11,
        "collective_bytes": {"all-reduce": 5e10, "count": 3},
        "peak_bytes": 2 ** 30, "params": 8e9, "active_params": 8e9,
    }
    rec.update(over)
    return rec


def test_ledger_load_analyze_round_trip(tmp_path, capsys):
    """dryrun-style ledger → load_ledger → analyze, including dedup,
    error records, and skipped-line accounting."""
    from repro.launch.roofline import analyze, load_ledger

    path = tmp_path / "dryrun.jsonl"
    stale = _ledger_record(flops=1.0)        # superseded by the re-run
    err = _ledger_record(cell="decode_32k", error="RuntimeError: boom")
    with open(path, "w") as f:
        f.write(json.dumps(stale) + "\n")
        f.write("{definitely not json\n")
        f.write("\n")                         # blank lines are not errors
        f.write(json.dumps(err) + "\n")
        f.write(json.dumps(_ledger_record()) + "\n")

    recs = load_ledger(str(path))
    assert recs.skipped == 1 and recs.skipped_lines == [2]
    assert "skipped 1 undecodable" in capsys.readouterr().err
    # dedup keeps the LAST record per (arch, cell, mesh, tag)
    assert len(recs) == 2
    by_cell = {r["cell"]: r for r in recs}
    assert by_cell["train_4k"]["flops"] == 1.97e14

    rows = [analyze(r) for r in recs]
    good = [r for r in rows if r is not None]
    assert len(good) == 1 and rows.count(None) == 1   # error rec → None
    a = good[0]
    # default profile reproduces the legacy constants bit-for-bit
    assert a["t_compute_s"] == 1.97e14 / 197e12
    assert a["t_memory_s"] == 8.19e11 / 819e9
    assert a["t_collective_s"] == 5e10 / 50e9
    assert a["dominant"] in ("compute", "memory", "collective")


def test_analyze_with_custom_profile():
    from repro.calibrate import CalibrationProfile
    from repro.launch.roofline import analyze

    rec = _ledger_record()
    prof = CalibrationProfile(name="half", device="t", peak_flops=98.5e12,
                              hbm_bw=819e9, ici_bw=50e9)
    a0, a1 = analyze(rec), analyze(rec, prof)
    assert a1["t_compute_s"] == pytest.approx(2 * a0["t_compute_s"])
    assert a1["t_memory_s"] == a0["t_memory_s"]


def test_roofline_main_with_profile_flag(tmp_path, capsys):
    from repro.launch import roofline

    ledger = tmp_path / "l.jsonl"
    ledger.write_text(json.dumps(_ledger_record()) + "\n")
    prof_path = tmp_path / "p.json"
    from repro.calibrate import CalibrationProfile
    CalibrationProfile(name="half", device="t", peak_flops=98.5e12
                       ).save(prof_path)

    assert roofline.main(["--ledger", str(ledger), "--json"]) == 0
    rows_default = json.loads(capsys.readouterr().out)
    assert roofline.main(["--ledger", str(ledger), "--json",
                          "--profile", str(prof_path)]) == 0
    rows_half = json.loads(capsys.readouterr().out)
    assert rows_half[0]["t_compute_s"] == \
        pytest.approx(2 * rows_default[0]["t_compute_s"])
