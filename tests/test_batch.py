"""Batched-evaluation tests: the bit-identity contract.

The batched path (``repro.explore.batch``) may reorganise *how* work
executes — one costing pass per variant group, stacked tile-grid
precompute across a whole batch — but never *what* it computes: every
CostReport must equal the per-point ``evaluate_job`` result field for
field, and every result must land under the per-point cache key.
"""
import numpy as np
import pytest

from repro.calibrate.profile import resolve_profile
from repro.core import (TABLE_II_PATTERNS, default_mapping, hybrid,
                        usecase_arch)
from repro.core.mapping import (TileGridCache, precompute_tile_grids,
                                reference_loops, reshape_and_compress)
from repro.core.schedule import SchedulePolicy
from repro.core.workload import Workload
from repro.explore import (ExploreJob, FaultPlan, ResultCache, SweepRunner,
                           content_key, evaluate_batch, evaluate_job, faults,
                           group_jobs, job_keys, plan_batches)
from repro.explore.sweeps import GridPoint, run_grid

RATIO = 0.8


@pytest.fixture(scope="module")
def arch4():
    return usecase_arch(4)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


def small_wl():
    w = Workload("batchy")
    w.fc("fc1", 96, 96)
    w.fc("fc2", 96, 48, inputs=("fc1",))
    return w


def variant_jobs(arch, n_patterns=3):
    """Jobs spanning patterns × strategies × schedules × profiles — the
    variant axes (profile, schedule) group; the rest don't."""
    patterns = dict(list(TABLE_II_PATTERNS(RATIO, c_in=16).items())
                    [:n_patterns])
    prof = resolve_profile("default")
    jobs = []
    for name, spec in patterns.items():
        wl = small_wl().set_sparsity(spec)
        for strat in ("spatial", "duplicate"):
            m = default_mapping(arch, strat)
            for pol in (None, "partitioned", "resident"):
                sched = SchedulePolicy(policy=pol) if pol else None
                for p in (None, prof):
                    jobs.append(ExploreJob.simulate(arch, wl, m,
                                                    profile=p,
                                                    schedule=sched))
    return jobs


# ---------------------------------------------------------------------------
# keys and grouping
# ---------------------------------------------------------------------------

def test_job_keys_match_content_key(arch4):
    job = ExploreJob.simulate(arch4, small_wl().set_sparsity(
        hybrid(2, 16, RATIO)), default_mapping(arch4))
    full, base = job_keys(job)
    assert full == content_key(job) == job.key
    assert base != full                    # distinct "b" domain


def test_variants_share_base_key(arch4):
    wl = small_wl().set_sparsity(hybrid(2, 16, RATIO))
    m = default_mapping(arch4)
    j1 = ExploreJob.simulate(arch4, wl, m)
    j2 = ExploreJob.simulate(arch4, wl, m,
                             schedule=SchedulePolicy(policy="partitioned"))
    j3 = ExploreJob.simulate(arch4, wl, m,
                             profile=resolve_profile("default"))
    j4 = ExploreJob.simulate(arch4, wl, default_mapping(arch4, "duplicate"))
    keys = [job_keys(j) for j in (j1, j2, j3, j4)]
    assert len({k for k, _ in keys}) == 4          # full keys all distinct
    assert keys[0][1] == keys[1][1] == keys[2][1]  # variants share base
    assert keys[3][1] != keys[0][1]                # mapping change splits


def test_group_jobs_buckets_by_base_key(arch4):
    jobs = variant_jobs(arch4, n_patterns=2)
    groups = group_jobs(jobs)
    # 2 patterns × 2 strategies = 4 groups of 3 schedules × 2 profiles
    assert len(groups) == 4
    assert all(len(g) == 6 for g in groups)
    # no job lost or duplicated
    assert sorted(j.key for g in groups for j in g) \
        == sorted(j.key for j in jobs)


def test_plan_batches_never_splits_groups():
    groups = [[object()] * n for n in (3, 1, 3, 1, 5)]
    batches = plan_batches(groups, batch_size=4)
    flat = [g for b in batches for g in b]
    assert flat == groups                          # order preserved, whole
    assert [sum(len(g) for g in b) for b in batches] == [4, 4, 5]
    # an oversized group still ships whole, in its own batch
    big = [[object()] * 9]
    assert plan_batches(big, 4) == [big]


# ---------------------------------------------------------------------------
# bit-identity
# ---------------------------------------------------------------------------

def test_batched_reports_bit_identical(arch4):
    """The tentpole contract: evaluate_batch == evaluate_job, field for
    field, across patterns × strategies × schedules × profiles."""
    jobs = variant_jobs(arch4)
    groups = group_jobs(jobs)
    batched = evaluate_batch(groups)
    assert set(batched) == {j.key for j in jobs}
    for job in jobs:
        solo = evaluate_job(job)
        assert batched[job.key].to_dict() == solo.to_dict(), job.key


def test_precompute_tile_grids_bit_identical(arch4):
    """Stacked reduceat precompute produces the same TileGrids as the
    one-at-a-time path."""
    m = default_mapping(arch4)
    requests = []
    for spec in TABLE_II_PATTERNS(RATIO, c_in=16).values():
        wl = small_wl().set_sparsity(spec)
        for op in wl.nodes.values():
            if op.is_mvm:
                requests.append((op, arch4, m.reshape, None))
    warm = TileGridCache()
    precompute_tile_grids(requests, cache=warm)
    for op, arch, reshape, keep in requests:
        got = reshape_and_compress(op, arch, reshape, block_keep=keep,
                                   cache=warm)
        ref = reshape_and_compress(op, arch, reshape, block_keep=keep,
                                   cache=TileGridCache())
        np.testing.assert_array_equal(got.k_eff, ref.k_eff)
        np.testing.assert_array_equal(got.occupancy, ref.occupancy)
        np.testing.assert_array_equal(got.band_stats(arch.macro.sub_rows),
                                      ref.band_stats(arch.macro.sub_rows))


def test_precompute_noop_under_reference_loops(arch4):
    m = default_mapping(arch4)
    wl = small_wl().set_sparsity(hybrid(2, 16, RATIO))
    op = next(o for o in wl.nodes.values() if o.is_mvm)
    cache = TileGridCache()
    with reference_loops():
        out = precompute_tile_grids([(op, arch4, m.reshape, None)],
                                    cache=cache)
    assert out == {} and len(cache) == 0


# ---------------------------------------------------------------------------
# runner integration
# ---------------------------------------------------------------------------

def _grid_points(arch):
    prof = resolve_profile("default")
    points = []
    for name, spec in TABLE_II_PATTERNS(RATIO, c_in=16).items():
        wl = small_wl().set_sparsity(spec)
        for pol in ("monolithic", "partitioned"):
            sched = SchedulePolicy(policy=pol)
            for p in (None, prof):
                m = default_mapping(arch)
                job = ExploreJob.simulate(arch, wl, m, profile=p,
                                          schedule=sched)
                dense = ExploreJob.dense(arch, small_wl(), m, profile=p,
                                         schedule=sched)
                points.append(GridPoint(job, dense, meta=(
                    ("pattern", name), ("ratio", RATIO),
                    ("schedule", pol))))
    return points


def test_runner_batched_rows_equal_per_point(arch4):
    points = _grid_points(arch4)
    ref = run_grid(points, runner=SweepRunner(workers=1))
    for batch_size in (0, 3, 64):
        res = run_grid(points,
                       runner=SweepRunner(workers=1,
                                          batch_size=batch_size))
        assert res.rows == ref.rows, f"batch_size={batch_size}"
        assert res.stats.batched_points > 0
        assert res.stats.batches > 0
        assert "batched:" in res.stats.stats_text()


def test_runner_batched_parallel_equals_sequential(arch4):
    points = _grid_points(arch4)
    ref = run_grid(points, runner=SweepRunner(workers=1))
    runner = SweepRunner(workers=2, batch_size=8)
    try:
        res = run_grid(points, runner=runner)
    finally:
        runner.close()
    assert res.rows == ref.rows
    assert res.stats.batched_points > 0


def test_batched_results_share_per_point_cache_keys(arch4, tmp_path):
    """CIM207 behavioural half: a batched run fully warms the store a
    per-point run reads — batching never enters the key."""
    points = _grid_points(arch4)
    batched = run_grid(points, runner=SweepRunner(
        workers=1, batch_size=16, cache=ResultCache(tmp_path)))
    assert batched.stats.evaluated > 0
    replay = run_grid(points, runner=SweepRunner(
        workers=1, cache=ResultCache(tmp_path)))   # per-point, cold memory
    assert replay.stats.evaluated == 0
    assert replay.stats.disk_hits == replay.stats.unique
    assert replay.rows == batched.rows


def test_fault_in_batch_falls_back_to_per_point(arch4):
    """A fault anywhere in a batch fails the whole dispatch UNCHARGED:
    the per-point retry machinery then heals it, so surviving rows are
    bit-identical to a fault-free run."""
    points = _grid_points(arch4)
    ref = run_grid(points, runner=SweepRunner(workers=1))
    keys = {p.job.key for p in points} | {p.dense.key for p in points}
    # a seed whose plan injects at least one first-attempt exception
    for seed in range(200):
        plan = FaultPlan(seed=seed, exc=0.2, times=1)
        if any(plan.selected("exc", k) for k in keys):
            break
    else:
        raise AssertionError("no seed selects a key")
    faults.install(plan, export_env=False)
    try:
        res = run_grid(points, runner=SweepRunner(workers=1,
                                                  batch_size=16))
    finally:
        faults.uninstall()
    assert res.rows == ref.rows
