"""Launch-layer units that don't need the 512-device dry-run process:
collective-byte HLO parsing, input spec shapes, mesh construction on the
local device, roofline math."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import cells_for, get_config
from repro.configs.base import SHAPE_CELLS


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[4,4]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[16]{0}, f32[16]{0}) all-to-all(%p, %q)
  %cp = u32[10]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %other = f32[999]{0} add(%a, %b)
  %ags = bf16[64]{0} all-gather-start(%v)
  %agd = bf16[64]{0} all-gather-done(%ags)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2 + 64 * 2   # ag + ag-start
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 16 * 4
    assert out["all-to-all"] == 32 * 4
    assert out["collective-permute"] == 10 * 4
    assert out["count"] == 6


def test_input_specs_shapes():
    from repro.launch.dryrun import input_specs
    cfg = get_config("llama3-8b")
    cell = SHAPE_CELLS["train_4k"]
    specs = input_specs(cfg, cell)
    assert specs["batch"]["tokens"].shape == (256, 4096)
    cell_d = SHAPE_CELLS["decode_32k"]
    sd = input_specs(cfg, cell_d)
    assert sd["tokens"].shape == (128,)
    assert sd["cache"]["k"].shape == (32, 128, 32768, 8, 128)

    wcfg = get_config("whisper-medium")
    sw = input_specs(wcfg, SHAPE_CELLS["prefill_32k"])
    assert sw["enc_embed"].shape == (32, 1500, 1024)

    pcfg = get_config("paligemma-3b")
    sp = input_specs(pcfg, SHAPE_CELLS["train_4k"])
    assert sp["batch"]["prefix_embed"].shape == (256, 256, 2048)


def test_spec_for_param_divisibility_fallbacks():
    from repro.distributed.sharding import options, spec_for_param
    from jax.sharding import PartitionSpec as P
    # hymba: 25 q heads don't divide 16 → REPLICATE (never shard the
    # score-contraction head_dim — §Perf it1: hd-sharding on both sides
    # of the contraction forces score-matrix all-reduces)
    assert spec_for_param("wq", (32, 1600, 25, 64)) == P(None, None, None, None)
    assert spec_for_param("wq", (32, 4096, 32, 128)) == P(None, None, "model", None)
    # legacy mode keeps the old hd fallback for A/B runs
    with options(attn_kv_fallback="head_dim"):
        assert spec_for_param("wq", (32, 1600, 25, 64)) == \
            P(None, None, None, "model")
    # odd vocab → d_model sharding
    assert spec_for_param("embed", (51865, 1024)) == P(None, "model")
    assert spec_for_param("embed", (128256, 4096)) == P("model", None)
    # MoE experts expert-sharded
    assert spec_for_param("w_up", (40, 16, 6144, 10752)) == \
        P(None, "model", None, None)
    # FSDP adds a "data" axis on the largest free non-layer dim
    with options(fsdp=True):
        assert spec_for_param("w_up", (40, 16, 6144, 10752)) == \
            P(None, "model", None, "data")
        assert spec_for_param("embed", (128256, 4096)) == P("model", "data")


def test_cells_for_skips():
    assert "long_500k" not in cells_for(get_config("llama3-8b"))
    assert "long_500k" in cells_for(get_config("mamba2-130m"))


def test_roofline_math():
    from repro.launch.roofline import analyze
    rec = {
        "arch": "x", "cell": "train_4k", "mesh": "single", "tag": "",
        "chips": 256, "kind": "train", "seq_len": 4096, "global_batch": 256,
        "flops": 1.97e14, "bytes_accessed": 8.19e11,
        "collective_bytes": {"all-reduce": 5e10, "count": 3},
        "peak_bytes": 2 ** 30, "params": 8e9, "active_params": 8e9,
    }
    a = analyze(rec)
    assert abs(a["t_compute_s"] - 1.0) < 1e-6
    assert abs(a["t_memory_s"] - 1.0) < 1e-6
    assert abs(a["t_collective_s"] - 1.0) < 1e-6
    assert a["model_flops"] == 6 * 8e9 * 4096 * 256
    assert a["dominant"] in ("compute", "memory", "collective")


def test_make_local_mesh():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    assert set(mesh.axis_names) == {"data", "model"}


def test_tiny_lower_on_local_mesh():
    """End-to-end lower+compile of a reduced arch on the local 1-device
    mesh — the same code path the 512-device dry-run exercises."""
    from repro.launch.mesh import make_local_mesh
    from repro.runtime import compat
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.step import make_train_step
    from repro.models.transformer import init_params

    cfg = get_config("qwen3-4b").reduced()
    mesh = make_local_mesh()
    params = jax.eval_shape(
        lambda k: init_params(cfg, k, dtype=jnp.float32),
        jax.random.PRNGKey(0))
    opt = jax.eval_shape(adamw_init, params)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
    }
    step = make_train_step(cfg, AdamWConfig())
    with compat.set_mesh(mesh):
        lowered = jax.jit(step).lower(params, opt, batch)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_timed_execute_refeeds_donated_args():
    """`dryrun --execute` timing helper: donated args are re-fed from the
    step's outputs between repeats, warmup is excluded from the stats."""
    from repro.launch.dryrun import _timed_execute

    calls = []

    def fake_compiled(params, opt, batch):
        calls.append((params, opt, batch))
        return (params + 1, opt + 10, {"loss": 0.0})

    out = _timed_execute(fake_compiled, [0, 0, "batch"], repeats=3,
                         refeed=((0, 0), (1, 1)), block=lambda o: None)
    assert out["execute_repeats"] == 3
    assert out["time_s"] > 0.0
    assert out["time_s_median"] >= out["time_s"]
    # warmup + 3 timed calls; params/opt chain through the outputs
    assert [(c[0], c[1]) for c in calls] == [(0, 0), (1, 10), (2, 20), (3, 30)]
    assert all(c[2] == "batch" for c in calls)   # non-donated arg untouched


def test_timed_execute_zeros_materialisation_local():
    """_zeros_like_structs + _timed_execute against a real compiled fn on
    the local device — the --execute path minus the 512-device mesh."""
    from repro.launch.dryrun import _timed_execute, _zeros_like_structs

    def f(x, y):
        return (x @ y, x.sum())

    structs = (jax.ShapeDtypeStruct((8, 8), jnp.float32),
               jax.ShapeDtypeStruct((8, 8), jnp.float32))
    compiled = jax.jit(f).lower(*structs).compile()
    args = _zeros_like_structs(structs, compiled.input_shardings[0])
    assert args[0].shape == (8, 8)
    out = _timed_execute(compiled, args, repeats=2)
    assert out["execute_repeats"] == 2 and out["time_s"] > 0.0
