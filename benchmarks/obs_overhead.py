"""Observability overhead benchmark (jax-free, informational).

The ``repro.obs`` hooks are compiled into the explore hot path
(``evaluate_job`` spans, run heartbeats, energy-CSV emission), so their
cost when *disabled* — the default for every cache-keyed production
sweep — must stay negligible.  Rows:

* ``disabled/<entry>`` — ns per call of each disabled entry point
  (``span``/``counter``/``event``/``heartbeat.tick``); these are the
  no-op object paths every un-instrumented run pays.
* ``sweep/off`` / ``sweep/on`` — one identical mini sparsity sweep
  (fresh runner each, no shared cache) with recording off and on; the
  ``on`` row carries the end-to-end ``overhead_pct`` of *enabled*
  recording (file writes included — expected small but nonzero).
* ``overhead/disabled`` — the pinned number: estimated disabled-mode
  overhead as a fraction of the sweep, ``hook_calls x ns_per_call /
  sweep_wall``.  The acceptance bar is < 2 %; measured values sit
  around 0.01 %, so this row is an early-warning canary, not a tight
  gate.

The suite is new relative to the committed ``BENCH_baseline.json``, so
``compare.py`` reports it as informational until a refreshed baseline
lands.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict, List

from repro import obs
from repro.core import (TABLE_II_PATTERNS, default_mapping, resnet18,
                        usecase_arch)
from repro.explore import SweepRunner, sparsity_sweep

__all__ = ["run"]

_NOOP_REPEATS = 200_000
_RATIOS = (0.6, 0.7, 0.8)


def _pattern_factory(r):
    return TABLE_II_PATTERNS(r, c_in=16)


def _mini_sweep() -> float:
    """One cold mini sparsity sweep; returns wall seconds and the point
    count via the runner stats (fresh runner — no cross-run cache)."""
    arch = usecase_arch(4)
    runner = SweepRunner(workers=1)
    t0 = time.perf_counter()
    sparsity_sweep(arch, lambda: resnet18(32), {}, ratios=_RATIOS,
                   mapping=default_mapping(arch),
                   pattern_factory=_pattern_factory, runner=runner)
    return time.perf_counter() - t0, runner.stats.evaluated


def _noop_ns(fn) -> float:
    t0 = time.perf_counter()
    for _ in range(_NOOP_REPEATS):
        fn()
    return (time.perf_counter() - t0) / _NOOP_REPEATS * 1e9


def run() -> List[Dict]:
    obs.disable()
    rows: List[Dict] = []

    hb = obs.heartbeat("bench", total=1)
    entries = {
        "span": lambda: obs.span("bench.x", k=1),
        "counter": lambda: obs.counter("bench.c"),
        "event": lambda: obs.event("bench.e"),
        "heartbeat.tick": lambda: hb.tick(1),
    }
    ns: Dict[str, float] = {}
    for name, fn in entries.items():
        ns[name] = _noop_ns(fn)
        rows.append({"name": f"disabled/{name}",
                     "us_per_call": ns[name] / 1e3,
                     "ns_per_call": round(ns[name], 1)})

    # warm the process-wide tile-grid memo so off/on see the same cache
    # state (the first sweep in a process is always the cold one)
    _mini_sweep()
    off_s, evaluated = _mini_sweep()
    rows.append({"name": "sweep/off", "us_per_call": off_s * 1e6,
                 "wall_s": round(off_s, 4), "evaluated": evaluated})

    with tempfile.TemporaryDirectory() as td:
        with obs.enabled(Path(td) / "bench"):
            on_s, _ = _mini_sweep()
    rows.append({"name": "sweep/on", "us_per_call": on_s * 1e6,
                 "wall_s": round(on_s, 4),
                 "overhead_pct": round((on_s - off_s) / off_s * 100, 2)})

    # the pinned number: disabled-mode hook cost as a share of the sweep.
    # evaluate_job wraps each point in one span; the run loop ticks the
    # heartbeat once per point — 2 hook calls per evaluated point.
    hook_s = evaluated * (ns["span"] + ns["heartbeat.tick"]) / 1e9
    rows.append({"name": "overhead/disabled",
                 "us_per_call": hook_s * 1e6,
                 "pct_of_sweep": round(hook_s / off_s * 100, 4),
                 "budget_pct": 2.0})
    return rows
