"""Shared engine-accounting helpers for the exploration suites."""
from __future__ import annotations

from typing import Dict

from repro.core.mapping import default_tile_cache

__all__ = ["tile_cache_snapshot", "engine_stats_row"]


def tile_cache_snapshot() -> Dict[str, int]:
    """Counter snapshot of the process-wide tile-grid memo, taken before
    a suite runs so its stats row can report the delta."""
    return dict(default_tile_cache().stats())


def engine_stats_row(runner, tg0: Dict[str, int]) -> Dict:
    """The ``engine/stats`` row both exploration suites append.

    Tile-grid memo traffic is per-process, so the delta vs ``tg0`` is
    only reported for sequential runs — with worker fan-out the hits
    happen inside the pool and this process's counters would read a
    misleading 0/0.
    """
    s = runner.stats
    row = {
        "name": "engine/stats",
        "us_per_call": 0.0,
        "requested": s.requested,
        "unique": s.unique,
        "cache_hits": s.cache_hits,
        "evaluated": s.evaluated,
        "workers": s.workers,
        "wall_s": round(s.wall_s, 2),
    }
    if s.workers == 1:
        tg = default_tile_cache().stats()
        row["tile_grid_hits"] = tg["hits"] - tg0["hits"]
        row["tile_grid_misses"] = tg["misses"] - tg0["misses"]
    return row
