"""Perf-regression gate: diff a benchmark JSON summary against the
committed baseline.

``BENCH_baseline.json`` (repo root) is the pre-vectorization measurement
of ``python -m benchmarks.run --json`` — the reference the tentpole
speedup is certified against and the ceiling no commit may creep back
toward.  CI reruns the suites on every push and fails when any suite's
``wall_s`` regresses more than ``--max-regress`` (default 25%) over the
baseline; the per-suite delta table prints either way so the perf
trajectory is visible in green runs too.

Usage:
  python -m benchmarks.compare BENCH_baseline.json BENCH_run.json \
      [--max-regress 0.25] [--min-speedup 1.0] \
      [--require SUITE:ROW:FIELD>=MIN ...]

``--min-speedup`` optionally also asserts the current total is at least
that many times faster than the baseline total (e.g. ``--min-speedup 5``
certifies the tentpole's acceptance bar).

``--require`` gates an **absolute** number inside the current run — a
named field of a named row of a named suite must be >= the bound, e.g.
``--require "explore_scale:guided/halving:speedup>=10"`` certifies the
batched/guided exploration pipeline's 10x bar.  Absolute gates don't
need the suite to exist in the baseline (within-run ratios like
``speedup`` are machine-speed independent, which is exactly why they
gate this way); a missing suite/row/field fails the gate.

Suites present in the current run but absent from the baseline (a suite
added after the baseline was frozen, e.g. ``schedule``) are
**informational**: their rows print as ``NEW (informational)``, they are
excluded from the per-suite gate and from both totals, and they can
never fail the build — the gate stays green for new suites without
weakening the thresholds on the measured ones.  Re-freeze the baseline
(``python -m benchmarks.run --json BENCH_baseline.json --repeat 3``)
when a new suite should start gating.

Exit status: 0 = within budget, 1 = regression (or speedup bar missed),
2 = unusable inputs.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple


def _suite_walls(summary: Dict) -> Dict[str, float]:
    """Per-suite best wall seconds from a ``benchmarks.run`` summary.

    ``wall_s`` is already the min across ``--repeat`` runs for new
    summaries and the single-sample wall for old ones."""
    out = {}
    for name, s in summary.get("suites", {}).items():
        if s.get("ok") and isinstance(s.get("wall_s"), (int, float)):
            out[name] = float(s["wall_s"])
    return out


def parse_require(spec: str) -> Tuple[str, str, str, float]:
    """``"suite:row:field>=min"`` → ``(suite, row, field, min)``.

    The row name may contain ``/`` (benchmark rows do); only the two
    framing ``:`` and the ``>=`` are structural."""
    head, _, bound = spec.partition(">=")
    parts = head.split(":", 2)
    if not bound or len(parts) != 3 or not all(p.strip() for p in parts):
        raise ValueError(
            f"bad --require spec {spec!r}; want SUITE:ROW:FIELD>=MIN")
    try:
        minimum = float(bound)
    except ValueError:
        raise ValueError(f"bad --require bound in {spec!r}") from None
    suite, row, field = (p.strip() for p in parts)
    return suite, row, field, minimum


def check_requirements(current: Dict, requires: List[str]) -> List[str]:
    """Absolute-number gates against the current run's suite rows."""
    failures: List[str] = []
    suites = current.get("suites", {})
    for spec in requires:
        suite, row_name, field, minimum = parse_require(spec)
        s = suites.get(suite)
        if not s or not s.get("ok"):
            failures.append(f"require {spec!r}: suite {suite!r} "
                            f"missing or failed in current run")
            continue
        row = next((r for r in current.get("rows", [])
                    if r.get("suite") == suite
                    and r.get("name") == row_name), None)
        if row is None:
            failures.append(f"require {spec!r}: row {row_name!r} not in "
                            f"suite {suite!r}")
            continue
        val = row.get(field)
        if not isinstance(val, (int, float)):
            failures.append(f"require {spec!r}: field {field!r} missing "
                            f"or non-numeric (got {val!r})")
        elif val < minimum:
            failures.append(f"require {spec!r}: {val:g} < {minimum:g}")
        else:
            print(f"require OK: {suite}:{row_name}:{field} = {val:g} "
                  f">= {minimum:g}")
    return failures


def compare_summaries(baseline: Dict, current: Dict, *,
                      max_regress: float = 0.25,
                      min_speedup: Optional[float] = None,
                      ) -> Tuple[List[str], List[Dict]]:
    """Returns (failures, per-suite delta rows)."""
    base = _suite_walls(baseline)
    cur = _suite_walls(current)
    failures: List[str] = []
    rows: List[Dict] = []
    for name, b in sorted(base.items()):
        c = cur.get(name)
        row = {"suite": name, "baseline_s": b, "current_s": c}
        if c is None:
            failures.append(f"suite {name!r} missing/failed in current run")
            row["delta"] = "MISSING"
        else:
            delta = (c - b) / b if b > 0 else 0.0
            row["delta"] = f"{delta:+.1%}"
            row["speedup"] = f"{b / c:.2f}x" if c > 0 else "inf"
            if c > b * (1.0 + max_regress):
                failures.append(
                    f"suite {name!r} regressed {delta:+.1%} "
                    f"({b:.3f}s -> {c:.3f}s, budget +{max_regress:.0%})")
        rows.append(row)
    # Suites without a baseline have nothing to diff against: report
    # them, but keep them out of the gate and of both totals.
    for name in sorted(set(cur) - set(base)):
        rows.append({"suite": name, "baseline_s": None,
                     "current_s": cur[name],
                     "delta": "NEW (informational)"})

    b_tot = sum(base.values())
    c_tot = sum(cur.get(n, 0.0) for n in base if n in cur)
    rows.append({"suite": "TOTAL", "baseline_s": round(b_tot, 3),
                 "current_s": round(c_tot, 3),
                 "delta": f"{(c_tot - b_tot) / b_tot:+.1%}" if b_tot else "",
                 "speedup": f"{b_tot / c_tot:.2f}x" if c_tot else "inf"})
    if min_speedup is not None and c_tot > 0:
        if b_tot / c_tot < min_speedup:
            failures.append(
                f"total speedup {b_tot / c_tot:.2f}x below the required "
                f"{min_speedup:g}x bar ({b_tot:.3f}s -> {c_tot:.3f}s)")
    return failures, rows


def _print_table(rows: List[Dict]) -> None:
    cols = ("suite", "baseline_s", "current_s", "delta", "speedup")
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = "  ".join(c.ljust(widths[c]) for c in cols)
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", help="fresh benchmarks.run --json output")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="max tolerated per-suite slowdown vs baseline "
                         "(fraction, default 0.25 = +25%%)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="additionally require current total to be at "
                         "least this many times faster than baseline")
    ap.add_argument("--require", action="append", default=[],
                    metavar="SUITE:ROW:FIELD>=MIN",
                    help="absolute gate on a row field of the current "
                         "run (repeatable)")
    args = ap.parse_args(argv)
    try:
        for spec in args.require:
            parse_require(spec)
    except ValueError as e:
        print(f"compare: {e}", file=sys.stderr)
        return 2

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.current) as f:
            current = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"compare: cannot load inputs: {e}", file=sys.stderr)
        return 2
    if not _suite_walls(baseline):
        print("compare: baseline has no usable suite timings", file=sys.stderr)
        return 2

    failures, rows = compare_summaries(
        baseline, current, max_regress=args.max_regress,
        min_speedup=args.min_speedup)
    failures += check_requirements(current, args.require)
    _print_table(rows)
    if failures:
        print("\nPERF GATE FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nperf gate OK (budget: +{args.max_regress:.0%} per suite"
          + (f", >={args.min_speedup:g}x total" if args.min_speedup else "")
          + ")")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
