"""Benchmark harness — one module per paper table/figure.

  validation           paper Fig. 6 / Table I   (MARS & SDP targets)
  runtime_analysis     paper Fig. 7             (framework runtime)
  sparsity_exploration paper Fig. 8–10 / Tab II (§VII-B use-case)
  mapping_exploration  paper Fig. 11–12         (§VII-C use-case)

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--csv FILE]
                                                [--workers N]
Each row prints as ``name,us_per_call,<derived...>``.

``--workers`` fans the exploration suites (sparsity / mapping) out
across processes via the :mod:`repro.explore` engine; their
``engine/stats`` rows report cache-hit accounting either way.
"""
from __future__ import annotations

import argparse
import csv
import time
from typing import Dict, List

from . import (mapping_exploration, runtime_analysis, sparsity_exploration,
               validation)

SUITES = {
    "validation": validation.run,
    "runtime": runtime_analysis.run,
    "sparsity": sparsity_exploration.run,
    "mapping": mapping_exploration.run,
}

# suites built on the repro.explore engine accept a worker count
PARALLEL_SUITES = ("sparsity", "mapping")


def _fmt(row: Dict) -> str:
    head = f"{row['name']},{row.get('us_per_call', 0.0):.1f}"
    rest = ",".join(
        f"{k}={v}" for k, v in row.items()
        if k not in ("name", "us_per_call"))
    return head + ("," + rest if rest else "")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--csv", default=None, help="also write rows to CSV")
    ap.add_argument("--workers", type=int, default=1,
                    help="process count for the exploration suites "
                         "(default 1 = sequential; 0 = one per CPU)")
    args = ap.parse_args(argv)

    all_rows: List[Dict] = []
    names = [args.only] if args.only else list(SUITES)
    t_total = time.perf_counter()
    ok = True
    for name in names:
        print(f"== {name} ==", flush=True)
        t0 = time.perf_counter()
        try:
            if name in PARALLEL_SUITES:
                # 0 = one worker per CPU (SweepRunner's None default)
                rows = SUITES[name](workers=args.workers or None)
            else:
                rows = SUITES[name]()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"  SUITE FAILED: {type(e).__name__}: {e}", flush=True)
            ok = False
            continue
        for r in rows:
            r.setdefault("suite", name)
            print("  " + _fmt(r), flush=True)
        all_rows.extend(rows)
        print(f"  ({len(rows)} rows, {time.perf_counter() - t0:.1f}s)",
              flush=True)

    if args.csv and all_rows:
        keys: List[str] = []
        for r in all_rows:
            for k in r:
                if k not in keys:
                    keys.append(k)
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(all_rows)
        print(f"wrote {len(all_rows)} rows to {args.csv}")

    print(f"total: {len(all_rows)} rows in "
          f"{time.perf_counter() - t_total:.1f}s")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
