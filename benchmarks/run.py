"""Benchmark harness — one module per paper table/figure.

  validation           paper Fig. 6 / Table I   (MARS & SDP targets)
  runtime_analysis     paper Fig. 7             (framework runtime)
  sparsity_exploration paper Fig. 8–10 / Tab II (§VII-B use-case)
  mapping_exploration  paper Fig. 11–12         (§VII-C use-case)
  schedule_exploration paper §IV use-case 2     (multi-macro scheduling)
  traced_lm            traced-DAG pipeline      (fixture replay, jax-free)
  explore_scale        §VII scale pipeline      (per-point vs batched vs guided)

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--csv FILE]
                                                [--workers N] [--json [FILE]]
                                                [--repeat N]
Each row prints as ``name,us_per_call,<derived...>``.

``--workers`` fans the exploration suites (sparsity / mapping) out
across processes via the :mod:`repro.explore` engine; their
``engine/stats`` rows report cache-hit accounting either way.

``--repeat N`` runs every suite N times and reports min/median wall
seconds per suite (rows come from the first, cold, run) so CI perf
comparisons against ``BENCH_baseline.json`` aren't single-sample noise.
Note repeats share the process-wide tile-grid memo — the min is a warm
measurement by design.

``--json`` writes a machine-readable summary (default
``BENCH_run.json``): per-suite wall time + row counts and every
``us_per_call`` row — the artifact CI archives so the perf trajectory
across commits is a file diff, not log archaeology.  See
``docs/performance.md`` for the workflow around it.
"""
from __future__ import annotations

import argparse
import csv
import json
import statistics
import time
from typing import Dict, List

from . import (analysis_preflight, explore_scale, fault_overhead,
               mapping_exploration, obs_overhead, runtime_analysis,
               schedule_exploration, sparsity_exploration, traced_lm,
               validation)

SUITES = {
    "validation": validation.run,
    "runtime": runtime_analysis.run,
    "sparsity": sparsity_exploration.run,
    "mapping": mapping_exploration.run,
    "schedule": schedule_exploration.run,
    "traced_lm": traced_lm.run,
    "analysis": analysis_preflight.run,
    "obs": obs_overhead.run,
    "faults": fault_overhead.run,
    "explore_scale": explore_scale.run,
}

# suites built on the repro.explore engine accept a worker count
PARALLEL_SUITES = ("sparsity", "mapping", "schedule", "explore_scale")


def _fmt(row: Dict) -> str:
    head = f"{row['name']},{row.get('us_per_call', 0.0):.1f}"
    rest = ",".join(
        f"{k}={v}" for k, v in row.items()
        if k not in ("name", "us_per_call"))
    return head + ("," + rest if rest else "")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--csv", default=None, help="also write rows to CSV")
    ap.add_argument("--json", nargs="?", const="BENCH_run.json", default=None,
                    metavar="FILE",
                    help="write a JSON summary (per-suite wall time + "
                         "us_per_call rows); FILE defaults to BENCH_run.json")
    ap.add_argument("--workers", type=int, default=1,
                    help="process count for the exploration suites "
                         "(default 1 = sequential; 0 = one per CPU)")
    ap.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="run each suite N times; report min/median wall_s "
                         "(default 1)")
    args = ap.parse_args(argv)
    repeat = max(1, args.repeat)

    all_rows: List[Dict] = []
    suites_summary: Dict[str, Dict] = {}
    names = [args.only] if args.only else list(SUITES)
    t_total = time.perf_counter()
    ok = True
    for name in names:
        print(f"== {name} ==", flush=True)
        rows: List[Dict] = []
        walls: List[float] = []
        failed = False
        for rep_i in range(repeat):
            t0 = time.perf_counter()
            try:
                if name in PARALLEL_SUITES:
                    # 0 = one worker per CPU (SweepRunner's None default)
                    run_rows = SUITES[name](workers=args.workers or None)
                else:
                    run_rows = SUITES[name]()
            except Exception as e:  # noqa: BLE001 — report and continue
                print(f"  SUITE FAILED: {type(e).__name__}: {e}", flush=True)
                ok = False
                failed = True
                suites_summary[name] = {
                    "ok": False, "wall_s": round(time.perf_counter() - t0, 3),
                    "rows": 0, "error": f"{type(e).__name__}: {e}"}
                break
            walls.append(time.perf_counter() - t0)
            if rep_i == 0:
                rows = run_rows          # report the first (cold) run's rows
        if failed:
            continue
        for r in rows:
            r.setdefault("suite", name)
            print("  " + _fmt(r), flush=True)
        all_rows.extend(rows)
        suites_summary[name] = {
            "ok": True,
            "wall_s": round(min(walls), 3),
            "wall_s_median": round(statistics.median(walls), 3),
            "wall_s_runs": [round(w, 3) for w in walls],
            "rows": len(rows)}
        runs = "" if repeat == 1 else f", min of {repeat} runs"
        print(f"  ({len(rows)} rows, {min(walls):.1f}s{runs})", flush=True)

    if args.csv and all_rows:
        keys: List[str] = []
        for r in all_rows:
            for k in r:
                if k not in keys:
                    keys.append(k)
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(all_rows)
        print(f"wrote {len(all_rows)} rows to {args.csv}")

    total_s = time.perf_counter() - t_total
    if args.json:
        summary = {
            "ok": ok,
            "total_s": round(total_s, 3),
            # noise-resistant total: sum of per-suite best walls
            "total_wall_s": round(sum(s.get("wall_s", 0.0)
                                      for s in suites_summary.values()), 3),
            "repeat": repeat,
            "workers": args.workers,
            "suites": suites_summary,
            "rows": [{"suite": r.get("suite"), "name": r.get("name"),
                      "us_per_call": r.get("us_per_call", 0.0),
                      **{k: v for k, v in r.items()
                         if k not in ("suite", "name", "us_per_call")}}
                     for r in all_rows],
        }
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1, default=str)
            f.write("\n")
        print(f"wrote JSON summary to {args.json}")

    print(f"total: {len(all_rows)} rows in {total_s:.1f}s")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
