"""Paper Fig. 8–10 + Table II — sparsity exploitation analysis (§VII-B).

Reproduces the paper's first use-case on the §VII-A architecture
(4 macros of 1024×32 / 32×32 sub-arrays, 8-bit, shared input buffer):

* Fig. 8  — Table II patterns × sparsity ratios 0.5–0.9 on ResNet50:
            speedup / energy saving vs the dense baseline, plus an
            accuracy PROXY (fraction of |W| L1 mass the mask preserves —
            model training is out of scope offline, see DESIGN.md §2.3).
* Fig. 9a — block-size study at 80 %: sizes aligned with the optimal
            parallelism dims (16 broadcast / 32 accumulate) vs misaligned.
* Fig. 9b — cross-model study at 80 % (ResNet50 / VGG16 / MobileNetV2).
* Fig. 10 — input (bit-level) sparsity: dense-model gains and the
            interaction with weight-sparsity patterns and ratios.

Paper findings checked here: coarse patterns → higher efficiency, lower
accuracy proxy; hardware-aligned fine patterns balance both (Finding 1);
input sparsity adds 1.2–1.4× and amplifies coarse patterns.

All grid points run through the :mod:`repro.explore` engine on one
shared runner, so every section's dense baselines are computed once and
repeated configurations (e.g. Fig. 10's weight-only probes at specs
Fig. 9 already costed) are cache hits.  A final ``engine/stats`` row
reports the accounting.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import numpy as np

from repro.core import (TABLE_II_PATTERNS, column_block, compare,
                        default_mapping, flexblock_mask, hybrid,
                        mobilenet_v2, quantize_int8, resnet50, row_block,
                        skippable_bit_ratio, usecase_arch, vgg16)
from repro.explore import (ExploreJob, GridPoint, SweepRunner, run_grid,
                           sparsity_sweep)

from ._stats import engine_stats_row, tile_cache_snapshot

__all__ = ["run"]


@functools.lru_cache(maxsize=None)
def _l1_preserved(spec, shape=(512, 288), seed=0) -> float:
    """Accuracy proxy: share of |W| L1 mass kept by the pruning mask.

    Memoised — FlexBlock specs are frozen/hashable and several sections
    re-probe the same pattern at the same ratio."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(shape).astype(np.float32)
    mask = flexblock_mask(w, spec)
    tot = float(np.abs(w).sum())
    return float(np.abs(w * mask).sum()) / max(tot, 1e-9)


def _synthetic_skip(group_rows: int, zero_rate: float, *, seed: int = 0,
                    n: int = 4096) -> float:
    """Empirical skippable-bit ratio from realistic post-ReLU samples.

    The paper profiles dataset activations; pretrained CNN weights are
    unavailable offline, so we sample the canonical post-ReLU activation
    shape instead — half-normal magnitudes (heavy-tailed: high bit planes
    rarely set) gated by a Bernoulli(zero_rate) ReLU zero mask — then run
    the same int8-quantise → bit-plane → OR-across-rows pipeline.
    """
    rng = np.random.default_rng(seed)
    a = np.abs(rng.standard_normal((8, n)).astype(np.float32))
    a *= rng.random((8, n)) > zero_rate
    q = quantize_int8(a)
    return float(skippable_bit_ratio(q, group_rows))


def run(workers: Optional[int] = 1) -> List[Dict]:
    rows: List[Dict] = []
    arch = usecase_arch(4, input_sparsity=True)
    mapping = default_mapping(arch, "duplicate")
    runner = SweepRunner(workers=workers)
    tg0 = tile_cache_snapshot()

    # ---- Fig. 8: Table II patterns × ratios on ResNet50 -------------------
    result = sparsity_sweep(
        arch, lambda: resnet50(32), {},
        ratios=(0.5, 0.7, 0.8, 0.9),
        mapping=mapping,
        pattern_factory=lambda r: TABLE_II_PATTERNS(r, c_in=16),
        runner=runner,
    )
    grid = result.rows
    dt = result.stats.wall_s / max(len(grid), 1)
    for g in grid:
        spec = TABLE_II_PATTERNS(g["ratio"], c_in=16)[g["pattern"]]
        rows.append({
            "name": f"fig8/{g['pattern']}/r{g['ratio']}",
            "us_per_call": dt * 1e6,
            "speedup": round(g["speedup"], 3),
            "energy_saving": round(g["energy_saving"], 3),
            "utilization": round(g["utilization"], 3),
            "l1_preserved": round(_l1_preserved(spec), 4),
            "index_kib": round(g["index_kib"], 2),
        })

    # Finding 1 check: coarse (row-wise) beats fine (hybrid) on efficiency,
    # loses on the accuracy proxy, at the same ratio.
    by = {(g["pattern"], g["ratio"]): g for g in grid}
    coarse, fine = by[("row-wise", 0.8)], by[("1:2+row-block", 0.8)]
    rows.append({
        "name": "fig8/finding1",
        "us_per_call": 0.0,
        "coarse_speedup": round(coarse["speedup"], 3),
        "fine_speedup": round(fine["speedup"], 3),
        "coarse_l1": round(_l1_preserved(TABLE_II_PATTERNS(0.8, c_in=16)["row-wise"]), 4),
        "fine_l1": round(_l1_preserved(TABLE_II_PATTERNS(0.8, c_in=16)["1:2+row-block"]), 4),
        "holds": bool(coarse["speedup"] >= fine["speedup"]),
    })

    # ---- Fig. 9a: block sizes aligned vs misaligned at 80 % ---------------
    size_specs = {
        "row-block-16(aligned)": row_block(0.8, 16),
        "row-block-24(misaligned)": row_block(0.8, 24),
        "row-block-48(misaligned)": row_block(0.8, 48),
        "column-block-32(aligned)": column_block(0.8, 32),
        "column-block-48(misaligned)": column_block(0.8, 48),
        "hybrid-1:2+rb16": hybrid(2, 16, 0.8),
        "hybrid-1:4+rb16": hybrid(4, 16, 0.8),
    }
    points = [
        GridPoint(
            ExploreJob.simulate(arch, resnet50(32).set_sparsity(spec), mapping),
            ExploreJob.dense(arch, resnet50(32), mapping),
            meta=(("pattern", name), ("ratio", 0.8)),
        )
        for name, spec in size_specs.items()
    ]
    res9a = run_grid(points, runner=runner)
    dt = res9a.stats.wall_s / max(len(res9a.rows), 1)
    for g in res9a.rows:
        rows.append({
            "name": f"fig9a/{g['pattern']}",
            "us_per_call": dt * 1e6,
            "speedup": round(g["speedup"], 3),
            "energy_saving": round(g["energy_saving"], 3),
            "utilization": round(g["utilization"], 3),
            "l1_preserved": round(_l1_preserved(size_specs[g["pattern"]]), 4),
        })

    # ---- Fig. 9b: across models at 80 % -----------------------------------
    # VGG16 FC layers and MobileNetV2 depthwise convs are pruning-hostile
    # (paper restricts pruning to standard convs there) → conv-only scope.
    points = []
    for mname, wl_fn, scope in (
            ("resnet50", lambda: resnet50(32), "all"),
            ("vgg16", lambda: vgg16(32), "conv_only"),
            ("mobilenetv2", lambda: mobilenet_v2(32), "conv_only")):
        spec = hybrid(2, 16, 0.8)
        kinds = ("conv",) if scope == "conv_only" else ("conv", "fc", "matmul")
        wl = wl_fn().set_sparsity(spec, kinds=kinds)
        points.append(GridPoint(
            ExploreJob.simulate(arch, wl, mapping),
            ExploreJob.dense(arch, wl_fn(), mapping),
            meta=(("pattern", mname), ("scope", scope)),
        ))
    res9b = run_grid(points, runner=runner)
    dt = res9b.stats.wall_s / max(len(res9b.rows), 1)
    for g in res9b.rows:
        rows.append({
            "name": f"fig9b/{g['pattern']}",
            "us_per_call": dt * 1e6,
            "speedup": round(g["speedup"], 3),
            "energy_saving": round(g["energy_saving"], 3),
            "scope": g["scope"],
        })

    # ---- Fig. 10: input sparsity ------------------------------------------
    # Dense models + input sparsity: paper reports 1.2–1.4×.  Raw jobs via
    # the runner: each point needs rep-vs-dense AND rep-vs-rep comparisons.
    jobs = []
    model_zr = (("resnet50", lambda: resnet50(32), 0.45),
                ("vgg16", lambda: vgg16(32), 0.40),
                ("mobilenetv2", lambda: mobilenet_v2(32), 0.35))
    for mname, wl_fn, zr in model_zr:
        wl = wl_fn()
        sr = _synthetic_skip(arch.macro.sub_rows, zr)
        skip = {op.name: sr for op in wl.mvm_ops()}
        jobs.append(ExploreJob.simulate(arch, wl, mapping, input_sparsity=skip))
        jobs.append(ExploreJob.dense(arch, wl_fn(), mapping))
    reports = runner.run(jobs)
    dt = runner.last_stats.wall_s / max(runner.last_stats.requested, 1)
    for i, (mname, _, _) in enumerate(model_zr):
        c = compare(reports[2 * i], reports[2 * i + 1])
        rows.append({
            "name": f"fig10/dense+{mname}",
            "us_per_call": dt * 1e6,
            "speedup": round(c["speedup"], 3),
            "energy_saving": round(c["energy_saving"], 3),
            "in_band_1.2_1.4": bool(1.05 <= c["speedup"] <= 1.6),
        })

    # weight patterns × input sparsity at 80 % (coarse skips more: the
    # skippable ratio shrinks as more rows share one array row)
    pat_cfg = (("column-wise", TABLE_II_PATTERNS(0.8, c_in=16)["column-wise"], 1.0),
               ("row-block", row_block(0.8, 16), 1.0),
               ("1:2+row-block", hybrid(2, 16, 0.8), 2.0))
    jobs = []
    for pname, spec, group_mult in pat_cfg:
        wl = resnet50(32).set_sparsity(spec)
        # IntraBlock routing broadcasts ``intra.m`` inputs per row → the
        # effective OR-group widens, shrinking the skippable ratio
        sr = _synthetic_skip(int(arch.macro.sub_rows * group_mult), 0.45)
        skip = {op.name: sr for op in wl.mvm_ops()}
        jobs.append(ExploreJob.simulate(arch, wl, mapping))
        jobs.append(ExploreJob.simulate(arch, wl, mapping, input_sparsity=skip))
        jobs.append(ExploreJob.dense(arch, resnet50(32), mapping))
    reports = runner.run(jobs)
    for i, (pname, _, _) in enumerate(pat_cfg):
        rep_w, rep_wi, dense_m = reports[3 * i:3 * i + 3]
        cw, cwi = compare(rep_w, dense_m), compare(rep_wi, dense_m)
        rows.append({
            "name": f"fig10/weight+input/{pname}",
            "us_per_call": 0.0,
            "speedup_w": round(cw["speedup"], 3),
            "speedup_wi": round(cwi["speedup"], 3),
            "input_gain": round(cwi["speedup"] / max(cw["speedup"], 1e-9), 3),
        })

    # input-sparsity gain across weight ratios (row-wise)
    jobs = []
    for ratio in (0.5, 0.7, 0.9):
        spec = TABLE_II_PATTERNS(ratio, c_in=16)["row-wise"]
        wl = resnet50(32).set_sparsity(spec)
        # sparser models shift activation stats toward more zero bits
        zr = 0.40 + 0.10 * ratio
        sr = _synthetic_skip(arch.macro.sub_rows, zr)
        skip = {op.name: sr for op in wl.mvm_ops()}
        jobs.append(ExploreJob.simulate(arch, wl, mapping))
        jobs.append(ExploreJob.simulate(arch, wl, mapping, input_sparsity=skip))
        jobs.append(ExploreJob.dense(arch, resnet50(32), mapping))
    reports = runner.run(jobs)
    for i, ratio in enumerate((0.5, 0.7, 0.9)):
        rep_w, rep_wi, dense_m = reports[3 * i:3 * i + 3]
        gain = compare(rep_wi, dense_m)["speedup"] / \
            max(compare(rep_w, dense_m)["speedup"], 1e-9)
        rows.append({
            "name": f"fig10/ratio/r{ratio}",
            "us_per_call": 0.0,
            "input_gain": round(gain, 3),
        })

    rows.append(engine_stats_row(runner, tg0))
    return rows
