"""Multi-macro schedule exploration (paper §IV, use-case 2).

Sweeps the scheduling policies of :mod:`repro.core.schedule` over
workloads with real inter-op concurrency and reports what each policy
buys:

* ``policy/<wl>/<policy>`` — ResNet-18 (shortcut convs) and a lowered
  LM block (attention Q/K/V fan-out) under monolithic / partitioned /
  resident scheduling: absolute latency, speedup vs monolithic, achieved
  concurrency, the critical-path share of the makespan, and whether the
  partitioned accounting identity held (dynamic energy bit-identical to
  monolithic — the policy only reshuffles time).
* ``resident/<wl>/inv<N>`` — weight-residency amortisation: a
  band-fitting MLP stack re-invoked N times (decode steps); resident
  pays its load waves once, so its speedup over monolithic grows with N
  while the weight-buffer energy stays pinned at the 1-invocation cost.

All points run through the :mod:`repro.explore` engine on one shared
runner (the schedule policy is part of each job's content key), so the
suite also exercises the scheduler's cache plumbing; the final
``engine/stats`` row reports the accounting.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import (OpNode, SchedulePolicy, Workload, default_mapping,
                        lm_workload, resnet18, row_block, usecase_arch)
from repro.core.schedule import POLICIES
from repro.explore import ExploreJob, SweepRunner

from ._stats import engine_stats_row, tile_cache_snapshot

__all__ = ["run"]


def _mlp_stack(depth: int = 3, width: int = 512) -> Workload:
    """A band-fitting fc stack: the resident policy's home turf (every
    op single-wave, aggregate band demand within one 16-macro org)."""
    wl = Workload(f"mlp{depth}x{width}")
    prev = ()
    for i in range(depth):
        wl.add(OpNode(name=f"fc{i}", kind="fc", K=width, N=width, V=64,
                      c_in=width, inputs=prev,
                      sparsity=row_block(0.8, 16)))
        prev = (f"fc{i}",)
    return wl


def _dyn_energy(rep) -> Dict[str, float]:
    return {k: v for k, v in rep.energy_pj.items() if k != "static"}


def run(workers: Optional[int] = 1) -> List[Dict]:
    rows: List[Dict] = []
    runner = SweepRunner(workers=workers)
    tg0 = tile_cache_snapshot()
    spec = row_block(0.8, 16)

    # ---- policies × workloads with independent branches -------------------
    # whisper-medium's d_model=1024 projections are single-wave on the
    # 16-macro org (~0.5 share each), so the attention Q/K/V fan-out has
    # real overlap headroom; billion-parameter configs are multi-wave on
    # every op and partitioned degenerates to monolithic there.
    arch4 = usecase_arch(4)
    from repro.configs import get_config
    cfg = get_config("whisper-medium")
    cases = (
        ("resnet18", arch4, lambda: resnet18(32).set_sparsity(spec)),
        ("lm-whisper", usecase_arch(16),
         lambda: lm_workload(cfg, seq_len=32).set_sparsity(spec)),
    )
    for wl_name, arch, wl_fn in cases:
        mapping = default_mapping(arch, "spatial")
        jobs = [ExploreJob.simulate(arch, wl_fn(), mapping,
                                    schedule=SchedulePolicy(policy=pol))
                for pol in POLICIES]
        reports = runner.run(jobs)
        dt = runner.last_stats.wall_s / max(len(jobs), 1)
        mono = reports[0]
        for pol, rep in zip(POLICIES, reports):
            s = rep.schedule
            row = {
                "name": f"policy/{wl_name}/{pol}",
                "us_per_call": dt * 1e6,
                "latency_ms": round(rep.latency_ms, 4),
                "vs_monolithic": round(
                    mono.latency_cycles / max(rep.latency_cycles, 1e-9), 3),
                "concurrency": round(s.concurrency, 3),
                "cp_frac": round(s.critical_path_cycles
                                 / max(s.makespan_cycles, 1e-9), 3),
            }
            if pol == "partitioned":
                row["dyn_identical"] = _dyn_energy(rep) == _dyn_energy(mono)
            if pol == "resident":
                row["resident"] = s.resident
            rows.append(row)

    # ---- weight-residency amortisation across invocations -----------------
    arch16 = usecase_arch(16)
    mapping = default_mapping(arch16, "spatial")
    wl_fn = _mlp_stack
    for inv in (1, 8, 64):
        jobs = [ExploreJob.simulate(
                    arch16, wl_fn(), mapping,
                    schedule=SchedulePolicy(policy=pol, invocations=inv))
                for pol in ("monolithic", "resident")]
        mono, res = runner.run(jobs)
        dt = runner.last_stats.wall_s / max(len(jobs), 1)
        rows.append({
            "name": f"resident/{wl_fn().name}/inv{inv}",
            "us_per_call": dt * 1e6,
            "amortised_speedup": round(
                mono.latency_cycles / max(res.latency_cycles, 1e-9), 3),
            "preload_cycles": res.schedule.preload_cycles,
            "wbuf_energy_ratio": round(
                mono.energy_pj["weight_buf"]
                / max(res.energy_pj["weight_buf"], 1e-9), 3),
            "resident": res.schedule.resident,
        })

    rows.append(engine_stats_row(runner, tg0))
    return rows
