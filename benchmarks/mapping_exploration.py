"""Paper Fig. 11–12 — mapping strategy exploration (§VII-C).

Second use-case: 16 macros (same per-macro spec as §VII-A) across the
organisations 8×2 / 4×4 / 2×8, comparing *spatial* weight-unroll mapping
against *weight duplication* on ResNet50 (Conv-dominated) and VGG16
(FC-parameter-dominated), then the effect of compressed-weight
REARRANGEMENT (equalising ragged compressed matrices) on the hybrid
IntraBlock(2,1)+FullBlock(2,16) pattern at the 4×4 organisation.

Paper findings checked: duplication lifts utilisation up to ~7.7× for
Conv-dominated models and the balanced 4×4 organisation is best
(Finding 2); rearrangement raises utilisation but can trade energy for
buffer-access overhead.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (compare, default_mapping, dense_baseline, hybrid,
                        resnet50, simulate, sweep_mappings, usecase_arch,
                        vgg16)

__all__ = ["run"]

ORGS = ((8, 2), (4, 4), (2, 8))


def run() -> List[Dict]:
    rows: List[Dict] = []
    spec = hybrid(2, 16, 0.8)

    # ---- Fig. 11: strategy × organisation × model --------------------------
    for mname, wl_fn in (("resnet50", lambda: resnet50(32)),
                         ("vgg16", lambda: vgg16(32))):
        t0 = time.perf_counter()
        grid = sweep_mappings(
            lambda org: usecase_arch(16, org), wl_fn, spec,
            orgs=ORGS, strategies=("spatial", "duplicate"))
        dt = (time.perf_counter() - t0) / max(len(grid), 1)
        for g in grid:
            rows.append({
                "name": f"fig11/{mname}/{g['org']}/{g['mapping']}",
                "us_per_call": dt * 1e6,
                "latency_ms": round(g["latency_ms"], 4),
                "energy_uj": round(g["energy_uj"], 2),
                "utilization": round(g["utilization"], 4),
                "speedup": round(g["speedup"], 3),
            })

        # utilisation lift from duplication per org
        by = {(g["org"], g["mapping"]): g for g in grid}
        for org in ORGS:
            o = f"{org[0]}x{org[1]}"
            lift = by[(o, "duplicate")]["utilization"] / \
                max(by[(o, "spatial")]["utilization"], 1e-9)
            rows.append({
                "name": f"fig11/{mname}/{o}/dup_util_lift",
                "us_per_call": 0.0,
                "lift": round(lift, 2),
            })

    # Finding 2 (part 1): for the Conv-dominated model, duplication helps
    # and 4×4 is the best organisation; for FC-heavy VGG16 the benefit
    # shrinks (less weight reuse).
    g_r = sweep_mappings(lambda org: usecase_arch(16, org),
                         lambda: resnet50(32), spec, orgs=ORGS,
                         strategies=("duplicate",))
    best = min(g_r, key=lambda g: g["latency_ms"])
    rows.append({
        "name": "fig11/finding2/best_org_resnet50",
        "us_per_call": 0.0,
        "best_org": best["org"],
        "latency_ms": round(best["latency_ms"], 4),
    })

    # ---- Fig. 12: rearrangement on/off (4×4, hybrid pattern) ---------------
    for mname, wl_fn in (("resnet50", lambda: resnet50(32)),
                         ("vgg16", lambda: vgg16(32))):
        arch = usecase_arch(16, (4, 4))
        dense = dense_baseline(arch, wl_fn(),
                               default_mapping(arch, "spatial"))
        for strat in ("spatial", "duplicate"):
            for rr, rr_name in ((None, "none"), ("slice", "rearranged")):
                mapping = default_mapping(
                    arch, strat, rearrange=rr,
                    slice_size=arch.macro.sub_rows if rr else 0)
                wl = wl_fn().set_sparsity(spec)
                t0 = time.perf_counter()
                rep = simulate(arch, wl, mapping)
                dt = time.perf_counter() - t0
                c = compare(rep, dense)
                shares = rep.grouped_energy()
                tot = max(sum(shares.values()), 1e-9)
                rows.append({
                    "name": f"fig12/{mname}/{strat}/{rr_name}",
                    "us_per_call": dt * 1e6,
                    "latency_ms": round(rep.latency_ms, 4),
                    "energy_uj": round(rep.total_energy_uj, 2),
                    "utilization": round(rep.utilization, 4),
                    "buffer_share": round(shares.get("buffers", 0.0) / tot, 3),
                    "speedup": round(c["speedup"], 3),
                })
    return rows
