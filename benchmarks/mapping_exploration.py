"""Paper Fig. 11–12 — mapping strategy exploration (§VII-C).

Second use-case: 16 macros (same per-macro spec as §VII-A) across the
organisations 8×2 / 4×4 / 2×8, comparing *spatial* weight-unroll mapping
against *weight duplication* on ResNet50 (Conv-dominated) and VGG16
(FC-parameter-dominated), then the effect of compressed-weight
REARRANGEMENT (equalising ragged compressed matrices) on the hybrid
IntraBlock(2,1)+FullBlock(2,16) pattern at the 4×4 organisation.

Paper findings checked: duplication lifts utilisation up to ~7.7× for
Conv-dominated models and the balanced 4×4 organisation is best
(Finding 2); rearrangement raises utilisation but can trade energy for
buffer-access overhead.

Runs on the :mod:`repro.explore` engine with one shared runner: the
Finding-2 re-probe of the duplicate strategy is a pure cache hit, and
Fig. 12's dense baselines are shared across rearrangement settings.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import (compare, default_mapping, hybrid, resnet50,
                        usecase_arch, vgg16)
from repro.explore import ExploreJob, SweepRunner, mapping_sweep

from ._stats import engine_stats_row, tile_cache_snapshot

__all__ = ["run"]

ORGS = ((8, 2), (4, 4), (2, 8))


def run(workers: Optional[int] = 1) -> List[Dict]:
    rows: List[Dict] = []
    spec = hybrid(2, 16, 0.8)
    runner = SweepRunner(workers=workers)
    tg0 = tile_cache_snapshot()

    # ---- Fig. 11: strategy × organisation × model --------------------------
    for mname, wl_fn in (("resnet50", lambda: resnet50(32)),
                         ("vgg16", lambda: vgg16(32))):
        result = mapping_sweep(
            lambda org: usecase_arch(16, org), wl_fn, spec,
            orgs=ORGS, strategies=("spatial", "duplicate"), runner=runner)
        grid = result.rows
        dt = result.stats.wall_s / max(len(grid), 1)
        for g in grid:
            rows.append({
                "name": f"fig11/{mname}/{g['org']}/{g['mapping']}",
                "us_per_call": dt * 1e6,
                "latency_ms": round(g["latency_ms"], 4),
                "energy_uj": round(g["energy_uj"], 2),
                "utilization": round(g["utilization"], 4),
                "speedup": round(g["speedup"], 3),
            })

        # utilisation lift from duplication per org
        by = {(g["org"], g["mapping"]): g for g in grid}
        for org in ORGS:
            o = f"{org[0]}x{org[1]}"
            lift = by[(o, "duplicate")]["utilization"] / \
                max(by[(o, "spatial")]["utilization"], 1e-9)
            rows.append({
                "name": f"fig11/{mname}/{o}/dup_util_lift",
                "us_per_call": 0.0,
                "lift": round(lift, 2),
            })

    # Finding 2 (part 1): for the Conv-dominated model, duplication helps
    # and 4×4 is the best organisation; for FC-heavy VGG16 the benefit
    # shrinks (less weight reuse).  Every job here is already cached.
    g_r = mapping_sweep(lambda org: usecase_arch(16, org),
                        lambda: resnet50(32), spec, orgs=ORGS,
                        strategies=("duplicate",), runner=runner).rows
    best = min(g_r, key=lambda g: g["latency_ms"])
    rows.append({
        "name": "fig11/finding2/best_org_resnet50",
        "us_per_call": 0.0,
        "best_org": best["org"],
        "latency_ms": round(best["latency_ms"], 4),
    })

    # ---- Fig. 12: rearrangement on/off (4×4, hybrid pattern) ---------------
    arch = usecase_arch(16, (4, 4))
    cases = [(mname, wl_fn, strat, rr)
             for mname, wl_fn in (("resnet50", lambda: resnet50(32)),
                                  ("vgg16", lambda: vgg16(32)))
             for strat in ("spatial", "duplicate")
             for rr in (None, "slice")]
    jobs = []
    for mname, wl_fn, strat, rr in cases:
        mapping = default_mapping(
            arch, strat, rearrange=rr,
            slice_size=arch.macro.sub_rows if rr else 0)
        jobs.append(ExploreJob.simulate(
            arch, wl_fn().set_sparsity(spec), mapping))
        jobs.append(ExploreJob.dense(
            arch, wl_fn(), default_mapping(arch, "spatial")))
    reports = runner.run(jobs)
    dt = runner.last_stats.wall_s / max(runner.last_stats.requested, 1)
    for i, (mname, _, strat, rr) in enumerate(cases):
        rep, dense = reports[2 * i], reports[2 * i + 1]
        c = compare(rep, dense)
        shares = rep.grouped_energy()
        tot = max(sum(shares.values()), 1e-9)
        rows.append({
            "name": f"fig12/{mname}/{strat}/{'rearranged' if rr else 'none'}",
            "us_per_call": dt * 1e6,
            "latency_ms": round(rep.latency_ms, 4),
            "energy_uj": round(rep.total_energy_uj, 2),
            "utilization": round(rep.utilization, 4),
            "buffer_share": round(shares.get("buffers", 0.0) / tot, 3),
            "speedup": round(c["speedup"], 3),
        })

    rows.append(engine_stats_row(runner, tg0))
    return rows
