"""Fault-injection hook overhead benchmark (jax-free, informational).

The fault-tolerance layer compiles two hooks into hot paths:
``faults.maybe_fail`` inside every ``evaluate_job`` call and
``faults.corrupt_payload`` inside every ``ResultStore.put``.  With no
plan installed — the default for every production sweep — each is a
single module-global ``None`` check, and this suite pins that
disabled-mode cost under the same <2 % budget discipline as the obs
canary (``benchmarks/obs_overhead.py``).  Rows:

* ``disabled/<entry>`` — ns per call of each disabled hook.
* ``sweep/off`` — one cold mini sparsity sweep with no plan installed
  (the denominator).
* ``sweep/faulted`` — the same sweep under an installed exc-fault plan
  with retries absorbing the injected failures; informational, shows
  what chaos-mode actually costs.
* ``overhead/disabled`` — the pinned number: estimated disabled-mode
  hook cost as a fraction of the sweep (1 ``maybe_fail`` per evaluated
  point), with ``budget_pct: 2.0``.

The suite is new relative to the committed ``BENCH_baseline.json``, so
``compare.py`` reports it as informational until a refreshed baseline
lands.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (TABLE_II_PATTERNS, default_mapping, resnet18,
                        usecase_arch)
from repro.explore import SweepRunner, faults, sparsity_sweep

__all__ = ["run"]

_NOOP_REPEATS = 200_000
_RATIOS = (0.6, 0.7, 0.8)


def _pattern_factory(r):
    return TABLE_II_PATTERNS(r, c_in=16)


def _mini_sweep() -> float:
    """One cold mini sparsity sweep; returns wall seconds and the point
    count via the runner stats (fresh runner — no cross-run cache)."""
    arch = usecase_arch(4)
    runner = SweepRunner(workers=1, backoff_s=0.0)
    t0 = time.perf_counter()
    sparsity_sweep(arch, lambda: resnet18(32), {}, ratios=_RATIOS,
                   mapping=default_mapping(arch),
                   pattern_factory=_pattern_factory, runner=runner)
    return time.perf_counter() - t0, runner.stats.evaluated


def _noop_ns(fn) -> float:
    t0 = time.perf_counter()
    for _ in range(_NOOP_REPEATS):
        fn()
    return (time.perf_counter() - t0) / _NOOP_REPEATS * 1e9


def run() -> List[Dict]:
    faults.uninstall()
    rows: List[Dict] = []

    key = "ab" * 32
    payload = b"x" * 4096
    entries = {
        "maybe_fail": lambda: faults.maybe_fail(key, 0),
        "corrupt_payload": lambda: faults.corrupt_payload(key, payload),
    }
    ns: Dict[str, float] = {}
    for name, fn in entries.items():
        ns[name] = _noop_ns(fn)
        rows.append({"name": f"disabled/{name}",
                     "us_per_call": ns[name] / 1e3,
                     "ns_per_call": round(ns[name], 1)})

    # warm the process-wide tile-grid memo so off/faulted see the same
    # cache state (the first sweep in a process is always the cold one)
    _mini_sweep()
    off_s, evaluated = _mini_sweep()
    rows.append({"name": "sweep/off", "us_per_call": off_s * 1e6,
                 "wall_s": round(off_s, 4), "evaluated": evaluated})

    # informational: the same sweep with transient faults actually
    # firing (sequential path, retries absorb every failure)
    faults.install("seed=11,exc=0.3,times=1", export_env=False)
    try:
        faulted_s, _ = _mini_sweep()
    finally:
        faults.uninstall()
    rows.append({"name": "sweep/faulted", "us_per_call": faulted_s * 1e6,
                 "wall_s": round(faulted_s, 4),
                 "overhead_pct": round((faulted_s - off_s) / off_s * 100, 2)})

    # the pinned number: disabled-mode hook cost as a share of the sweep
    # (evaluate_job calls maybe_fail once per evaluated point; store
    # writes add one corrupt_payload per point when a store is attached)
    hook_s = evaluated * (ns["maybe_fail"] + ns["corrupt_payload"]) / 1e9
    rows.append({"name": "overhead/disabled",
                 "us_per_call": hook_s * 1e6,
                 "pct_of_sweep": round(hook_s / off_s * 100, 4),
                 "budget_pct": 2.0})
    return rows
