"""Paper Fig. 6 / Table I — validation against MARS and SDP.

Reproduces the validation *setups* (architectures, sparsity patterns,
models, scopes from Table I) and reports estimated speedups / energy
savings / power-breakdown shares.  Reference points are transcribed from
the cited works' reported ranges (MARS [19]; SDP [20]) — marked
APPROXIMATE since the originals' figure data is not published as
numbers; the paper's own validation claim is a ≤5.27 % error envelope
against such points.

Operating points follow the original designs' evaluations:
* MARS prunes 16-weight row groups; its accuracy-constrained operating
  sparsity lands at ~72 % for the CIFAR models.
* SDP's hybrid IntraBlock(2,1)+FullBlock(2,8) runs at 70 % overall with
  a measured input-bit skip ratio of 0.15 (profiled int8 activations).
* SDP's macro is row-granular (1×64 sub-arrays, shared per-column MAC)
  → modeled ``row_serial=True``: row pruning saves time, IntraBlock's
  double-broadcast saves energy but streams both candidates.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (compare, default_mapping, dense_baseline, hybrid,
                        mars_arch, resnet18, resnet50, row_block, sdp_arch,
                        simulate, vgg16)

# Approximate reported relative gains (speedup, energy saving) —
# transcription notes in EXPERIMENTS.md §Validation.
REFERENCE = {
    ("mars", "vgg16"): (2.7, 3.1),
    ("mars", "resnet18"): (2.4, 2.9),
    ("sdp", "resnet18"): (2.3, 3.4),
    ("sdp", "resnet50"): (2.1, 3.2),
}


def run() -> List[Dict]:
    rows = []
    cases = [
        # design, arch, model, workload, spec, profiled input-skip ratio
        ("mars", mars_arch(), "vgg16", lambda: vgg16(32),
         row_block(0.72, 16), None),
        ("mars", mars_arch(), "resnet18", lambda: resnet18(32),
         row_block(0.72, 16), None),
        ("sdp", sdp_arch(), "resnet18", lambda: resnet18(224, 1000),
         hybrid(2, 8, 0.70), 0.15),
        ("sdp", sdp_arch(), "resnet50", lambda: resnet50(224, 1000),
         hybrid(2, 8, 0.70), 0.15),
    ]
    errs = []
    for design, arch, model, wl_fn, spec, skip in cases:
        mapping = default_mapping(arch, "duplicate")
        wl = wl_fn().set_sparsity(spec)
        sk = None
        if arch.input_sparsity_support and skip:
            sk = {op.name: skip for op in wl.mvm_ops(arch.eval_scope)}
        t0 = time.perf_counter()
        rep = simulate(arch, wl, mapping, input_sparsity=sk)
        dt = time.perf_counter() - t0
        dense = dense_baseline(arch, wl, mapping)
        c = compare(rep, dense)
        ref = REFERENCE[(design, model)]
        err = max(abs(c["speedup"] - ref[0]) / ref[0],
                  abs(c["energy_saving"] - ref[1]) / ref[1])
        errs.append(err)
        shares = rep.grouped_energy()
        tot = max(sum(shares.values()), 1e-9)
        rows.append({
            "name": f"validation/{design}/{model}",
            "us_per_call": dt * 1e6,
            "speedup": round(c["speedup"], 3),
            "energy_saving": round(c["energy_saving"], 3),
            "utilization": round(c["utilization"], 3),
            "ref_speedup": ref[0],
            "ref_energy": ref[1],
            "rel_err": round(err, 4),
            "power_shares": {k: round(v / tot, 3) for k, v in shares.items()},
        })
    rows.append({
        "name": "validation/error_envelope",
        "us_per_call": 0.0,
        "max_rel_err": round(max(errs), 4),
        "mean_rel_err": round(sum(errs) / len(errs), 4),
        "paper_claim": 0.0527,
    })
    return rows
