"""Million-point exploration throughput: per-point vs batched vs guided.

The PR 10 tentpole claims the explore plane turns enumeration-bound
sweeps into an amortised pipeline.  This suite measures the three
execution modes on ONE variant-heavy grid — ratios × (3 schedule
policies × 8 invocation counts × 2 calibration profiles) = 48
variants per tile-grid-identical group, a 4-layer fc-512 workload —
and reports throughput as both ``us_per_call`` per point and
``points_per_s``:

  ``per_point/eval``   every point through ``simulate()`` + per-job
                       content keying: the pre-PR-10 baseline path.
  ``batched/eval``     the same grid through ``SweepRunner(batch_size=…)``:
                       grouped ``simulate_variants`` costing + stacked
                       tile-grid precompute + shared-subform keying.
                       Rows are asserted equal to the per-point rows —
                       this speedup is *bit-identity preserving*.
  ``guided/halving``   the full pipeline over the same space:
                       batch-shared monolithic estimates rank ALL
                       points, the top eighth promotes to batched full
                       evaluation.  Same space coverage, fraction of
                       the wall time — the ROADMAP's 10⁶-points-on-a-
                       laptop mode.

``speedup`` on the batched/guided rows is points/s over the per-point
row; ``guided/halving``'s is the gated ≥10× number (see
``benchmarks/compare.py --require`` in CI).  Each mode clears the
process-wide tile-grid/keep-grid/canonical memos and rebuilds its
points, so every path starts cold — nothing leaks between modes.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.calibrate.profile import resolve_profile
from repro.core import FlexBlockSpec, FullBlock, default_mapping, usecase_arch
from repro.core.mapping import default_tile_cache
from repro.core.schedule import SchedulePolicy
from repro.core.workload import Workload
from repro.explore import (ExploreJob, PointSpace, SearchPolicy, SweepRunner,
                           run_search)
from repro.explore.sweeps import GridPoint, run_grid

N_RATIOS = 100
POLICIES = ("monolithic", "partitioned", "resident")
INVOCATIONS = (1, 2, 3, 4, 6, 8, 12, 16)
PROFILES = (False, True)
VARIANTS = [(pol, inv, p) for pol in POLICIES for inv in INVOCATIONS
            for p in PROFILES]
SIZE = N_RATIOS * len(VARIANTS)
BATCH = 4096


def _wl() -> Workload:
    w = Workload("scale_bench")
    w.fc("fc1", 512, 512)
    w.fc("fc2", 512, 512, inputs=("fc1",))
    w.fc("fc3", 512, 512, inputs=("fc2",))
    w.fc("fc4", 512, 256, inputs=("fc3",))
    return w


def _space(arch, prof) -> PointSpace:
    """The suite's lazily-indexed grid; shares the heavy objects across
    variants exactly the way ``repro.explore __main__``'s scale factory
    does (one workload per ratio, one dense baseline per variant)."""
    m = default_mapping(arch)
    dense_wl = _wl()
    scheds = {(pol, inv): SchedulePolicy(policy=pol, invocations=inv)
              for pol in POLICIES for inv in INVOCATIONS}
    dense_jobs: Dict[tuple, ExploreJob] = {}
    wl_cache: Dict[int, Workload] = {}

    def factory(i: int) -> GridPoint:
        ri, vi = divmod(i, len(VARIANTS))
        pol, inv, use_p = VARIANTS[vi]
        p = prof if use_p else None
        ratio = 0.05 + 0.9 * ri / (N_RATIOS - 1)
        wl = wl_cache.get(ri)
        if wl is None:
            wl = wl_cache[ri] = _wl().set_sparsity(
                FlexBlockSpec((FullBlock(16, 16, ratio),), name="full16"))
        dk = (pol, inv, use_p)
        dense = dense_jobs.get(dk)
        if dense is None:
            dense = dense_jobs[dk] = ExploreJob.dense(
                arch, dense_wl, m, profile=p, schedule=scheds[(pol, inv)])
        return GridPoint(
            ExploreJob.simulate(arch, wl, m, profile=p,
                                schedule=scheds[(pol, inv)]),
            dense, meta=(("ratio", ratio),))

    return PointSpace(SIZE, factory, (N_RATIOS, len(VARIANTS)))


def _cold_start() -> None:
    """Drop every process-wide memo a previous mode may have warmed."""
    default_tile_cache().clear()
    from repro.core import mapping as _mapping
    _mapping._KEEP_GRID_CACHE.clear()
    from repro.explore import job as _job
    _job._CANON_MEMO.clear()


def run(workers: int = 1) -> List[Dict]:
    arch = usecase_arch(4)
    prof = resolve_profile("default")
    rows: List[Dict] = []

    _cold_start()
    space = _space(arch, prof)
    points = [space.factory(i) for i in range(SIZE)]
    t0 = time.perf_counter()
    ref = run_grid(points, runner=SweepRunner(workers=workers))
    per_point_s = time.perf_counter() - t0
    rows.append({"name": "per_point/eval",
                 "us_per_call": per_point_s / SIZE * 1e6,
                 "points": SIZE,
                 "points_per_s": round(SIZE / per_point_s, 1)})

    _cold_start()
    space = _space(arch, prof)
    points = [space.factory(i) for i in range(SIZE)]
    t0 = time.perf_counter()
    res = run_grid(points, runner=SweepRunner(workers=workers,
                                              batch_size=BATCH))
    batched_s = time.perf_counter() - t0
    if res.rows != ref.rows:            # the bit-identity contract
        raise AssertionError("batched rows diverge from per-point rows")
    rows.append({"name": "batched/eval",
                 "us_per_call": batched_s / SIZE * 1e6,
                 "points": SIZE,
                 "points_per_s": round(SIZE / batched_s, 1),
                 "speedup": round(per_point_s / batched_s, 2)})

    _cold_start()
    space = _space(arch, prof)
    t0 = time.perf_counter()
    sr = run_search(space, SearchPolicy(kind="halving", budget=SIZE // 8),
                    runner=SweepRunner(workers=workers, batch_size=BATCH),
                    chunk=BATCH)
    guided_s = time.perf_counter() - t0
    rows.append({"name": "guided/halving",
                 "us_per_call": guided_s / SIZE * 1e6,
                 "points": SIZE,
                 "estimated": sr.estimated,
                 "evaluated": sr.points,
                 "points_per_s": round(SIZE / guided_s, 1),
                 "speedup": round(per_point_s / guided_s, 2)})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
