"""Traced-workload pipeline benchmark (jax-free, informational).

Replays the committed golden TraceGraphs under ``tests/fixtures/trace/``
through the full modeling pipeline — load → lower → differential vs the
hand DAG → simulate — and reports the cost of each stage.  No jax and no
live capture: this measures the half of :mod:`repro.trace` that every
consumer (tests, CI, the explore cache) actually runs, on inputs pinned
in-tree.

Rows:

* ``lower/<fixture>``      — TraceGraph → Workload lowering latency,
  with the op count and MVM macs of the result.
* ``diff/<fixture>``       — hand-sibling rebuild + differential; the
  ``mvm_match`` field is the contract the trace-smoke CI job gates on.
* ``simulate/<fixture>/<policy>`` — the lowered DAG through the cost
  model under each schedule policy.

The suite is new relative to older baselines, so ``compare.py`` reports
it as informational until a refreshed ``BENCH_baseline.json`` lands.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

from repro.configs import get_config
from repro.core import (SchedulePolicy, default_mapping, lm_workload,
                        simulate, usecase_arch)
from repro.core.schedule import POLICIES
from repro.core.workload import MODEL_BUILDERS
from repro.trace import TraceGraph, diff_workloads, lower_graph

__all__ = ["run"]

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                           "tests", "fixtures", "trace")

# (fixture, has a hand sibling to diff against)
FIXTURES = (
    ("lm_llama3-8b_forward.json", True),
    ("lm_llama3-8b_decode.json", False),
    ("lm_dbrx-132b_forward.json", True),
    ("cnn_resnet18_32.json", True),
)
SIMULATED = ("lm_llama3-8b_forward.json", "cnn_resnet18_32.json")


def _hand_for(graph: TraceGraph):
    meta = graph.meta
    if "config" in meta:
        return lm_workload(get_config(meta["config"]),
                           seq_len=int(meta["seq_len"]),
                           batch=int(meta["batch"]))
    return MODEL_BUILDERS[meta["model"]](int(meta["img"]),
                                         int(meta["num_classes"]))


def run() -> List[Dict]:
    rows: List[Dict] = []
    arch = usecase_arch(16)
    mapping = default_mapping(arch, "spatial")

    for fname, diffable in FIXTURES:
        path = os.path.join(FIXTURE_DIR, fname)
        stem = fname[:-len(".json")]
        graph = TraceGraph.load(path)

        t0 = time.perf_counter()
        wl = lower_graph(graph)
        dt = time.perf_counter() - t0
        rows.append({"name": f"lower/{stem}", "us_per_call": dt * 1e6,
                     "ops": len(wl), "mvm_macs": wl.total_macs(),
                     "digest": graph.digest()[:16]})

        if diffable:
            t0 = time.perf_counter()
            d = diff_workloads(wl, _hand_for(graph))
            dt = time.perf_counter() - t0
            rows.append({"name": f"diff/{stem}", "us_per_call": dt * 1e6,
                         "mvm_match": d["mvm_match"],
                         "elementwise_surplus": d["elementwise_surplus"]})

        if fname in SIMULATED:
            for pol in POLICIES:
                wl_pol = lower_graph(graph)
                t0 = time.perf_counter()
                rep = simulate(arch, wl_pol, mapping,
                               schedule=SchedulePolicy(pol))
                dt = time.perf_counter() - t0
                rows.append({"name": f"simulate/{stem}/{pol}",
                             "us_per_call": dt * 1e6,
                             "latency_ms": round(rep.latency_ms, 4)})
    return rows
