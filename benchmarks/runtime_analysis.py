"""Paper Fig. 7 — framework runtime and scalability analysis.

Times the CIMinus evaluation itself (mapping + cycle-level simulation)
across models (MobileNetV2 3.4M → VGG16-224 138M params), sparsity
patterns (row-wise / row-block / hybrids), sparsity ratios 0.5–0.9, and
macro counts 4–64.  The paper's claim: runtime stays under ~100 s per
configuration and scales with workload complexity, not hardware size.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (default_mapping, hybrid, mobilenet_v2, resnet50,
                        row_block, row_wise, simulate, usecase_arch, vgg16)


def _timed(arch, wl_fn, spec) -> Dict:
    wl = wl_fn().set_sparsity(spec)
    t0 = time.perf_counter()
    rep = simulate(arch, wl, default_mapping(arch, "duplicate"))
    dt = time.perf_counter() - t0
    return {"wall_s": dt, "ops": len(wl), "latency_ms": rep.latency_ms}


def run() -> List[Dict]:
    rows = []
    arch4 = usecase_arch(4)

    # models at fixed pattern (hybrid 1:2 + row-block, 80%)
    for mname, wl_fn in (("mobilenetv2", lambda: mobilenet_v2(224, 1000)),
                         ("resnet50", lambda: resnet50(224, 1000)),
                         ("vgg16", lambda: vgg16(224, 1000))):
        r = _timed(arch4, wl_fn, hybrid(2, 16, 0.8))
        rows.append({"name": f"runtime/model/{mname}",
                     "us_per_call": r["wall_s"] * 1e6,
                     "ops": r["ops"], "under_100s": r["wall_s"] < 100})

    # patterns on resnet50
    for pname, spec in (("row-wise", row_wise(0.8)),
                        ("row-block", row_block(0.8)),
                        ("1:2+row-block", hybrid(2, 16, 0.8)),
                        ("1:4+row-block", hybrid(4, 16, 0.8))):
        r = _timed(arch4, lambda: resnet50(224, 1000), spec)
        rows.append({"name": f"runtime/pattern/{pname}",
                     "us_per_call": r["wall_s"] * 1e6,
                     "under_100s": r["wall_s"] < 100})

    # sparsity ratios (hybrid 1:2 floor is 0.5 ⇒ sweep starts above it)
    for ratio in (0.6, 0.75, 0.9):
        r = _timed(arch4, lambda: resnet50(224, 1000), hybrid(2, 16, ratio))
        rows.append({"name": f"runtime/ratio/{ratio}",
                     "us_per_call": r["wall_s"] * 1e6,
                     "under_100s": r["wall_s"] < 100})

    # macro counts: runtime should scale with workload, not hardware
    walls = {}
    for n in (4, 16, 64):
        org = {4: (2, 2), 16: (4, 4), 64: (8, 8)}[n]
        r = _timed(usecase_arch(n, org), lambda: resnet50(224, 1000),
                   hybrid(2, 16, 0.8))
        walls[n] = r["wall_s"]
        rows.append({"name": f"runtime/macros/{n}",
                     "us_per_call": r["wall_s"] * 1e6,
                     "under_100s": r["wall_s"] < 100})
    rows.append({"name": "runtime/hw_scaling_64_vs_4",
                 "us_per_call": 0.0,
                 "ratio": round(walls[64] / max(walls[4], 1e-9), 2)})
    return rows
