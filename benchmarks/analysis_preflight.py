"""Analysis pre-flight overhead benchmark (jax-free, informational).

``repro.analysis.validate`` now runs ahead of every explore sweep,
trace lowering, and dryrun trace emission — so its cost *is* explore
hot-path cost and needs to stay a tracked number.  Rows:

* ``validate/<workload>`` — one full semantic validation (structure +
  dims + sparsity + capacity) of a representative workload against the
  usecase arch and default mapping; ``ops`` is the DAG size.  This is
  the per-sweep overhead ``run_grid`` pays once per distinct input.
* ``pass/<name>`` — one cold run of each *static* pass (source-level
  AST checks) plus the full model-plane corpus sweep; this is what the
  CI ``analysis`` job pays.

The suite is new relative to older baselines, so ``compare.py`` reports
it as informational until a refreshed ``BENCH_baseline.json`` lands.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.analysis import validate
from repro.analysis.framework import PassContext, get_pass
from repro.configs import get_config
from repro.core import default_mapping, lm_workload, usecase_arch
from repro.core.workload import MODEL_BUILDERS

__all__ = ["run"]

_VALIDATE_REPEATS = 50
_WORKLOADS = ("resnet50", "vgg16", "lm:llama3-8b")
_PASSES = ("import-boundary", "cache-key", "determinism", "model-plane")


def _build(name: str):
    if name.startswith("lm:"):
        return lm_workload(get_config(name[3:]), seq_len=128)
    return MODEL_BUILDERS[name]()


def run() -> List[Dict]:
    rows: List[Dict] = []
    arch = usecase_arch(16)
    mapping = default_mapping(arch, "spatial")

    for wname in _WORKLOADS:
        w = _build(wname)
        t0 = time.perf_counter()
        for _ in range(_VALIDATE_REPEATS):
            diags = validate(w, arch, mapping)
        dt = (time.perf_counter() - t0) / _VALIDATE_REPEATS
        rows.append({"name": f"validate/{wname}",
                     "us_per_call": dt * 1e6,
                     "ops": len(w), "diags": len(diags)})

    for pname in _PASSES:
        t0 = time.perf_counter()
        diags = get_pass(pname).run(PassContext())
        dt = time.perf_counter() - t0
        rows.append({"name": f"pass/{pname}",
                     "us_per_call": dt * 1e6, "diags": len(diags)})
    return rows
