"""Deterministic synthetic token pipeline.

Seeded, stateful, restartable: the stream position is part of the
checkpointed training state, so restart-after-failure resumes on the
exact batch.  Sharded by host: each host draws only its slice of the
global batch (``host_id``/``n_hosts``), matching multi-host data
loading on a real pod.

Generates structured (not uniform) token streams — a mixture of Zipfian
unigrams and short repeated motifs — so language-model training loss has
actual signal to descend on in the end-to-end examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["PipelineConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


class TokenPipeline:
    """Iterator of training batches with explicit, checkpointable state."""

    def __init__(self, cfg: PipelineConfig, start_step: int = 0):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self._step = start_step
        # Zipfian unigram table (clipped to vocab)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()

    # -- checkpointable state -------------------------------------------------
    def state(self) -> Dict:
        return {"step": self._step, "seed": self.cfg.seed,
                "host_id": self.cfg.host_id}

    @classmethod
    def from_state(cls, cfg: PipelineConfig, state: Dict) -> "TokenPipeline":
        if state.get("seed", cfg.seed) != cfg.seed:
            raise ValueError("checkpoint seed mismatch")
        return cls(cfg, start_step=int(state["step"]))

    # -- batch generation --------------------------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        # independent stream per (seed, step, host): restart-stable
        return np.random.default_rng(
            np.random.SeedSequence(
                [self.cfg.seed, step, self.cfg.host_id]))

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng_for(self._step)
        local_b = cfg.global_batch // cfg.n_hosts
        L = cfg.seq_len + 1
        toks = rng.choice(cfg.vocab_size, size=(local_b, L), p=self._probs)
        # inject repeated motifs (learnable structure)
        n_motifs = int(L * cfg.motif_prob / cfg.motif_len)
        motif_vocab = min(1000, cfg.vocab_size)
        for b in range(local_b):
            motif = rng.choice(motif_vocab, size=cfg.motif_len)
            for _ in range(n_motifs):
                pos = rng.integers(0, L - cfg.motif_len)
                toks[b, pos:pos + cfg.motif_len] = motif
        self._step += 1
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
