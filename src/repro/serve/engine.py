"""Batched serving engine: continuous-batching-lite over a fixed slot pool.

``ServeEngine`` owns a prefill function and a decode step (both jitted
once at fixed shapes — slot count and max length — so serving never
recompiles).  Requests occupy slots; every engine step decodes one token
for all active slots; finished slots (EOS or max tokens) free and refill
from the queue.  This is the standard static-shape continuous batching
pattern for TPU serving.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from .. import obs
from ..configs.base import ArchConfig
from ..models.transformer import decode_step, forward, init_cache, prefill
from ..obs.metrics import ServeMetrics

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                # -1 = never
    # wall-second budget from submit(); a request still queued past it
    # is dropped, one mid-decode is cut off with partial output.
    # None = no deadline.
    deadline_s: Optional[float] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    done: bool = False
    # why the engine refused/abandoned this request ("queue_full",
    # "deadline"); None while healthy.  ``done`` stays False for a
    # request that never produced output.
    reject_reason: Optional[str] = None
    # telemetry (observational only): monotonic submit time, for TTFT
    submit_t: Optional[float] = None


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 max_queue: Optional[int] = None,
                 dtype=jnp.float32):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        # admission bound: submit() rejects (reject_reason="queue_full")
        # once this many requests wait, instead of growing without limit.
        # None = unbounded (the historical behaviour).
        self.max_queue = max_queue
        # warn-only pre-flight: surface a structurally broken config
        # (bad dims, incoherent DAG) at engine construction instead of
        # as a shape error mid-request
        from ..analysis import preflight
        from ..core.workload import lm_workload
        preflight(lm_workload(cfg, seq_len=max_len, batch=slots),
                  strict=False, where="serve.engine")
        self.greedy = greedy
        self.cache = init_cache(cfg, slots, max_len, dtype=dtype)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_remaining = np.zeros(slots, np.int64)
        self.slot_pos = np.zeros(slots, np.int64)     # per-slot lengths
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, t, cfg, c))
        self._last_tokens = np.zeros(slots, np.int32)
        # cumulative across the engine's lifetime; run() additionally
        # leaves a per-call delta in ``last_stats`` (mirroring the sweep
        # engine's RunStats split)
        self.metrics = ServeMetrics()
        self.last_stats: Dict[str, Any] = {}

    # -- request management --------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit ``req`` (True) or reject it with backpressure (False).

        Rejection is immediate and structured — ``req.reject_reason`` is
        set to ``"queue_full"`` and the request never enters the queue —
        so a load generator can shed or retry instead of the queue
        growing without bound."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.reject_reason = "queue_full"
            self.metrics.on_reject()
            return False
        req.output = []
        req.submit_t = time.monotonic()
        self.queue.append(req)
        self.metrics.on_submit()
        return True

    def _expired(self, req: Request, now: float) -> bool:
        return (req.deadline_s is not None and req.submit_t is not None
                and now - req.submit_t > req.deadline_s)

    def _fill_slots(self) -> None:
        now = time.monotonic()
        # drop queued requests whose deadline already passed — decoding
        # them would only delay every request behind them
        kept: List[Request] = []
        for req in self.queue:
            if self._expired(req, now):
                req.reject_reason = "deadline"
                self.metrics.on_expire(queued=True)
            else:
                kept.append(req)
        self.queue = kept
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(s, req)

    def _prefill_slot(self, s: int, req: Request) -> None:
        """Per-slot prefill: run the prompt, merge its KV into the pool.

        Uses a batch-1 prefill then scatters into the slot's cache lanes;
        per-slot variable positions are tracked host-side (static shapes,
        no recompile).
        """
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        S = prompt.shape[1]
        if S >= self.max_len:
            raise ValueError(f"prompt {S} ≥ max_len {self.max_len}")
        logits, pc = prefill(self.params, prompt, self.cfg)
        for key in ("k", "v"):
            if key in self.cache:
                upd = pc[key]  # (L, 1, S, H, hd)
                self.cache[key] = jax.lax.dynamic_update_slice(
                    self.cache[key], upd.astype(self.cache[key].dtype),
                    (0, s, 0, 0, 0))
        if "ssm" in self.cache:
            self.cache["ssm"] = self.cache["ssm"].at[:, s].set(pc["ssm"][:, 0])
            self.cache["conv"] = self.cache["conv"].at[:, s].set(
                pc["conv"][:, 0].astype(self.cache["conv"].dtype))
        tok = int(jnp.argmax(logits[0, -1]))
        req.output.append(tok)
        self._last_tokens[s] = tok
        self.slot_req[s] = req
        self.slot_remaining[s] = req.max_new_tokens - 1
        self.slot_pos[s] = S
        self.metrics.on_scheduled()
        self.metrics.tokens_generated += 1       # the prefill's first token
        if req.submit_t is not None:
            self.metrics.on_first_token(time.monotonic() - req.submit_t)

    # -- decoding ------------------------------------------------------------
    def step(self) -> int:
        """Decode one token for all active slots; returns #active."""
        t0 = time.monotonic()
        self._fill_slots()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        # per-slot positions: each slot decodes at its own cache length
        self.cache["pos"] = jnp.asarray(self.slot_pos, jnp.int32)
        tokens = jnp.asarray(self._last_tokens)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        completed = 0
        now = time.monotonic()
        for s in active:
            req = self.slot_req[s]
            tok = int(next_tokens[s])
            req.output.append(tok)
            self._last_tokens[s] = tok
            self.slot_pos[s] += 1
            self.slot_remaining[s] -= 1
            if (self.slot_remaining[s] <= 0 or tok == req.eos_id
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None
                completed += 1
            elif self._expired(req, now):
                # deadline passed mid-decode: keep the partial output,
                # free the slot for requests that can still make it
                req.reject_reason = "deadline"
                self.slot_req[s] = None
                self.metrics.on_expire(queued=False)
        step_s = time.monotonic() - t0
        m = self.metrics
        m.on_step(len(active), step_s)
        m.on_tokens(len(active), step_s)
        for _ in range(completed):
            m.on_complete()
        obs.counter("serve.step", len(active),
                    queue_depth=m.queue_depth, completed=completed)
        return len(active)

    def run(self) -> None:
        """Drain queue + slots; leaves this call's deltas in
        ``last_stats`` (``metrics`` keeps cumulating across calls)."""
        m = self.metrics
        before = (m.steps, m.tokens_generated, m.requests_completed, m.busy_s)
        t0 = time.monotonic()
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
        self.last_stats = {
            "steps": m.steps - before[0],
            "tokens_generated": m.tokens_generated - before[1],
            "requests_completed": m.requests_completed - before[2],
            "busy_s": m.busy_s - before[3],
            "wall_s": time.monotonic() - t0,
        }

    # -- exposition ----------------------------------------------------------
    def stats_snapshot(self) -> Dict[str, Any]:
        """Cumulative metrics as a JSON-able dict (queue depth, TTFT and
        per-token latency p50/p99, tokens/s, …)."""
        return self.metrics.snapshot()

    def stats_text(self) -> str:
        return self.metrics.render_text()
