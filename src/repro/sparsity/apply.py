"""FlexBlock sparsity on live model parameters (execution plane).

Bridges the paper's pruning workflow (core.pruning) to the JAX models:

* ``prune_params`` — walk a model's stacked layer weights, generate a
  FlexBlock mask per 2-D weight matrix (per layer), apply it, and return
  (pruned_params, masks).  Masks plug into ``make_train_step(masks=…)``
  for sparse fine-tuning where pruned weights stay exactly zero.
* ``sparsity_report`` — per-tensor density accounting.
* ``cim_cost_of_model`` — lower the arch to a CIMinus workload and cost
  it on a CIM architecture (modeling plane round-trip).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.costmodel import compare, dense_baseline, simulate
from ..core.flexblock import FlexBlockSpec
from ..core.mapping import default_mapping
from ..core.pruning import flexblock_mask
from ..core.workload import lm_workload

__all__ = ["PRUNABLE_KEYS", "prune_params", "sparsity_report",
           "cim_cost_of_model"]

# stacked layer weights eligible for FlexBlock pruning (2-D per layer);
# biases/norms/ssm dynamics params are never pruned.
PRUNABLE_KEYS = ("w_gate", "w_up", "w_down", "w_in", "w_out",
                 "wq", "wk", "wv", "wo")


def _as_matrix(w: np.ndarray) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Collapse a (possibly >2-D) weight to 2-D (in_features, out)."""
    shape = w.shape
    if w.ndim == 2:
        return w, shape
    return w.reshape(shape[0], -1), shape


def prune_params(
    params: Dict[str, Any],
    spec: FlexBlockSpec,
    *,
    criterion: str = "l1",
    align_cols: bool = False,
    keys: Tuple[str, ...] = PRUNABLE_KEYS,
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Prune every eligible stacked layer weight; returns (params, masks).

    The masks pytree mirrors ``params['layers']`` (None for untouched
    leaves) so it can be passed straight to ``make_train_step``.
    """
    layers = params["layers"]
    new_layers = dict(layers)
    masks: Dict[str, Any] = {"layers": {}}
    for name, w in layers.items():
        if name not in keys:
            masks["layers"][name] = None
            continue
        w_np = np.asarray(w)
        L = w_np.shape[0]
        per_layer = []
        for l in range(L):
            mat, orig = _as_matrix(w_np[l])
            if mat.ndim != 2 or 1 in mat.shape:
                per_layer.append(np.ones_like(mat, dtype=np.uint8))
                continue
            m = flexblock_mask(jnp.asarray(mat), spec, criterion,
                               align_cols=align_cols)
            per_layer.append(m)
        mask = np.stack(per_layer).reshape(w_np.shape)
        masks["layers"][name] = mask
        new_layers[name] = (w * jnp.asarray(mask, dtype=w.dtype))
    out = dict(params)
    out["layers"] = new_layers
    return out, masks


def sparsity_report(params: Dict[str, Any],
                    masks: Dict[str, Any]) -> Dict[str, float]:
    rep = {}
    for name, m in masks.get("layers", {}).items():
        if m is None:
            continue
        rep[f"layers/{name}"] = float(np.asarray(m).mean())
    total_nz = sum(float(np.asarray(m).sum())
                   for m in masks["layers"].values() if m is not None)
    total = sum(float(np.asarray(m).size)
                for m in masks["layers"].values() if m is not None)
    rep["overall_density"] = total_nz / max(total, 1)
    return rep


def cim_cost_of_model(
    cfg: ArchConfig,
    cim_arch,
    spec: FlexBlockSpec,
    *,
    seq_len: int = 128,
    batch: int = 1,
    mapping_strategy: str = "duplicate",
    input_sparsity: Optional[Dict[str, float]] = None,
):
    """Modeling-plane round trip: arch → MVM DAG → CIMinus cost report
    (sparse vs dense baseline)."""
    wl = lm_workload(cfg, seq_len=seq_len, batch=batch).set_sparsity(spec)
    mapping = default_mapping(cim_arch, mapping_strategy)
    rep = simulate(cim_arch, wl, mapping, input_sparsity=input_sparsity)
    dense = dense_baseline(cim_arch, wl, mapping)
    return rep, compare(rep, dense)
