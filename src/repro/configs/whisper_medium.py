"""whisper-medium [audio] — 24L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=51865 — encoder-decoder; conv frontend is a STUB: ``input_specs``
provides precomputed 1500-frame embeddings.  [arXiv:2212.04356; unverified]

Adaptation notes: whisper uses learned/sinusoidal positions and GELU
MLPs; we use RoPE positions (framework-wide) and non-gated GELU MLPs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,           # decoder layers
    enc_dec=True,
    enc_layers=24,
    enc_seq=1500,          # stub: precomputed audio-frame embeddings
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    gated_mlp=False,
    attention="global",
    subquadratic=False,    # full attention → long_500k skipped
)
