"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16, MHA) d_ff=24576
vocab=256000 — GeGLU, head_dim=256 (q/o projections are 16×256=4096 wide
on a 3072 residual stream).  [arXiv:2403.08295; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    gated_mlp=True,
    attention="global",
    tie_embeddings=True,
    subquadratic=False,    # pure full attention → long_500k skipped
)
