"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — alternating local(4096)/global attention, logit softcap.
[arXiv:2408.00118; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    gated_mlp=True,            # GeGLU
    attention="local_global",  # alternating sliding(4096) / global
    window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    post_norms=True,
    tie_embeddings=True,
    # local/global alternation bounds half the layers' KV to the window;
    # long_500k decode is O(L) per token → runs (see DESIGN.md §3.2).
    subquadratic=True,
)
