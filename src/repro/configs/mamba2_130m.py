"""mamba2-130m [ssm] — 24L d_model=768, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,             # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,                # no MLP block; SSM mixer only
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_head_dim=64,
    tie_embeddings=True,
    subquadratic=True,     # constant-size state → long_500k runs
)
