"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
(per expert) vocab=151936, MoE 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    gated_mlp=True,
    qk_norm=True,          # qwen3 family applies RMSNorm to q/k heads
    attention="global",
    rope_theta=1_000_000.0,
    subquadratic=False,    # pure full attention → long_500k skipped
)
