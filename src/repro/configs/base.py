"""Architecture configuration schema and shape-cell definitions.

Every assigned architecture ships a ``configs/<id>.py`` exposing
``CONFIG: ArchConfig`` with the exact assignment parameters, plus a
``reduced()`` variant for CPU smoke tests (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeCell", "SHAPE_CELLS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // n_heads
    n_experts: int = 1
    top_k: int = 1
    gated_mlp: bool = True
    attention: str = "global"      # global | local_global | sliding | none
    window: int = 4096
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    qk_norm: bool = False
    post_norms: bool = False       # gemma2-style post-layer norms
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256           # SSD intra-chunk length Q (perf knob)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0               # stub-frontend sequence (whisper frames /
    prefix_len: int = 0            # paligemma patch-prefix length
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    subquadratic: bool = False     # eligible for the long_500k cell
    capacity_factor: float = 1.25  # MoE dispatch capacity
    moe_chunked: bool = False      # scan experts in chunks (memory-bound MoE)

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def ssm_inner(self, d: Optional[int] = None) -> int:
        return 2 * (d or self.d_model)

    @property
    def ssm_heads(self) -> int:
        return max(1, self.ssm_inner() // self.ssm_head_dim)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = 0
        if self.attention != "none":
            attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
                + hd * self.n_heads * d
        if self.n_experts > 1:
            n_up = 2 if self.gated_mlp else 1
            ffn = self.n_experts * (n_up * d * self.d_ff + self.d_ff * d) \
                + d * self.n_experts
        elif self.d_ff > 0:
            n_up = 2 if self.gated_mlp else 1
            ffn = n_up * d * self.d_ff + self.d_ff * d
        else:
            ffn = 0
        ssm = 0
        if self.ssm_state > 0:
            din = self.ssm_inner()
            # in_proj emits [z, x, B, C, dt]
            ssm = d * (2 * din + 2 * self.ssm_state + self.ssm_heads) + din * d
        per_layer = attn + ffn + ssm + 2 * d
        total = self.n_layers * per_layer + self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.enc_dec:
            enc_attn = d * hd * self.n_heads * 2 + 2 * d * hd * self.n_kv_heads
            enc_ffn = 2 * d * self.d_ff  # non-gated enc MLP (whisper)
            total += self.enc_layers * (attn + enc_ffn + attn + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts <= 1:
            return self.param_count()
        d = self.d_model
        n_up = 2 if self.gated_mlp else 1
        ffn_all = self.n_experts * (n_up * d * self.d_ff + self.d_ff * d)
        ffn_act = self.top_k * (n_up * d * self.d_ff + self.d_ff * d)
        return int(self.param_count() - self.n_layers * (ffn_all - ffn_act))

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 if not self.enc_dec else 2,
            enc_layers=min(self.enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            window=32,
            enc_seq=min(self.enc_seq, 16) if self.enc_seq else 0,
            prefix_len=min(self.prefix_len, 8) if self.prefix_len else 0,
            # dropless capacity (cf ≥ E/k) so routing is sequence-order
            # independent — keeps decode ≡ forward exactly in smoke tests
            capacity_factor=float(max(2.0, min(self.n_experts, 4))),
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) cell: what gets lowered for the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPE_CELLS: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
