"""Architecture config registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig, ShapeCell, SHAPE_CELLS

_ARCH_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-medium": "whisper_medium",
    "gemma2-9b": "gemma2_9b",
    "llama3-8b": "llama3_8b",
    "qwen3-4b": "qwen3_4b",
    "gemma-7b": "gemma_7b",
    "mamba2-130m": "mamba2_130m",
    "hymba-1.5b": "hymba_1_5b",
    "paligemma-3b": "paligemma_3b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in _ARCH_MODULES}


def cells_for(cfg: ArchConfig) -> Dict[str, ShapeCell]:
    """The shape cells that run for this arch (long_500k needs
    sub-quadratic attention; see DESIGN.md §3.2)."""
    cells = dict(SHAPE_CELLS)
    if not cfg.subquadratic:
        cells.pop("long_500k")
    return cells


__all__ = ["ArchConfig", "ShapeCell", "SHAPE_CELLS", "get_config",
           "list_archs", "all_configs", "cells_for"]
