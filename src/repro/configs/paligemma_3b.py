"""paligemma-3b [vlm] — 18L d_model=2048 8H (kv=1, MQA) d_ff=16384
vocab=257216 — SigLIP vision frontend STUB (``input_specs`` provides 256
precomputed patch embeddings as a bidirectional prefix) + gemma decoder.
[arXiv:2407.07726; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    gated_mlp=True,
    attention="global",
    prefix_len=256,        # stub: SigLIP patch embeddings
    tie_embeddings=True,
    subquadratic=False,    # full attention → long_500k skipped
)
