"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf]

Adaptation: Hymba fuses attention and SSM heads in parallel within each
layer with per-branch output normalisation; attention is sliding-window
(global on a few layers — we use sliding everywhere, noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    gated_mlp=True,
    attention="sliding",
    window=1024,
    ssm_state=16,
    ssm_head_dim=64,
    subquadratic=True,     # sliding attn + SSM → long_500k runs
)
