"""Sharding rules for the production mesh.

Mesh axes: single-pod ``("data", "model")`` = (16, 16); multi-pod
``("pod", "data", "model")`` = (2, 16, 16).  Batch shards over
("pod","data"); weights tensor-parallel over "model"
(column-parallel qkv/up, row-parallel o/down ⇒ one all-reduce per pair);
embeddings vocab-sharded; MoE experts expert-parallel on "model";
optimizer state additionally ZeRO-1 sharded over "data".

Everything here degrades gracefully off-mesh: ``maybe_shard`` is a no-op
when no mesh is active and silently drops axes the active mesh lacks, so
the same model code runs on 1 CPU device (smoke tests) and on the
512-chip dry-run mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime import compat

__all__ = ["maybe_shard", "batch_axes", "spec_for_param", "tree_specs",
           "tree_shardings", "batch_spec", "cache_specs", "logits_spec",
           "filter_spec", "ShardOpts", "get_options", "set_options",
           "options"]


# ---------------------------------------------------------------------------
# Tunable sharding strategy (the §Perf hillclimb knobs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardOpts:
    """Global sharding strategy knobs.

    fsdp            — additionally shard weights over the "data" axis on a
                      second (divisible) dimension; layers gather them
                      per-use (FSDP/ZeRO-3 style).  Optimizer m/v always
                      use the fsdp=True specs when ``zero1`` (ZeRO-1).
    attn_kv_fallback— what to do when head counts don't divide the model
                      axis: "replicate" (no collectives in the score
                      einsums) or "head_dim" (legacy; shards the score
                      CONTRACTION dim ⇒ pathological all-reduces).
    ep_shardmap     — dispatch MoE via shard_map expert parallelism
                      (local per-shard routing + all_to_all) instead of
                      the global-scatter path that SPMD cannot data-
                      parallelise.
    """
    fsdp: bool = False
    # ZeRO-1 only pays off when params share the fsdp layout: GSPMD
    # reshards mismatched (model)↔(data,model) layouts via full
    # replication (§Perf llama it1 lesson) — so it defaults off and is
    # enabled together with fsdp.
    zero1: bool = False
    attn_kv_fallback: str = "replicate"
    ep_shardmap: bool = True


_OPTS = ShardOpts()


def get_options() -> ShardOpts:
    return _OPTS


def set_options(**kw) -> ShardOpts:
    global _OPTS
    _OPTS = dataclasses.replace(_OPTS, **kw)
    return _OPTS


@contextlib.contextmanager
def options(**kw):
    global _OPTS
    prev = _OPTS
    _OPTS = dataclasses.replace(_OPTS, **kw)
    try:
        yield _OPTS
    finally:
        _OPTS = prev


def _mesh_axis_names() -> Tuple[str, ...]:
    mesh = compat.get_abstract_mesh()
    return tuple(mesh.axis_names) if not mesh.empty else ()


def filter_spec(spec: P) -> Optional[P]:
    """Drop axes absent from the active mesh; None when no mesh."""
    names = _mesh_axis_names()
    if not names:
        return None
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def maybe_shard(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint when a mesh is active; identity otherwise."""
    f = filter_spec(spec)
    if f is None:
        return x
    return jax.lax.with_sharding_constraint(x, f)


def batch_axes() -> Any:
    """The mesh axes a global batch dimension shards over."""
    names = _mesh_axis_names()
    axes = tuple(a for a in ("pod", "data") if a in names)
    return axes if axes else None


# ---------------------------------------------------------------------------
# Spec assignment: per-leaf, driven by (trailing key name, leaf shape).
# Divisibility-aware: an axis only shards if its extent divides the mesh
# axis size (e.g. hymba's 25 q-heads fall back to head_dim sharding; odd
# vocabs fall back to d_model sharding).
# ---------------------------------------------------------------------------

_MODEL = 16  # production "model" axis size


def _b(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _div(n: int) -> bool:
    return n % _MODEL == 0


_DATA = 16   # production "data" axis size (per pod)


def _fsdp_augment(spec_entries, shape) -> P:
    """Add "data" sharding on the largest still-unsharded divisible axis
    (FSDP / ZeRO second-axis sharding)."""
    entries = list(spec_entries)
    best, best_ax = 0, -1
    for ax, (e, n) in enumerate(zip(entries, shape)):
        if ax == 0 and len(shape) >= 3:
            continue   # never shard the layer-scan axis
        if e is None and n % _DATA == 0 and n > best:
            best, best_ax = n, ax
    if best_ax >= 0:
        entries[best_ax] = "data"
    return P(*entries)


def spec_for_param(key: str, shape: Tuple[int, ...],
                   fsdp: Optional[bool] = None) -> P:
    nd = len(shape)
    fsdp = _OPTS.fsdp if fsdp is None else fsdp

    def out(*entries):
        if fsdp:
            return _fsdp_augment(entries, shape)
        return P(*entries)

    if key == "embed":
        if _div(shape[0]):
            return out("model", None)
        return out(None, "model")
    if key == "lm_head":
        if _div(shape[1]):
            return out(None, "model")
        return out("model", None)
    if key in ("wq", "wo") and nd == 4:
        # (L, D, Hq, hd) / (L, Hq, hd, D): shard heads when divisible.
        # With the "replicate" fallback, NEVER shard q's head_dim — it is
        # the contraction dim of the score einsum, and a double-sharded
        # contraction (q AND k on hd) forces score-matrix all-reduces.
        h_ax = 2 if key == "wq" else 1
        spec = [None] * nd
        if _div(shape[h_ax]):
            spec[h_ax] = "model"
        elif _OPTS.attn_kv_fallback == "head_dim":
            spec[3 if key == "wq" else 2] = "model"
        return out(*spec)
    if key in ("wk", "wv") and nd == 4:
        # (L, D, Hkv, hd): shard kv heads when divisible.  Otherwise hd-
        # sharding is safe ONLY when q is head-sharded (XLA then inserts a
        # cheap k/v all-gather while keeping the 16× projection sharding);
        # when q-heads are ALSO non-divisible (hymba 25H/5kv) both sides of
        # the score contraction would be hd-sharded ⇒ score all-reduces —
        # replicate instead.  Whether q-heads divide is tree context,
        # provided by tree_specs/tree_shardings via _QHEADS_DIVISIBLE.
        spec = [None] * nd
        if _div(shape[2]):
            spec[2] = "model"
        elif _OPTS.attn_kv_fallback == "head_dim":   # legacy pathological
            spec[3] = "model"
        # else: replicate.  Measured (llama3 L=1/2 A/B): replicated k/v
        # projections cost LESS than hd-sharded ones once SPMD's
        # "involuntary full rematerialization" resharding copies are
        # counted (6.51e12 vs 7.47e12 flops/layer, bytes equal).
        return out(*spec)
    if key in ("w_gate", "w_up") and nd == 4:      # (L, E, D, F) experts
        return out(None, "model", None, None)
    if key == "w_down" and nd == 4:                # (L, E, F, D)
        return out(None, "model", None, None)
    if key in ("w_gate", "w_up") and nd == 3:      # (L, D, F)
        return out(None, None, "model")
    if key == "w_down" and nd == 3:                # (L, F, D)
        return out(None, "model", None)
    if key == "w_in" and nd == 3:                  # (L, D, e)
        return out(None, None, "model") if _div(shape[2]) else out(*([None] * nd))
    if key == "w_out" and nd == 3:                 # (L, din, D)
        return out(None, "model", None) if _div(shape[1]) else out(*([None] * nd))
    if key == "conv_w":                            # (L, 4, din)
        return out(None, None, "model") if _div(shape[2]) else out(*([None] * nd))
    if key == "w_router":                          # (L, D, E)
        return out(None, None, "model") if _div(shape[2]) else out(None, None, None)
    return P(*([None] * nd))                       # norms, biases, dynamics


def _leaf_key(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""




def tree_specs(template, fsdp: Optional[bool] = None) -> Any:
    """PartitionSpec tree matching an arbitrary params/opt-state tree."""
    def assign(path, leaf):
        return spec_for_param(_leaf_key(path), tuple(leaf.shape), fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(assign, template)


def tree_shardings(mesh, template, fsdp: Optional[bool] = None) -> Any:
    """NamedSharding tree for ``jax.jit`` in_shardings."""
    from jax.sharding import NamedSharding

    def assign(path, leaf):
        spec = spec_for_param(_leaf_key(path), tuple(leaf.shape), fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, template)


def batch_spec(*, multi_pod: bool = False) -> P:
    return P(_b(multi_pod), None)


def logits_spec(*, multi_pod: bool = False) -> P:
    return P(_b(multi_pod), None, "model")


def cache_specs(cfg, cell, *, multi_pod: bool = False) -> Dict[str, Any]:
    """KV/SSM cache shardings for serving.

    decode_32k (large batch): batch over ("pod","data"), kv-heads over
    "model" when divisible else sequence over "model".
    long_500k (batch=1): sequence over every mesh axis (sequence
    parallelism); SSM state replicated (it is small and seq-free).
    """
    b = _b(multi_pod)
    data_size = 16 * (2 if multi_pod else 1)
    batched = cell.global_batch >= data_size
    if batched:
        if cfg.n_kv_heads % _MODEL == 0:
            kv = P(None, b, None, "model", None)
        else:
            kv = P(None, b, "model", None, None)
    else:
        kv = P(None, None, b + ("model",), None, None)
    specs: Dict[str, Any] = {"pos": P()}
    if cfg.attention != "none":
        specs["k"] = specs["v"] = kv
    if cfg.ssm_state > 0:
        # state (L, B, H, Pd, N), conv (L, B, 3, din)
        if batched:
            nspec = "model" if _div(cfg.ssm_state) else None
            specs["ssm"] = P(None, b, None, None, nspec)
            din = cfg.ssm_inner()
            specs["conv"] = P(None, b, None, "model" if _div(din) else None)
        else:
            specs["ssm"] = P(None, None, None, None, None)
            specs["conv"] = P(None, None, None, None)
    if cfg.enc_dec:
        hspec = "model" if _div(cfg.n_kv_heads) else None
        cb = b if batched else None
        specs["cross_k"] = P(None, cb, None, hspec, None)
        specs["cross_v"] = P(None, cb, None, hspec, None)
    return specs
