"""Gradient compression for cross-pod reductions.

Block-wise symmetric int8 quantisation with deterministic-seeded
stochastic rounding.  At multi-pod scale the pod-axis all-reduce crosses
the slow DCI links; quantising the pod-crossing reduction to int8 cuts
that traffic 4× (the "data"-axis reduction inside a pod stays bf16/f32).

Applied in the train step as quantise→dequantise around the gradient
(XLA then reduces the re-expanded tensor; on real multi-pod deployments
the quantised payload itself is what crosses the DCI — we model the
numerics faithfully and the dry-run's collective bytes reflect the
uncompressed in-pod schedule; see DESIGN.md).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8_stochastic", "dequantize_int8",
           "compress_decompress_grads"]

_BLOCK = 256


def quantize_int8_stochastic(x: jnp.ndarray, key) -> tuple:
    """Block-wise symmetric int8 with stochastic rounding."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scaled = blocks / scale
    noise = jax.random.uniform(key, scaled.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale, x.shape, pad


def dequantize_int8(q, scale, shape, pad) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_decompress_grads(grads: Any, seed: int = 0) -> Any:
    """Round-trip every gradient leaf through int8 (numerics of a
    compressed cross-pod all-reduce)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = []
    for leaf, key in zip(leaves, keys):
        q, s, shape, pad = quantize_int8_stochastic(leaf, key)
        out.append(dequantize_int8(q, s, shape, pad).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
