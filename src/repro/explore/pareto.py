"""Post-processing for sweep rows: Pareto frontiers and top-k tables.

Rows are the plain dicts the engine emits (CSV-ready).  The frontier is
computed over any subset of numeric columns; by default the three axes
the paper's exploration use-cases trade off — latency, energy, and index
storage (§VII-B/C).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

__all__ = ["pareto_front", "top_k", "DEFAULT_OBJECTIVES"]

# (column, direction): direction 'min' or 'max'
DEFAULT_OBJECTIVES: Tuple[Tuple[str, str], ...] = (
    ("latency_ms", "min"),
    ("energy_uj", "min"),
    ("index_kib", "min"),
)


def _vector(row: Dict, objectives: Sequence[Tuple[str, str]]) -> List[float]:
    """Objective vector in canonical minimisation form."""
    v = []
    for col, direction in objectives:
        x = float(row[col])
        v.append(x if direction == "min" else -x)
    return v


def _dominates(a: List[float], b: List[float]) -> bool:
    """True iff ``a`` is no worse than ``b`` everywhere and better somewhere."""
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(
    rows: Sequence[Dict],
    objectives: Sequence[Tuple[str, str]] = DEFAULT_OBJECTIVES,
) -> List[Dict]:
    """Non-dominated subset of ``rows``, preserving input order.

    Rows missing an objective column are excluded from the frontier
    (e.g. derived "finding" rows mixed into benchmark output).  Duplicate
    objective vectors all survive (none strictly dominates the other).
    """
    scored = [(i, _vector(r, objectives)) for i, r in enumerate(rows)
              if all(c in r and r[c] is not None for c, _ in objectives)]
    front = []
    for i, vi in scored:
        if not any(_dominates(vj, vi) for j, vj in scored if j != i):
            front.append(rows[i])
    return front


def top_k(
    rows: Sequence[Dict],
    metric: str,
    k: int = 5,
    *,
    direction: str = "min",
) -> List[Dict]:
    """The ``k`` best rows by one metric ('min' = lower is better)."""
    usable = [r for r in rows if metric in r and r[metric] is not None]
    return sorted(usable, key=lambda r: float(r[metric]),
                  reverse=(direction == "max"))[:k]
