"""Post-processing for sweep rows: Pareto frontiers and top-k tables.

Rows are the plain dicts the engine emits (CSV-ready).  The frontier is
computed over any subset of numeric columns; by default the three axes
the paper's exploration use-cases trade off — latency, energy, and index
storage (§VII-B/C).

Two surfaces share the dominance semantics:

* :func:`pareto_front` / :func:`top_k` — one-shot over a materialised
  row list (small sweeps, tests, CLI output).
* :class:`ParetoFront` / :class:`StreamingTopK` — incremental
  maintenance for million-point runs that never hold all rows in
  memory.  Feeding the same rows in the same order produces exactly the
  one-shot results (``tests/test_pareto.py`` pins the equivalence).

NaN semantics: a row with a NaN objective value is **excluded** from the
frontier — NaN compares false against everything, so it can neither
dominate nor be dominated, and keeping such rows would grow the front
with points that carry no trade-off information.  ``inf`` participates
normally (it is simply the worst value on its axis).  Rows missing an
objective column (or carrying ``None``) are likewise excluded.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["pareto_front", "top_k", "ParetoFront", "StreamingTopK",
           "DEFAULT_OBJECTIVES"]

# (column, direction): direction 'min' or 'max'
DEFAULT_OBJECTIVES: Tuple[Tuple[str, str], ...] = (
    ("latency_ms", "min"),
    ("energy_uj", "min"),
    ("index_kib", "min"),
)


def _vector(row: Dict, objectives: Sequence[Tuple[str, str]]
            ) -> Optional[List[float]]:
    """Objective vector in canonical minimisation form, or ``None`` if
    the row is unusable (missing/None/NaN objective)."""
    v = []
    for col, direction in objectives:
        x = row.get(col)
        if x is None:
            return None
        x = float(x)
        if math.isnan(x):
            return None
        v.append(x if direction == "min" else -x)
    return v


def _dominates(a: List[float], b: List[float]) -> bool:
    """True iff ``a`` is no worse than ``b`` everywhere and better somewhere."""
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(
    rows: Sequence[Dict],
    objectives: Sequence[Tuple[str, str]] = DEFAULT_OBJECTIVES,
) -> List[Dict]:
    """Non-dominated subset of ``rows``, preserving input order.

    Rows missing an objective column — or carrying ``None``/NaN there —
    are excluded from the frontier (e.g. derived "finding" rows mixed
    into benchmark output, failed degraded-mode points).  Duplicate
    objective vectors all survive (none strictly dominates the other).
    """
    scored = []
    for i, r in enumerate(rows):
        v = _vector(r, objectives)
        if v is not None:
            scored.append((i, v))
    front = []
    for i, vi in scored:
        if not any(_dominates(vj, vi) for j, vj in scored if j != i):
            front.append(rows[i])
    return front


def top_k(
    rows: Sequence[Dict],
    metric: str,
    k: int = 5,
    *,
    direction: str = "min",
) -> List[Dict]:
    """The ``k`` best rows by one metric ('min' = lower is better).

    Rows whose metric is missing, ``None``, or NaN are excluded — NaN
    would otherwise land at a sort-implementation-defined position.
    """
    usable = [r for r in rows if metric in r and r[metric] is not None
              and not math.isnan(float(r[metric]))]
    return sorted(usable, key=lambda r: float(r[metric]),
                  reverse=(direction == "max"))[:k]


class ParetoFront:
    """Incremental Pareto front: O(front) per added row, O(front) memory.

    Feeding every row of a sweep (in any order) leaves exactly the rows
    :func:`pareto_front` would return; in *input* order the survivors
    come out in input order too, so the equivalence is list-equality.
    Correctness is dominance transitivity: a row evicted by ``r`` stays
    dominated by whatever later evicts ``r``, so discarding dominated
    rows immediately never loses a final survivor.
    """

    def __init__(self, objectives: Sequence[Tuple[str, str]]
                 = DEFAULT_OBJECTIVES):
        self.objectives = tuple(objectives)
        self._rows: List[Dict] = []
        self._vecs: List[List[float]] = []
        self.seen = 0            # usable rows offered (excl. NaN/missing)
        self.skipped = 0         # rows excluded as unusable

    def add(self, row: Dict) -> bool:
        """Offer one row; returns True if it (currently) survives."""
        v = _vector(row, self.objectives)
        if v is None:
            self.skipped += 1
            return False
        self.seen += 1
        for u in self._vecs:
            if _dominates(u, v):
                return False
        keep_r, keep_v = [], []
        for r, u in zip(self._rows, self._vecs):
            if not _dominates(v, u):
                keep_r.append(r)
                keep_v.append(u)
        keep_r.append(row)
        keep_v.append(v)
        self._rows, self._vecs = keep_r, keep_v
        return True

    def extend(self, rows: Sequence[Dict]) -> None:
        for row in rows:
            self.add(row)

    def front(self) -> List[Dict]:
        """The current non-dominated set, in arrival order."""
        return list(self._rows)

    def __len__(self) -> int:
        return len(self._rows)


class StreamingTopK:
    """Incremental top-k by one metric: a bounded heap over the stream.

    Matches :func:`top_k` exactly — including its stable-sort tie
    order — by keying entries ``(value, arrival_index)``: among equal
    values the earliest row wins, which is precisely what a stable sort
    over the stream produces.
    """

    def __init__(self, metric: str, k: int = 5, *, direction: str = "min"):
        if direction not in ("min", "max"):
            raise ValueError(f"direction {direction!r} is not 'min'/'max'")
        self.metric = metric
        self.k = max(0, int(k))
        self.direction = direction
        # heap of (sort_key, row) where sort_key orders WORST-first so
        # heappushpop evicts the worst; idx breaks value ties without
        # ever comparing row dicts
        self._heap: List[Tuple[Tuple[float, float], int, Dict]] = []
        self._idx = 0

    def add(self, row: Dict) -> None:
        x = row.get(self.metric)
        if x is None:
            return
        val = float(x)
        if math.isnan(val):
            return
        i = self._idx
        self._idx += 1
        if self.direction == "min":
            entry = ((-val, -i), i, row)     # root = largest val/latest
        else:
            entry = ((val, -i), i, row)      # root = smallest val/latest
        if self.k == 0:
            return
        if len(self._heap) < self.k:
            heapq.heappush(self._heap, entry)
        else:
            heapq.heappushpop(self._heap, entry)

    def extend(self, rows: Sequence[Dict]) -> None:
        for row in rows:
            self.add(row)

    def best(self) -> List[Dict]:
        """The current top-k rows, best first (= :func:`top_k` order).

        Value ties break on arrival index ascending in BOTH directions —
        ``top_k``'s stable sort keeps arrival order among equals whether
        or not it reverses."""
        sign = -1.0 if self.direction == "max" else 1.0
        return [row for _key, i, row in
                sorted(self._heap,
                       key=lambda e: (sign * float(e[2][self.metric]),
                                      e[1]))]

    def __len__(self) -> int:
        return len(self._heap)
