"""CIMinus design-space exploration engine (paper §VII use-cases).

A job-based sweep runner over the cost model in :mod:`repro.core`:

* :mod:`repro.explore.job`    — hashable, content-addressed ``ExploreJob``
* :mod:`repro.explore.cache`  — memory + on-disk result memoisation
* :mod:`repro.explore.runner` — dedup / cache / process fan-out with
  deterministic row ordering
* :mod:`repro.explore.batch`  — batched evaluation: variant groups share
  one costing pass, bit-identical to per-point results
* :mod:`repro.explore.search` — guided search policies (exhaustive /
  successive halving / evolutionary) over lazily-indexed point spaces
* :mod:`repro.explore.sweeps` — the paper's §VII-B/§VII-C grids as jobs,
  plus streaming evaluation for million-point runs
* :mod:`repro.explore.pareto` — Pareto frontiers and top-k tables,
  one-shot and incremental

CLI: ``python -m repro.explore <sweep> [options]`` runs a named sweep
and emits CSV/JSON (see ``--help``).

The legacy ``repro.core.explorer`` sweeps remain as thin compatibility
wrappers over this engine.
"""
from . import faults
from .batch import evaluate_batch, group_jobs, job_keys, plan_batches
from .cache import (STORE_SCHEMA, CacheStats, KeyJournal, ResultCache,
                    ResultStore, StoreError)
from .faults import FaultError, FaultPlan, parse_fault_spec
from .job import CACHE_SCHEMA, ExploreJob, canonical, content_key
from .pareto import (DEFAULT_OBJECTIVES, ParetoFront, StreamingTopK,
                     pareto_front, top_k)
from .runner import (JobFailure, RunStats, SweepFailure, SweepRunner,
                     evaluate_job)
from .search import (SEARCH_KINDS, PointSpace, SearchPolicy, SearchResult,
                     estimate_job, estimate_jobs, run_search)
from .sweeps import (GridPoint, StreamResult, SweepResult, mapping_sweep,
                     org_sweep, run_grid, schedule_sweep, sparsity_sweep,
                     stream_grid)

__all__ = [
    "CACHE_SCHEMA", "ExploreJob", "canonical", "content_key",
    "CacheStats", "ResultCache", "ResultStore", "KeyJournal",
    "StoreError", "STORE_SCHEMA",
    "RunStats", "SweepRunner", "evaluate_job",
    "JobFailure", "SweepFailure",
    "faults", "FaultPlan", "FaultError", "parse_fault_spec",
    "job_keys", "group_jobs", "plan_batches", "evaluate_batch",
    "SEARCH_KINDS", "SearchPolicy", "SearchResult", "PointSpace",
    "estimate_job", "estimate_jobs", "run_search",
    "GridPoint", "SweepResult", "StreamResult", "run_grid", "stream_grid",
    "sparsity_sweep", "mapping_sweep", "org_sweep", "schedule_sweep",
    "DEFAULT_OBJECTIVES", "pareto_front", "top_k",
    "ParetoFront", "StreamingTopK",
]
