"""CIMinus design-space exploration engine (paper §VII use-cases).

A job-based sweep runner over the cost model in :mod:`repro.core`:

* :mod:`repro.explore.job`    — hashable, content-addressed ``ExploreJob``
* :mod:`repro.explore.cache`  — memory + on-disk result memoisation
* :mod:`repro.explore.runner` — dedup / cache / process fan-out with
  deterministic row ordering
* :mod:`repro.explore.sweeps` — the paper's §VII-B/§VII-C grids as jobs
* :mod:`repro.explore.pareto` — Pareto frontiers and top-k tables

CLI: ``python -m repro.explore <sweep> [options]`` runs a named sweep
and emits CSV/JSON (see ``--help``).

The legacy ``repro.core.explorer`` sweeps remain as thin compatibility
wrappers over this engine.
"""
from .cache import CacheStats, ResultCache
from .job import CACHE_SCHEMA, ExploreJob, canonical, content_key
from .pareto import DEFAULT_OBJECTIVES, pareto_front, top_k
from .runner import RunStats, SweepRunner, evaluate_job
from .sweeps import (GridPoint, SweepResult, mapping_sweep, org_sweep,
                     run_grid, schedule_sweep, sparsity_sweep)

__all__ = [
    "CACHE_SCHEMA", "ExploreJob", "canonical", "content_key",
    "CacheStats", "ResultCache",
    "RunStats", "SweepRunner", "evaluate_job",
    "GridPoint", "SweepResult", "run_grid",
    "sparsity_sweep", "mapping_sweep", "org_sweep", "schedule_sweep",
    "DEFAULT_OBJECTIVES", "pareto_front", "top_k",
]
