"""CIMinus design-space exploration engine (paper §VII use-cases).

A job-based sweep runner over the cost model in :mod:`repro.core`:

* :mod:`repro.explore.job`    — hashable, content-addressed ``ExploreJob``
* :mod:`repro.explore.cache`  — memory + on-disk result memoisation
* :mod:`repro.explore.runner` — dedup / cache / process fan-out with
  deterministic row ordering
* :mod:`repro.explore.sweeps` — the paper's §VII-B/§VII-C grids as jobs
* :mod:`repro.explore.pareto` — Pareto frontiers and top-k tables

CLI: ``python -m repro.explore <sweep> [options]`` runs a named sweep
and emits CSV/JSON (see ``--help``).

The legacy ``repro.core.explorer`` sweeps remain as thin compatibility
wrappers over this engine.
"""
from . import faults
from .cache import (STORE_SCHEMA, CacheStats, KeyJournal, ResultCache,
                    ResultStore, StoreError)
from .faults import FaultError, FaultPlan, parse_fault_spec
from .job import CACHE_SCHEMA, ExploreJob, canonical, content_key
from .pareto import DEFAULT_OBJECTIVES, pareto_front, top_k
from .runner import (JobFailure, RunStats, SweepFailure, SweepRunner,
                     evaluate_job)
from .sweeps import (GridPoint, SweepResult, mapping_sweep, org_sweep,
                     run_grid, schedule_sweep, sparsity_sweep)

__all__ = [
    "CACHE_SCHEMA", "ExploreJob", "canonical", "content_key",
    "CacheStats", "ResultCache", "ResultStore", "KeyJournal",
    "StoreError", "STORE_SCHEMA",
    "RunStats", "SweepRunner", "evaluate_job",
    "JobFailure", "SweepFailure",
    "faults", "FaultPlan", "FaultError", "parse_fault_spec",
    "GridPoint", "SweepResult", "run_grid",
    "sparsity_sweep", "mapping_sweep", "org_sweep", "schedule_sweep",
    "DEFAULT_OBJECTIVES", "pareto_front", "top_k",
]
