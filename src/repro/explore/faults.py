"""Deterministic fault injection for the explore plane.

Chaos testing only earns trust when the chaos is *reproducible*: a
:class:`FaultPlan` decides — as a pure function of ``(seed, kind,
job key)`` — whether a given evaluation crashes its worker, hangs,
raises a transient exception, or has its store entry corrupted on
write.  The same plan against the same sweep injects the same faults
in any process, on any host, in any dispatch order, which is what lets
``tests/test_faults.py`` assert that every *surviving* report of a
faulted sweep is bit-identical to the fault-free run.

Fault kinds
-----------
``crash``    the worker process dies mid-evaluation (``os._exit``) —
             the runner sees ``BrokenProcessPool`` and must self-heal.
``hang``     the evaluation sleeps ``hang_s`` seconds — only a per-job
             timeout recovers the worker.
``exc``      a transient :class:`FaultError` is raised — bounded retry
             absorbs it.
``corrupt``  the result's on-disk payload is garbled before the write —
             the store must treat it as a miss on read-back.

Spec grammar (``REPRO_FAULTS`` environment variable)
----------------------------------------------------
Comma-separated ``name=value`` pairs::

    REPRO_FAULTS="seed=7,crash=0.1,exc=0.2,times=1"
    REPRO_FAULTS="seed=1,hang=1.0,hang_s=30,match=ab12,times=inf"

* ``seed``   integer salt for the selection digest (default 0);
* ``crash`` / ``hang`` / ``exc`` / ``corrupt``   injection rates in
  [0, 1] — the fraction of job keys the fault selects (default 0);
* ``times``  how many *attempts* of a selected job the fault fires on
  (default 1, so one retry recovers; ``inf`` makes a permanent poison
  job for quarantine tests);
* ``hang_s`` sleep length for ``hang`` faults (default 3600);
* ``match``  hex prefix — only job keys starting with it are eligible
  (default "" = all keys); lets a test target one specific job.

Activation mirrors :mod:`repro.obs`: :func:`install` sets a process
global and exports ``REPRO_FAULTS`` so pool workers (fork or spawn)
inherit the plan; :func:`active_plan` consults the environment once per
process and is a single global read afterwards, so the disabled-mode
cost of the :func:`maybe_fail` hook in the evaluation hot path is a
``None`` check (pinned by ``benchmarks/fault_overhead.py``).

Everything here is jax-free and deterministic by construction: the
selection digest is ``blake2b`` (never the salted builtin ``hash``),
and no wall clock or entropy source is read — the determinism analysis
pass scans this module like the rest of ``repro.explore``.

The one contract this module must never break: fault knobs are
*runner-level* state.  They may not become :class:`ExploreJob` fields
or ``simulate()`` parameters — a fault plan changes how a sweep
executes, never what a job computes, so cache keys must not vary with
it (machine-checked by the ``cache-key`` analysis pass, CIM206).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import time
from typing import Dict, Optional, Union

__all__ = [
    "FAULT_KINDS", "FaultError", "FaultPlan", "parse_fault_spec",
    "install", "uninstall", "active_plan", "mark_worker", "in_worker",
    "maybe_fail", "corrupt_payload", "CRASH_EXIT_CODE",
]

FAULT_KINDS = ("crash", "hang", "exc", "corrupt")

# exit code of a fault-injected worker crash — distinguishable from a
# real interpreter death in test logs
CRASH_EXIT_CODE = 113

_ENV_VAR = "REPRO_FAULTS"


class FaultError(RuntimeError):
    """The injected transient exception (``exc`` faults raise this)."""


def _unit(seed: int, kind: str, key: str) -> float:
    """Uniform-ish value in [0, 1) derived from content, never entropy."""
    digest = hashlib.blake2b(f"{seed}:{kind}:{key}".encode(),
                             digest_size=8).digest()
    return int.from_bytes(digest, "big") / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, content-addressed fault schedule (see module docstring)."""

    seed: int = 0
    crash: float = 0.0
    hang: float = 0.0
    exc: float = 0.0
    corrupt: float = 0.0
    times: float = 1.0          # attempts a selected fault fires on (inf ok)
    hang_s: float = 3600.0
    match: str = ""             # key prefix filter ("" = every key)

    def __post_init__(self):
        for kind in FAULT_KINDS:
            rate = getattr(self, kind)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"fault rate {kind}={rate} not in [0, 1]")
        if self.times < 0:
            raise ValueError(f"times={self.times} must be >= 0")

    def rate(self, kind: str) -> float:
        if kind not in FAULT_KINDS:
            raise KeyError(f"unknown fault kind {kind!r}")
        return getattr(self, kind)

    def selected(self, kind: str, key: str) -> bool:
        """Does this plan target ``key`` with ``kind`` at all?  Pure
        function of (seed, kind, key) — stable across processes."""
        rate = self.rate(kind)
        if rate <= 0.0 or not key.startswith(self.match):
            return False
        return _unit(self.seed, kind, key) < rate

    def should(self, kind: str, key: str, attempt: int = 0) -> bool:
        """Fire ``kind`` on this attempt?  Selected faults fire on the
        first ``times`` attempts, so bounded retry recovers transient
        faults while ``times=inf`` models a permanent poison job."""
        return attempt < self.times and self.selected(kind, key)

    def spec(self) -> str:
        """Serialise back to the ``REPRO_FAULTS`` grammar."""
        parts = [f"seed={self.seed}"]
        for kind in FAULT_KINDS:
            rate = getattr(self, kind)
            if rate > 0:
                parts.append(f"{kind}={rate!r}")
        if self.times != 1.0:
            times = "inf" if math.isinf(self.times) else repr(self.times)
            parts.append(f"times={times}")
        if self.hang_s != 3600.0:
            parts.append(f"hang_s={self.hang_s!r}")
        if self.match:
            parts.append(f"match={self.match}")
        return ",".join(parts)


def parse_fault_spec(text: str) -> FaultPlan:
    """Parse the ``REPRO_FAULTS`` grammar into a :class:`FaultPlan`."""
    fields: Dict[str, Union[int, float, str]] = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, value = part.partition("=")
        name = name.strip()
        value = value.strip()
        if not sep or not name or not value:
            raise ValueError(f"fault spec entry {part!r} is not name=value")
        if name == "seed":
            fields["seed"] = int(value)
        elif name == "match":
            fields["match"] = value
        elif name in (*FAULT_KINDS, "times", "hang_s"):
            fields[name] = float(value)      # float('inf') parses for times
        else:
            raise ValueError(
                f"unknown fault spec field {name!r} "
                f"(known: seed, {', '.join(FAULT_KINDS)}, times, hang_s, "
                f"match)")
    return FaultPlan(**fields)   # type: ignore[arg-type]


# -- process state ------------------------------------------------------------

_PLAN: Optional[FaultPlan] = None
_ENV_CHECKED = False
_OWNS_ENV = False
_IN_WORKER = False


def active_plan() -> Optional[FaultPlan]:
    """The installed plan, or None.  First call per process consults
    ``REPRO_FAULTS`` so pool workers inherit the parent's plan; after
    that the disabled fast path is one global read."""
    global _ENV_CHECKED, _PLAN
    if _PLAN is not None:
        return _PLAN
    if not _ENV_CHECKED:
        _ENV_CHECKED = True
        spec = os.environ.get(_ENV_VAR)
        if spec:
            _PLAN = parse_fault_spec(spec)
    return _PLAN


def install(plan: Union[FaultPlan, str], *, export_env: bool = True
            ) -> FaultPlan:
    """Activate ``plan`` for this process (and, via ``REPRO_FAULTS``,
    for every worker process it spawns or forks)."""
    global _PLAN, _ENV_CHECKED, _OWNS_ENV
    if isinstance(plan, str):
        plan = parse_fault_spec(plan)
    _PLAN = plan
    _ENV_CHECKED = True
    if export_env:
        os.environ[_ENV_VAR] = plan.spec()
        _OWNS_ENV = True
    return plan


def uninstall() -> None:
    """Deactivate fault injection (and drop the env hand-off we set)."""
    global _PLAN, _ENV_CHECKED, _OWNS_ENV
    _PLAN = None
    _ENV_CHECKED = True                       # do not re-install from env
    if _OWNS_ENV:
        os.environ.pop(_ENV_VAR, None)
        _OWNS_ENV = False


def mark_worker() -> None:
    """Called from the pool initializer: this process may be killed by
    ``crash`` faults (the parent never is — see :func:`maybe_fail`)."""
    global _IN_WORKER
    _IN_WORKER = True


def in_worker() -> bool:
    return _IN_WORKER


# -- injection points ---------------------------------------------------------

def maybe_fail(key: str, attempt: int = 0) -> None:
    """Evaluation-time injection point (called by ``evaluate_job``).

    Fires in selection order hang → crash → exc so a key selected by
    several kinds behaves predictably.  ``crash`` only hard-kills pool
    workers (:func:`mark_worker`); in the parent process — sequential
    sweeps, unit tests — it degrades to a :class:`FaultError` so the
    test process survives while the retry path is still exercised.
    """
    plan = active_plan()
    if plan is None:
        return
    if plan.should("hang", key, attempt):
        time.sleep(plan.hang_s)
    if plan.should("crash", key, attempt):
        if _IN_WORKER:
            os._exit(CRASH_EXIT_CODE)
        raise FaultError(f"injected crash (in-process) for {key[:16]}")
    if plan.should("exc", key, attempt):
        raise FaultError(f"injected transient exception for {key[:16]} "
                         f"(attempt {attempt})")


def corrupt_payload(key: str, payload: bytes, attempt: int = 0) -> bytes:
    """Store-write injection point: garble ``payload`` when a ``corrupt``
    fault targets ``key`` — simulates a torn/bit-rotted entry the store
    must survive on read-back."""
    plan = active_plan()
    if plan is None or not plan.should("corrupt", key, attempt):
        return payload
    # truncate and prepend junk: invalid as JSON, wrong length, and
    # deterministic (no entropy) so reruns corrupt identically
    return b"\x00CORRUPT\x00" + payload[: max(1, len(payload) // 3)]
