"""Exploration jobs: hashable, content-addressed simulation requests.

A sweep is a list of :class:`ExploreJob` — pure-data descriptions of one
simulator evaluation (a sparse :func:`~repro.core.costmodel.simulate` or
a dense baseline).  Jobs carry fully-materialised inputs (arch, workload
with sparsity already bound, mapping), so they pickle cleanly across
process boundaries and two jobs with identical content produce identical
cache keys no matter which process, run, or host built them.

The key is a digest over a *canonical form* of the job: dataclasses are
flattened to ``(class-name, sorted fields)``, dicts are sorted, numpy
arrays are serialised with their dtype and shape.  ``CACHE_SCHEMA`` salts
the digest so stale on-disk results are invalidated whenever the cost
model changes shape.

Execution-policy knobs stay out of jobs by contract: retry budgets,
timeouts, backoff, fault-injection plans (:mod:`repro.explore.faults`)
change how a sweep *executes*, never what a job *computes*, so they are
runner-level state and must not become job fields or ``simulate()``
parameters — cache keys may not vary with them.  The ``cache-key``
analysis pass machine-checks this (CIM206: no fault-named fields here,
no ``faults`` import in this module).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from ..calibrate.profile import CalibrationProfile
from ..core.hardware import CIMArch
from ..core.mapping import MappingSpec
from ..core.schedule import SchedulePolicy
from ..core.workload import Workload

__all__ = ["ExploreJob", "canonical", "content_key", "CACHE_SCHEMA"]

# Bump when the cost model or job serialisation changes incompatibly:
# on-disk caches keyed under an older schema are simply never hit again.
# 2: jobs grew a calibration-profile field (repro.calibrate).
# 3: synthesised keep-grid seeds became shape-addressed (shared across
#    same-shape ops), changing simulated results for FullBlock patterns.
# 4: jobs grew a schedule-policy field (repro.core.schedule); reports
#    carry ScheduleResult/per-op placement fields and the index-capacity
#    check dropped its spurious 64x slack.
# 5: workloads carry source_digest (repro.trace): traced DAGs are keyed
#    by the jaxpr content digest of the program they were lowered from,
#    and lm_workload grew the attention context matmul (attn_ctx).
CACHE_SCHEMA = 5


@functools.lru_cache(maxsize=None)
def _sorted_field_names(cls) -> Tuple[str, ...]:
    """Field names of a dataclass type, sorted once per class.

    Field names are unique, so sorting names alone reproduces the
    original ``sorted((name, value), ...)`` pair order exactly without
    re-canonicalising values for every comparison."""
    return tuple(sorted(f.name for f in dataclasses.fields(cls)))


# Canonical forms of *hashable* immutable values (frozen dataclasses:
# specs, mapping/reshape descriptions, hardware units) recur across every
# job of a sweep — memoise them.  Keyed by (type, value) so equal values
# of different classes never collide; bounded FIFO so mask-sized oddities
# can't grow without bound.  Forms are plain JSON-able structures built
# once, so sharing them across jobs cannot change any key.
_CANON_MEMO: "OrderedDict[tuple, object]" = OrderedDict()
_CANON_MEMO_CAPACITY = 4096


def canonical(obj) -> object:
    """Reduce ``obj`` to a JSON-serialisable canonical form.

    Deterministic across processes and runs (no ``id``/``hash`` leakage):
    dataclasses become ``[class-name, [(field, value), ...]]`` with fields
    sorted by name, dicts are sorted by stringified key, and numpy arrays
    carry dtype + shape + values.
    """
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        # repr round-trips exactly and avoids JSON float surprises
        return ["f", repr(obj)]
    if isinstance(obj, CalibrationProfile):
        # key by the profile's own content address (physical parameters
        # only): two fits that agree on peaks/efficiencies are the same
        # profile for every consumer, however their provenance/residual
        # metadata differs — they must hit the same cache entries.
        return ["CalibrationProfile", obj.content_hash()]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        memo_key = None
        # never hash an ExploreJob here: its __hash__ routes through
        # content_key → canonical and would recurse
        if not isinstance(obj, ExploreJob):
            try:
                memo_key = (type(obj), obj)
                hit = _CANON_MEMO.get(memo_key)
                if hit is not None:
                    return hit
            except TypeError:                   # unhashable (mutable) field
                memo_key = None
        form = [type(obj).__name__,
                [(name, canonical(getattr(obj, name)))
                 for name in _sorted_field_names(type(obj))]]
        if memo_key is not None:
            _CANON_MEMO[memo_key] = form
            while len(_CANON_MEMO) > _CANON_MEMO_CAPACITY:
                _CANON_MEMO.popitem(last=False)
        return form
    if isinstance(obj, np.ndarray):
        # digest raw bytes: mask-sized arrays would be prohibitively slow
        # to serialise element-wise, and keying only needs content equality
        arr = np.ascontiguousarray(obj)
        return ["ndarray", str(arr.dtype), list(arr.shape),
                hashlib.sha256(arr.tobytes()).hexdigest()]
    if isinstance(obj, np.generic):
        return canonical(obj.item())
    if isinstance(obj, (list, tuple)):
        return [canonical(v) for v in obj]
    if isinstance(obj, dict):
        return ["dict", sorted((str(k), canonical(v)) for k, v in obj.items())]
    if isinstance(obj, Workload):
        return ["Workload", obj.name, obj.source_digest,
                [(name, canonical(node)) for name, node in obj.nodes.items()]]
    raise TypeError(f"cannot canonicalise {type(obj).__name__!r} for job keying")


def content_key(obj) -> str:
    """Stable hex digest of ``obj``'s canonical form."""
    payload = json.dumps(["v", CACHE_SCHEMA, canonical(obj)],
                         separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


@dataclasses.dataclass(frozen=True, eq=False)
class ExploreJob:
    """One simulator evaluation, as pure data.

    ``kind`` selects the evaluation: ``"simulate"`` runs the sparse cost
    model as configured; ``"dense"`` disables the sparsity-support
    hardware and expects ``workload`` to already be the stripped dense
    twin (see :func:`dense_job`), so that every grid point sharing a
    baseline maps onto the *same* cache key.

    ``input_sparsity`` is stored as a sorted tuple of pairs (hashable);
    ``masks`` maps op name → FullBlock keep-grid from the pruning
    workflow and participates in the key via array content.
    ``profile`` is an optional measured calibration profile
    (:mod:`repro.calibrate`); it scales the simulator's latency terms,
    so it is part of the job's content — analytic and calibrated
    evaluations of the same design never share a cache entry.
    ``schedule`` is the multi-macro scheduling policy
    (:class:`repro.core.schedule.SchedulePolicy`); it reshapes the
    report's timing (and, for resident, the amortised weight traffic),
    so it joins the canonical key.  The convenience constructors
    normalise the explicit default ``SchedulePolicy()`` to ``None`` so
    monolithic×1 jobs share one cache entry however they were spelled.
    """

    kind: str                                   # 'simulate' | 'dense'
    arch: CIMArch
    workload: Workload
    mapping: MappingSpec
    input_sparsity: Optional[Tuple[Tuple[str, float], ...]] = None
    masks: Optional[Tuple[Tuple[str, np.ndarray], ...]] = None
    profile: Optional[CalibrationProfile] = None
    schedule: Optional[SchedulePolicy] = None

    def __post_init__(self):
        if self.kind not in ("simulate", "dense"):
            raise ValueError(f"unknown job kind {self.kind!r}")

    @property
    def key(self) -> str:
        """Content-addressed cache key (memoised per instance)."""
        k = self.__dict__.get("_key")
        if k is None:
            k = content_key(self)
            object.__setattr__(self, "_key", k)
        return k

    def __hash__(self) -> int:
        return hash(self.key)

    def __eq__(self, other) -> bool:
        return isinstance(other, ExploreJob) and self.key == other.key

    # -- convenience constructors -------------------------------------------
    @staticmethod
    def _norm_schedule(schedule: Optional[SchedulePolicy]
                       ) -> Optional[SchedulePolicy]:
        return None if schedule == SchedulePolicy() else schedule

    @staticmethod
    def simulate(arch: CIMArch, workload: Workload, mapping: MappingSpec, *,
                 input_sparsity: Optional[Dict[str, float]] = None,
                 masks: Optional[Dict[str, np.ndarray]] = None,
                 profile: Optional[CalibrationProfile] = None,
                 schedule: Optional[SchedulePolicy] = None) -> "ExploreJob":
        return ExploreJob(
            kind="simulate", arch=arch, workload=workload, mapping=mapping,
            input_sparsity=(tuple(sorted(input_sparsity.items()))
                            if input_sparsity else None),
            masks=tuple(sorted(masks.items())) if masks else None,
            profile=profile,
            schedule=ExploreJob._norm_schedule(schedule),
        )

    @staticmethod
    def dense(arch: CIMArch, workload: Workload, mapping: MappingSpec,
              profile: Optional[CalibrationProfile] = None,
              schedule: Optional[SchedulePolicy] = None) -> "ExploreJob":
        """Dense-baseline job: sparsity stripped, support hardware off.

        Stripping happens *here* (via :func:`~repro.core.costmodel.dense_twin`,
        the same helper ``dense_baseline`` uses) so that e.g. every ratio
        of a pattern sweep keys its baseline identically and pays for it
        once.
        """
        from ..core.costmodel import dense_twin

        dense_arch, dense_wl = dense_twin(arch, workload)
        return ExploreJob(kind="dense", arch=dense_arch, workload=dense_wl,
                          mapping=mapping, profile=profile,
                          schedule=ExploreJob._norm_schedule(schedule))
