"""Sweep definitions: enumerate grids as jobs, assemble comparison rows.

Each sweep builds a list of grid points — (sparse job, dense-baseline
job, row metadata) — hands every job to a :class:`SweepRunner` in one
batch, and assembles rows in grid-enumeration order.  Because jobs are
content-addressed, shared baselines (every ratio of a pattern sweep, the
re-swept best-organisation probe, …) are evaluated once regardless of
how many rows reference them.

Row schema matches the legacy ``repro.core.explorer`` sweeps field for
field, so downstream CSV consumers are unaffected.
"""
from __future__ import annotations

import csv
import dataclasses
import itertools
import json
from pathlib import Path
from typing import (Callable, Dict, List, Optional, Sequence, Tuple, Union)

from .. import obs
from ..analysis import preflight
from ..calibrate.profile import CalibrationProfile
from ..core.costmodel import compare
from ..core.flexblock import FlexBlockSpec
from ..core.hardware import CIMArch
from ..core.mapping import MappingSpec, default_mapping
from ..core.report import CostReport
from ..core.schedule import POLICIES, SchedulePolicy
from ..core.workload import Workload
from .cache import ResultCache
from .job import ExploreJob
from .pareto import (DEFAULT_OBJECTIVES, ParetoFront, StreamingTopK,
                     pareto_front, top_k)
from .runner import RunStats, SweepRunner

__all__ = ["GridPoint", "SweepResult", "StreamResult", "run_grid",
           "stream_grid", "sparsity_sweep", "mapping_sweep", "org_sweep",
           "schedule_sweep"]


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One sweep row: a sparse evaluation, its baseline, and metadata."""

    job: ExploreJob
    dense: ExploreJob
    meta: Tuple[Tuple[str, object], ...] = ()


@dataclasses.dataclass
class SweepResult:
    """Ordered rows plus run accounting and post-processing views."""

    rows: List[Dict]
    stats: RunStats

    def pareto(self, objectives: Sequence[Tuple[str, str]] = DEFAULT_OBJECTIVES
               ) -> List[Dict]:
        return pareto_front(self.rows, objectives)

    def top_k(self, metric: str, k: int = 5, *, direction: str = "min"
              ) -> List[Dict]:
        return top_k(self.rows, metric, k, direction=direction)

    # -- serialisation ------------------------------------------------------
    def fieldnames(self) -> List[str]:
        names: List[str] = []
        for r in self.rows:
            for k in r:
                if k not in names:
                    names.append(k)
        return names

    def to_csv(self, path: Union[str, Path]) -> None:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=self.fieldnames())
            w.writeheader()
            w.writerows(self.rows)

    def to_json(self, path: Optional[Union[str, Path]] = None) -> str:
        payload = json.dumps({"rows": self.rows,
                              "stats": self.stats.as_dict()}, indent=2)
        if path is not None:
            Path(path).write_text(payload + "\n")
        return payload


def _row(arch: CIMArch, wl: Workload, spec_name: str, ratio, mapping: str,
         rep: CostReport, cmp: Dict[str, float]) -> Dict:
    """Legacy explorer row schema (kept byte-compatible)."""
    return {
        "arch": arch.name,
        "workload": wl.name,
        "pattern": spec_name,
        "ratio": ratio,
        "mapping": mapping,
        "latency_ms": rep.latency_ms,
        "energy_uj": rep.total_energy_uj,
        "utilization": rep.utilization,
        "speedup": cmp["speedup"],
        "energy_saving": cmp["energy_saving"],
        "index_kib": rep.index_storage_bits / 8 / 1024,
    }


def _assemble_rows(points: Sequence[GridPoint],
                   reports: Sequence[Optional[CostReport]]) -> List[Dict]:
    """Assemble comparison rows in point order from interleaved
    ``[job, dense, job, dense, ...]`` reports."""
    rows: List[Dict] = []
    for i, p in enumerate(points):
        rep, dense = reports[2 * i], reports[2 * i + 1]
        meta = dict(p.meta)
        if rep is None or dense is None:
            # degrade-mode runner quarantined this point (or its
            # baseline): keep the row identifiable, mark it failed
            row = {"arch": p.job.arch.name, "workload": p.job.workload.name,
                   "pattern": meta.pop("pattern", ""),
                   "ratio": meta.pop("ratio", None),
                   "mapping": p.job.mapping.strategy, "failed": True}
            row.update(meta)
            rows.append(row)
            continue
        row = _row(p.job.arch, p.job.workload, meta.pop("pattern", ""),
                   meta.pop("ratio", None), p.job.mapping.strategy,
                   rep, compare(rep, dense))
        row.update(meta)
        rows.append(row)
    return rows


def _preflight_points(points: Sequence[GridPoint], checked: set,
                      where: str) -> None:
    # warn-only pre-flight (strict rejection lives in the CLIs): each
    # distinct workload/arch/mapping triple is validated once, O(ops),
    # before any simulation burns time on ill-formed inputs
    for p in points:
        key = (id(p.job.workload), id(p.job.arch), id(p.job.mapping))
        if key not in checked:
            checked.add(key)
            preflight(p.job.workload, p.job.arch, p.job.mapping,
                      strict=False, where=where)


def run_grid(points: Sequence[GridPoint], *,
             runner: Optional[SweepRunner] = None,
             workers: Optional[int] = None,
             cache: Optional[ResultCache] = None,
             tile_cache_capacity: Optional[int] = None,
             batch_size: Optional[int] = None) -> SweepResult:
    """Evaluate a grid and assemble rows in point order.

    ``tile_cache_capacity`` sizes the per-process tile-grid memo the
    simulator shares across grid points; ``batch_size`` enables the
    batched evaluation path (see :class:`SweepRunner`).  Both are
    ignored when ``runner`` is supplied — the runner already owns those
    settings."""
    runner = runner or SweepRunner(workers=workers, cache=cache,
                                   tile_cache_capacity=tile_cache_capacity,
                                   batch_size=batch_size)
    _preflight_points(points, set(), "explore.run_grid")
    jobs: List[ExploreJob] = []
    for p in points:
        jobs.append(p.job)
        jobs.append(p.dense)
    reports = runner.run(jobs)
    rows = _assemble_rows(points, reports)
    observer = obs.get_observer()
    if observer is not None:
        # observational artifact only: per-component energy attribution
        # for every sparse point, long-format, one CSV per recorded run
        from ..obs.energy import append_energy_csv, component_rows
        erows: List[Dict] = []
        for i, p in enumerate(points):
            if reports[2 * i] is None:
                continue
            erows.extend(component_rows(reports[2 * i], meta=dict(p.meta)))
        append_energy_csv(
            erows, observer.artifact_path("energy_components.csv"))
    return SweepResult(rows=rows, stats=runner.last_stats)


@dataclasses.dataclass
class StreamResult:
    """What a :func:`stream_grid` run keeps: the incremental fronts and
    merged accounting — NOT the full row list (that is the point)."""

    front_rows: List[Dict]
    topk_rows: List[Dict]
    stats: RunStats
    points: int                      # grid points streamed through
    rows: List[Dict]                 # only populated with keep_rows=True

    def pareto(self, objectives: Sequence[Tuple[str, str]]
               = DEFAULT_OBJECTIVES) -> List[Dict]:
        return self.front_rows

    def top_k(self, metric: str, k: int = 5, *, direction: str = "min"
              ) -> List[Dict]:
        return self.topk_rows[:k]

    # CSV/JSON mirror SweepResult's surface over the retained rows
    fieldnames = SweepResult.fieldnames
    to_csv = SweepResult.to_csv
    to_json = SweepResult.to_json


def stream_grid(point_iter, *,
                runner: SweepRunner,
                chunk: int = 4096,
                objectives: Sequence[Tuple[str, str]] = DEFAULT_OBJECTIVES,
                metric: str = "latency_ms",
                k: int = 5,
                direction: str = "min",
                keep_rows: bool = False,
                csv_path: Optional[Union[str, Path]] = None,
                total: Optional[int] = None) -> StreamResult:
    """Evaluate a (lazily generated) point stream in chunks, keeping
    only the incremental Pareto front and top-k — million-point sweeps
    never hold all rows in memory.

    Feeds ``chunk`` points at a time through ``runner.run`` (batched if
    the runner has a ``batch_size``), folds the assembled rows into a
    :class:`~repro.explore.pareto.ParetoFront` and
    :class:`~repro.explore.pareto.StreamingTopK` (both provably
    equivalent to their one-shot counterparts), optionally appends every
    row to ``csv_path``, then drops the rows unless ``keep_rows``.
    Progress surfaces through ``explore.stream`` heartbeats carrying
    points/s, chunk size, and current front size.
    """
    front = ParetoFront(objectives)
    topk = StreamingTopK(metric, k, direction=direction)
    stats = RunStats(workers=runner.workers)
    kept: List[Dict] = []
    checked: set = set()
    n_points = 0
    hb = obs.heartbeat("explore.stream", total=total or 0)
    csv_writer = None
    csv_file = None
    point_iter = iter(point_iter)
    try:
        while True:
            points = list(itertools.islice(point_iter, chunk))
            if not points:
                break
            _preflight_points(points, checked, "explore.stream_grid")
            jobs: List[ExploreJob] = []
            for p in points:
                jobs.append(p.job)
                jobs.append(p.dense)
            reports = runner.run(jobs)
            rows = _assemble_rows(points, reports)
            for row in rows:
                front.add(row)
                topk.add(row)
            if csv_path is not None:
                if csv_writer is None:
                    csv_file = open(csv_path, "w", newline="")
                    csv_writer = csv.DictWriter(
                        csv_file, fieldnames=list(rows[0].keys()),
                        extrasaction="ignore")
                    csv_writer.writeheader()
                csv_writer.writerows(rows)
            if keep_rows:
                kept.extend(rows)
            n_points += len(points)
            stats = stats.merge(runner.last_stats)
            hb.tick(n_points, chunk=len(points), front=len(front),
                    batches=runner.last_stats.batches)
    finally:
        if csv_file is not None:
            csv_file.close()
    stats.workers = runner.workers
    return StreamResult(front_rows=front.front(), topk_rows=topk.best(),
                        stats=stats, points=n_points, rows=kept)


# ---------------------------------------------------------------------------
# The paper's two exploration grids (§VII-B, §VII-C).
# ---------------------------------------------------------------------------

def sparsity_sweep(
    arch: CIMArch,
    workload_fn: Callable[[], Workload],
    patterns: Dict[str, FlexBlockSpec],
    *,
    ratios: Sequence[float] = (0.5, 0.6, 0.7, 0.8, 0.9),
    mapping: Optional[MappingSpec] = None,
    pattern_factory: Optional[Callable[[float], Dict[str, FlexBlockSpec]]] = None,
    input_sparsity: Optional[Dict[str, float]] = None,
    profile: Optional[CalibrationProfile] = None,
    schedule: Optional[SchedulePolicy] = None,
    runner: Optional[SweepRunner] = None,
    workers: Optional[int] = None,
    cache: Optional[ResultCache] = None,
    tile_cache_capacity: Optional[int] = None,
) -> SweepResult:
    """§VII-B: sparsity pattern × ratio grid on one architecture.

    All points share one dense baseline; the engine evaluates it once.
    ``profile`` switches the whole grid — sparse points and the shared
    baseline alike — to calibrated mode (:mod:`repro.calibrate`);
    ``schedule`` likewise applies one scheduling policy to every point
    and its baseline (:mod:`repro.core.schedule`).
    """
    mapping = mapping or default_mapping(arch)
    dense = ExploreJob.dense(arch, workload_fn(), mapping, profile=profile,
                             schedule=schedule)
    points: List[GridPoint] = []
    for ratio in ratios:
        pats = pattern_factory(ratio) if pattern_factory else patterns
        for name, spec in pats.items():
            wl = workload_fn().set_sparsity(spec)
            job = ExploreJob.simulate(arch, wl, mapping,
                                      input_sparsity=input_sparsity,
                                      profile=profile, schedule=schedule)
            points.append(GridPoint(job, dense,
                                    meta=(("pattern", name), ("ratio", ratio))))
    return run_grid(points, runner=runner, workers=workers, cache=cache,
                    tile_cache_capacity=tile_cache_capacity)


def mapping_sweep(
    arch_fn: Callable[[Tuple[int, int]], CIMArch],
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    *,
    orgs: Sequence[Tuple[int, int]] = ((8, 2), (4, 4), (2, 8)),
    strategies: Sequence[str] = ("spatial", "duplicate"),
    rearrange: Sequence[Optional[str]] = (None,),
    profile: Optional[CalibrationProfile] = None,
    schedule: Optional[SchedulePolicy] = None,
    runner: Optional[SweepRunner] = None,
    workers: Optional[int] = None,
    cache: Optional[ResultCache] = None,
    tile_cache_capacity: Optional[int] = None,
) -> SweepResult:
    """§VII-C: mapping strategy × macro organisation (× rearrangement)."""
    points: List[GridPoint] = []
    for org, strat, rr in itertools.product(orgs, strategies, rearrange):
        arch = arch_fn(org)
        mapping = default_mapping(arch, strat, rearrange=rr)
        wl = workload_fn().set_sparsity(spec)
        job = ExploreJob.simulate(arch, wl, mapping, profile=profile,
                                  schedule=schedule)
        dense = ExploreJob.dense(arch, wl, mapping, profile=profile,
                                 schedule=schedule)
        points.append(GridPoint(job, dense, meta=(
            ("pattern", spec.name), ("ratio", None),
            ("org", f"{org[0]}x{org[1]}"), ("rearrange", rr or "none"))))
    return run_grid(points, runner=runner, workers=workers, cache=cache,
                    tile_cache_capacity=tile_cache_capacity)


def org_sweep(
    arch_fn: Callable[[Tuple[int, int]], CIMArch],
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    orgs: Sequence[Tuple[int, int]],
    strategy: str = "spatial",
    **kw,
) -> SweepResult:
    return mapping_sweep(arch_fn, workload_fn, spec, orgs=orgs,
                         strategies=(strategy,), **kw)


def schedule_sweep(
    arch: CIMArch,
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    *,
    policies: Sequence[str] = POLICIES,
    strategies: Sequence[str] = ("spatial",),
    invocations: Sequence[int] = (1,),
    profile: Optional[CalibrationProfile] = None,
    runner: Optional[SweepRunner] = None,
    workers: Optional[int] = None,
    cache: Optional[ResultCache] = None,
    tile_cache_capacity: Optional[int] = None,
) -> SweepResult:
    """Scheduling-policy × mapping-strategy (× invocation-count) grid.

    The new exploration axis the multi-macro scheduling layer opens
    (paper §IV, use-case 2): how much does overlapping independent DAG
    branches (``partitioned``) or pinning weights across repeated
    executions (``resident``) buy on a given workload?  Each point's
    dense baseline shares its policy, so the ``speedup`` column isolates
    the sparsity gain while ``latency_ms`` is directly comparable across
    rows of one strategy.
    """
    points: List[GridPoint] = []
    for strat, pol, inv in itertools.product(strategies, policies,
                                             invocations):
        mapping = default_mapping(arch, strat)
        sched = SchedulePolicy(policy=pol, invocations=inv)
        wl = workload_fn().set_sparsity(spec)
        job = ExploreJob.simulate(arch, wl, mapping, profile=profile,
                                  schedule=sched)
        dense = ExploreJob.dense(arch, wl, mapping, profile=profile,
                                 schedule=sched)
        points.append(GridPoint(job, dense, meta=(
            ("pattern", spec.name), ("ratio", None),
            ("schedule", pol), ("invocations", inv))))
    return run_grid(points, runner=runner, workers=workers, cache=cache,
                    tile_cache_capacity=tile_cache_capacity)
