"""Crash-safe, content-addressed result storage for exploration jobs.

Three layers share one key space (:attr:`ExploreJob.key`):

* :class:`ResultCache` — the in-memory front every runner hits first,
  optionally backed by a
* :class:`ResultStore` — the durable tier: an SQLite database in WAL
  mode (concurrent writers across processes and hosts, torn writes
  impossible by construction) or, when ``sqlite3`` is unavailable, a
  directory of atomically-renamed JSON files.  Entries are JSON-encoded
  :class:`~repro.core.report.CostReport` payloads, schema-versioned via
  ``STORE_SCHEMA``; a corrupt or truncated entry is treated as a miss,
  deleted, and counted — it can never poison later runs.
* :class:`KeyJournal` — an append-only completed-keys log a sweep run
  directory keeps next to its store.  After a SIGKILL the journal says
  exactly which points finished, so ``python -m repro.explore --resume
  <run-dir>`` re-evaluates only the missing ones (a torn final line is
  dropped by the hex-key validation).

Fault injection (:mod:`repro.explore.faults`) hooks the store's write
path — ``corrupt`` faults garble the payload *before* it lands on disk,
which is how the chaos tests prove the read path's corruption
tolerance.  The hook is a no-op ``None`` check when no plan is active.
"""
from __future__ import annotations

import dataclasses
import json
import os
import string
import tempfile
import warnings
from pathlib import Path
from typing import Dict, IO, Iterable, Optional, Sequence, Set, Union

from ..core.report import CostReport
from . import faults

__all__ = ["ResultCache", "ResultStore", "CacheStats", "KeyJournal",
           "StoreCheck", "StoreError", "STORE_SCHEMA"]

# Bump when the durable tier's layout changes incompatibly (table shape,
# payload encoding).  Distinct from job.CACHE_SCHEMA, which salts the
# *keys*: a CACHE_SCHEMA bump silently retires old entries, while a
# STORE_SCHEMA mismatch is a hard error — never guess at someone
# else's bytes.
STORE_SCHEMA = 1

_HEXDIGITS = set(string.hexdigits)


class StoreError(RuntimeError):
    """The durable tier is unusable (schema mismatch, unreadable db)."""


@dataclasses.dataclass
class CacheStats:
    """Hit/miss accounting, split by tier."""

    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    corrupt_entries: int = 0     # torn/garbled entries dropped on read

    @property
    def hits(self) -> int:
        return self.memory_hits + self.disk_hits

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def as_dict(self) -> Dict[str, int]:
        return {"memory_hits": self.memory_hits, "disk_hits": self.disk_hits,
                "misses": self.misses, "hits": self.hits,
                "lookups": self.lookups,
                "corrupt_entries": self.corrupt_entries}


@dataclasses.dataclass
class StoreCheck:
    """Result of :meth:`ResultStore.self_check`."""

    backend: str
    entries: int                 # entries present before the check
    readable: int                # entries that decoded to a CostReport
    corrupt: int                 # entries dropped as undecodable

    @property
    def ok(self) -> bool:
        return self.corrupt == 0


def _encode(report: CostReport) -> bytes:
    return json.dumps(report.to_dict(), separators=(",", ":")).encode()


def _decode(payload: bytes) -> CostReport:
    rep = CostReport.from_dict(json.loads(payload.decode()))
    if not isinstance(rep, CostReport):
        raise ValueError("payload is not a CostReport")
    return rep


class ResultStore:
    """Durable ``job.key -> CostReport`` storage.

    ``path`` may be a directory (the store lives at
    ``<path>/results.sqlite``) or an explicit ``*.sqlite`` file.
    ``backend`` forces ``"sqlite"`` or ``"json"``; the default picks
    sqlite when the module is importable and falls back to the
    atomic-rename JSON directory otherwise.

    Crash-safety: sqlite runs in WAL mode (readers never block writers,
    a killed writer's transaction simply never commits); the JSON
    backend stages each entry in a temp file and ``os.replace``\\ s it
    into place.  Either way a reader sees a complete old entry, a
    complete new entry, or nothing — and anything undecodable is
    deleted, counted in :attr:`corrupt_entries`, and reported as a miss.
    """

    def __init__(self, path: Union[str, Path], *,
                 backend: Optional[str] = None):
        path = Path(path)
        if backend is None:
            backend = "sqlite" if _sqlite3() is not None else "json"
        if backend not in ("sqlite", "json"):
            raise ValueError(f"unknown store backend {backend!r}")
        if backend == "sqlite" and _sqlite3() is None:
            raise StoreError("backend='sqlite' requested but the sqlite3 "
                             "module is unavailable")
        self.backend = backend
        self.corrupt_entries = 0
        if backend == "sqlite":
            if path.suffix == ".sqlite":
                self.dir, self.db_path = path.parent, path
            else:
                self.dir, self.db_path = path, path / "results.sqlite"
            self.dir.mkdir(parents=True, exist_ok=True)
            self._pid: Optional[int] = None
            self._con = None
            self._connect()                    # validate schema eagerly
        else:
            self.dir = path
            self.dir.mkdir(parents=True, exist_ok=True)
            self._check_json_meta()

    # -- sqlite backend ------------------------------------------------------
    def _connect(self):
        """Per-process connection (forked workers never share one)."""
        pid = os.getpid()
        if self._con is not None and pid == self._pid:
            return self._con
        sqlite3 = _sqlite3()
        con = sqlite3.connect(self.db_path, timeout=30.0)
        con.execute("PRAGMA journal_mode=WAL")
        con.execute("PRAGMA synchronous=NORMAL")
        con.execute("PRAGMA busy_timeout=30000")
        with con:
            con.execute("CREATE TABLE IF NOT EXISTS meta "
                        "(k TEXT PRIMARY KEY, v TEXT NOT NULL)")
            con.execute("CREATE TABLE IF NOT EXISTS results "
                        "(key TEXT PRIMARY KEY, payload BLOB NOT NULL)")
            con.execute("INSERT OR IGNORE INTO meta VALUES "
                        "('store_schema', ?)", (str(STORE_SCHEMA),))
        row = con.execute("SELECT v FROM meta WHERE k='store_schema'"
                          ).fetchone()
        if row is None or int(row[0]) != STORE_SCHEMA:
            found = "none" if row is None else row[0]
            con.close()
            raise StoreError(
                f"result store {self.db_path} has store_schema {found}, "
                f"this build expects {STORE_SCHEMA} — migrate or delete it")
        self._con, self._pid = con, pid
        return con

    # -- json backend --------------------------------------------------------
    def _check_json_meta(self) -> None:
        meta = self.dir / "store_meta.json"
        if meta.exists():
            try:
                recorded = json.loads(meta.read_text()).get("store_schema")
            except (OSError, json.JSONDecodeError):
                recorded = None
            if recorded != STORE_SCHEMA:
                raise StoreError(
                    f"result store {self.dir} has store_schema "
                    f"{recorded!r}, this build expects {STORE_SCHEMA} — "
                    f"migrate or delete it")
        else:
            self._atomic_write(meta, json.dumps(
                {"store_schema": STORE_SCHEMA}).encode())

    def _entry_path(self, key: str) -> Path:
        return self.dir / f"{key}.json"

    def _atomic_write(self, path: Path, payload: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- shared surface ------------------------------------------------------
    def get(self, key: str) -> Optional[CostReport]:
        payload: Optional[bytes] = None
        if self.backend == "sqlite":
            try:
                row = self._connect().execute(
                    "SELECT payload FROM results WHERE key=?",
                    (key,)).fetchone()
            except _sqlite3().Error as e:       # pragma: no cover - env
                warnings.warn(f"result store read failed ({e})",
                              RuntimeWarning, stacklevel=2)
                return None
            payload = bytes(row[0]) if row is not None else None
        else:
            p = self._entry_path(key)
            if p.exists():
                try:
                    payload = p.read_bytes()
                except OSError:
                    payload = None
        if payload is None:
            return None
        try:
            return _decode(payload)
        except Exception:
            # torn / bit-rotted entry: drop it so it cannot poison every
            # later run of the same sweep, count it, report a miss
            self.corrupt_entries += 1
            self.delete(key)
            return None

    def put(self, key: str, report: CostReport) -> None:
        payload = faults.corrupt_payload(key, _encode(report))
        if self.backend == "sqlite":
            try:
                con = self._connect()
                with con:
                    con.execute("INSERT OR REPLACE INTO results VALUES "
                                "(?, ?)", (key, payload))
            except _sqlite3().Error as e:       # pragma: no cover - env
                warnings.warn(f"result store write failed ({e})",
                              RuntimeWarning, stacklevel=2)
        else:
            try:
                self._atomic_write(self._entry_path(key), payload)
            except OSError as e:
                warnings.warn(f"result store write failed ({e})",
                              RuntimeWarning, stacklevel=2)

    def put_many(self, items: Dict[str, CostReport]) -> None:
        """Land many results at once.

        The sqlite backend commits ONE transaction (one fsync) for the
        whole batch instead of one per entry — the difference between
        the store being a rounding error and being the bottleneck of a
        batched sweep.  The JSON backend stays a per-entry atomic
        rename (there is no multi-file atomic rename).  Each payload
        still passes through the fault-injection corruption hook
        individually, so chaos plans see the same per-key surface as
        :meth:`put`.
        """
        if not items:
            return
        encoded = [(k, faults.corrupt_payload(k, _encode(r)))
                   for k, r in items.items()]
        if self.backend == "sqlite":
            try:
                con = self._connect()
                with con:
                    con.executemany(
                        "INSERT OR REPLACE INTO results VALUES (?, ?)",
                        encoded)
            except _sqlite3().Error as e:       # pragma: no cover - env
                warnings.warn(f"result store write failed ({e})",
                              RuntimeWarning, stacklevel=2)
        else:
            for key, payload in encoded:
                try:
                    self._atomic_write(self._entry_path(key), payload)
                except OSError as e:
                    warnings.warn(f"result store write failed ({e})",
                                  RuntimeWarning, stacklevel=2)

    def get_many(self, keys: Sequence[str]) -> Dict[str, CostReport]:
        """Fetch many keys in chunked ``SELECT ... IN`` queries (sqlite)
        or per-file reads (JSON).  Missing keys are simply absent from
        the result; corrupt entries are dropped/counted exactly like
        :meth:`get`."""
        out: Dict[str, CostReport] = {}
        if not keys:
            return out
        payloads: Dict[str, bytes] = {}
        if self.backend == "sqlite":
            try:
                con = self._connect()
                ks = list(keys)
                for i in range(0, len(ks), 500):
                    chunk = ks[i:i + 500]
                    marks = ",".join("?" * len(chunk))
                    rows = con.execute(
                        f"SELECT key, payload FROM results "
                        f"WHERE key IN ({marks})", chunk)
                    for k, p in rows:
                        payloads[k] = bytes(p)
            except _sqlite3().Error as e:       # pragma: no cover - env
                warnings.warn(f"result store read failed ({e})",
                              RuntimeWarning, stacklevel=2)
                return out
        else:
            for key in keys:
                p = self._entry_path(key)
                if p.exists():
                    try:
                        payloads[key] = p.read_bytes()
                    except OSError:
                        pass
        for key, payload in payloads.items():
            try:
                out[key] = _decode(payload)
            except Exception:
                self.corrupt_entries += 1
                self.delete(key)
        return out

    def delete(self, key: str) -> None:
        if self.backend == "sqlite":
            try:
                con = self._connect()
                with con:
                    con.execute("DELETE FROM results WHERE key=?", (key,))
            except _sqlite3().Error:            # pragma: no cover - env
                pass
        else:
            try:
                self._entry_path(key).unlink()
            except OSError:
                pass

    def keys(self) -> Set[str]:
        if self.backend == "sqlite":
            rows = self._connect().execute("SELECT key FROM results")
            return {r[0] for r in rows}
        return {p.stem for p in sorted(self.dir.glob("*.json"))
                if p.name != "store_meta.json"}

    def __contains__(self, key: str) -> bool:
        return key in self.keys()

    def __len__(self) -> int:
        if self.backend == "sqlite":
            row = self._connect().execute(
                "SELECT COUNT(*) FROM results").fetchone()
            return int(row[0])
        return len(self.keys())

    def self_check(self) -> StoreCheck:
        """Decode every entry; drop (and count) the undecodable ones."""
        all_keys = sorted(self.keys())
        before = self.corrupt_entries
        readable = sum(1 for k in all_keys if self.get(k) is not None)
        return StoreCheck(backend=self.backend, entries=len(all_keys),
                          readable=readable,
                          corrupt=self.corrupt_entries - before)

    def close(self) -> None:
        if self.backend == "sqlite" and self._con is not None:
            try:
                self._con.close()
            except Exception:
                pass
            self._con = None


def _sqlite3():
    try:
        import sqlite3
    except ImportError:          # pragma: no cover - stdlib nearly always has it
        return None
    return sqlite3


class KeyJournal:
    """Append-only completed-keys log: one 64-hex job key per line.

    Appends are line-buffered single writes, so a SIGKILL leaves at most
    one torn *final* line — and :meth:`keys` drops anything that is not
    a full hex key.  The journal is the resume contract: a key present
    here was evaluated AND durably stored before the line was written.
    """

    def __init__(self, path: Union[str, Path]):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh: Optional[IO[str]] = None
        self._pid: Optional[int] = None

    def record(self, key: str) -> None:
        pid = os.getpid()
        if self._fh is None or pid != self._pid:
            self._fh = open(self.path, "a", buffering=1)
            self._pid = pid
        self._fh.write(key + "\n")

    def record_many(self, keys: Iterable[str]) -> None:
        """Record many completed keys in ONE write syscall — a SIGKILL
        mid-write still tears at most the final line, and every key in
        the batch was durably stored before this is called (the runner
        commits store-then-journal, batched or not)."""
        keys = list(keys)
        if not keys:
            return
        pid = os.getpid()
        if self._fh is None or pid != self._pid:
            self._fh = open(self.path, "a", buffering=1)
            self._pid = pid
        self._fh.write("".join(k + "\n" for k in keys))

    def keys(self) -> Set[str]:
        if not self.path.exists():
            return set()
        out: Set[str] = set()
        with open(self.path) as f:
            for line in f:
                key = line.strip()
                if len(key) == 64 and set(key) <= _HEXDIGITS:
                    out.add(key)
        return out

    def close(self) -> None:
        if self._fh is not None:
            try:
                self._fh.close()
            except OSError:
                pass
            self._fh = None


class ResultCache:
    """Memoises ``job.key -> CostReport``: an in-memory dict fronting an
    optional durable :class:`ResultStore`.

    ``path`` builds a store at that location (the pre-PR-9 pickle
    directory is gone — old ``*.pkl`` entries are simply never read);
    pass ``store`` to share one durable tier across caches.  Corrupt
    durable entries surface as misses and are counted in
    ``stats.corrupt_entries``.
    """

    def __init__(self, path: Optional[Union[str, Path]] = None, *,
                 store: Optional[ResultStore] = None):
        self._mem: Dict[str, CostReport] = {}
        self.stats = CacheStats()
        if store is not None:
            self.store: Optional[ResultStore] = store
        elif path is not None:
            self.store = ResultStore(path)
        else:
            self.store = None

    def __len__(self) -> int:
        return len(self._mem)

    def get(self, key: str) -> Optional[CostReport]:
        rep = self._mem.get(key)
        if rep is not None:
            self.stats.memory_hits += 1
            return rep
        if self.store is not None:
            before = self.store.corrupt_entries
            rep = self.store.get(key)
            self.stats.corrupt_entries += self.store.corrupt_entries - before
            if rep is not None:
                self._mem[key] = rep
                self.stats.disk_hits += 1
                return rep
        self.stats.misses += 1
        return None

    def get_many(self, keys: Sequence[str]) -> Dict[str, CostReport]:
        """Batched :meth:`get` with identical stats accounting: memory
        hits first, one chunked store query for the rest, misses counted
        for keys found nowhere."""
        out: Dict[str, CostReport] = {}
        missing: list = []
        for key in keys:
            rep = self._mem.get(key)
            if rep is not None:
                self.stats.memory_hits += 1
                out[key] = rep
            else:
                missing.append(key)
        if missing and self.store is not None:
            before = self.store.corrupt_entries
            found = self.store.get_many(missing)
            self.stats.corrupt_entries += self.store.corrupt_entries - before
            for key, rep in found.items():
                self._mem[key] = rep
                self.stats.disk_hits += 1
                out[key] = rep
            self.stats.misses += len(missing) - len(found)
        else:
            self.stats.misses += len(missing)
        return out

    def put(self, key: str, report: CostReport) -> None:
        self._mem[key] = report
        if self.store is not None:
            self.store.put(key, report)

    def put_many(self, items: Dict[str, CostReport]) -> None:
        """Batched :meth:`put`: one store transaction for the batch."""
        self._mem.update(items)
        if self.store is not None:
            self.store.put_many(items)

    def close(self) -> None:
        if self.store is not None:
            self.store.close()
