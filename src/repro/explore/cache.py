"""Content-addressed result cache for exploration jobs.

Two tiers share one key space (:attr:`ExploreJob.key`):

* an in-memory dict — hit for free within a runner's lifetime, shared
  across every sweep that reuses the runner;
* an optional on-disk directory — one pickle per key, so repeated CLI
  invocations and benchmark re-runs skip already-costed grid points.

Writes are atomic (tmp file + ``os.replace``) so a crashed or parallel
writer never leaves a torn entry, and a corrupt/unreadable entry is
treated as a miss rather than an error.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import tempfile
import warnings
from pathlib import Path
from typing import Dict, Optional, Union

from ..core.report import CostReport

__all__ = ["ResultCache", "CacheStats"]


@dataclasses.dataclass
class CacheStats:
    """Hit/miss accounting, split by tier."""

    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0

    @property
    def hits(self) -> int:
        return self.memory_hits + self.disk_hits

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def as_dict(self) -> Dict[str, int]:
        return {"memory_hits": self.memory_hits, "disk_hits": self.disk_hits,
                "misses": self.misses, "hits": self.hits,
                "lookups": self.lookups}


class ResultCache:
    """Memoises ``job.key -> CostReport``."""

    def __init__(self, path: Optional[Union[str, Path]] = None):
        self._mem: Dict[str, CostReport] = {}
        self._dir: Optional[Path] = None
        self.stats = CacheStats()
        if path is not None:
            self._dir = Path(path)
            self._dir.mkdir(parents=True, exist_ok=True)

    def __len__(self) -> int:
        return len(self._mem)

    def _disk_path(self, key: str) -> Optional[Path]:
        return self._dir / f"{key}.pkl" if self._dir else None

    def get(self, key: str) -> Optional[CostReport]:
        rep = self._mem.get(key)
        if rep is not None:
            self.stats.memory_hits += 1
            return rep
        p = self._disk_path(key)
        if p is not None and p.exists():
            try:
                with open(p, "rb") as f:
                    rep = pickle.load(f)
            except Exception:
                rep = None            # torn/stale entry: fall through to miss
            if isinstance(rep, CostReport):
                self._mem[key] = rep
                self.stats.disk_hits += 1
                return rep
        self.stats.misses += 1
        return None

    def put(self, key: str, report: CostReport) -> None:
        self._mem[key] = report
        p = self._disk_path(key)
        if p is None:
            return
        tmp = None
        try:
            fd, tmp = tempfile.mkstemp(dir=self._dir, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump(report, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, p)
        except OSError as e:
            # mirror the read path's soft-miss contract: a full or
            # read-only cache volume must not abort a finished sweep —
            # degrade to memory-only and keep going
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            warnings.warn(f"result cache disk tier disabled ({e})",
                          RuntimeWarning, stacklevel=2)
            self._dir = None
