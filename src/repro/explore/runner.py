"""Job runner: dedup → cache lookup → (parallel) evaluate → ordered rows.

The runner is where the sweep engine earns its keep:

* **Dedup** — jobs are content-addressed, so a grid whose points share a
  dense baseline (or repeat a configuration) evaluates each distinct job
  exactly once per process pool, however many rows request it.
* **Memoisation** — a :class:`~repro.explore.cache.ResultCache` serves
  repeats across sweeps (in memory) and across runs (on disk).
* **Fan-out** — remaining jobs are dispatched one future each to a
  ``concurrent.futures.ProcessPoolExecutor``.  Results are keyed, not
  positional, so completion order never affects output order: callers
  always get reports in the order they submitted jobs.
* **Fault tolerance** — each dispatch carries an optional per-job
  timeout; failures are retried with exponential backoff up to
  ``max_retries``; a dead worker (``BrokenProcessPool``) triggers pool
  respawn and re-dispatch of the in-flight jobs; a job that keeps
  failing is quarantined as a structured :class:`JobFailure` instead of
  sinking the sweep.  ``failure_mode="strict"`` (default) raises
  :class:`SweepFailure` *after* the sweep completes — every surviving
  result is already cached/journaled — while ``"degrade"`` returns
  ``None`` in the failed rows' slots.

Determinism note: the cost model synthesises sparsity masks from
content-stable seeds (see ``repro.core.mapping._block_keep_grid``), so a
job evaluates to bit-identical results in any process — parallel runs
match sequential runs row for row, and a sweep that loses workers
mid-flight still produces surviving rows bit-identical to a fault-free
run (asserted under injected faults in ``tests/test_faults.py``).

Crash identification: when a worker dies, *every* in-flight future
raises ``BrokenProcessPool`` — the executor cannot say which job killed
it.  The runner therefore marks all in-flight jobs as suspects and
re-dispatches them **solo** (one at a time on a fresh pool): an
innocent job clears itself on success, while the culprit crashes alone
and is charged another attempt until quarantined.  This bounds the
blast radius of a poison job to ``workers`` extra solo evaluations per
crash instead of cascading misattributed retries.

Below the job-level result cache sits the tile-grid memo
(:class:`repro.core.mapping.TileGridCache`): a process-wide cache of
reshape+compress+tile results that distinct jobs share whenever they
tile the same layer shapes.  It is per-process state — the sequential
path warms the parent's, and each ProcessPool worker warms its own copy
once (the runner's ``tile_cache_capacity`` is pushed into workers via
the pool initializer).
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

from ..core import mapping as _mapping
from ..core.costmodel import simulate
from ..core.report import CostReport
from .. import obs
from . import faults
from .batch import (evaluate_batch, group_jobs, plan_batches,
                    warm_job_keys)
from .cache import KeyJournal, ResultCache
from .job import ExploreJob

__all__ = ["evaluate_job", "SweepRunner", "RunStats", "JobFailure",
           "SweepFailure"]


def evaluate_job(job: ExploreJob, attempt: int = 0) -> CostReport:
    """Evaluate one job.  Module-level so worker processes can import it.

    ``attempt`` is the retry ordinal the runner is on for this job; the
    simulation ignores it (results are attempt-invariant) — it only
    feeds the fault-injection hook, whose plan decides per ``(kind, key,
    attempt)`` whether to fire, so bounded retry deterministically
    recovers transient faults.

    The obs span is observational-only (a no-op object when recording is
    off) and runs in *this* process — pool workers auto-attach to the
    parent's trace directory via ``REPRO_OBS_DIR`` and write their own
    ``events-<pid>.jsonl``, so per-job spans line up with the parent's
    run span on one monotonic clock."""
    with obs.span("explore.evaluate_job", key=job.key[:16],
                  workload=job.workload.name, kind=job.kind):
        faults.maybe_fail(job.key, attempt)
        return simulate(
            job.arch, job.workload, job.mapping,
            input_sparsity=(dict(job.input_sparsity)
                            if job.input_sparsity else None),
            masks=dict(job.masks) if job.masks else None,
            profile=job.profile,
            schedule=job.schedule,
        )


def _init_worker(tile_cache_capacity: Optional[int]) -> None:
    """ProcessPool initializer: size the worker's process-wide tile-grid
    cache before any job lands, so every worker warms it exactly once;
    and mark the process as a pool worker so injected ``crash`` faults
    may hard-kill it (the parent process never is)."""
    faults.mark_worker()
    if tile_cache_capacity is not None:
        _mapping.set_default_tile_cache(
            _mapping.TileGridCache(tile_cache_capacity))


@dataclasses.dataclass(frozen=True)
class JobFailure:
    """A job quarantined after exhausting its retry budget."""

    key: str                     # ExploreJob.key of the poison job
    reason: str                  # "crash" | "timeout" | "exception"
    attempts: int                # dispatches consumed (1 + retries)
    error: str                   # repr of the last error seen

    def as_dict(self) -> Dict[str, Union[str, int]]:
        return dataclasses.asdict(self)


class SweepFailure(RuntimeError):
    """Raised at the *end* of a strict-mode run that quarantined jobs.

    Every surviving result was evaluated, cached, and journaled before
    this raises — ``results`` holds them aligned with the input job
    order (``None`` in failed slots), and a run directory can be
    resumed to retry just the failures.
    """

    def __init__(self, failures: List[JobFailure],
                 results: List[Optional[CostReport]]):
        self.failures = failures
        self.results = results
        sample = ", ".join(f"{f.key[:12]}({f.reason})" for f in failures[:3])
        more = "" if len(failures) <= 3 else f", +{len(failures) - 3} more"
        super().__init__(
            f"{len(failures)} job(s) failed after retries: {sample}{more} "
            f"— surviving results are cached; re-run or --resume to retry")


@dataclasses.dataclass
class RunStats:
    """Accounting for one :meth:`SweepRunner.run` call."""

    requested: int = 0          # jobs asked for (rows)
    unique: int = 0             # distinct cache keys among them
    memory_hits: int = 0
    disk_hits: int = 0
    evaluated: int = 0          # jobs successfully evaluated this run
    workers: int = 1
    wall_s: float = 0.0
    # tile-grid memo traffic during evaluation (sequential path only —
    # parallel evaluations hit the caches inside worker processes)
    tile_grid_hits: int = 0
    tile_grid_misses: int = 0
    # fault accounting
    failed: int = 0             # jobs quarantined after retry budget
    retried: int = 0            # extra dispatches caused by faults
    timed_out: int = 0          # dispatches cut off by the job timeout
    corrupt_entries: int = 0    # store entries dropped as undecodable
    # batched-evaluation accounting (repro.explore.batch)
    batched_points: int = 0     # points evaluated via the batched path
    batches: int = 0            # batch dispatches that landed results

    @property
    def cache_hits(self) -> int:
        """Evaluations avoided: tiered-cache hits + intra-batch dedup."""
        return self.requested - self.evaluated - self.failed

    def as_dict(self) -> Dict[str, Union[int, float]]:
        d = dataclasses.asdict(self)
        d["cache_hits"] = self.cache_hits
        return d

    def stats_text(self) -> str:
        """One-line human summary (the CLI's ``engine:`` line)."""
        text = (f"{self.requested} jobs, {self.unique} unique, "
                f"{self.cache_hits} cache hits "
                f"({self.memory_hits} memory, {self.disk_hits} disk), "
                f"{self.evaluated} evaluated on {self.workers} worker(s) "
                f"in {self.wall_s:.2f}s")
        if self.failed or self.retried or self.timed_out \
                or self.corrupt_entries:
            text += (f" | faults: {self.failed} failed, "
                     f"{self.retried} retried, {self.timed_out} timed out, "
                     f"{self.corrupt_entries} corrupt entries dropped")
        if self.batches:
            text += (f" | batched: {self.batched_points} points in "
                     f"{self.batches} batches")
        return text

    def merge(self, other: "RunStats") -> "RunStats":
        return RunStats(
            requested=self.requested + other.requested,
            unique=self.unique + other.unique,
            memory_hits=self.memory_hits + other.memory_hits,
            disk_hits=self.disk_hits + other.disk_hits,
            evaluated=self.evaluated + other.evaluated,
            workers=max(self.workers, other.workers),
            wall_s=self.wall_s + other.wall_s,
            tile_grid_hits=self.tile_grid_hits + other.tile_grid_hits,
            tile_grid_misses=self.tile_grid_misses + other.tile_grid_misses,
            failed=self.failed + other.failed,
            retried=self.retried + other.retried,
            timed_out=self.timed_out + other.timed_out,
            corrupt_entries=self.corrupt_entries + other.corrupt_entries,
            batched_points=self.batched_points + other.batched_points,
            batches=self.batches + other.batches,
        )


def _resolve_workers(workers: Optional[int]) -> int:
    if workers is None:
        return max(1, (os.cpu_count() or 1))
    return max(1, workers) if workers else 1


class SweepRunner:
    """Evaluates batches of :class:`ExploreJob` with memoisation.

    ``workers``: process count for fan-out.  ``None`` → one per CPU;
    ``0``/``1`` → sequential in-process (useful for debugging and for
    row-equivalence tests).
    ``cache``: a shared :class:`ResultCache`; default is a fresh
    in-memory cache scoped to this runner.
    ``tile_cache_capacity``: entry budget for the per-process tile-grid
    memo (:mod:`repro.core.mapping`); applied to this process and pushed
    into every worker via the pool initializer.  ``None`` keeps whatever
    capacity each process already has.

    Fault-tolerance knobs (runner-level by contract — never job fields,
    see the ``cache-key`` analysis pass, CIM206):

    ``timeout_s``: per-job wall-clock budget.  A dispatch that exceeds
    it has its worker killed and is charged a retry; other in-flight
    jobs are re-dispatched uncharged.  ``None`` (default) disables the
    timeout; the sequential path cannot enforce one (documented in
    ``docs/robustness.md``).
    ``max_retries``: extra dispatches a failing job gets before being
    quarantined as a :class:`JobFailure` (default 2).
    ``backoff_s``: base of the exponential re-dispatch backoff
    ``backoff_s * 2**(attempt-1)``, capped at 32× (default 0.05).
    ``failure_mode``: ``"strict"`` raises :class:`SweepFailure` after
    the sweep finishes (surviving results cached); ``"degrade"``
    returns ``None`` in failed slots.
    ``journal``: optional :class:`~repro.explore.cache.KeyJournal`;
    every key is recorded immediately after its result lands in the
    cache, which is what makes ``--resume`` exact after a SIGKILL.
    ``batch_size``: enable batched evaluation (:mod:`repro.explore.batch`):
    pending jobs are grouped on their variant-free base key and
    dispatched ``batch_size`` points at a time through
    :func:`~repro.explore.batch.evaluate_batch` — bit-identical results
    under the same cache keys, with the costing pass, tile-grid
    precompute, and store transaction amortised per batch.  ``None``
    (default) keeps the per-point path; ``0`` picks an automatic size.
    A batch that fails for any reason (fault, crash, timeout) falls
    back wholesale to the per-point machinery *uncharged*, so retry
    budgets and crash conviction keep their per-job semantics.  Like
    the fault knobs, ``batch_size`` is runner-level execution state by
    contract — never a job field (analysis code CIM207).
    """

    def __init__(self, *, workers: Optional[int] = None,
                 cache: Optional[ResultCache] = None,
                 tile_cache_capacity: Optional[int] = None,
                 timeout_s: Optional[float] = None,
                 max_retries: int = 2,
                 backoff_s: float = 0.05,
                 failure_mode: str = "strict",
                 journal: Optional[KeyJournal] = None,
                 batch_size: Optional[int] = None):
        if failure_mode not in ("strict", "degrade"):
            raise ValueError(f"failure_mode {failure_mode!r} is not "
                             f"'strict' or 'degrade'")
        self.workers = _resolve_workers(workers)
        self.cache = cache if cache is not None else ResultCache()
        self.tile_cache_capacity = tile_cache_capacity
        self.timeout_s = timeout_s
        self.max_retries = max(0, int(max_retries))
        self.backoff_s = max(0.0, backoff_s)
        self.failure_mode = failure_mode
        self.journal = journal
        if batch_size is not None and batch_size < 0:
            raise ValueError(f"batch_size must be >= 0, got {batch_size}")
        self.batch_size = batch_size
        if tile_cache_capacity is not None:
            # resize in place — replacing the process-wide cache would
            # throw away warm entries and break stats deltas other code
            # holds against the current object; workers (fresh processes
            # with nothing warm) get a new right-sized cache instead.
            _mapping.default_tile_cache().resize(tile_cache_capacity)
        self.stats = RunStats()          # cumulative across run() calls
        self._pool: Optional[ProcessPoolExecutor] = None
        self._seen_keys: set = set()     # distinct keys across the lifetime

    def _get_pool(self) -> ProcessPoolExecutor:
        # pool spin-up costs ~0.5s on small containers: amortise it
        # across every run() call of the runner's lifetime
        if self._pool is None:
            self._pool = ProcessPoolExecutor(
                max_workers=self.workers, initializer=_init_worker,
                initargs=(self.tile_cache_capacity,))
        return self._pool

    def _kill_pool(self) -> None:
        """Tear the pool down *now* — used after a worker death or a
        hung job.  ``ProcessPoolExecutor`` has no per-task cancel, so
        recovering a hung worker means killing the processes (guarded
        use of the private ``_processes`` map; shutdown alone would
        block on the hung task forever)."""
        pool, self._pool = self._pool, None
        if pool is None:
            return
        procs = getattr(pool, "_processes", None) or {}
        for proc in list(procs.values()):
            try:
                proc.kill()
            except Exception:
                pass
        pool.shutdown(wait=False, cancel_futures=True)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None

    def __enter__(self) -> "SweepRunner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # -- evaluation ----------------------------------------------------------

    def _backoff(self, attempt: int) -> None:
        if attempt > 0 and self.backoff_s > 0:
            time.sleep(min(self.backoff_s * 2 ** min(attempt - 1, 5), 5.0))

    def _commit(self, job: ExploreJob, rep: CostReport,
                results: Dict[str, CostReport]) -> None:
        """Durably land one result: cache (memory + store) first, then
        the completed-keys journal — the journal line is the promise
        that the store already holds the result, so a SIGKILL between
        the two only costs a re-evaluation, never a phantom key."""
        results[job.key] = rep
        self.cache.put(job.key, rep)
        if self.journal is not None:
            self.journal.record(job.key)

    def _commit_many(self, reports: Dict[str, CostReport],
                     results: Dict[str, CostReport]) -> None:
        """Batched :meth:`_commit`: one store transaction, then one
        journal write — same store-then-journal durability order."""
        results.update(reports)
        self.cache.put_many(reports)
        if self.journal is not None:
            self.journal.record_many(reports)

    def _auto_batch_size(self, n_pending: int) -> int:
        """Pick a dispatch batch size: large enough to amortise the
        costing pass and store transaction, small enough to keep every
        worker busy and heartbeats flowing."""
        if self.workers <= 1:
            return 256
        return max(16, min(512, -(-n_pending // (self.workers * 4))))

    def _run_batched(self, pending: Sequence[ExploreJob],
                     results: Dict[str, CostReport], stats: RunStats,
                     hb) -> List[ExploreJob]:
        """Dispatch variant-grouped batches; returns the jobs that must
        fall back to the per-point path (their batch failed — fault,
        crash, or timeout — each job uncharged so per-job retry budgets
        and crash conviction semantics are preserved)."""
        size = self.batch_size or self._auto_batch_size(len(pending))
        batches: Deque[List[List[ExploreJob]]] = deque(
            plan_batches(group_jobs(pending), size))
        fallback: List[ExploreJob] = []
        done = 0

        def batch_jobs(batch: List[List[ExploreJob]]) -> List[ExploreJob]:
            return [job for grp in batch for job in grp]

        def land(batch: List[List[ExploreJob]],
                 reports: Dict[str, CostReport]) -> None:
            nonlocal done
            self._commit_many(reports, results)
            stats.batches += 1
            stats.batched_points += len(reports)
            done += len(reports)
            hb.tick(done, workers=self.workers, batch=len(reports),
                    batches=stats.batches)

        if self.workers <= 1 or len(batches) == 1:
            for batch in batches:
                try:
                    land(batch, evaluate_batch(batch))
                except Exception:   # noqa: BLE001 - fall back per-point
                    fallback.extend(batch_jobs(batch))
            return fallback

        inflight: Dict[Future, Tuple[List[List[ExploreJob]], float]] = {}
        poll = None if self.timeout_s is None \
            else max(0.02, min(0.25, self.timeout_s / 4))
        while batches or inflight:
            while batches and len(inflight) < self.workers:
                batch = batches.popleft()
                try:
                    fut = self._get_pool().submit(evaluate_batch, batch)
                except BrokenProcessPool:
                    self._kill_pool()
                    batches.appendleft(batch)
                    break
                inflight[fut] = (batch, time.monotonic())
            if not inflight:
                continue
            done_set, _ = wait(set(inflight), timeout=poll,
                               return_when=FIRST_COMPLETED)
            broken = False
            for fut in done_set:
                batch, _t = inflight.pop(fut)
                try:
                    land(batch, fut.result())
                except BrokenProcessPool:
                    broken = True
                    fallback.extend(batch_jobs(batch))
                except Exception:   # noqa: BLE001 - fall back per-point
                    fallback.extend(batch_jobs(batch))
            if broken:
                # the pool died with every other in-flight batch; their
                # jobs fall back too rather than waiting on doomed futures
                for batch, _t in inflight.values():
                    fallback.extend(batch_jobs(batch))
                inflight.clear()
                self._kill_pool()
                continue
            if self.timeout_s is not None and inflight:
                now = time.monotonic()
                # a batch gets one per-point budget per member job; a
                # genuinely hung job still trips it, just later — the
                # per-point fallback then enforces the exact per-job cut
                expired = [(f, b) for f, (b, t) in inflight.items()
                           if now - t > self.timeout_s
                           * max(1, len(batch_jobs(b)))]
                if expired:
                    for fut, batch in expired:
                        inflight.pop(fut, None)
                        fallback.extend(batch_jobs(batch))
                    survivors = [b for b, _t in inflight.values()]
                    inflight.clear()
                    self._kill_pool()
                    batches.extendleft(reversed(survivors))
        return fallback

    def _run_sequential(self, pending: Sequence[ExploreJob],
                        results: Dict[str, CostReport], stats: RunStats,
                        failures: List[JobFailure], hb) -> None:
        done = 0
        for job in pending:
            attempt = 0
            while True:
                self._backoff(attempt)
                try:
                    rep = evaluate_job(job, attempt)
                except Exception as e:      # noqa: BLE001 - retry boundary
                    attempt += 1
                    if attempt > self.max_retries:
                        stats.failed += 1
                        failures.append(JobFailure(
                            key=job.key, reason="exception",
                            attempts=attempt, error=repr(e)))
                        obs.event("explore.job.failed", key=job.key[:16],
                                  reason="exception", attempts=attempt)
                        break
                    stats.retried += 1
                    obs.event("explore.job.retry", key=job.key[:16],
                              reason="exception", attempt=attempt)
                    continue
                self._commit(job, rep, results)
                done += 1
                hb.tick(done, workers=1)
                break

    def _run_parallel(self, pending: Sequence[ExploreJob],
                      results: Dict[str, CostReport], stats: RunStats,
                      failures: List[JobFailure], hb) -> None:
        queue: Deque[ExploreJob] = deque(pending)
        # suspects of a pool break, re-dispatched one at a time (see
        # the module docstring's crash-identification note)
        solo: Deque[ExploreJob] = deque()
        attempts: Dict[str, int] = {job.key: 0 for job in pending}
        inflight: Dict[Future, Tuple[ExploreJob, float]] = {}
        done = 0

        def retry_or_fail(job: ExploreJob, reason: str, error: str,
                          to_solo: bool) -> None:
            attempts[job.key] += 1
            if attempts[job.key] > self.max_retries:
                stats.failed += 1
                failures.append(JobFailure(
                    key=job.key, reason=reason,
                    attempts=attempts[job.key], error=error))
                obs.event("explore.job.failed", key=job.key[:16],
                          reason=reason, attempts=attempts[job.key])
                return
            stats.retried += 1
            obs.event("explore.job.retry", key=job.key[:16], reason=reason,
                      attempt=attempts[job.key])
            (solo if to_solo else queue).append(job)

        def dispatch(job: ExploreJob) -> bool:
            self._backoff(attempts[job.key])
            try:
                fut = self._get_pool().submit(
                    evaluate_job, job, attempts[job.key])
            except BrokenProcessPool:
                # broke between heals: requeue uncharged, heal lazily
                self._kill_pool()
                queue.appendleft(job)
                return False
            inflight[fut] = (job, time.monotonic())
            return True

        poll = None if self.timeout_s is None \
            else max(0.02, min(0.25, self.timeout_s / 4))
        while queue or solo or inflight:
            if solo:
                # drain suspects strictly one at a time on an otherwise
                # idle pool, so a crash unambiguously convicts its job
                if not inflight:
                    dispatch(solo.popleft())
            else:
                while queue and len(inflight) < self.workers:
                    if not dispatch(queue.popleft()):
                        break
            if not inflight:
                continue

            done_set, _ = wait(set(inflight), timeout=poll,
                               return_when=FIRST_COMPLETED)
            broken = False
            victims: List[ExploreJob] = []
            for fut in done_set:
                job, _t = inflight.pop(fut)
                try:
                    rep = fut.result()
                except BrokenProcessPool as e:
                    broken = True
                    victims.append(job)
                    error = repr(e)
                except Exception as e:   # noqa: BLE001 - retry boundary
                    retry_or_fail(job, "exception", repr(e), to_solo=False)
                else:
                    self._commit(job, rep, results)
                    done += 1
                    hb.tick(done, workers=self.workers)

            if broken:
                # every other in-flight future is doomed with the pool;
                # fold them into the suspect set rather than waiting for
                # each to raise
                victims.extend(job for job, _t in inflight.values())
                inflight.clear()
                self._kill_pool()
                for job in victims:
                    retry_or_fail(job, "crash", error, to_solo=True)
                continue

            if self.timeout_s is not None and inflight:
                now = time.monotonic()
                expired = [job for job, t in inflight.values()
                           if now - t > self.timeout_s]
                if expired:
                    expired_keys = {job.key for job in expired}
                    innocents = [job for job, _t in inflight.values()
                                 if job.key not in expired_keys]
                    inflight.clear()
                    self._kill_pool()   # no per-task cancel: kill + respawn
                    for job in expired:
                        stats.timed_out += 1
                        retry_or_fail(
                            job, "timeout",
                            f"no result within {self.timeout_s}s",
                            to_solo=True)
                    # innocents lose their partial work but not a retry
                    for job in innocents:
                        queue.appendleft(job)

    def run(self, jobs: Sequence[ExploreJob]
            ) -> List[Optional[CostReport]]:
        """Evaluate ``jobs``; returns reports aligned with input order.

        Strict mode raises :class:`SweepFailure` if any job exhausted
        its retries — after finishing and caching everything else.
        Degrade mode returns ``None`` in failed slots instead."""
        t0 = time.perf_counter()
        stats = RunStats(requested=len(jobs), workers=self.workers)

        # dedup while preserving first-seen order; under batching, key
        # in one shared-subform pass (byte-identical keys, but shared
        # field objects — the workload above all — encode once)
        if self.batch_size is not None:
            warm_job_keys(jobs)
        unique: Dict[str, ExploreJob] = {}
        for job in jobs:
            unique.setdefault(job.key, job)
        stats.unique = len(unique)

        cs = self.cache.stats
        mem0, disk0, cor0 = cs.memory_hits, cs.disk_hits, cs.corrupt_entries
        results: Dict[str, CostReport] = self.cache.get_many(list(unique))
        pending: List[ExploreJob] = [job for key, job in unique.items()
                                     if key not in results]
        stats.memory_hits = cs.memory_hits - mem0
        stats.disk_hits = cs.disk_hits - disk0

        failures: List[JobFailure] = []
        tg = _mapping.default_tile_cache()
        tg_h0, tg_m0 = tg.hits, tg.misses
        n_pending = len(pending)
        if pending:
            # telemetry (no-ops when recording is off): rate-limited
            # heartbeats with points/s + ETA as evaluations complete
            hb = obs.heartbeat("explore.run", total=n_pending)
            if self.batch_size is not None and len(pending) > 1:
                pending = self._run_batched(pending, results, stats, hb)
            if pending:
                if self.workers > 1 and len(pending) > 1:
                    self._run_parallel(pending, results, stats, failures,
                                       hb)
                else:
                    self._run_sequential(pending, results, stats,
                                         failures, hb)
        stats.evaluated = n_pending - len(failures)
        stats.corrupt_entries = cs.corrupt_entries - cor0
        stats.tile_grid_hits = tg.hits - tg_h0
        stats.tile_grid_misses = tg.misses - tg_m0

        stats.wall_s = time.perf_counter() - t0
        self._seen_keys.update(unique)
        self.stats = self.stats.merge(stats)
        # cumulative 'unique' means distinct keys over the runner's
        # lifetime, not the sum of per-batch uniques
        self.stats.unique = len(self._seen_keys)
        self.last_stats = stats
        observer = obs.get_observer()
        if observer is not None:
            # one record per run() call in the run manifest, plus an
            # aggregate event so `repro.obs report` needs no special case
            record = stats.as_dict()
            if failures:
                record["failures"] = [f.as_dict() for f in failures]
            observer.append_jsonl("runs.jsonl", record)
            obs.event("explore.run.done", **stats.as_dict())
        ordered = [results.get(job.key) for job in jobs]
        if failures and self.failure_mode == "strict":
            raise SweepFailure(failures, ordered)
        return ordered
