"""Job runner: dedup → cache lookup → (parallel) evaluate → ordered rows.

The runner is where the sweep engine earns its keep:

* **Dedup** — jobs are content-addressed, so a grid whose points share a
  dense baseline (or repeat a configuration) evaluates each distinct job
  exactly once per process pool, however many rows request it.
* **Memoisation** — a :class:`~repro.explore.cache.ResultCache` serves
  repeats across sweeps (in memory) and across runs (on disk).
* **Fan-out** — remaining jobs are shipped to worker processes via
  ``concurrent.futures.ProcessPoolExecutor``.  Results are keyed, not
  positional, so completion order never affects output order: callers
  always get reports in the order they submitted jobs.

Determinism note: the cost model synthesises sparsity masks from
content-stable seeds (see ``repro.core.mapping._block_keep_grid``), so a
job evaluates to bit-identical results in any process — parallel runs
match sequential runs row for row.

Below the job-level result cache sits the tile-grid memo
(:class:`repro.core.mapping.TileGridCache`): a process-wide cache of
reshape+compress+tile results that distinct jobs share whenever they
tile the same layer shapes.  It is per-process state — the sequential
path warms the parent's, and each ProcessPool worker warms its own copy
once (the runner's ``tile_cache_capacity`` is pushed into workers via
the pool initializer).
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, List, Optional, Sequence, Union

from ..core import mapping as _mapping
from ..core.costmodel import simulate
from ..core.report import CostReport
from .. import obs
from .cache import ResultCache
from .job import ExploreJob

__all__ = ["evaluate_job", "SweepRunner", "RunStats"]


def evaluate_job(job: ExploreJob) -> CostReport:
    """Evaluate one job.  Module-level so worker processes can import it.

    The obs span is observational-only (a no-op object when recording is
    off) and runs in *this* process — pool workers auto-attach to the
    parent's trace directory via ``REPRO_OBS_DIR`` and write their own
    ``events-<pid>.jsonl``, so per-job spans line up with the parent's
    run span on one monotonic clock."""
    with obs.span("explore.evaluate_job", key=job.key[:16],
                  workload=job.workload.name, kind=job.kind):
        return simulate(
            job.arch, job.workload, job.mapping,
            input_sparsity=(dict(job.input_sparsity)
                            if job.input_sparsity else None),
            masks=dict(job.masks) if job.masks else None,
            profile=job.profile,
            schedule=job.schedule,
        )


def _init_worker(tile_cache_capacity: Optional[int]) -> None:
    """ProcessPool initializer: size the worker's process-wide tile-grid
    cache before any job lands, so every worker warms it exactly once."""
    if tile_cache_capacity is not None:
        _mapping.set_default_tile_cache(
            _mapping.TileGridCache(tile_cache_capacity))


@dataclasses.dataclass
class RunStats:
    """Accounting for one :meth:`SweepRunner.run` call."""

    requested: int = 0          # jobs asked for (rows)
    unique: int = 0             # distinct cache keys among them
    memory_hits: int = 0
    disk_hits: int = 0
    evaluated: int = 0          # simulator calls actually made
    workers: int = 1
    wall_s: float = 0.0
    # tile-grid memo traffic during evaluation (sequential path only —
    # parallel evaluations hit the caches inside worker processes)
    tile_grid_hits: int = 0
    tile_grid_misses: int = 0

    @property
    def cache_hits(self) -> int:
        """Evaluations avoided: tiered-cache hits + intra-batch dedup."""
        return self.requested - self.evaluated

    def as_dict(self) -> Dict[str, Union[int, float]]:
        d = dataclasses.asdict(self)
        d["cache_hits"] = self.cache_hits
        return d

    def merge(self, other: "RunStats") -> "RunStats":
        return RunStats(
            requested=self.requested + other.requested,
            unique=self.unique + other.unique,
            memory_hits=self.memory_hits + other.memory_hits,
            disk_hits=self.disk_hits + other.disk_hits,
            evaluated=self.evaluated + other.evaluated,
            workers=max(self.workers, other.workers),
            wall_s=self.wall_s + other.wall_s,
            tile_grid_hits=self.tile_grid_hits + other.tile_grid_hits,
            tile_grid_misses=self.tile_grid_misses + other.tile_grid_misses,
        )


def _resolve_workers(workers: Optional[int]) -> int:
    if workers is None:
        return max(1, (os.cpu_count() or 1))
    return max(1, workers) if workers else 1


class SweepRunner:
    """Evaluates batches of :class:`ExploreJob` with memoisation.

    ``workers``: process count for fan-out.  ``None`` → one per CPU;
    ``0``/``1`` → sequential in-process (useful for debugging and for
    row-equivalence tests).
    ``cache``: a shared :class:`ResultCache`; default is a fresh
    in-memory cache scoped to this runner.
    ``tile_cache_capacity``: entry budget for the per-process tile-grid
    memo (:mod:`repro.core.mapping`); applied to this process and pushed
    into every worker via the pool initializer.  ``None`` keeps whatever
    capacity each process already has.
    """

    def __init__(self, *, workers: Optional[int] = None,
                 cache: Optional[ResultCache] = None,
                 tile_cache_capacity: Optional[int] = None):
        self.workers = _resolve_workers(workers)
        self.cache = cache if cache is not None else ResultCache()
        self.tile_cache_capacity = tile_cache_capacity
        if tile_cache_capacity is not None:
            # resize in place — replacing the process-wide cache would
            # throw away warm entries and break stats deltas other code
            # holds against the current object; workers (fresh processes
            # with nothing warm) get a new right-sized cache instead.
            _mapping.default_tile_cache().resize(tile_cache_capacity)
        self.stats = RunStats()          # cumulative across run() calls
        self._pool: Optional[ProcessPoolExecutor] = None
        self._seen_keys: set = set()     # distinct keys across the lifetime

    def _get_pool(self) -> ProcessPoolExecutor:
        # pool spin-up costs ~0.5s on small containers: amortise it
        # across every run() call of the runner's lifetime
        if self._pool is None:
            self._pool = ProcessPoolExecutor(
                max_workers=self.workers, initializer=_init_worker,
                initargs=(self.tile_cache_capacity,))
        return self._pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None

    def __enter__(self) -> "SweepRunner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def run(self, jobs: Sequence[ExploreJob]) -> List[CostReport]:
        """Evaluate ``jobs``; returns reports aligned with input order."""
        t0 = time.perf_counter()
        stats = RunStats(requested=len(jobs), workers=self.workers)

        # dedup while preserving first-seen order
        unique: Dict[str, ExploreJob] = {}
        for job in jobs:
            unique.setdefault(job.key, job)
        stats.unique = len(unique)

        mem0, disk0 = self.cache.stats.memory_hits, self.cache.stats.disk_hits
        results: Dict[str, CostReport] = {}
        pending: List[ExploreJob] = []
        for key, job in unique.items():
            rep = self.cache.get(key)
            if rep is not None:
                results[key] = rep
            else:
                pending.append(job)
        stats.memory_hits = self.cache.stats.memory_hits - mem0
        stats.disk_hits = self.cache.stats.disk_hits - disk0

        tg = _mapping.default_tile_cache()
        tg_h0, tg_m0 = tg.hits, tg.misses
        if pending:
            # telemetry (no-ops when recording is off): rate-limited
            # heartbeats with points/s + ETA as evaluations complete
            hb = obs.heartbeat("explore.run", total=len(pending))
            done = 0
            if self.workers > 1 and len(pending) > 1:
                pool = self._get_pool()
                chunk = max(1, len(pending) // (self.workers * 4))
                for job, rep in zip(pending,
                                    pool.map(evaluate_job, pending,
                                             chunksize=chunk)):
                    results[job.key] = rep
                    done += 1
                    hb.tick(done, workers=self.workers)
            else:
                for job in pending:
                    results[job.key] = evaluate_job(job)
                    done += 1
                    hb.tick(done, workers=1)
            for job in pending:
                self.cache.put(job.key, results[job.key])
        stats.evaluated = len(pending)
        stats.tile_grid_hits = tg.hits - tg_h0
        stats.tile_grid_misses = tg.misses - tg_m0

        stats.wall_s = time.perf_counter() - t0
        self._seen_keys.update(unique)
        self.stats = self.stats.merge(stats)
        # cumulative 'unique' means distinct keys over the runner's
        # lifetime, not the sum of per-batch uniques
        self.stats.unique = len(self._seen_keys)
        self.last_stats = stats
        observer = obs.get_observer()
        if observer is not None:
            # one record per run() call in the run manifest, plus an
            # aggregate event so `repro.obs report` needs no special case
            observer.append_jsonl("runs.jsonl", stats.as_dict())
            obs.event("explore.run.done", **stats.as_dict())
        return [results[job.key] for job in jobs]
