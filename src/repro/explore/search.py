"""Guided search over exploration grids: seeded, deterministic, resumable.

Combinatorial design spaces (dataflow × org × sparsity × schedule) grow
far past exhaustive enumeration.  This layer walks a
:class:`PointSpace` — a lazily-indexed grid (points are built on demand,
so a 10⁶-point space costs no memory up front) — under a
:class:`SearchPolicy`:

* ``exhaustive`` — every point (optionally budget-capped), streamed
  through :func:`~repro.explore.sweeps.stream_grid`.
* ``halving`` — successive-halving promotion: rank ALL points on a
  cheap monolithic-schedule estimate (:func:`estimate_job` — the per-op
  costing pass without schedule/energy/baseline, hundreds of µs per
  point), promote the best ``budget`` (or ``1/eta``) and pay full
  evaluation — dense baseline, schedule, energy — only for them.
* ``evolve`` — a seeded evolutionary loop over the space's lattice
  coordinates: mutate mapping/org/sparsity knobs axis-wise from the
  fittest survivors, evaluate each generation as one batched grid.

Every policy is **deterministic** (seeded ``np.random.default_rng``,
index-ordered tie-breaks, no wall-clock dependence), so a re-run with
the same policy walks the same trajectory — and with a PR 9 run
directory (``ResultStore`` + journal) every previously evaluated point
is a cache hit: resume after a crash re-pays estimates (cheap) but no
full evaluations.  Search knobs are execution policy by contract — they
never enter :class:`~repro.explore.job.ExploreJob` or its cache key
(analysis code CIM207): a point found by any search strategy shares its
store entry with the same point in an exhaustive sweep.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from collections import OrderedDict

from ..core.costmodel import _cost_ops, op_class
from .. import obs
from .job import ExploreJob
from .pareto import DEFAULT_OBJECTIVES, ParetoFront, StreamingTopK
from .runner import RunStats, SweepRunner
from .sweeps import (GridPoint, StreamResult, _assemble_rows,
                     _preflight_points, stream_grid)

__all__ = ["SearchPolicy", "PointSpace", "SearchResult", "estimate_job",
           "estimate_jobs", "run_search", "SEARCH_KINDS"]

SEARCH_KINDS = ("exhaustive", "halving", "evolve")


@dataclasses.dataclass(frozen=True)
class SearchPolicy:
    """How to walk a :class:`PointSpace`.

    ``budget``: full evaluations the search may spend.  ``None`` means
    ``size // eta`` for halving and ``4 × population`` for evolve;
    exhaustive ignores it unless set.
    ``eta``: halving's promotion factor (keep the top ``1/eta``).
    ``population``: evolve's generation size.
    ``metric``/``direction``: the scalar fitness evolve selects on (and
    the top-k ordering every search reports).
    """

    kind: str = "exhaustive"
    budget: Optional[int] = None
    seed: int = 0
    eta: int = 4
    population: int = 16
    metric: str = "latency_ms"
    direction: str = "min"

    def __post_init__(self):
        if self.kind not in SEARCH_KINDS:
            raise ValueError(f"unknown search kind {self.kind!r}; "
                             f"choose from {SEARCH_KINDS}")
        if self.budget is not None and self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")
        if self.eta < 2:
            raise ValueError(f"eta must be >= 2, got {self.eta}")
        if self.direction not in ("min", "max"):
            raise ValueError(f"direction {self.direction!r} is not "
                             f"'min'/'max'")


@dataclasses.dataclass(frozen=True)
class PointSpace:
    """A lazily-indexed design space: ``factory(i)`` builds point ``i``.

    ``shape`` optionally names the mixed-radix lattice the flat index
    enumerates (row-major, last axis fastest) — evolve mutates along
    those axes; without it the space is treated as one axis.  Factories
    must be deterministic: point ``i`` is rebuilt on every visit (and
    on resume) and must produce content-identical jobs each time.
    """

    size: int
    factory: Callable[[int], "GridPoint"]
    shape: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.size < 0:
            raise ValueError(f"size must be >= 0, got {self.size}")
        if self.shape is not None:
            n = 1
            for s in self.shape:
                n *= s
            if n != self.size:
                raise ValueError(f"shape {self.shape} enumerates {n} "
                                 f"points, size says {self.size}")

    @staticmethod
    def from_points(points: Sequence["GridPoint"],
                    shape: Optional[Tuple[int, ...]] = None) -> "PointSpace":
        pts = list(points)
        return PointSpace(len(pts), pts.__getitem__, shape)

    def coords(self, i: int) -> Tuple[int, ...]:
        shape = self.shape or (self.size,)
        out = []
        for s in reversed(shape):
            out.append(i % s)
            i //= s
        return tuple(reversed(out))

    def index(self, coords: Sequence[int]) -> int:
        shape = self.shape or (self.size,)
        i = 0
        for c, s in zip(coords, shape):
            i = i * s + c
        return i


@dataclasses.dataclass
class SearchResult(StreamResult):
    """A :class:`~repro.explore.sweeps.StreamResult` plus search
    accounting: how many points were *estimated* (cheap pass) vs fully
    evaluated (``points``)."""

    estimated: int = 0
    policy: Optional[SearchPolicy] = None


def estimate_job(job: ExploreJob) -> float:
    """Cheap fidelity: the op-serial (monolithic) total latency in
    cycles — the per-op costing pass alone, no schedule resolution, no
    energy aggregation, no dense baseline.  Deterministic, and served
    by the same process-wide tile-grid memo as full evaluation, so
    repeated shapes across the space cost microseconds."""
    costed = _cost_ops(
        job.arch, job.workload, job.mapping,
        input_sparsity=(dict(job.input_sparsity)
                        if job.input_sparsity else None),
        masks=dict(job.masks) if job.masks else None,
        profile=job.profile, tile_cache=None)
    return float(sum(oc.latency_cycles for _op, oc, _led in costed
                     if oc is not None))


def estimate_jobs(jobs: Sequence[ExploreJob]) -> List[float]:
    """Batch :func:`estimate_job`: one costing pass per variant group.

    Jobs are bucketed on the *identity* of the fields the estimate
    reads (arch, workload, mapping, masks, input-sparsity) — factories
    share those objects across schedule/profile variants, and identity
    equality implies content equality, so each bucket can pay
    ``_cost_ops`` once with ``profile=None``.  Every member re-derives
    its estimate by replaying the profile's per-op efficiency division
    — the exact float operations ``_cost_ops(profile=p)`` would apply,
    in the same per-op order, so each value is bit-identical to the
    per-job call (pinned by ``tests/test_search.py``); a factory that
    shares nothing merely degrades to one pass per job.  Identity
    grouping (no canonical-form hashing at all) is what makes halving's
    estimate pass ~cost_ops/|group| per point instead of cost_ops.
    """
    groups: "OrderedDict[tuple, List[int]]" = OrderedDict()
    for pos, job in enumerate(jobs):
        sig = (id(job.arch), id(job.workload), id(job.mapping),
               id(job.masks), id(job.input_sparsity))
        groups.setdefault(sig, []).append(pos)
    out = [0.0] * len(jobs)
    for positions in groups.values():
        rep = jobs[positions[0]]
        costed = _cost_ops(
            rep.arch, rep.workload, rep.mapping,
            input_sparsity=(dict(rep.input_sparsity)
                            if rep.input_sparsity else None),
            masks=dict(rep.masks) if rep.masks else None,
            profile=None, tile_cache=None)
        base_est = float(sum(oc.latency_cycles for _op, oc, _led in costed
                             if oc is not None))
        by_profile = {id(None): base_est}
        for pos in positions:
            prof = jobs[pos].profile
            est = by_profile.get(id(prof))
            if est is None:
                est = float(sum(
                    (oc.latency_cycles / eff
                     if (eff := prof.efficiency_for(op_class(op))) != 1.0
                     else oc.latency_cycles)
                    for op, oc, _led in costed if oc is not None))
                by_profile[id(prof)] = est
            out[pos] = est
    return out


def _stream_indices(space: PointSpace, indices: Sequence[int], *,
                    runner: SweepRunner, policy: SearchPolicy,
                    objectives, chunk: int, keep_rows: bool,
                    csv_path) -> StreamResult:
    return stream_grid((space.factory(i) for i in indices), runner=runner,
                       chunk=chunk, objectives=objectives,
                       metric=policy.metric, direction=policy.direction,
                       k=max(policy.population, 16), keep_rows=keep_rows,
                       csv_path=csv_path, total=len(indices))


def _search_halving(space: PointSpace, policy: SearchPolicy, *,
                    runner: SweepRunner, objectives, chunk: int,
                    keep_rows: bool, csv_path) -> SearchResult:
    keep = policy.budget if policy.budget is not None \
        else max(1, space.size // policy.eta)
    keep = min(keep, space.size)
    # rank every point on the cheap estimate, keeping only the current
    # top-`keep` in a bounded heap; (−est, −i) roots the worst kept
    # entry so ties promote the EARLIER index deterministically.
    # Estimates run through estimate_jobs in contiguous chunks: variant
    # neighbours share one costing pass, and the chunk bounds peak
    # memory on million-point spaces.
    hb = obs.heartbeat("explore.estimate", total=space.size)
    best: List[Tuple[Tuple[float, float], int]] = []
    for start in range(0, space.size, max(chunk, 1)):
        stop = min(start + max(chunk, 1), space.size)
        ests = estimate_jobs([space.factory(i).job
                              for i in range(start, stop)])
        for i, est in zip(range(start, stop), ests):
            entry = ((-est, -i), i)
            if len(best) < keep:
                heapq.heappush(best, entry)
            else:
                heapq.heappushpop(best, entry)
        hb.tick(stop, kept=len(best))
    survivors = sorted(i for _key, i in best)     # original grid order
    sr = _stream_indices(space, survivors, runner=runner, policy=policy,
                         objectives=objectives, chunk=chunk,
                         keep_rows=keep_rows, csv_path=csv_path)
    return SearchResult(front_rows=sr.front_rows, topk_rows=sr.topk_rows,
                        stats=sr.stats, points=sr.points, rows=sr.rows,
                        estimated=space.size, policy=policy)


def _search_evolve(space: PointSpace, policy: SearchPolicy, *,
                   runner: SweepRunner, objectives, chunk: int,
                   keep_rows: bool, csv_path) -> SearchResult:
    budget = policy.budget if policy.budget is not None \
        else 4 * policy.population
    budget = min(budget, space.size)
    rng = np.random.default_rng(policy.seed)
    shape = space.shape or (space.size,)
    sign = 1.0 if policy.direction == "min" else -1.0
    worst = float("inf")

    front = ParetoFront(objectives)
    topk = StreamingTopK(policy.metric, max(policy.population, 16),
                         direction=policy.direction)
    stats = RunStats(workers=runner.workers)
    kept: List[Dict] = []
    checked: set = set()
    fitness: Dict[int, float] = {}
    hb = obs.heartbeat("explore.search", total=budget)

    def evaluate(indices: List[int]) -> None:
        nonlocal stats
        new = sorted(i for i in set(indices) if i not in fitness)
        if not new:
            return
        points = [space.factory(i) for i in new]
        _preflight_points(points, checked, "explore.search")
        jobs = []
        for p in points:
            jobs.append(p.job)
            jobs.append(p.dense)
        reports = runner.run(jobs)
        rows = _assemble_rows(points, reports)
        for i, row in zip(new, rows):
            row["space_index"] = i
            val = row.get(policy.metric)
            fitness[i] = (sign * float(val)
                          if val is not None and not row.get("failed")
                          else worst)
            front.add(row)
            topk.add(row)
            if keep_rows:
                kept.append(row)
        stats = stats.merge(runner.last_stats)
        hb.tick(len(fitness), front=len(front))

    pop = min(policy.population, space.size, budget)
    # seeded init: distinct random indices, evaluated in sorted order
    evaluate(list(rng.choice(space.size, size=pop, replace=False)))

    while len(fitness) < budget:
        ranked = sorted(fitness, key=lambda i: (fitness[i], i))
        parents = ranked[:max(1, len(ranked) // 2)]
        children: List[int] = []
        tries = 0
        want = min(pop, budget - len(fitness))
        while len(children) < want and tries < 50 * want:
            tries += 1
            base = parents[int(rng.integers(len(parents)))]
            coords = list(space.coords(base))
            axis = int(rng.integers(len(shape)))
            step = 1 if rng.random() < 0.5 else -1
            coords[axis] = min(shape[axis] - 1, max(0, coords[axis] + step))
            child = space.index(coords)
            if child not in fitness and child not in children:
                children.append(child)
        # stagnation: refill with random immigrants so the budget is
        # always spent exploring rather than spinning
        while len(children) < want:
            cand = int(rng.integers(space.size))
            if cand not in fitness and cand not in children:
                children.append(cand)
            elif len(fitness) + len(children) >= space.size:
                break
        if not children:
            break
        evaluate(children)

    return SearchResult(front_rows=front.front(), topk_rows=topk.best(),
                        stats=stats, points=len(fitness), rows=kept,
                        estimated=0, policy=policy)


def run_search(space: PointSpace, policy: SearchPolicy, *,
               runner: SweepRunner,
               objectives: Sequence[Tuple[str, str]] = DEFAULT_OBJECTIVES,
               chunk: int = 4096,
               keep_rows: bool = False,
               csv_path=None) -> SearchResult:
    """Walk ``space`` under ``policy``; returns a :class:`SearchResult`
    whose ``front_rows``/``topk_rows`` summarise every fully evaluated
    point (rows retained only with ``keep_rows``)."""
    with obs.span("explore.search", kind=policy.kind, size=space.size,
                  budget=policy.budget or 0, seed=policy.seed):
        if policy.kind == "halving":
            return _search_halving(space, policy, runner=runner,
                                   objectives=objectives, chunk=chunk,
                                   keep_rows=keep_rows, csv_path=csv_path)
        if policy.kind == "evolve":
            return _search_evolve(space, policy, runner=runner,
                                  objectives=objectives, chunk=chunk,
                                  keep_rows=keep_rows, csv_path=csv_path)
        indices = range(space.size if policy.budget is None
                        else min(policy.budget, space.size))
        sr = _stream_indices(space, list(indices), runner=runner,
                             policy=policy, objectives=objectives,
                             chunk=chunk, keep_rows=keep_rows,
                             csv_path=csv_path)
        return SearchResult(front_rows=sr.front_rows,
                            topk_rows=sr.topk_rows, stats=sr.stats,
                            points=sr.points, rows=sr.rows, estimated=0,
                            policy=policy)
