"""``python -m repro.explore`` — run a named sweep from the command line.

Named sweeps:

* ``sparsity`` — §VII-B: Table II patterns × sparsity ratios on one
  architecture (default: the 4-macro use-case arch, ResNet-50).
* ``mapping``  — §VII-C: mapping strategy × macro organisation
  (× rearrangement) on the 16-macro use-case arch.
* ``lm``       — lower one of the repo's LM configs to an MVM DAG and
  sweep Table II patterns × ratios over it.
* ``scale``    — a synthetic ratio × strategy × schedule lattice of
  ``--points`` points, generated lazily and streamed in ``--chunk``
  chunks: the million-point stress grid for the batched engine and the
  guided-search layer (see ``docs/exploration.md``).

Examples::

    python -m repro.explore sparsity --model resnet50 --ratios 0.7,0.8,0.9 \
        --workers 4 --cache-dir .cim_cache --csv sparsity.csv --pareto
    python -m repro.explore mapping --model vgg16 --rearrange none,slice
    python -m repro.explore lm --config llama3-8b --seq-len 64 --top-k 3

``--profile PATH`` (or ``--profile default``) reruns any sweep in
*calibrated* mode: every job carries the measured
:class:`repro.calibrate.CalibrationProfile`, so rows are priced by
fitted peaks/efficiencies instead of the analytic assumptions.
``--diff-analytic`` additionally evaluates the analytic twin of every
row and prints the calibrated/analytic latency and energy ratios.

``--schedule POLICIES`` (comma list from {monolithic, partitioned,
resident}, or ``all``) reruns any sweep across multi-macro scheduling
policies (:mod:`repro.core.schedule`) and adds a ``schedule`` column;
``--invocations N`` models N repeated DAG executions (decode steps /
batches) so the resident policy's weight-pinning amortisation shows up::

    python -m repro.explore sparsity --model resnet18 --ratios 0.8 \
        --schedule all
    python -m repro.explore lm --config llama3-8b --schedule \
        monolithic,resident --invocations 16

Fault tolerance (see ``docs/robustness.md``): ``--run-dir DIR`` makes
the sweep durable — a crash-safe result store, a completed-keys
journal, and a ``sweep.json`` manifest land in DIR, every finished
point is committed immediately, and after any crash (even SIGKILL)
``--resume DIR`` replays the recorded invocation, re-evaluating only
the missing points.  ``--timeout`` / ``--retries`` / ``--backoff``
bound individual job failures; ``--degrade`` keeps going past
quarantined jobs (their rows are marked ``failed``) instead of exiting
non-zero.  ``--check-store DIR`` audits a run directory::

    python -m repro.explore sparsity --model resnet50 --run-dir runs/s50 \
        --timeout 300 --retries 2
    python -m repro.explore --resume runs/s50
    python -m repro.explore --check-store runs/s50

Scale (see ``docs/exploration.md``): ``--batch [N]`` turns on batched
evaluation — variant groups share one costing pass, tile grids
precompute in stacked reduceat passes; results stay bit-identical and
land under the same cache keys.  ``--search {exhaustive,halving,evolve}``
with ``--budget``/``--seed`` walks the ``scale`` lattice under a guided
:class:`repro.explore.search.SearchPolicy` instead of exhaustively::

    python -m repro.explore scale --points 1000000 --batch \
        --search halving --budget 2000 --run-dir runs/million
    python -m repro.explore --resume runs/million   # re-evaluates nothing
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional

from ..analysis import AnalysisError, preflight
from ..core import (TABLE_II_PATTERNS, MODEL_BUILDERS, FlexBlockSpec,
                    FullBlock, hybrid, lm_workload, usecase_arch)
from ..core.mapping import default_mapping
from ..core.presets import PRESET_ARCHS
from ..core.schedule import POLICIES, SchedulePolicy
from ..core.workload import Workload
from .cache import KeyJournal, ResultCache, ResultStore
from .job import CACHE_SCHEMA, ExploreJob
from .pareto import DEFAULT_OBJECTIVES
from .runner import SweepFailure, SweepRunner
from .search import (SEARCH_KINDS, PointSpace, SearchPolicy, SearchResult,
                     run_search)
from .sweeps import (GridPoint, SweepResult, mapping_sweep, sparsity_sweep)

_ROW_COLS = ("pattern", "ratio", "mapping", "org", "rearrange", "schedule",
             "latency_ms", "energy_uj", "utilization", "speedup",
             "energy_saving", "index_kib")


def _print_rows(rows: List[Dict], title: str) -> None:
    print(f"\n== {title} ({len(rows)} rows) ==")
    cols = [c for c in _ROW_COLS if any(c in r for r in rows)]
    print("  " + "  ".join(f"{c:>12}" for c in cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c)
            if isinstance(v, float):
                cells.append(f"{v:>12.4f}")
            else:
                cells.append(f"{str(v):>12}")
        print("  " + "  ".join(cells))


_KEY_COLS = ("pattern", "ratio", "mapping", "org", "rearrange", "schedule")


def _print_diff(calibrated: List[Dict], analytic: List[Dict]) -> None:
    """Per-row calibrated-vs-analytic comparison (grids enumerate in the
    same order, so rows pair positionally; keys shown for readability)."""
    print(f"\n== calibrated vs analytic ({len(calibrated)} rows) ==")
    hdr = [c for c in _KEY_COLS if any(c in r for r in calibrated)]
    print("  " + "  ".join(f"{c:>10}" for c in hdr)
          + f"{'lat_ana_ms':>14}{'lat_cal_ms':>14}{'lat_ratio':>11}"
          + f"{'energy_ratio':>14}")
    for cal, ana in zip(calibrated, analytic):
        cells = [f"{str(cal.get(c)):>10}" for c in hdr]
        lr = cal["latency_ms"] / max(ana["latency_ms"], 1e-30)
        er = cal["energy_uj"] / max(ana["energy_uj"], 1e-30)
        print("  " + "  ".join(cells)
              + f"{ana['latency_ms']:>14.4f}{cal['latency_ms']:>14.4f}"
              + f"{lr:>11.3f}{er:>14.3f}")


def _finish(result: SweepResult, args: argparse.Namespace) -> int:
    _print_rows(result.rows, f"{args.sweep} sweep")
    if args.pareto:
        objs = [o for o in DEFAULT_OBJECTIVES
                if any(o[0] in r for r in result.rows)]
        _print_rows(result.pareto(objs),
                    "Pareto frontier (" + " / ".join(c for c, _ in objs) + ")")
    if args.top_k:
        _print_rows(result.top_k(args.metric, args.top_k),
                    f"top-{args.top_k} by {args.metric}")
    print(f"\nengine: {result.stats.stats_text()}")
    status = 0
    for path, write, what in ((args.csv, result.to_csv,
                               f"{len(result.rows)} rows"),
                              (args.json, result.to_json, "rows + stats")):
        if not path:
            continue
        try:
            write(path)
            print(f"wrote {what} to {path}")
        except OSError as e:
            print(f"error: could not write {path}: {e}", file=sys.stderr)
            status = 1
    return status


def _parse_floats(ap: argparse.ArgumentParser, text: str) -> List[float]:
    try:
        vals = [float(t) for t in text.split(",") if t]
    except ValueError:
        ap.error(f"--ratios expects comma-separated numbers, got {text!r}")
    if not vals:
        ap.error("--ratios must name at least one ratio")
    bad = [v for v in vals if not 0.0 < v < 1.0]
    if bad:
        ap.error(f"sparsity ratios must be in (0, 1), got {bad}")
    return vals


def _parse_orgs(ap: argparse.ArgumentParser, text: str) -> List[tuple]:
    orgs = []
    for t in text.split(","):
        if not t:
            continue
        try:
            r, c = t.lower().split("x")
            orgs.append((int(r), int(c)))
        except ValueError:
            ap.error(f"--orgs expects ROWSxCOLS entries like 4x4, got {t!r}")
    if not orgs:
        ap.error("--orgs must name at least one organisation")
    return orgs


def _runner(args: argparse.Namespace,
            journal: Optional[KeyJournal] = None) -> SweepRunner:
    # --run-dir supersedes --cache-dir: the run directory *is* the
    # durable tier (store + journal + manifest) for this invocation
    cache_path = args.run_dir or args.cache_dir
    cache = ResultCache(cache_path) if cache_path else None
    return SweepRunner(
        workers=args.workers, cache=cache,
        timeout_s=args.timeout, max_retries=args.retries,
        backoff_s=args.backoff,
        failure_mode="degrade" if args.degrade else "strict",
        journal=journal, batch_size=args.batch)


def _resume(run_dir: str) -> int:
    """Replay the invocation recorded in ``<run-dir>/sweep.json``; the
    store serves every completed point, so only missing ones evaluate."""
    manifest = Path(run_dir) / "sweep.json"
    if not manifest.exists():
        print(f"error: {manifest} not found — was this run started with "
              f"--run-dir?", file=sys.stderr)
        return 2
    try:
        saved = json.loads(manifest.read_text())
        argv = list(saved["argv"])
    except (OSError, json.JSONDecodeError, KeyError, TypeError) as e:
        print(f"error: could not read run manifest {manifest}: {e}",
              file=sys.stderr)
        return 2
    if saved.get("cache_schema") != CACHE_SCHEMA:
        print(f"warning: run recorded with cache_schema "
              f"{saved.get('cache_schema')}, this build keys with "
              f"{CACHE_SCHEMA} — every point will re-evaluate",
              file=sys.stderr)
    print(f"resuming: python -m repro.explore {' '.join(argv)}",
          file=sys.stderr)
    return main(argv)


def _check_store(run_dir: str) -> int:
    """Audit a run directory: decode every store entry (dropping any
    that are corrupt) and cross-check the completed-keys journal."""
    try:
        store = ResultStore(run_dir)
    except Exception as e:
        print(f"error: could not open result store in {run_dir}: {e}",
              file=sys.stderr)
        return 1
    check = store.self_check()
    journal_keys = KeyJournal(Path(run_dir) / "journal.txt").keys()
    missing = sorted(journal_keys - store.keys())
    print(f"store [{check.backend}]: {check.entries} entries, "
          f"{check.readable} readable, {check.corrupt} corrupt (dropped)")
    print(f"journal: {len(journal_keys)} completed keys, "
          f"{len(missing)} journaled but absent from the store")
    if check.corrupt or missing:
        print(f"hint: rerun with --resume {run_dir} to re-evaluate the "
              f"missing points", file=sys.stderr)
        return 1
    print("store check: ok")
    return 0


_SCALE_STRATEGIES = ("spatial", "duplicate")
_SCALE_POLICIES = ("monolithic", "partitioned")


def _scale_workload() -> Workload:
    """The fixed DAG every scale point sweeps: two FC layers, small
    enough that a single point evaluates in sub-millisecond time."""
    w = Workload("scale")
    w.fc("fc1", 128, 128)
    w.fc("fc2", 128, 64, inputs=("fc1",))
    return w


def _scale_space(n_points: int, arch) -> PointSpace:
    """A lazily-generated ratio × strategy × schedule lattice of at
    least ``n_points`` points.

    The schedule axis is innermost so a point and its schedule variants
    are adjacent in flat-index order — they land in the same stream
    chunk and collapse into one batched costing pass.  The four dense
    baselines (strategy × policy) are shared by every ratio, so a
    million-point space evaluates exactly four baseline jobs.
    """
    inner = len(_SCALE_STRATEGIES) * len(_SCALE_POLICIES)
    n_ratios = max(1, -(-n_points // inner))
    shape = (n_ratios, len(_SCALE_STRATEGIES), len(_SCALE_POLICIES))
    mappings = {s: default_mapping(arch, s) for s in _SCALE_STRATEGIES}
    scheds = {p: SchedulePolicy(policy=p) for p in _SCALE_POLICIES}
    dense_wl = _scale_workload()
    dense_jobs = {
        (s, p): ExploreJob.dense(arch, dense_wl, mappings[s],
                                 schedule=scheds[p])
        for s in _SCALE_STRATEGIES for p in _SCALE_POLICIES}

    # One sparsified workload OBJECT per ratio, in a small LRU: a
    # point's schedule/strategy variants (and its revisits on resume or
    # promotion) must reuse the same object so batch keying's
    # shared-subform memo and estimate_jobs's identity grouping engage.
    # Content is deterministic either way; sharing is purely throughput.
    wl_lru: "OrderedDict[int, Workload]" = OrderedDict()

    def _ratio_wl(ri: int):
        wl = wl_lru.get(ri)
        if wl is None:
            ratio = 0.05 + 0.90 * (ri / max(1, n_ratios - 1))
            spec = FlexBlockSpec((FullBlock(16, 16, ratio),), name="full16")
            wl = _scale_workload().set_sparsity(spec)
            wl_lru[ri] = wl
            if len(wl_lru) > 4096:
                wl_lru.popitem(last=False)
        else:
            wl_lru.move_to_end(ri)
        return wl

    def factory(i: int) -> GridPoint:
        ri, rem = divmod(i, inner)
        si, pi = divmod(rem, len(_SCALE_POLICIES))
        ratio = 0.05 + 0.90 * (ri / max(1, n_ratios - 1))
        strat = _SCALE_STRATEGIES[si]
        pol = _SCALE_POLICIES[pi]
        job = ExploreJob.simulate(arch, _ratio_wl(ri), mappings[strat],
                                  schedule=scheds[pol])
        return GridPoint(job, dense_jobs[(strat, pol)], meta=(
            ("pattern", "full16"), ("ratio", round(ratio, 9)),
            ("schedule", pol)))

    return PointSpace(n_ratios * inner, factory, shape)


def _finish_stream(result: SearchResult, args: argparse.Namespace) -> int:
    est = f", {result.estimated} estimated" if result.estimated else ""
    print(f"\n== scale sweep: {result.points} points evaluated{est} ==")
    _print_rows(result.front_rows, "Pareto frontier")
    k = args.top_k or 5
    _print_rows(result.top_k(args.metric, k), f"top-{k} by {args.metric}")
    print(f"\nengine: {result.stats.stats_text()}")
    if args.csv:
        # rows streamed to the CSV during evaluation — report, don't rewrite
        print(f"wrote streamed rows to {args.csv}")
    if args.json:
        payload = json.dumps({"points": result.points,
                              "estimated": result.estimated,
                              "front": result.front_rows,
                              "topk": result.topk_rows,
                              "stats": result.stats.as_dict()}, indent=2)
        try:
            Path(args.json).write_text(payload + "\n")
            print(f"wrote front + top-k + stats to {args.json}")
        except OSError as e:
            print(f"error: could not write {args.json}: {e}",
                  file=sys.stderr)
            return 1
    return 0


def _run_scale(args: argparse.Namespace, ap: argparse.ArgumentParser,
               runner: SweepRunner) -> int:
    arch = PRESET_ARCHS[args.arch]() if args.arch else usecase_arch(4)
    space = _scale_space(args.points, arch)
    policy = SearchPolicy(kind=args.search or "exhaustive",
                          budget=args.budget, seed=args.seed,
                          metric=args.metric)
    print(f"scale lattice: {space.size} points {space.shape}, "
          f"search={policy.kind}"
          + (f", budget={policy.budget}" if policy.budget else ""),
          file=sys.stderr)
    try:
        result = run_search(space, policy, runner=runner, chunk=args.chunk,
                            csv_path=args.csv)
    except SweepFailure as e:
        print(f"error: {e}", file=sys.stderr)
        if args.run_dir:
            print(f"hint: `python -m repro.explore --resume "
                  f"{args.run_dir}` retries only the failures",
                  file=sys.stderr)
        return 3
    return _finish_stream(result, args)


def _traced_wl_fn(ap: argparse.ArgumentParser, spec: str, seq_len: int):
    """Parse ``traced:<config>[:<step>]`` into a fresh-workload factory.

    The trace runs once (it needs jax); every sweep evaluation gets a
    deep copy so per-job ``set_sparsity`` mutations never alias.  The
    lowered DAG carries ``source_digest``, which :func:`job.canonical`
    folds into every content key.
    """
    parts = spec.split(":")
    if parts[0] != "traced" or len(parts) not in (2, 3) or not parts[1]:
        ap.error(f"--workload expects 'traced:<config>[:<step>]', "
                 f"got {spec!r}")
    step = parts[2] if len(parts) == 3 else "forward"
    try:
        from ..trace import traced_workload
        base = traced_workload(parts[1], step=step, seq_len=seq_len)
    except ImportError:
        ap.error("--workload traced:… needs jax to capture the model; "
                 "install it or sweep a hand-built workload instead")
    except (KeyError, ValueError) as e:
        ap.error(f"--workload {spec!r}: {e}")
    import copy
    print(f"traced workload {base.name!r}: {len(base)} ops, "
          f"digest {base.source_digest[:16]}")
    return lambda: copy.deepcopy(base)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.explore",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("sweep", nargs="?", default=None,
                    choices=("sparsity", "mapping", "lm", "scale"))
    ap.add_argument("--model", choices=sorted(MODEL_BUILDERS),
                    default="resnet50", help="workload model (CNN sweeps)")
    ap.add_argument("--img", type=int, default=32,
                    help="input resolution for CNN models")
    ap.add_argument("--arch", choices=sorted(PRESET_ARCHS), default=None,
                    help="preset architecture (default per sweep)")
    ap.add_argument("--ratios", default="0.5,0.7,0.8,0.9",
                    help="comma-separated sparsity ratios")
    ap.add_argument("--spec-ratio", type=float, default=0.8,
                    help="overall ratio of the hybrid spec (mapping sweep)")
    ap.add_argument("--orgs", default="8x2,4x4,2x8",
                    help="macro organisations, e.g. 8x2,4x4")
    ap.add_argument("--strategies", default="spatial,duplicate")
    ap.add_argument("--rearrange", default="none",
                    help="comma list from {none,pad,slice}")
    ap.add_argument("--config", default="llama3-8b",
                    help="LM config name (lm sweep)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--workload", default=None, metavar="SPEC",
                    help="override the swept workload with a traced DAG: "
                         "'traced:<config>[:<step>]' lowers the config's "
                         "jaxpr (repro.trace, needs jax; step defaults to "
                         "forward) instead of a hand-built model — cached "
                         "results are keyed by the jaxpr content digest")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: one per CPU; 1 = serial)")
    ap.add_argument("--cache-dir", default=None,
                    help="on-disk result cache directory")
    ap.add_argument("--run-dir", default=None, metavar="DIR",
                    help="durable run directory: crash-safe result store, "
                         "completed-keys journal, and a sweep manifest that "
                         "--resume replays (supersedes --cache-dir)")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="replay the sweep recorded in DIR/sweep.json, "
                         "re-evaluating only points missing from its store")
    ap.add_argument("--check-store", default=None, metavar="DIR",
                    help="audit a run directory's store + journal and exit "
                         "(0 = consistent)")
    ap.add_argument("--timeout", type=float, default=None, metavar="S",
                    help="per-job wall-clock budget; a dispatch exceeding "
                         "it has its worker killed and is retried "
                         "(parallel runs only)")
    ap.add_argument("--retries", type=int, default=2, metavar="N",
                    help="extra dispatches a failing job gets before it "
                         "is quarantined (default 2)")
    ap.add_argument("--backoff", type=float, default=0.05, metavar="S",
                    help="base of the exponential retry backoff "
                         "(default 0.05)")
    ap.add_argument("--degrade", action="store_true",
                    help="keep going past quarantined jobs — their rows "
                         "are marked failed — instead of exiting non-zero")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None)
    ap.add_argument("--pareto", action="store_true",
                    help="print the Pareto frontier")
    ap.add_argument("--top-k", type=int, default=0, metavar="K",
                    help="print the top-K rows by --metric")
    ap.add_argument("--metric", default="latency_ms")
    ap.add_argument("--profile", default=None,
                    help="calibration profile JSON (or 'default'): run "
                         "the sweep in calibrated mode")
    ap.add_argument("--diff-analytic", action="store_true",
                    help="with --profile: also run the analytic twin of "
                         "every row and print the ratios")
    ap.add_argument("--obs", action="store_true",
                    help="record sweep telemetry (repro.obs): run "
                         "manifest, live heartbeats on stderr, per-"
                         "component energy CSV — observational only, "
                         "rows and cache keys are unchanged")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="trace directory for --obs (default "
                         "obs_runs/<run-id>)")
    ap.add_argument("--batch", nargs="?", const=0, default=None, type=int,
                    metavar="N",
                    help="batched evaluation: group points sharing "
                         "everything but profile/schedule and evaluate "
                         "each group in one costing pass — bit-identical "
                         "results, same cache keys (N points per "
                         "dispatch; bare --batch sizes automatically)")
    ap.add_argument("--search", choices=SEARCH_KINDS, default=None,
                    help="guided search over the scale lattice (scale "
                         "sweep only): halving promotes on cheap "
                         "monolithic estimates, evolve mutates lattice "
                         "knobs from a seeded RNG")
    ap.add_argument("--budget", type=int, default=None, metavar="N",
                    help="full evaluations a guided search may spend "
                         "(default: size/4 for halving)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for --search evolve (deterministic "
                         "per seed)")
    ap.add_argument("--points", type=int, default=10000, metavar="N",
                    help="scale sweep: lattice size (rounded up to a "
                         "whole number of ratio rows)")
    ap.add_argument("--chunk", type=int, default=4096, metavar="N",
                    help="scale sweep: points per streamed chunk "
                         "(bounds peak memory)")
    ap.add_argument("--schedule", default=None, metavar="POLICIES",
                    help="rerun the sweep across multi-macro scheduling "
                         "policies (comma list from "
                         f"{{{','.join(POLICIES)}}}, or 'all') and add a "
                         "schedule column")
    ap.add_argument("--invocations", type=int, default=1, metavar="N",
                    help="repeated DAG executions per evaluation (resident "
                         "amortises its weight preload across them)")
    argv = list(sys.argv[1:]) if argv is None else list(argv)
    args = ap.parse_args(argv)

    if args.resume:
        return _resume(args.resume)
    if args.check_store:
        return _check_store(args.check_store)
    if args.sweep is None:
        ap.error("a sweep name is required "
                 "(or use --resume / --check-store)")

    journal = None
    if args.run_dir:
        run_dir = Path(args.run_dir)
        run_dir.mkdir(parents=True, exist_ok=True)
        # the manifest lands before any evaluation so a SIGKILL at any
        # later instant leaves a resumable run directory behind
        (run_dir / "sweep.json").write_text(json.dumps(
            {"argv": argv, "cache_schema": CACHE_SCHEMA}, indent=2) + "\n")
        journal = KeyJournal(run_dir / "journal.txt")

    observer = None
    if args.obs or args.obs_dir:
        from .. import obs
        observer = obs.enable(args.obs_dir, echo=True,
                              manifest={"cli": "repro.explore",
                                        "sweep": args.sweep})
        print(f"obs: recording to {observer.dir}", file=sys.stderr)

    profile = None
    if args.profile is not None:
        from ..calibrate.profile import ProfileError, resolve_profile
        try:
            profile = resolve_profile(args.profile)
        except ProfileError as e:
            ap.error(str(e))
        print(f"calibrated mode: profile {profile.name!r} "
              f"(hash {profile.content_hash()[:12]})")
    if args.diff_analytic and profile is None:
        ap.error("--diff-analytic requires --profile")

    if args.invocations < 1:
        ap.error("--invocations must be >= 1")
    policies: List[Optional[str]] = [None]
    if args.schedule is not None:
        text = ",".join(POLICIES) if args.schedule == "all" else args.schedule
        policies = [t for t in text.split(",") if t]
        bad = [p for p in policies if p not in POLICIES]
        if bad:
            ap.error(f"unknown schedule policies {bad}; "
                     f"choose from {POLICIES} (or 'all')")
        if not policies:
            ap.error("--schedule must name at least one policy")

    if args.search and args.sweep != "scale":
        ap.error("--search applies to the scale sweep only")
    if args.sweep == "scale":
        for flag, name in ((args.profile, "--profile"),
                           (args.schedule, "--schedule"),
                           (args.workload, "--workload"),
                           (args.diff_analytic, "--diff-analytic")):
            if flag:
                ap.error(f"{name} does not apply to the scale sweep")
        if args.points < 1:
            ap.error("--points must be >= 1")
        if args.chunk < 1:
            ap.error("--chunk must be >= 1")
        try:
            preflight(_scale_workload(),
                      PRESET_ARCHS[args.arch]() if args.arch else None,
                      strict=True, where="repro.explore")
        except AnalysisError as e:
            ap.error(str(e))
        status = _run_scale(args, ap, _runner(args, journal))
        if observer is not None:
            print(f"obs: trace recorded to {observer.dir}", file=sys.stderr)
        return status

    runner = _runner(args, journal)
    ratios = _parse_floats(ap, args.ratios)
    wl_override = (_traced_wl_fn(ap, args.workload, args.seq_len)
                   if args.workload else None)

    def run_sweep(prof, sched):
        if args.sweep == "sparsity":
            arch = PRESET_ARCHS[args.arch]() if args.arch else usecase_arch(4)
            wl_fn = (wl_override or
                     (lambda: MODEL_BUILDERS[args.model](args.img)))
            return sparsity_sweep(
                arch, wl_fn, {}, ratios=ratios, runner=runner, profile=prof,
                schedule=sched,
                pattern_factory=lambda r: TABLE_II_PATTERNS(r, c_in=16))
        if args.sweep == "mapping":
            wl_fn = (wl_override or
                     (lambda: MODEL_BUILDERS[args.model](args.img)))
            rearrange = [None if t == "none" else t
                         for t in args.rearrange.split(",") if t]
            if args.arch:
                base = PRESET_ARCHS[args.arch]
                arch_fn = lambda org: base().with_org(org)  # noqa: E731
            else:
                arch_fn = lambda org: usecase_arch(org[0] * org[1], org)  # noqa: E731
            return mapping_sweep(
                arch_fn, wl_fn,
                hybrid(2, 16, args.spec_ratio),
                orgs=_parse_orgs(ap, args.orgs),
                strategies=tuple(t for t in args.strategies.split(",") if t),
                rearrange=rearrange, runner=runner, profile=prof,
                schedule=sched)
        # lm
        from ..configs import get_config
        cfg = get_config(args.config)
        arch = PRESET_ARCHS[args.arch]() if args.arch else usecase_arch(16)
        wl_fn = (wl_override or
                 (lambda: lm_workload(cfg, seq_len=args.seq_len)))
        return sparsity_sweep(
            arch, wl_fn, {}, ratios=ratios, runner=runner, profile=prof,
            schedule=sched,
            pattern_factory=lambda r: TABLE_II_PATTERNS(r, c_in=16))

    def run_policies(prof) -> SweepResult:
        """One sweep per requested policy, concatenated with a
        ``schedule`` column (rows stay grid-ordered within a policy)."""
        results: List[SweepResult] = []
        for pol in policies:
            if pol is None:
                sched = (SchedulePolicy(invocations=args.invocations)
                         if args.invocations != 1 else None)
            else:
                sched = SchedulePolicy(policy=pol,
                                       invocations=args.invocations)
            r = run_sweep(prof, sched)
            if pol is not None:
                for row in r.rows:
                    row["schedule"] = pol
            results.append(r)
        if len(results) == 1:
            return results[0]
        stats = results[0].stats
        for r in results[1:]:
            stats = stats.merge(r.stats)
        return SweepResult(rows=[row for r in results for row in r.rows],
                           stats=stats)

    # strict pre-flight (CIMFlow-style front-end rejection): validate a
    # fresh instance of the swept workload — plus the preset arch, when
    # one is named — before any grid is built or simulated.  Costs one
    # extra workload build; saves hours on a million-point sweep fed an
    # ill-formed traced DAG.
    if args.sweep == "lm":
        from ..configs import get_config
        _wl = (wl_override
               or (lambda: lm_workload(get_config(args.config),
                                       seq_len=args.seq_len)))()
    else:
        _wl = (wl_override
               or (lambda: MODEL_BUILDERS[args.model](args.img)))()
    _arch = PRESET_ARCHS[args.arch]() if args.arch else None
    try:
        preflight(_wl, _arch, strict=True, where="repro.explore")
    except AnalysisError as e:
        ap.error(str(e))

    try:
        result = run_policies(profile)
        if args.diff_analytic:
            _print_diff(result.rows, run_policies(None).rows)
    except SweepFailure as e:
        print(f"error: {e}", file=sys.stderr)
        for f in e.failures[:10]:
            print(f"  failed {f.key[:16]} ({f.reason}, {f.attempts} "
                  f"attempts): {f.error}", file=sys.stderr)
        if len(e.failures) > 10:
            print(f"  … {len(e.failures) - 10} more", file=sys.stderr)
        if args.run_dir:
            print(f"hint: surviving results are stored — "
                  f"`python -m repro.explore --resume {args.run_dir}` "
                  f"retries only the failures", file=sys.stderr)
        return 3
    status = _finish(result, args)
    if observer is not None:
        ecsv = observer.artifact_path("energy_components.csv")
        print(f"obs: trace recorded to {observer.dir}"
              + (f" (energy CSV: {ecsv})" if ecsv.exists() else ""),
              file=sys.stderr)
        print(f"obs: inspect with `python -m repro.obs report "
              f"{observer.dir}`", file=sys.stderr)
    return status


if __name__ == "__main__":
    sys.exit(main())
