"""Batched grid-point evaluation: share one costing pass per group.

Grid points frequently differ only in *variant* knobs — the calibration
``profile`` and the ``schedule`` policy — while the expensive inputs
(arch, workload with sparsity bound, mapping, masks, input-sparsity map)
are content-identical.  :func:`group_jobs` buckets jobs on a **base
key** — the job's canonical form with the variant fields nulled out —
and :func:`evaluate_batch` evaluates each bucket through
:func:`repro.core.costmodel.simulate_variants`: one per-op costing pass
(tiling, band packing, access ledgers) serves every variant, and the
tile grids of ALL groups in a batch precompute together in stacked
``np.add.reduceat`` passes (:func:`repro.core.mapping.precompute_tile_grids`).

Contract (pinned by ``tests/test_batch.py``): results are **bit-
identical** to per-point :func:`~repro.explore.runner.evaluate_job`, and
cache keys are untouched — a batched evaluation of a point lands under
exactly the key a per-point evaluation would, so batched and per-point
runs share one store.  Batching is therefore an execution knob and must
never become an :class:`~repro.explore.job.ExploreJob` field (analysis
code CIM207).

Fault injection fires *before* any evaluation, once per job: a fault
anywhere in a batch fails the whole dispatch, and the runner falls back
to the per-point retry machinery where the existing crash-conviction
semantics identify the culprit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.costmodel import simulate_variants
from ..core.mapping import TileGridCache, precompute_tile_grids
from ..core.report import CostReport
from .. import obs
from . import faults
from .job import CACHE_SCHEMA, ExploreJob, canonical

__all__ = ["job_keys", "warm_job_keys", "group_jobs", "evaluate_batch",
           "plan_batches"]

# the job fields a group may vary in: simulate_variants re-aggregates one
# costing pass under every (profile, schedule) combination bit-identically
VARIANT_FIELDS = ("profile", "schedule")

# ExploreJob's field order inside content_key's payload: canonical()
# sorts dataclass fields by name, so replicate that here once
_JOB_FIELDS = tuple(sorted(f.name for f in dataclasses.fields(ExploreJob)))


def _field_texts(job: ExploreJob, memo: Dict[int, str]) -> Dict[str, str]:
    """JSON text of each field's canonical form, shared via ``memo``.

    Canonical forms are pure lists/str/int/bool/None (``canonical``
    rewrites dicts and dataclasses into sorted lists), so the JSON
    encoding of a field is position-independent text that concatenates
    into exactly what ``json.dumps(separators=(",", ":"))`` would emit
    for the whole payload — byte-identical keys, but the expensive
    fields (the workload above all) encode once per *object* instead of
    once per job.  ``memo`` keys by ``id``; it is call-local and the
    caller's job list keeps every field object alive, so ids are stable
    for the memo's lifetime.
    """
    texts: Dict[str, str] = {}
    for name in _JOB_FIELDS:
        v = getattr(job, name)
        if v is None:
            texts[name] = "null"
            continue
        # scalars memoise by (type, value) — 1 == True == 1.0 but their
        # canonical texts differ; objects by identity, stable for the
        # call-local memo's lifetime
        mk = (("v", v.__class__, v)
              if isinstance(v, (bool, int, float, str)) else id(v))
        t = memo.get(mk)
        if t is None:
            t = json.dumps(canonical(v), separators=(",", ":"),
                           sort_keys=True)
            memo[mk] = t
        texts[name] = t
    return texts


def _keys_from_texts(texts: Dict[str, str]) -> Tuple[str, str]:
    body = ",".join(f'["{n}",{texts[n]}]' for n in _JOB_FIELDS)
    full = f'["v",{CACHE_SCHEMA},["ExploreJob",[{body}]]]'
    base_body = ",".join(
        f'["{n}",{"null" if n in VARIANT_FIELDS else texts[n]}]'
        for n in _JOB_FIELDS)
    base = f'["b",{CACHE_SCHEMA},["ExploreJob",[{base_body}]]]'
    return (hashlib.sha256(full.encode()).hexdigest(),
            hashlib.sha256(base.encode()).hexdigest())


def _ensure_keys(job: ExploreJob, memo: Dict[int, str]) -> Tuple[str, str]:
    """Memoise ``(full_key, base_key)`` onto ``job``; compute at most
    once per job across every explore-plane keying pass."""
    full = job.__dict__.get("_key")
    base = job.__dict__.get("_base_key")
    if full is None or base is None:
        full, base = _keys_from_texts(_field_texts(job, memo))
        object.__setattr__(job, "_key", full)
        object.__setattr__(job, "_base_key", base)
    return full, base


def job_keys(job: ExploreJob) -> Tuple[str, str]:
    """``(full_key, base_key)`` from one canonical traversal.

    ``full_key`` equals :attr:`ExploreJob.key` exactly (pinned by
    ``tests/test_batch.py`` against ``content_key``) and is memoised
    onto the job so later ``.key`` reads are free.  ``base_key``
    digests the same form with the :data:`VARIANT_FIELDS` nulled, under
    a distinct ``"b"`` domain tag so a base key can never collide with
    a result-store key; it is memoised as ``_base_key`` so grouping
    passes that follow a :func:`warm_job_keys` pass are free.
    """
    return _ensure_keys(job, {})


def warm_job_keys(jobs: Sequence[ExploreJob]) -> None:
    """Memoise ``.key`` (and the base key) onto every job in one
    shared-subform pass.

    Grid points overwhelmingly share their heavy field objects (one
    workload serves every schedule/profile variant; one arch and
    mapping serve the whole sweep), so encoding each *object* once cuts
    keying from the dominant cost of a large sweep to near-noise.  Keys
    are byte-identical to per-job ``content_key`` — this is purely a
    sharing optimisation.
    """
    memo: Dict[int, str] = {}
    for job in jobs:
        _ensure_keys(job, memo)


def group_jobs(jobs: Sequence[ExploreJob]) -> List[List[ExploreJob]]:
    """Bucket jobs by base key, preserving first-seen order.

    Equal base keys ⟹ content-identical non-variant fields, so the
    first member's arch/workload/mapping/masks objects stand in for the
    whole group (content-identical inputs evaluate bit-identically —
    the determinism contract the explore plane is built on).
    """
    groups: "OrderedDict[str, List[ExploreJob]]" = OrderedDict()
    memo: Dict[int, str] = {}
    for job in jobs:
        _full, base = _ensure_keys(job, memo)
        groups.setdefault(base, []).append(job)
    return list(groups.values())


def plan_batches(groups: Sequence[List[ExploreJob]],
                 batch_size: int) -> List[List[List[ExploreJob]]]:
    """Chunk groups into dispatch batches of ≤ ``batch_size`` points.

    Groups are never split (a split group would pay the costing pass
    twice); a single group larger than ``batch_size`` ships whole.
    """
    batches: List[List[List[ExploreJob]]] = []
    cur: List[List[ExploreJob]] = []
    n = 0
    for grp in groups:
        if cur and n + len(grp) > batch_size:
            batches.append(cur)
            cur, n = [], 0
        cur.append(grp)
        n += len(grp)
    if cur:
        batches.append(cur)
    return batches


def evaluate_batch(groups: List[List[ExploreJob]], attempt: int = 0,
                   tile_cache: Optional[TileGridCache] = None,
                   ) -> Dict[str, CostReport]:
    """Evaluate a batch of variant groups; returns ``{job.key: report}``.

    Module-level so ProcessPool workers can import it.  ``attempt`` is
    the runner's retry ordinal, forwarded to the fault-injection hook
    for every member job up front — results are attempt-invariant.
    """
    n_jobs = sum(len(g) for g in groups)
    with obs.span("explore.evaluate_batch", groups=len(groups),
                  jobs=n_jobs):
        for grp in groups:
            for job in grp:
                faults.maybe_fail(job.key, attempt)

        # stacked tile-grid precompute across every group in the batch:
        # one reduceat pass per (tile_k, tile_n, kt) shape, one cache
        # entry per distinct grid — cold grids across the batch cost a
        # few numpy calls instead of one pass per point
        requests = []
        for grp in groups:
            job = grp[0]
            masks = dict(job.masks) if job.masks else {}
            scoped = {o.name for o in
                      job.workload.mvm_ops(job.arch.eval_scope)}
            for op in job.workload.nodes.values():
                if (op.is_mvm or op.kind == "dwconv") \
                        and op.name in scoped:
                    requests.append((op, job.arch, job.mapping.reshape,
                                     masks.get(op.name)))
        precompute_tile_grids(requests, cache=tile_cache)

        out: Dict[str, CostReport] = {}
        for grp in groups:
            job = grp[0]
            reports = simulate_variants(
                job.arch, job.workload, job.mapping,
                input_sparsity=(dict(job.input_sparsity)
                                if job.input_sparsity else None),
                masks=dict(job.masks) if job.masks else None,
                tile_cache=tile_cache,
                variants=[(j.profile, j.schedule) for j in grp])
            for j, rep in zip(grp, reports):
                out[j.key] = rep
        return out
