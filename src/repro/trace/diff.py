"""Traced-vs-hand Workload comparison (jax-free).

The differential contract (tests/test_trace.py, the ``trace-smoke`` CI
job): a traced DAG must agree with its hand-built sibling *bit-exactly*
on MVM ``total_macs()`` and MVM weight storage; the elementwise volume
is expected to differ — the hand DAGs fold most of it away — and is
reported rather than asserted, so the omission is a visible number
instead of silent drift.
"""
from __future__ import annotations

from typing import Dict, List

from ..core.workload import Workload

__all__ = ["summarize", "diff_workloads", "diff_table"]


def summarize(w: Workload) -> Dict[str, int]:
    mvm = w.mvm_ops()
    other = w.other_ops()
    return {
        "n_mvm": len(mvm),
        "n_other": len(other),
        "mvm_macs": w.total_macs(),
        "mvm_weights": sum(n.weights for n in mvm),
        "total_weights": w.total_weights(),
        "elementwise": sum(n.elements for n in other),
    }


def diff_workloads(traced: Workload, hand: Workload) -> Dict[str, object]:
    """Structured diff; ``mvm_match`` is the hard differential criterion."""
    t, h = summarize(traced), summarize(hand)
    return {
        "traced": t,
        "hand": h,
        "mvm_macs_equal": t["mvm_macs"] == h["mvm_macs"],
        "mvm_weights_equal": t["mvm_weights"] == h["mvm_weights"],
        "total_weights_equal": t["total_weights"] == h["total_weights"],
        "mvm_match": (t["mvm_macs"] == h["mvm_macs"]
                      and t["mvm_weights"] == h["mvm_weights"]),
        # what the hand DAG leaves unpriced on the post-processing unit
        "elementwise_surplus": t["elementwise"] - h["elementwise"],
    }


def diff_table(traced: Workload, hand: Workload) -> str:
    """Human-readable diff table for the CLI / CI log."""
    d = diff_workloads(traced, hand)
    t, h = d["traced"], d["hand"]
    rows: List[str] = [
        f"{'':22}{'traced':>18}{'hand':>18}{'match':>8}",
        f"{'workload':22}{traced.name:>18}{hand.name:>18}",
    ]
    for key, exact in (("n_mvm", False), ("mvm_macs", True),
                       ("mvm_weights", True), ("total_weights", True),
                       ("n_other", False), ("elementwise", False)):
        mark = ""
        if exact:
            mark = "OK" if t[key] == h[key] else "DIFF"
        rows.append(f"{key:22}{t[key]:>18}{h[key]:>18}{mark:>8}")
    rows.append(f"{'elementwise surplus':22}"
                f"{d['elementwise_surplus']:>18} (traced - hand)")
    rows.append(f"MVM differential: {'PASS' if d['mvm_match'] else 'FAIL'}")
    return "\n".join(rows)
