"""``python -m repro.trace`` — capture / lower / diff traced workloads.

Subcommands:

* ``lower``   — lower a saved TraceGraph fixture (``--graph``, jax-free)
  or a live trace (``--config``/``--cnn``, needs jax) into a Workload;
  print the op table, optionally simulate it under every schedule policy
  (``--simulate``) and save the graph JSON (``--save-graph``).
* ``diff``    — same sources, then diff against the hand-built sibling
  DAG (:func:`lm_workload` / the CNN builders).  Exits non-zero when the
  MVM totals disagree — the check the ``trace-smoke`` CI job gates on.
* ``fixture`` — regenerate the golden fixtures under
  ``tests/fixtures/trace/`` (needs jax; run after changing capture or
  the reference programs, commit the result).

Examples::

    python -m repro.trace diff --graph tests/fixtures/trace/lm_llama3-8b_forward.json
    python -m repro.trace lower --config dbrx-132b --step decode --simulate
    python -m repro.trace diff --cnn resnet18 --img 32
    python -m repro.trace fixture --out tests/fixtures/trace
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from ..core import (SchedulePolicy, default_mapping, lm_workload, simulate,
                    usecase_arch)
from ..core.schedule import POLICIES
from ..core.workload import MODEL_BUILDERS, Workload
from .diff import diff_table, diff_workloads
from .ir import TraceGraph
from .lower import lower_graph

# the committed golden set: (kind, config/model, step) — one LM config
# per step kind plus one CNN, small shapes so the JSON stays reviewable
FIXTURES = (
    ("lm", "llama3-8b", "forward"),
    ("lm", "llama3-8b", "prefill"),
    ("lm", "llama3-8b", "decode"),
    ("lm", "dbrx-132b", "forward"),
    ("cnn", "resnet18", None),
)
FIXTURE_SEQ_LEN = 8
FIXTURE_BATCH = 1
FIXTURE_IMG = 32


def fixture_name(kind: str, model: str, step: Optional[str]) -> str:
    return (f"lm_{model}_{step}.json" if kind == "lm"
            else f"cnn_{model}_{FIXTURE_IMG}.json")


def _require_jax(ap, what: str):
    try:
        import jax  # noqa: F401
    except ImportError:
        ap.error(f"{what} requires jax; with no jax installed, use "
                 "--graph with a committed fixture instead")


def _load_workload(ap, args) -> Workload:
    if args.graph:
        return lower_graph(TraceGraph.load(args.graph))
    if args.cnn:
        _require_jax(ap, f"tracing --cnn {args.cnn}")
        from .capture import traced_cnn
        return traced_cnn(args.cnn, args.img, args.classes)
    if args.config:
        _require_jax(ap, f"tracing --config {args.config}")
        from .capture import trace_model
        from ..configs import get_config
        graph = trace_model(get_config(args.config), step=args.step,
                            seq_len=args.seq_len, batch=args.batch,
                            source=args.source)
        if args.save_graph:
            graph.save(args.save_graph)
            print(f"saved graph to {args.save_graph} "
                  f"(digest {graph.digest()[:16]})")
        return lower_graph(graph)
    ap.error("one of --graph / --config / --cnn is required")


def _hand_sibling(ap, args, traced: Workload) -> Workload:
    """Reconstruct the hand DAG the traced workload mirrors."""
    if args.graph:
        meta = TraceGraph.load(args.graph).meta
        if "config" in meta:
            from ..configs import get_config
            if meta.get("step") == "decode":
                ap.error("decode fixtures have no hand-DAG sibling to "
                         "diff against (lm_workload models a full "
                         "sequence); use 'lower --simulate' instead")
            return lm_workload(get_config(meta["config"]),
                               seq_len=int(meta.get("seq_len", 128)),
                               batch=int(meta.get("batch", 1)))
        builder = MODEL_BUILDERS[meta["model"].replace("_", "")]
        return builder(int(meta.get("img", 32)),
                       int(meta.get("num_classes", 100)))
    if args.cnn:
        key = args.cnn.replace("_", "")
        return MODEL_BUILDERS[key](args.img, args.classes)
    from ..configs import get_config
    if args.step == "decode":
        ap.error("step=decode has no hand-DAG sibling (see above)")
    return lm_workload(get_config(args.config), seq_len=args.seq_len,
                       batch=args.batch)


def _print_workload(wl: Workload) -> None:
    print(wl)
    if wl.source_digest:
        print(f"source digest: {wl.source_digest[:16]}")
    print(f"{'op':30}{'kind':8}{'K':>8}{'N':>8}{'V':>12}"
          f"{'elements':>12}{'weights':>14}")
    for n in wl.nodes.values():
        print(f"{n.name:30}{n.kind:8}{n.K:>8}{n.N:>8}{n.V:>12}"
              f"{n.elements:>12}{n.weights:>14}")


def _simulate_all(wl_src) -> None:
    arch = usecase_arch(16)
    mapping = default_mapping(arch, "spatial")
    print(f"\n{'policy':14}{'cycles':>14}{'energy_uJ':>12}"
          f"{'concurrency':>12}")
    for pol in POLICIES:
        rep = simulate(arch, wl_src(), mapping,
                       schedule=SchedulePolicy(pol))
        conc = rep.schedule.concurrency if rep.schedule else 1.0
        print(f"{pol:14}{rep.latency_cycles:>14.0f}"
              f"{rep.total_energy_uj:>12.3f}{conc:>12.2f}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("cmd", choices=("lower", "diff", "fixture"))
    ap.add_argument("--graph", default=None,
                    help="saved TraceGraph JSON (jax-free replay)")
    ap.add_argument("--config", default=None, help="LM config to trace")
    ap.add_argument("--step", default="forward",
                    choices=("forward", "prefill", "decode"))
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--source", default="reference",
                    choices=("reference", "model"),
                    help="'reference': shape-faithful mirror (MVM-exact "
                         "vs the hand DAG); 'model': the real execution-"
                         "plane transformer (diff is informational)")
    ap.add_argument("--cnn", default=None,
                    help="CNN reference to trace (vgg16/resnet18/resnet50)")
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--classes", type=int, default=100)
    ap.add_argument("--save-graph", default=None,
                    help="also save the captured TraceGraph JSON here")
    ap.add_argument("--simulate", action="store_true",
                    help="simulate under every schedule policy")
    ap.add_argument("--out", default="tests/fixtures/trace",
                    help="fixture output directory (fixture cmd)")
    args = ap.parse_args(argv)

    if args.cmd == "fixture":
        import os
        _require_jax(ap, "regenerating fixtures")
        from .capture import capture, trace_model
        from .reference import cnn_program
        from ..configs import get_config
        os.makedirs(args.out, exist_ok=True)
        for kind, model, step in FIXTURES:
            if kind == "lm":
                graph = trace_model(get_config(model), step=step,
                                    seq_len=FIXTURE_SEQ_LEN,
                                    batch=FIXTURE_BATCH)
            else:
                fn, params, fargs = cnn_program(model, img=FIXTURE_IMG)
                graph = capture(
                    fn, params, *fargs, name=f"{model}-{FIXTURE_IMG}",
                    meta={"model": model, "img": FIXTURE_IMG,
                          "num_classes": 100,
                          "workload_name": f"traced-{model}-{FIXTURE_IMG}"})
            path = os.path.join(args.out, fixture_name(kind, model, step))
            graph.save(path)
            print(f"wrote {path} (eqns={graph.n_eqns()}, "
                  f"digest {graph.digest()[:16]})")
        return 0

    wl = _load_workload(ap, args)
    # strict pre-flight: CLI entry points reject broken DAGs outright
    from ..analysis import AnalysisError, preflight
    try:
        preflight(wl, strict=True, where="repro.trace")
    except AnalysisError as e:
        ap.error(str(e))
    if args.cmd == "lower":
        _print_workload(wl)
        if args.simulate:
            _simulate_all(lambda: _load_workload(ap, args))
        return 0

    # diff
    hand = _hand_sibling(ap, args, wl)
    print(diff_table(wl, hand))
    if args.simulate:
        _simulate_all(lambda: _load_workload(ap, args))
    d = diff_workloads(wl, hand)
    if args.config and args.source == "model":
        return 0          # execution-plane capture: informational only
    return 0 if d["mvm_match"] else 1


if __name__ == "__main__":
    sys.exit(main())
