"""Jax-free trace IR: a serialisable mirror of a jaxpr.

:mod:`repro.trace.capture` turns a traced model (``jax.make_jaxpr``) into
a :class:`TraceGraph`; :mod:`repro.trace.lower` turns a graph into a
:class:`repro.core.workload.Workload`.  The IR sits between the two so
the lowering side — and every test built on committed golden fixtures —
runs without jax installed, the same split ``launch.dryrun`` uses for its
HLO-text ledgers.

A graph records only what lowering needs: per-variable shapes/dtypes,
the equation list (primitive name + JSON-safe params), which top-level
inputs are model parameters (``weights``: var id → parameter path), and
nested bodies for structured primitives (``scan`` / ``pjit`` / custom
derivative calls).  Values, RNG keys and donation/sharding metadata are
deliberately dropped — two traces of the same program at the same shapes
produce byte-identical graphs, which is what makes :meth:`TraceGraph.digest`
a usable content key for the explore cache.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Tuple

__all__ = ["TraceVar", "TraceEqn", "TraceGraph"]


@dataclasses.dataclass
class TraceVar:
    """Shape/dtype of one SSA variable."""

    shape: Tuple[int, ...]
    dtype: str

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n


@dataclasses.dataclass
class TraceEqn:
    """One primitive application.

    ``body`` holds the lowered sub-graph for structured primitives
    (``scan``'s per-iteration jaxpr, ``pjit``'s call jaxpr, …); the
    trip count and const/carry splits stay in ``params`` under the
    primitive's own key names (``length`` / ``num_consts`` / …).
    """

    prim: str
    invars: List[str]
    outvars: List[str]
    params: Dict[str, object] = dataclasses.field(default_factory=dict)
    body: Optional["TraceGraph"] = None


@dataclasses.dataclass
class TraceGraph:
    """A jaxpr-shaped dataflow graph (possibly nested under a TraceEqn)."""

    name: str
    invars: List[str]
    outvars: List[str]
    vars: Dict[str, TraceVar]
    eqns: List[TraceEqn]
    consts: List[str] = dataclasses.field(default_factory=list)
    weights: Dict[str, str] = dataclasses.field(default_factory=dict)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    # -- (de)serialisation ---------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "invars": list(self.invars),
            "outvars": list(self.outvars),
            "vars": {k: {"shape": list(v.shape), "dtype": v.dtype}
                     for k, v in self.vars.items()},
            "consts": list(self.consts),
            "weights": dict(self.weights),
            "meta": dict(self.meta),
            "eqns": [self._eqn_dict(e) for e in self.eqns],
        }

    @staticmethod
    def _eqn_dict(e: TraceEqn) -> dict:
        d = {"prim": e.prim, "invars": list(e.invars),
             "outvars": list(e.outvars), "params": e.params}
        if e.body is not None:
            d["body"] = e.body.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TraceGraph":
        return cls(
            name=d["name"],
            invars=list(d["invars"]),
            outvars=list(d["outvars"]),
            vars={k: TraceVar(tuple(int(x) for x in v["shape"]), v["dtype"])
                  for k, v in d["vars"].items()},
            consts=list(d.get("consts", ())),
            weights=dict(d.get("weights", {})),
            meta=dict(d.get("meta", {})),
            eqns=[TraceEqn(prim=e["prim"], invars=list(e["invars"]),
                           outvars=list(e["outvars"]),
                           params=dict(e.get("params", {})),
                           body=(cls.from_dict(e["body"])
                                 if e.get("body") else None))
                  for e in d["eqns"]],
        )

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path) -> "TraceGraph":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- content addressing --------------------------------------------------
    def digest(self) -> str:
        """Stable hex digest of the graph's canonical JSON form.

        Keys traced workloads in the explore cache: same program, same
        shapes → same digest, across processes and jax versions that
        trace to the same primitives.
        """
        payload = json.dumps(self.to_dict(), sort_keys=True,
                             separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()

    # -- introspection -------------------------------------------------------
    def n_eqns(self, recursive: bool = True) -> int:
        n = len(self.eqns)
        if recursive:
            for e in self.eqns:
                if e.body is not None:
                    n += e.body.n_eqns(True)
        return n

    def __repr__(self):
        return (f"TraceGraph({self.name!r}, eqns={self.n_eqns()}, "
                f"inputs={len(self.invars)}, weights={len(self.weights)})")
