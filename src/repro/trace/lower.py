"""Lower a :class:`~repro.trace.ir.TraceGraph` into a ``Workload`` DAG.

Pure Python — no jax.  The lowering rules (see ``docs/tracing.md``):

* ``dot_general`` / ``conv_general_dilated`` become MVM :class:`OpNode`\\ s.
  For a dot, K is the product of the contracting dims; the operand backed
  by a model parameter supplies the weight matrix (N = its free dims,
  weight_count = the parameter's stored size), the other side supplies the
  vector count (V = batch dims × its free dims).  Activation×activation
  dots become ``kind="matmul"`` with ``weight_count=0`` (score/context
  attention GEMMs) — K·N·V is invariant to which side is called N.
* ``scan`` bodies are lowered once and folded: every node emitted inside
  a body of length L has V (or ``elements``) scaled by L, and weights
  sized at the per-iteration slice — exactly the per-layer-block
  convention of :func:`repro.core.workload.lm_workload`.
* ``gather`` from a parameter is classified by its slice rank: one
  offset dim → an ``embed`` node (table lookup); two or more → weight
  selection (MoE expert dispatch), which stays a weight view priced at
  the *source* parameter's full size, matching the hand DAGs' replicated
  expert storage.
* Shape-only ops (reshape/transpose/broadcast/slice/convert/…) are
  transparent.  Equations whose inputs are all literals or parameters
  are constant-folded away (masks from ``iota``, ``1 + norm_scale``, …).
* Everything else becomes a :meth:`Workload.simple` node whose
  ``elements`` is the output element count; runs of simple nodes with a
  single simple consumer are merged, summing element counts, so the
  elementwise volume is preserved while the DAG stays compact.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from ..core.workload import OpNode, Workload
from .ir import TraceEqn, TraceGraph

__all__ = ["lower_graph", "LowerError"]


class LowerError(ValueError):
    """A graph that cannot be lowered into a Workload."""


# Primitives that only reshape/relabel data: the lowered value keeps its
# producer and (for parameters) its weight identity.
TRANSPARENT_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "convert_element_type", "bitcast_convert_type", "slice", "dynamic_slice",
    "rev", "copy", "stop_gradient", "real", "imag", "device_put",
    "sharding_constraint", "reduce_precision", "split", "concatenate",
    "pad", "tie_in", "opt_barrier", "squeeze_dims",
})

# Structured primitives whose params carry a nested TraceGraph.
_BODY_PRIMS = frozenset({
    "scan", "pjit", "closed_call", "core_call", "xla_call", "remat",
    "remat2", "checkpoint", "custom_jvp_call", "custom_vjp_call",
    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr", "while", "cond",
})

# Non-MVM kind per elementwise/reduction primitive; anything unlisted
# falls back to the primitive name itself, which the cost model prices
# as elementwise after a one-time warning (see costmodel._other_op_cost).
ELEMENTWISE_KINDS = {
    "add": "add", "sub": "add", "add_any": "add",
    "mul": "act", "div": "act", "max": "act", "min": "act", "rem": "act",
    "pow": "act", "integer_pow": "act", "exp": "act", "log": "act",
    "log1p": "act", "expm1": "act", "tanh": "act", "logistic": "act",
    "erf": "act", "erfc": "act", "erf_inv": "act", "rsqrt": "act",
    "sqrt": "act", "cbrt": "act", "neg": "act", "sign": "act",
    "abs": "act", "floor": "act", "ceil": "act", "round": "act",
    "clamp": "act", "select_n": "act", "is_finite": "act",
    "sin": "act", "cos": "act", "square": "act", "nextafter": "act",
    "and": "act", "or": "act", "xor": "act", "not": "act",
    "shift_left": "act", "shift_right_logical": "act",
    "shift_right_arithmetic": "act",
    "eq": "act", "ne": "act", "lt": "act", "le": "act", "gt": "act",
    "ge": "act",
    "reduce_sum": "reduce", "reduce_max": "reduce", "reduce_min": "reduce",
    "reduce_prod": "reduce", "reduce_and": "reduce", "reduce_or": "reduce",
    "argmax": "reduce", "argmin": "reduce", "reduce": "reduce",
    "cumsum": "reduce", "cumprod": "reduce", "cummax": "reduce",
    "cummin": "reduce", "cumlogsumexp": "reduce",
    "reduce_window_max": "pool", "reduce_window_min": "pool",
    "reduce_window_sum": "pool", "reduce_window": "pool",
    "select_and_scatter_add": "pool",
    "sort": "sort", "top_k": "sort", "approx_top_k": "sort",
    "iota": "act", "rng_uniform": "act", "rng_bit_generator": "act",
    "random_bits": "act", "random_seed": "act", "random_wrap": "act",
    "random_fold_in": "act",
    "gather": "gather", "scatter": "scatter", "scatter_add": "scatter",
    "scatter_mul": "scatter", "scatter_max": "scatter",
    "scatter_min": "scatter", "dynamic_update_slice": "scatter",
}


@dataclasses.dataclass
class _Val:
    """Lowering-time value info for one SSA variable.

    ``producer`` is the DAG node that computed it (None: graph input or
    constant).  ``weight`` is ``(param_path, stored_size)`` when the
    value is a view of a model parameter.  ``const`` marks values with
    no activation dependence at all (literals and pure functions of
    them) — equations over consts/weights alone emit no compute node.
    """

    producer: Optional[str] = None
    weight: Optional[Tuple[str, int]] = None
    const: bool = False


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


_NAME_RE = re.compile(r"[^A-Za-z0-9_]+")


class _Lowerer:
    def __init__(self, workload: Workload):
        self.w = workload
        self._counts: Dict[str, int] = {}

    # -- node naming ---------------------------------------------------------
    def _name(self, kind: str, param: Optional[str] = None) -> str:
        i = self._counts.get(kind, 0)
        self._counts[kind] = i + 1
        base = f"{kind}{i}"
        if param:
            base += "_" + _NAME_RE.sub("_", param).strip("_")[:48]
        while base in self.w.nodes:                # defensive: keep unique
            base += "_"
        return base

    # -- graph walk ----------------------------------------------------------
    def lower(self, graph: TraceGraph, env: Dict[str, _Val],
              mult: int) -> Dict[str, _Val]:
        """Lower ``graph`` with inputs bound via ``env`` (var id → _Val);
        returns the env extended with every var the graph defines."""
        for c in graph.consts:
            env.setdefault(c, _Val(const=True))
        for eqn in graph.eqns:
            self._eqn(graph, eqn, env, mult)
        return env

    def _vals(self, eqn: TraceEqn, env: Dict[str, _Val]) -> List[_Val]:
        out = []
        for v in eqn.invars:
            if v not in env:
                raise LowerError(f"{eqn.prim}: undefined input {v!r}")
            out.append(env[v])
        return out

    @staticmethod
    def _inputs_of(vals: List[_Val]) -> Tuple[str, ...]:
        seen, order = set(), []
        for v in vals:
            if v.producer and v.producer not in seen:
                seen.add(v.producer)
                order.append(v.producer)
        return tuple(order)

    def _eqn(self, graph: TraceGraph, eqn: TraceEqn,
             env: Dict[str, _Val], mult: int) -> None:
        vals = self._vals(eqn, env)

        if eqn.prim in _BODY_PRIMS:
            self._body_eqn(eqn, vals, env, mult)
            return

        # constant folding: no activation flows in → no compute node.
        # A parameter-only expression stays a weight view (offline weight
        # preprocessing, e.g. ``1 + rms_scale``).
        if all(v.const or v.weight for v in vals):
            wsrc = next((v.weight for v in vals if v.weight), None)
            out = _Val(const=wsrc is None, weight=wsrc)
            for o in eqn.outvars:
                env[o] = out
            return

        if eqn.prim in TRANSPARENT_PRIMS:
            # single-producer pass-through; multi-input shape ops
            # (concatenate) keep every producer via a zero-cost merge
            producers = self._inputs_of(vals)
            if len(producers) > 1:
                node = self.w.simple(self._name("act"), "act", 0,
                                     inputs=producers)
                out = _Val(producer=node.name)
            else:
                src = next((v for v in vals if not v.const), vals[0])
                out = _Val(producer=src.producer, weight=src.weight)
            for o in eqn.outvars:
                env[o] = out
            return

        if eqn.prim == "dot_general":
            self._dot(graph, eqn, vals, env, mult)
            return
        if eqn.prim == "conv_general_dilated":
            self._conv(graph, eqn, vals, env, mult)
            return
        if eqn.prim == "gather":
            operand = vals[0]
            if operand.weight is not None:
                self._weight_gather(graph, eqn, vals, env, mult)
                return
            # activation gather falls through to the elementwise default

        self._elementwise(graph, eqn, vals, env, mult)

    # -- MVM lowering --------------------------------------------------------
    def _dot(self, graph, eqn, vals, env, mult) -> None:
        lhs, rhs = vals[0], vals[1]
        ls = graph.vars[eqn.invars[0]].shape
        rs = graph.vars[eqn.invars[1]].shape
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lc, rc, lb, rb = (tuple(int(i) for i in t) for t in (lc, rc, lb, rb))
        K = _prod(ls[i] for i in lc)
        batch = _prod(ls[i] for i in lb)
        l_free = _prod(d for i, d in enumerate(ls) if i not in lc + lb)
        r_free = _prod(d for i, d in enumerate(rs) if i not in rc + rb)

        if rhs.weight is not None and lhs.weight is None:
            wname, wcount = rhs.weight
            node = OpNode(name=self._name("fc", wname), kind="fc",
                          inputs=self._inputs_of(vals), K=K, N=r_free,
                          V=batch * l_free * mult, c_in=K,
                          weight_count=wcount)
        elif lhs.weight is not None and rhs.weight is None:
            wname, wcount = lhs.weight
            node = OpNode(name=self._name("fc", wname), kind="fc",
                          inputs=self._inputs_of(vals), K=K, N=l_free,
                          V=batch * r_free * mult, c_in=K,
                          weight_count=wcount)
        else:
            # activation×activation (attention scores / context) — or the
            # degenerate weight×weight case, priced the same way
            node = OpNode(name=self._name("matmul"), kind="matmul",
                          inputs=self._inputs_of(vals), K=K, N=r_free,
                          V=batch * l_free * mult, c_in=K,
                          weight_count=0, prunable=False)
        self.w.add(node)
        for o in eqn.outvars:
            env[o] = _Val(producer=node.name)

    def _conv(self, graph, eqn, vals, env, mult) -> None:
        rhs = vals[1]
        kshape = graph.vars[eqn.invars[1]].shape
        oshape = graph.vars[eqn.outvars[0]].shape
        dn = eqn.params["dimension_numbers"]
        if isinstance(dn, dict):           # captured ConvDimensionNumbers
            dn = (dn["lhs_spec"], dn["rhs_spec"], dn["out_spec"])
        lhs_spec, rhs_spec, out_spec = (tuple(int(i) for i in s) for s in dn)
        groups = int(eqn.params.get("feature_group_count", 1))
        cout = kshape[rhs_spec[0]]
        cin_per_group = kshape[rhs_spec[1]]
        kspatial = tuple(kshape[i] for i in rhs_spec[2:])
        v = (oshape[out_spec[0]] * _prod(oshape[i] for i in out_spec[2:])
             * mult)
        kernel = (kspatial + (1, 1))[:2]
        wname, wcount = rhs.weight if rhs.weight else (None, _prod(kshape))
        depthwise = groups > 1 and cin_per_group == 1
        node = OpNode(
            name=self._name("dwconv" if depthwise else "conv", wname),
            kind="dwconv" if depthwise else "conv",
            inputs=self._inputs_of(vals),
            K=cin_per_group * _prod(kspatial), N=cout, V=v,
            c_in=cin_per_group * groups, kernel=kernel,
            weight_count=wcount, prunable=not depthwise and rhs.weight is not None)
        self.w.add(node)
        for o in eqn.outvars:
            env[o] = _Val(producer=node.name)

    def _weight_gather(self, graph, eqn, vals, env, mult) -> None:
        """Gather whose operand is a parameter view.

        Slice rank (``offset_dims``) decides the semantics: rank-1
        slices are an embedding lookup (a real table read, priced as an
        ``embed`` node); matrix-valued slices are weight *selection*
        (MoE expert dispatch) — the result stays a weight view carrying
        the full source parameter size, and the selection itself costs
        nothing (the hand DAGs likewise ignore routing data movement).
        """
        operand = vals[0]
        wname, wcount = operand.weight
        dn = eqn.params.get("dimension_numbers", {})
        offset = dn.get("offset_dims", ()) if isinstance(dn, dict) else ()
        out_size = _prod(graph.vars[eqn.outvars[0]].shape)
        if len(offset) >= 2:
            # keep the index chain as provenance so the selecting op
            # (router/top-k) stays an edge into the consuming MVM
            producers = self._inputs_of(vals)
            if len(producers) > 1:
                merge = self.w.simple(self._name("act"), "act", 0,
                                      inputs=producers)
                producers = (merge.name,)
            out = _Val(producer=producers[0] if producers else None,
                       weight=(wname, wcount))
            for o in eqn.outvars:
                env[o] = out
            return
        node = self.w.add(OpNode(
            name=self._name("embed", wname), kind="embed",
            inputs=self._inputs_of(vals), elements=out_size * mult,
            weight_count=wcount))
        for o in eqn.outvars:
            env[o] = _Val(producer=node.name)

    # -- everything else -----------------------------------------------------
    def _elementwise(self, graph, eqn, vals, env, mult) -> None:
        kind = ELEMENTWISE_KINDS.get(eqn.prim, eqn.prim)
        out_size = max((_prod(graph.vars[o].shape) for o in eqn.outvars
                        if o in graph.vars), default=0)
        node = self.w.simple(self._name(kind), kind, out_size * mult,
                             inputs=self._inputs_of(vals))
        for o in eqn.outvars:
            env[o] = _Val(producer=node.name)

    # -- structured bodies ---------------------------------------------------
    def _body_eqn(self, eqn, vals, env, mult) -> None:
        body = eqn.body
        if body is None:
            raise LowerError(f"{eqn.prim}: missing body graph")
        if eqn.prim == "scan":
            length = int(eqn.params.get("length", 1))
            nc = int(eqn.params.get("num_consts", 0))
            ncar = int(eqn.params.get("num_carry", 0))
            sub = {}
            for i, inner in enumerate(body.invars):
                outer = vals[i]
                if i >= nc + ncar and outer.weight is not None:
                    # stacked parameter: the body sees one layer's slice
                    outer = _Val(producer=outer.producer,
                                 weight=(outer.weight[0],
                                         body.vars[inner].size))
                sub[inner] = outer
            out_env = self.lower(body, sub, mult * length)
            outs = [out_env[o] for o in body.outvars]
            for o, v in zip(eqn.outvars, outs):
                env[o] = v
            return
        if eqn.prim == "while":
            # lowered once: trip count is data-dependent; documented as a
            # single-iteration floor in docs/tracing.md
            inner_vals = vals[-len(body.invars):] if body.invars else []
            sub = dict(zip(body.invars, inner_vals))
            out_env = self.lower(body, sub, mult)
            outs = [out_env[o] for o in body.outvars]
            for o, v in zip(eqn.outvars, outs[-len(eqn.outvars):]):
                env[o] = v
            return
        # pjit / custom_* / remat / cond(best branch): 1:1 arg mapping,
        # trailing-aligned when the eqn carries extra leading operands
        # (cond's predicate, custom_vjp's fn refs)
        n = len(body.invars)
        inner_vals = vals[-n:] if n else []
        sub = dict(zip(body.invars, inner_vals))
        out_env = self.lower(body, sub, mult)
        outs = [out_env[o] for o in body.outvars]
        for o, v in zip(eqn.outvars, outs):
            env[o] = v


# ---------------------------------------------------------------------------
# Elementwise folding.
# ---------------------------------------------------------------------------

def _fold_simple_chains(w: Workload) -> Workload:
    """Merge each non-MVM node with a single non-MVM consumer into that
    consumer (summing ``elements``), repeatedly — MVM nodes and ``embed``
    nodes (which carry weights) are fold barriers.  DAG edges through
    merged nodes are preserved, so ``topo_order``/``levels`` and the
    schedulers see the same dependence structure at a fraction of the
    node count."""

    def foldable(n: OpNode) -> bool:
        return (not n.is_mvm and n.kind != "dwconv" and n.kind != "embed"
                and not n.weight_count)

    changed = True
    while changed:
        changed = False
        succ = w.successors()
        for name in list(w.nodes):
            node = w.nodes.get(name)
            if node is None or not foldable(node):
                continue
            consumers = succ.get(name, [])
            if len(consumers) != 1:
                continue
            c = w.nodes[consumers[0]]
            if not foldable(c):
                continue
            # splice: c absorbs node's volume and upstream edges
            c.elements += node.elements
            new_inputs = []
            for i in c.inputs:
                srcs = node.inputs if i == name else (i,)
                for s in srcs:
                    if s not in new_inputs:
                        new_inputs.append(s)
            c.inputs = tuple(new_inputs)
            if node.elements > 0 and c.elements - node.elements < node.elements \
                    and c.kind != node.kind and node.kind != "act":
                c.kind = node.kind       # dominant-volume kind wins
            del w.nodes[name]
            changed = True
            break
    # rebuild in topological insertion order so Workload.add invariants
    # (no forward references) hold for downstream consumers
    order = w.topo_order()
    w.nodes = {n: w.nodes[n] for n in order}
    return w


def lower_graph(graph: TraceGraph, *, name: Optional[str] = None,
                fold: bool = True) -> Workload:
    """Lower a captured :class:`TraceGraph` into a :class:`Workload`.

    The result carries ``source_digest`` (the graph's content digest) so
    explore-cache keys distinguish traced DAGs by program content.
    """
    wname = name or graph.meta.get("workload_name") or f"traced-{graph.name}"
    w = Workload(str(wname))
    lo = _Lowerer(w)
    env: Dict[str, _Val] = {}
    for v in graph.invars:
        if v in graph.weights:
            env[v] = _Val(weight=(graph.weights[v],
                                  graph.vars[v].size))
        else:
            env[v] = _Val()
    lo.lower(graph, env, 1)
    if fold:
        _fold_simple_chains(w)
    w.source_digest = graph.digest()
    # warn-only pre-flight: a lowering bug that produces a structurally
    # broken DAG should surface here, not deep inside a sweep (CLIs —
    # repro.trace, repro.explore — re-check strictly and reject)
    from ..analysis import preflight
    preflight(w, strict=False, where="trace.lower")
    return w
