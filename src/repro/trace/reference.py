"""Shape-faithful reference programs for the differential trace harness.

These are small, *traceable* jax programs whose MVM structure mirrors the
hand-built DAGs (:func:`repro.core.workload.lm_workload` and the CNN
builders) op for op: stacked per-layer weights scanned over ``n_layers``,
top-k expert-gather MoE dispatch, GQA via K/V head repetition, fused
gate+up MLP projections.  They exist so the tracer can be tested
*differentially*: trace → lower → the MVM ``total_macs()`` /
``total_weights()`` must equal the hand DAG bit-exactly.

They are cost mirrors, not numerics mirrors — no causal masking, no
RoPE, no flash-attention tiling, no MoE capacity factors.  That is the
point: the hand DAGs model none of those either, so any disagreement is
a lowering bug, not a modeling choice.  The real execution-plane model
(``capture.trace_model(source="model")``) *does* tile and dispatch, and
its traced DAG legitimately differs; ``repro.trace.diff`` reports that
gap instead of asserting it away.

Everything jax lives behind function bodies: importing this module does
not import jax (the no-jax CI job imports the package).
"""
from __future__ import annotations

from typing import Tuple

__all__ = ["reference_program", "cnn_program", "CNN_REFERENCES"]


def _sds(shape, dtype_name="float32"):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(tuple(shape), getattr(jnp, dtype_name))


# ---------------------------------------------------------------------------
# LM reference: mirrors lm_workload's per-layer block, scanned over L.
# ---------------------------------------------------------------------------

def _lm_params(cfg):
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.head_dim
    p = {"embed": _sds((cfg.vocab_size, d))}
    if cfg.attention != "none":
        p["wq"] = _sds((L, d, cfg.n_heads * hd))
        p["wk"] = _sds((L, d, cfg.n_kv_heads * hd))
        p["wv"] = _sds((L, d, cfg.n_kv_heads * hd))
        p["wo"] = _sds((L, cfg.n_heads * hd, d))
    n_up = 2 if cfg.gated_mlp else 1
    if cfg.n_experts > 1:
        p["w_router"] = _sds((L, d, cfg.n_experts))
        p["w_up"] = _sds((L, cfg.n_experts, d, cfg.d_ff * n_up))
        p["w_down"] = _sds((L, cfg.n_experts, cfg.d_ff, d))
    elif cfg.d_ff > 0:
        p["w_up"] = _sds((L, d, cfg.d_ff * n_up))
        p["w_down"] = _sds((L, cfg.d_ff, d))
    if cfg.ssm_state > 0:
        din = cfg.ssm_inner(d)
        p["w_in"] = _sds((L, d, din * 2))
        p["w_out"] = _sds((L, din, d))
    p["norm_scale"] = _sds((d,))
    p["lm_head"] = _sds((d, cfg.vocab_size))
    return p


def _rms_norm(x, scale):
    import jax.numpy as jnp
    m = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(m + 1e-6)) * scale


def _attn_block(x, lp, cfg, *, kv=None):
    """Full (unmasked) attention over ``kv`` context (defaults to self)."""
    import jax
    import jax.numpy as jnp

    B, S, _ = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, lp["wq"]).reshape(B, S, Hq, hd)
    if kv is None:
        k = jnp.einsum("bsd,dk->bsk", x, lp["wk"]).reshape(B, S, Hkv, hd)
        v = jnp.einsum("bsd,dk->bsk", x, lp["wv"]).reshape(B, S, Hkv, hd)
        ret = (k, v)
    else:
        k, v = kv
        ret = None
    if Hkv != Hq:
        k = jnp.repeat(k, Hq // Hkv, axis=2)
        v = jnp.repeat(v, Hq // Hkv, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) * (hd ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bthd->bshd", probs, v)
    o = jnp.einsum("bsk,kd->bsd", ctx.reshape(B, S, Hq * hd), lp["wo"])
    return x + o, ret


def _ffn_block(x, lp, cfg):
    import jax
    import jax.numpy as jnp

    if cfg.n_experts > 1:
        gate = jnp.einsum("bsd,de->bse", x, lp["w_router"])
        top_p, top_e = jax.lax.top_k(jax.nn.softmax(gate, -1), cfg.top_k)
        up_sel = lp["w_up"][top_e]          # (B,S,k,d,ff·n_up) — selection
        dn_sel = lp["w_down"][top_e]        # stays a weight view (lowering)
        h = jnp.einsum("bsd,bskdf->bskf", x, up_sel)
        if cfg.gated_mlp:
            a, b = jnp.split(h, 2, axis=-1)
            h = jax.nn.silu(a) * b
        else:
            h = jax.nn.silu(h)
        y = jnp.einsum("bskf,bskfd->bskd", h, dn_sel)
        return x + (y * top_p[..., None]).sum(axis=2)
    h = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
    if cfg.gated_mlp:
        a, b = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(a) * b
    else:
        h = jax.nn.silu(h)
    return x + jnp.einsum("bsf,fd->bsd", h, lp["w_down"])


def _ssm_block(x, lp, cfg):
    """State mixing abstracted to elementwise work: the hand DAG prices
    only the in/out projections as MVMs, and so must the reference."""
    import jax
    import jax.numpy as jnp

    xp = jnp.einsum("bsd,dk->bsk", x, lp["w_in"])
    z, g = jnp.split(xp, 2, axis=-1)
    h = jax.nn.silu(z) * jnp.tanh(g)
    return x + jnp.einsum("bsk,kd->bsd", h, lp["w_out"])


def _layer(x, lp, cfg, *, kv=None):
    ret = None
    if cfg.attention != "none":
        x, ret = _attn_block(x, lp, cfg, kv=kv)
    if cfg.n_experts > 1 or cfg.d_ff > 0:
        x = _ffn_block(x, lp, cfg)
    if cfg.ssm_state > 0:
        x = _ssm_block(x, lp, cfg)
    return x, ret


def _stacked(params, cfg):
    """The per-layer (scanned) subset of the parameter dict."""
    return {k: v for k, v in params.items()
            if k not in ("embed", "norm_scale", "lm_head")}


def reference_program(cfg, *, step: str, seq_len: int,
                      batch: int) -> Tuple[object, dict, tuple]:
    """(fn, abstract params, abstract args) for one LM step kind."""
    import jax
    import jax.numpy as jnp

    params = _lm_params(cfg)
    B, S = batch, seq_len
    toks = _sds((B, S), "int32")

    if step == "forward":
        def fn(p, tokens):
            x = jnp.take(p["embed"], tokens, axis=0)

            def body(x, lp):
                x, _ = _layer(x, lp, cfg)
                return x, None

            x, _ = jax.lax.scan(body, x, _stacked(p, cfg))
            x = _rms_norm(x, p["norm_scale"])
            return jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
        return fn, params, (toks,)

    if step == "prefill":
        def fn(p, tokens):
            x = jnp.take(p["embed"], tokens, axis=0)

            def body(x, lp):
                x, kv = _layer(x, lp, cfg)
                return x, kv

            x, cache = jax.lax.scan(body, x, _stacked(p, cfg))
            x = _rms_norm(x, p["norm_scale"])
            return jnp.einsum("bsd,dv->bsv", x, p["lm_head"]), cache
        return fn, params, (toks,)

    if step == "decode":
        tok1 = _sds((B, 1), "int32")
        cache = {}
        if cfg.attention != "none":
            hd, Hkv, L = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
            cache = {"k": _sds((L, B, S, Hkv, hd)),
                     "v": _sds((L, B, S, Hkv, hd))}

        def fn(p, tokens, cache):
            x = jnp.take(p["embed"], tokens, axis=0)
            xs = _stacked(p, cfg)
            if cache:
                xs = (xs, cache["k"], cache["v"])

                def body(x, sc):
                    lp, ck, cv = sc
                    x, _ = _layer(x, lp, cfg, kv=(ck, cv))
                    return x, None
            else:
                def body(x, lp):
                    x, _ = _layer(x, lp, cfg)
                    return x, None

            x, _ = jax.lax.scan(body, x, xs)
            x = _rms_norm(x, p["norm_scale"])
            return jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
        return fn, params, (tok1, cache)

    raise ValueError(f"unknown step {step!r}")


# ---------------------------------------------------------------------------
# CNN references: mirror the paper-model builders (vgg16 / resnet18/50).
# ---------------------------------------------------------------------------

def _conv2d(x, w, stride=1):
    import jax
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _relu(x):
    import jax.numpy as jnp
    return jnp.maximum(x, 0.0)


def _maxpool2(x):
    import jax
    import jax.numpy as jnp
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def _vgg16_program(img: int, num_classes: int):
    layout = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"]

    params, cin, hw, i = {}, 3, img, 0
    for v in layout:
        if v == "M":
            hw //= 2
        else:
            params[f"conv{i}"] = _sds((v, cin, 3, 3))
            cin, i = v, i + 1
    flat = cin * hw * hw
    if img >= 224:
        params["fc1"] = _sds((flat, 4096))
        params["fc2"] = _sds((4096, 4096))
        params["fc3"] = _sds((4096, num_classes))
    else:
        params["fc1"] = _sds((flat, 512))
        params["fc2"] = _sds((512, num_classes))

    def fn(p, x):
        i = 0
        for v in layout:
            if v == "M":
                x = _maxpool2(x)
            else:
                x = _relu(_conv2d(x, p[f"conv{i}"]))
                i += 1
        x = x.reshape(1, -1)
        x = x @ p["fc1"]
        if "fc3" in p:
            x = x @ p["fc2"]
            x = x @ p["fc3"]
        else:
            x = x @ p["fc2"]
        return x

    return fn, params, (_sds((1, 3, img, img)),)


def _resnet_program(blocks, bottleneck: bool, img: int, num_classes: int):
    params = {}
    stem_k = 7 if img >= 224 else 3
    params["stem"] = _sds((64, 3, stem_k, stem_k))
    cin = 64
    for stage, (n_blocks, width) in enumerate(zip(blocks,
                                                  (64, 128, 256, 512))):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            tag = f"s{stage}b{b}"
            if bottleneck:
                params[f"{tag}_c1"] = _sds((width, cin, 1, 1))
                params[f"{tag}_c2"] = _sds((width, width, 3, 3))
                params[f"{tag}_c3"] = _sds((width * 4, width, 1, 1))
                out_c = width * 4
            else:
                params[f"{tag}_c1"] = _sds((width, cin, 3, 3))
                params[f"{tag}_c2"] = _sds((width, width, 3, 3))
                out_c = width
            if stride != 1 or cin != out_c:
                params[f"{tag}_sc"] = _sds((out_c, cin, 1, 1))
            cin = out_c
    params["fc"] = _sds((cin, num_classes))

    def fn(p, x):
        import jax.numpy as jnp
        x = _conv2d(x, p["stem"], 2 if img >= 224 else 1)
        if img >= 224:
            x = _maxpool2(x)
        cin = 64
        for stage, (n_blocks, width) in enumerate(zip(blocks,
                                                      (64, 128, 256, 512))):
            for b in range(n_blocks):
                stride = 2 if (b == 0 and stage > 0) else 1
                tag = f"s{stage}b{b}"
                if bottleneck:
                    h = _relu(_conv2d(x, p[f"{tag}_c1"]))
                    h = _relu(_conv2d(h, p[f"{tag}_c2"], stride))
                    h = _conv2d(h, p[f"{tag}_c3"])
                    out_c = width * 4
                else:
                    h = _relu(_conv2d(x, p[f"{tag}_c1"], stride))
                    h = _conv2d(h, p[f"{tag}_c2"])
                    out_c = width
                sc = (_conv2d(x, p[f"{tag}_sc"], stride)
                      if f"{tag}_sc" in p else x)
                x = _relu(h + sc)
                cin = out_c
        x = jnp.mean(x, axis=(2, 3))
        return x @ p["fc"]

    return fn, params, (_sds((1, 3, img, img)),)


CNN_REFERENCES = ("vgg16", "resnet18", "resnet50")


def cnn_program(model: str, *, img: int = 32, num_classes: int = 100):
    if model == "vgg16":
        return _vgg16_program(img, num_classes)
    if model == "resnet18":
        return _resnet_program((2, 2, 2, 2), False, img, num_classes)
    if model == "resnet50":
        return _resnet_program((3, 4, 6, 3), True, img, num_classes)
    raise ValueError(f"no CNN reference for {model!r}; "
                     f"choose from {CNN_REFERENCES}")
