"""Capture a jaxpr into the jax-free :class:`~repro.trace.ir.TraceGraph`.

The only module in :mod:`repro.trace` that imports jax — and it does so
lazily, so ``repro.trace.lower`` / fixture replay keep working in the
no-jax CI job.  ``jax.make_jaxpr`` runs on ``ShapeDtypeStruct`` inputs:
capture is abstract interpretation, no device, no compilation.

Three front doors:

* :func:`capture` — any callable + example (abstract) args, with the
  argument positions holding model parameters named so the lowerer can
  attribute weight storage.
* :func:`trace_model` — an LM :class:`~repro.configs.base.ArchConfig`
  plus a step kind (``forward`` / ``prefill`` / ``decode``), traced from
  the shape-faithful reference programs (default) or the real
  :mod:`repro.models.transformer` (``source="model"``, best-effort: the
  execution plane's flash-attention tiling and MoE capacity dispatch are
  *not* MAC-identical to the hand DAGs, see ``docs/tracing.md``).
* :func:`traced_workload` — config (or name) → lowered :class:`Workload`,
  the entry point the explore CLI's ``--workload traced:…`` uses.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

from ..core.workload import Workload
from .ir import TraceEqn, TraceGraph, TraceVar
from .lower import lower_graph

__all__ = ["capture", "trace_model", "traced_workload", "traced_cnn",
           "TRACE_STEPS"]

TRACE_STEPS = ("forward", "prefill", "decode")

_KEY_RE = re.compile(r"[^A-Za-z0-9_]+")


def _path_name(path) -> str:
    """``(DictKey('layers'), DictKey('wq'))`` → ``"layers/wq"``."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(_KEY_RE.sub("_", str(p)).strip("_"))
    return "/".join(parts)


def _json_safe(v):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if hasattr(v, "_asdict"):                     # namedtuple (Gather/Conv/
        return {k: _json_safe(x) for k, x in v._asdict().items()}  # Scatter DNs)
    if isinstance(v, (tuple, list)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if hasattr(v, "name") and not callable(v):    # enums (Precision, …)
        return str(v.name)
    return str(v)                                 # dtypes, everything else


def _deep_eqn_count(jaxpr) -> int:
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    n = len(jaxpr.eqns)
    for e in jaxpr.eqns:
        for val in e.params.values():
            inner = getattr(val, "jaxpr", val)
            if hasattr(inner, "eqns"):
                n += _deep_eqn_count(inner)
    return n


def _convert_jaxpr(closed, name: str) -> TraceGraph:
    """Recursively convert a (Closed)Jaxpr into a TraceGraph."""
    from jax import core

    jaxpr = getattr(closed, "jaxpr", closed)
    ids: Dict[object, str] = {}
    vars_table: Dict[str, TraceVar] = {}
    consts = []

    def vid(v) -> str:
        if isinstance(v, core.Literal):
            vn = f"c{len(consts)}"
            consts.append(vn)
            vars_table[vn] = TraceVar(tuple(getattr(v.aval, "shape", ())),
                                      str(getattr(v.aval, "dtype", "?")))
            return vn
        if v not in ids:
            vn = f"v{len(ids)}"
            ids[v] = vn
            vars_table[vn] = TraceVar(tuple(getattr(v.aval, "shape", ())),
                                      str(getattr(v.aval, "dtype", "?")))
        return ids[v]

    invars = [vid(v) for v in jaxpr.invars]
    for cv in jaxpr.constvars:
        consts.append(vid(cv))

    eqns = []
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        params: Dict[str, object] = {}
        body: Optional[TraceGraph] = None
        if prim == "while":
            body = _convert_jaxpr(eqn.params["body_jaxpr"], f"{prim}:body")
            for k in ("cond_nconsts", "body_nconsts"):
                params[k] = int(eqn.params.get(k, 0))
        elif prim == "cond":
            # data-dependent branch: keep the deepest one (upper bound on
            # the work a branch can do; documented in docs/tracing.md)
            branches = eqn.params["branches"]
            body = _convert_jaxpr(max(branches, key=_deep_eqn_count),
                                  f"{prim}:branch")
        else:
            for k, val in eqn.params.items():
                if isinstance(val, (core.Jaxpr, core.ClosedJaxpr)):
                    if body is None:
                        body = _convert_jaxpr(val, f"{prim}:{k}")
                    continue
                params[k] = _json_safe(val)
        eqns.append(TraceEqn(prim=prim,
                             invars=[vid(v) for v in eqn.invars],
                             outvars=[vid(v) for v in eqn.outvars],
                             params=params, body=body))

    # literal outvars become const vars so positional body-output
    # alignment in the lowerer is preserved
    return TraceGraph(name=name, invars=invars,
                      outvars=[vid(v) for v in jaxpr.outvars],
                      vars=vars_table, eqns=eqns, consts=consts)


def capture(fn, *example_args, param_argnums: Tuple[int, ...] = (0,),
            name: str = "traced", meta: Optional[dict] = None) -> TraceGraph:
    """Trace ``fn`` abstractly and convert its jaxpr to a TraceGraph.

    ``example_args`` may be (pytrees of) ``jax.ShapeDtypeStruct`` — no
    real data is needed.  Leaves of the arguments whose positions are in
    ``param_argnums`` are recorded as model parameters, named by their
    pytree path (``layers/wq``); the lowerer turns those names into
    weight attribution on the MVM nodes.
    """
    import jax

    closed = jax.make_jaxpr(fn)(*example_args)
    graph = _convert_jaxpr(closed, name)

    pos = 0
    weights: Dict[str, str] = {}
    for ai, arg in enumerate(example_args):
        leaves = jax.tree_util.tree_flatten_with_path(arg)[0]
        for path, _leaf in leaves:
            if ai in param_argnums:
                weights[graph.invars[pos]] = _path_name(path) or f"arg{ai}"
            pos += 1
    if pos != len(graph.invars):
        raise AssertionError(
            f"flattened args ({pos}) != jaxpr invars ({len(graph.invars)})")
    graph.weights = weights
    graph.meta = dict(meta or {})
    return graph


# ---------------------------------------------------------------------------
# Model-level capture.
# ---------------------------------------------------------------------------

def _model_program(cfg, step: str, seq_len: int, batch: int):
    """Abstract (fn, params, args) for the real execution-plane model."""
    import jax
    import jax.numpy as jnp

    from ..models import transformer

    params = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
    if step == "forward":
        return (lambda p, t: transformer.forward(p, t, cfg)), params, (toks,)
    if step == "prefill":
        return (lambda p, t: transformer.prefill(p, t, cfg)), params, (toks,)
    if step == "decode":
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, batch, seq_len))
        tok1 = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        return ((lambda p, t, c: transformer.decode_step(p, t, cfg, c)),
                params, (tok1, cache))
    raise ValueError(f"unknown step {step!r}; choose from {TRACE_STEPS}")


def trace_model(cfg, *, step: str = "forward", seq_len: int = 128,
                batch: int = 1, source: str = "reference") -> TraceGraph:
    """Trace one step of an LM config into a TraceGraph."""
    if step not in TRACE_STEPS:
        raise ValueError(f"unknown step {step!r}; choose from {TRACE_STEPS}")
    if source == "reference":
        from .reference import reference_program
        fn, params, args = reference_program(cfg, step=step,
                                             seq_len=seq_len, batch=batch)
    elif source == "model":
        fn, params, args = _model_program(cfg, step, seq_len, batch)
    else:
        raise ValueError(f"unknown source {source!r} "
                         "(choose 'reference' or 'model')")
    return capture(fn, params, *args,
                   name=f"{cfg.name}:{step}",
                   meta={"config": cfg.name, "step": step,
                         "seq_len": seq_len, "batch": batch,
                         "source": source,
                         "workload_name": f"traced-{cfg.name}-{step}"})


def traced_workload(cfg, *, step: str = "forward", seq_len: int = 128,
                    batch: int = 1, source: str = "reference") -> Workload:
    """Config (or config name) → auto-lowered :class:`Workload`.

    The traced sibling of :func:`repro.core.workload.lm_workload`: same
    DAG machinery downstream (schedulers, cost model, explore cache —
    keyed by the jaxpr digest via ``Workload.source_digest``), but the
    op list comes out of the program instead of out of a hand model.
    """
    if isinstance(cfg, str):
        from ..configs import get_config
        cfg = get_config(cfg)
    graph = trace_model(cfg, step=step, seq_len=seq_len, batch=batch,
                        source=source)
    return lower_graph(graph)


def traced_cnn(model: str = "resnet18", img: int = 32,
               num_classes: int = 100) -> Workload:
    """Traced sibling of the CNN builders (vgg16 / resnet18 / resnet50)."""
    from .reference import cnn_program
    fn, params, args = cnn_program(model, img=img, num_classes=num_classes)
    graph = capture(fn, params, *args, name=f"{model}-{img}",
                    meta={"model": model, "img": img,
                          "num_classes": num_classes,
                          "workload_name": f"traced-{model}-{img}"})
    return lower_graph(graph)
