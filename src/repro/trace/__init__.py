"""Compiler front end for the modeling plane (CIMFlow-style).

Auto-lowers traced jax models into :class:`~repro.core.workload.Workload`
DAGs so every config in :mod:`repro.configs` becomes a CIM scenario
without hand modeling:

* :mod:`repro.trace.ir` — jax-free, JSON-serialisable jaxpr mirror
  (:class:`TraceGraph`), content-digested for explore-cache keying.
* :mod:`repro.trace.capture` — ``jax.make_jaxpr`` → TraceGraph (the only
  jax-touching module, imported lazily at call time).
* :mod:`repro.trace.lower` — TraceGraph → Workload (jax-free; the
  committed fixtures under ``tests/fixtures/trace/`` replay through it
  in the no-jax CI job).
* :mod:`repro.trace.diff` — traced-vs-hand differential reports.

``python -m repro.trace lower|diff|fixture`` drives it from the shell;
``python -m repro.explore … --workload traced:<config>`` sweeps a traced
DAG through the exploration engine.  See ``docs/tracing.md``.
"""
from .capture import TRACE_STEPS, capture, trace_model, traced_cnn, \
    traced_workload
from .diff import diff_table, diff_workloads, summarize
from .ir import TraceEqn, TraceGraph, TraceVar
from .lower import LowerError, lower_graph

__all__ = [
    "TraceVar", "TraceEqn", "TraceGraph",
    "lower_graph", "LowerError",
    "capture", "trace_model", "traced_workload", "traced_cnn",
    "TRACE_STEPS",
    "summarize", "diff_workloads", "diff_table",
]
