"""Version-compat shims for jax APIs the execution plane depends on.

The mesh-context API moved between jax releases: 0.5+ exposes
``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh`` / ``jax.shard_map``
(with ``check_vma``), while 0.4.x only has the legacy ``Mesh`` context
manager and ``jax.experimental.shard_map.shard_map`` (with ``check_rep``).
Every call site in the repo goes through this module so the same model
code runs on both lines:

* :func:`set_mesh` — context manager activating a mesh for sharding
  resolution (``with_sharding_constraint`` with bare ``PartitionSpec``)
  and :func:`get_abstract_mesh` discovery.
* :func:`get_abstract_mesh` — the active mesh, or an EMPTY sentinel with
  the same ``.empty`` / ``.axis_names`` / ``.shape`` surface.  On 0.4.x
  the returned object is the *concrete* ``Mesh`` (its ``shape`` mapping
  and ``axis_names`` match ``AbstractMesh``), which is exactly what the
  0.4.x ``shard_map`` needs anyway.
* :func:`shard_map` — keyword-compatible with the 0.5+ signature
  (``check_vma``), mapped to ``check_rep`` on 0.4.x.

Import-time version guard: see ``_SUPPORTED`` below; kept in sync with
the ``[jax]`` extra in ``pyproject.toml``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax

__all__ = ["get_abstract_mesh", "set_mesh", "shard_map", "JAX_VERSION",
           "HAS_NATIVE_MESH_CONTEXT"]

# ---------------------------------------------------------------------------
# Supported-version guard (kept in sync with pyproject's [jax] extra)
# ---------------------------------------------------------------------------

_SUPPORTED = ((0, 4, 30), (0, 8, 0))   # [lower, upper) — upper exclusive


def _parse_version(v: str) -> Tuple[int, ...]:
    parts = []
    for tok in v.split(".")[:3]:
        digits = ""
        for ch in tok:            # leading digits only: "0rc1" → 0
            if not ch.isdigit():
                break
            digits += ch
        parts.append(int(digits) if digits else 0)
    while len(parts) < 3:
        parts.append(0)
    return tuple(parts)


JAX_VERSION: Tuple[int, ...] = _parse_version(jax.__version__)

if not (_SUPPORTED[0] <= JAX_VERSION < _SUPPORTED[1]):
    raise ImportError(
        f"repro's jax execution plane supports jax>="
        f"{'.'.join(map(str, _SUPPORTED[0]))},<"
        f"{'.'.join(map(str, _SUPPORTED[1]))} but found jax "
        f"{jax.__version__}.  The mesh-context and shard_map APIs this "
        f"repo shims (repro/runtime/compat.py) have not been validated "
        f"outside that range — install a supported jax "
        f"(pip install 'ciminus-repro[jax]') or extend the shim.")

# ``jax.set_mesh`` + ``jax.sharding.get_abstract_mesh`` + ``jax.shard_map``
# all appeared together on the 0.5+ line; probe once.
HAS_NATIVE_MESH_CONTEXT: bool = (
    hasattr(jax, "set_mesh")
    and hasattr(jax.sharding, "get_abstract_mesh")
    and hasattr(jax, "shard_map"))


if HAS_NATIVE_MESH_CONTEXT:
    import inspect

    # The check_rep→check_vma rename landed later than jax.shard_map
    # itself (mid 0.5/0.6 releases expose the new entry point with the
    # old kwarg), so probe the signature rather than the version.
    try:
        _REP_KWARG = ("check_vma"
                      if "check_vma" in inspect.signature(
                          jax.shard_map).parameters
                      else "check_rep")
    except (ValueError, TypeError):  # pragma: no cover - exotic wrappers
        _REP_KWARG = "check_vma"

    def get_abstract_mesh():
        """The mesh activated by :func:`set_mesh` (EMPTY-like when none)."""
        return jax.sharding.get_abstract_mesh()

    def set_mesh(mesh):
        """Activate ``mesh`` for sharding resolution + discovery."""
        return jax.set_mesh(mesh)

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        """0.5+-signature shard_map (``check_vma``) on any supported jax."""
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             **{_REP_KWARG: check_vma})

else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    class _EmptyMesh:
        """Sentinel matching the ``AbstractMesh`` surface the repo uses."""
        empty = True
        axis_names: Tuple[str, ...] = ()
        shape: dict = {}

        def __repr__(self) -> str:  # pragma: no cover - debugging aid
            return "_EmptyMesh()"

    _EMPTY = _EmptyMesh()

    class _MeshState(threading.local):
        def __init__(self):
            self.stack = []

    _STATE = _MeshState()

    def get_abstract_mesh():
        """The innermost :func:`set_mesh` mesh; EMPTY sentinel otherwise.

        On 0.4.x this returns the *concrete* ``Mesh`` — its ``.empty``,
        ``.axis_names`` and ``.shape`` (an axis-name→size mapping) match
        the ``AbstractMesh`` the 0.5+ API returns, and the legacy
        ``shard_map`` requires a concrete mesh anyway.  Also honours a
        plain ``with mesh:`` context entered without the shim.
        """
        if _STATE.stack:
            return _STATE.stack[-1]
        try:
            from jax.interpreters import pxla
            phys = pxla.thread_resources.env.physical_mesh
            if not phys.empty:
                return phys
        except Exception:  # pragma: no cover - jax internals moved
            pass
        return _EMPTY

    @contextlib.contextmanager
    def set_mesh(mesh):
        """Activate ``mesh``: legacy ``Mesh`` context (so bare-
        ``PartitionSpec`` ``with_sharding_constraint`` resolves) plus the
        discovery stack backing :func:`get_abstract_mesh`."""
        _STATE.stack.append(mesh)
        try:
            with mesh:
                yield mesh
        finally:
            _STATE.stack.pop()

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        """0.5+-signature shard_map (``check_vma``) on 0.4.x jax.

        ``check_vma`` maps to the 0.4.x ``check_rep`` flag (same meaning:
        verify per-axis replication of outputs).  ``mesh`` may be the
        object returned by :func:`get_abstract_mesh` — concrete on this
        line, which is what the legacy implementation requires.
        """
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
