"""Runtime support layer: jax version-compat shims for the execution plane."""
