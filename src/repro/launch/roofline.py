"""Roofline analysis over the dry-run ledger (deliverable g).

Reads ``results/dryrun.jsonl`` and derives, per (arch × cell × mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective term = collective_bytes_per_device / ICI_bandwidth_per_chip

(the per-device program *is* the per-chip workload under SPMD, so chip
terms use per-chip peaks directly).  Also reports MODEL_FLOPS = 6·N·D
(dense) or 6·N_active·D (MoE) and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs × chips), the dominant term, and a one-line
"what would move it" note.

Peaks come from a :class:`repro.calibrate.CalibrationProfile` — the
bundled default carries the analytic TPU v5e-class numbers (197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI); ``--profile`` swaps in a fitted
one (see ``python -m repro.calibrate``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

from ..calibrate.profile import (CalibrationProfile, ProfileError,
                                 default_profile, resolve_profile)

_DEFAULT_PROFILE = default_profile()

# Legacy module-level constants, kept as aliases of the bundled default
# profile so existing imports keep meaning exactly what they always did.
PEAK_FLOPS = _DEFAULT_PROFILE.peak_flops     # bf16 FLOP/s per chip
HBM_BW = _DEFAULT_PROFILE.hbm_bw             # bytes/s per chip
ICI_BW = _DEFAULT_PROFILE.ici_bw             # bytes/s per link per chip

__all__ = ["analyze", "load_ledger", "LedgerRecords", "main"]


class LedgerRecords(list):
    """Deduped ledger records, plus what loading had to skip.

    A plain ``list`` to callers; ``skipped`` / ``skipped_lines`` report
    undecodable lines so partial writes and corruption are visible
    instead of silently shrinking the analysis.
    """

    def __init__(self, records, skipped_lines: List[int]):
        super().__init__(records)
        self.skipped_lines = skipped_lines

    @property
    def skipped(self) -> int:
        return len(self.skipped_lines)


def load_ledger(path: str) -> LedgerRecords:
    recs = []
    skipped_lines: List[int] = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                skipped_lines.append(lineno)
    if skipped_lines:
        shown = ", ".join(map(str, skipped_lines[:8]))
        more = "" if len(skipped_lines) <= 8 else ", ..."
        print(f"roofline: skipped {len(skipped_lines)} undecodable ledger "
              f"line(s) in {path} (line {shown}{more})", file=sys.stderr)
    # keep the last record per (arch, cell, mesh, tag)
    dedup = {}
    for r in recs:
        dedup[(r.get("arch"), r.get("cell"), r.get("mesh"),
               r.get("tag", ""))] = r
    return LedgerRecords(dedup.values(), skipped_lines)


def model_flops(rec: Dict) -> float:
    """6·N_active·D per step (D = tokens processed)."""
    kind = rec.get("kind", "train")
    tokens = rec["global_batch"] * (rec["seq_len"] if kind != "decode" else 1)
    n = rec["active_params"]
    mult = 6.0 if kind == "train" else 2.0   # inference: fwd only
    return mult * n * tokens


def analyze(rec: Dict,
            profile: Optional[CalibrationProfile] = None) -> Optional[Dict]:
    if "error" in rec:
        return None
    prof = profile if profile is not None else _DEFAULT_PROFILE
    chips = rec["chips"]
    coll = sum(v for k, v in rec["collective_bytes"].items() if k != "count")
    t_comp = rec["flops"] / prof.peak_flops
    t_mem = rec["bytes_accessed"] / prof.hbm_bw
    t_coll = coll / prof.ici_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / max(rec["flops"] * chips, 1.0)
    bound = max(terms.values())
    # roofline fraction: useful model FLOPs per chip-second at the bound
    frac = (mf / chips / prof.peak_flops) / max(bound, 1e-30)
    hint = {
        "compute": "cut non-model FLOPs (remat policy, fused ops, "
                   "cheaper logits) or improve sharding balance",
        "memory": "improve reuse/layout (fuse elementwise chains, larger "
                  "tiles, bf16 partials, ring-buffer caches)",
        "collective": "reshard to cut resharding collectives / overlap "
                      "comm with compute / compress cross-pod traffic",
    }[dom]
    return {
        **{k: rec[k] for k in ("arch", "cell", "mesh", "tag", "chips",
                               "kind", "peak_bytes")},
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hint": hint,
    }


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | cell | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "dominant | peak GiB/dev | useful | roofline |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["cell"], x["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | **{r['dominant']}** "
            f"| {r['peak_bytes']/2**30:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ledger", default="results/dryrun.jsonl")
    ap.add_argument("--json", action="store_true", help="emit JSON rows")
    ap.add_argument("--tag", default=None, help="filter by ledger tag")
    ap.add_argument("--profile", default=None,
                    help="calibration profile JSON (or 'default') "
                         "supplying the per-chip peaks")
    args = ap.parse_args(argv)
    try:
        profile = resolve_profile(args.profile)
    except ProfileError as e:
        ap.error(str(e))
    rows = []
    errors = []
    for rec in load_ledger(args.ledger):
        if args.tag is not None and rec.get("tag", "") != args.tag:
            continue
        a = analyze(rec, profile)
        if a is None:
            errors.append(rec)
        else:
            rows.append(a)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(fmt_table(rows))
        if errors:
            print(f"\n{len(errors)} FAILED cells:")
            for e in errors:
                print(f"  {e['arch']} {e['cell']} {e['mesh']}: "
                      f"{e['error'][:160]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
