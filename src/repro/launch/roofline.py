"""Roofline analysis over the dry-run ledger (deliverable g).

Reads ``results/dryrun.jsonl`` and derives, per (arch × cell × mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective term = collective_bytes_per_device / ICI_bandwidth_per_chip

(the per-device program *is* the per-chip workload under SPMD, so chip
terms use per-chip peaks directly).  Also reports MODEL_FLOPS = 6·N·D
(dense) or 6·N_active·D (MoE) and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs × chips), the dominant term, and a one-line
"what would move it" note.

Hardware constants (TPU v5e-class, per assignment):
  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link per chip

__all__ = ["analyze", "load_ledger", "main"]


def load_ledger(path: str) -> List[Dict]:
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep the last record per (arch, cell, mesh, tag)
    dedup = {}
    for r in recs:
        dedup[(r.get("arch"), r.get("cell"), r.get("mesh"),
               r.get("tag", ""))] = r
    return list(dedup.values())


def model_flops(rec: Dict) -> float:
    """6·N_active·D per step (D = tokens processed)."""
    kind = rec.get("kind", "train")
    tokens = rec["global_batch"] * (rec["seq_len"] if kind != "decode" else 1)
    n = rec["active_params"]
    mult = 6.0 if kind == "train" else 2.0   # inference: fwd only
    return mult * n * tokens


def analyze(rec: Dict) -> Optional[Dict]:
    if "error" in rec:
        return None
    chips = rec["chips"]
    coll = sum(v for k, v in rec["collective_bytes"].items() if k != "count")
    t_comp = rec["flops"] / PEAK_FLOPS
    t_mem = rec["bytes_accessed"] / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / max(rec["flops"] * chips, 1.0)
    bound = max(terms.values())
    # roofline fraction: useful model FLOPs per chip-second at the bound
    frac = (mf / chips / PEAK_FLOPS) / max(bound, 1e-30)
    hint = {
        "compute": "cut non-model FLOPs (remat policy, fused ops, "
                   "cheaper logits) or improve sharding balance",
        "memory": "improve reuse/layout (fuse elementwise chains, larger "
                  "tiles, bf16 partials, ring-buffer caches)",
        "collective": "reshard to cut resharding collectives / overlap "
                      "comm with compute / compress cross-pod traffic",
    }[dom]
    return {
        **{k: rec[k] for k in ("arch", "cell", "mesh", "tag", "chips",
                               "kind", "peak_bytes")},
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hint": hint,
    }


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | cell | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "dominant | peak GiB/dev | useful | roofline |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["cell"], x["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | **{r['dominant']}** "
            f"| {r['peak_bytes']/2**30:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ledger", default="results/dryrun.jsonl")
    ap.add_argument("--json", action="store_true", help="emit JSON rows")
    ap.add_argument("--tag", default=None, help="filter by ledger tag")
    args = ap.parse_args(argv)
    rows = []
    errors = []
    for rec in load_ledger(args.ledger):
        if args.tag is not None and rec.get("tag", "") != args.tag:
            continue
        a = analyze(rec)
        if a is None:
            errors.append(rec)
        else:
            rows.append(a)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(fmt_table(rows))
        if errors:
            print(f"\n{len(errors)} FAILED cells:")
            for e in errors:
                print(f"  {e['arch']} {e['cell']} {e['mesh']}: "
                      f"{e['error'][:160]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
