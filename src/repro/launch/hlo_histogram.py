"""HLO opcode byte/flop histogram — the dry-run "profiler".

There is no wall-clock profile on CPU, so §Perf iterations localise the
dominant roofline term by ranking compiled-HLO ops by result bytes
(the quantity XLA cost analysis accumulates into ``bytes accessed``).
Feeds the hypothesis step: "what IS the per-layer byte whale?"

Usage:
  python -m repro.launch.hlo_histogram --arch llama3-8b --cell train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=256")

import argparse
import collections
import re
from typing import Dict, Tuple

import jax

from ..configs import get_config
from ..configs.base import SHAPE_CELLS
from ..launch.dryrun import lower_cell, _shape_bytes

_OP_RE = re.compile(r"^\s*(?:ROOT )?[%\w.\-]+ = (.+?) ([\w\-]+)\(")

# Opcodes XLA:TPU fuses into neighbouring producers/consumers — their
# results never round-trip HBM on the target backend.  The CPU backend
# (which the dry-run compiles with) fuses far less, so raw ``bytes
# accessed`` over-counts them; excluding them gives a TPU-fusion-adjusted
# LOWER estimate of the memory term (the truth lies between).
_FUSIBLE = {
    "convert", "broadcast", "add", "subtract", "multiply", "divide",
    "select", "compare", "exponential", "tanh", "maximum", "minimum",
    "and", "or", "not", "negate", "abs", "rsqrt", "sqrt", "power",
    "iota", "bitcast", "copy", "reduce-precision", "constant",
    "reshape", "exponential-minus-one", "log", "sign", "clamp",
    "concatenate", "pad", "slice", "reverse",
}


def fused_bytes_estimate(hlo_text: str) -> Tuple[int, int]:
    """(raw result bytes, TPU-fusion-adjusted bytes) over the module."""
    raw = fused = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        sig, op = m.groups()
        b = _shape_bytes(sig)
        raw += b
        if op not in _FUSIBLE:
            fused += b
    return raw, fused


def histogram(hlo_text: str, top: int = 25) -> Dict[str, Tuple[int, int]]:
    """opcode → (total result bytes, op count), descending by bytes."""
    agg: Dict[str, list] = collections.defaultdict(lambda: [0, 0])
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        sig, op = m.groups()
        b = _shape_bytes(sig)
        agg[op][0] += b
        agg[op][1] += 1
    ranked = sorted(agg.items(), key=lambda kv: -kv[1][0])[:top]
    return {k: (v[0], v[1]) for k, v in ranked}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True, choices=list(SHAPE_CELLS))
    ap.add_argument("--layers", type=int, default=2,
                    help="truncated layer count (keeps compiles fast)")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--remat-policy", default="dots")
    args = ap.parse_args(argv)

    import dataclasses
    from ..models import layers as _ly, transformer as _tf

    cfg = dataclasses.replace(get_config(args.arch), n_layers=args.layers)
    mesh = jax.make_mesh((16, 16), ("data", "model"))
    with _tf.scan_unroll(max(2, args.layers)), _ly.chunk_unroll(8):
        low = lower_cell(cfg, SHAPE_CELLS[args.cell], mesh,
                         multi_pod=False, remat=True,
                         remat_policy=args.remat_policy)
        compiled = low.compile()
    hist = histogram(compiled.as_text(), args.top)
    total = sum(b for b, _ in hist.values())
    print(f"{args.arch} × {args.cell} (L={args.layers}) — "
          f"top {args.top} opcodes by result bytes:")
    for op, (b, n) in hist.items():
        print(f"  {op:28s} {b/1e9:10.2f} GB  ×{n:5d}  "
              f"({b / max(total, 1):5.1%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
