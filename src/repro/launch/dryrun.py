import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
# (No `from __future__ import annotations` here for the same reason: the
#  XLA_FLAGS assignment must be the first statements of the module.)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the appropriate step function (train_step /
prefill / serve_step) against ShapeDtypeStruct inputs on the production
mesh, compiles it, and records:

* ``compiled.memory_analysis()``  — bytes per device (proves it fits);
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline;
* collective bytes by op kind     — parsed from the optimized HLO.

With ``--execute N`` the compiled cell is additionally *run* N times on
zero-filled sharded inputs (donated buffers are re-fed from the step's
own outputs) and the best wall-clock lands in the record as ``time_s``
— turning the characterisation ledger into calibration samples that
``python -m repro.calibrate collect/fit`` harvests as ``step:<kind>``
op classes, so production-scale runs feed the roofline fit, not just
microbenchmarks and fixtures.  Execution allocates the cell's real
footprint; keep it for hardware runs.

Results append to a JSONL ledger (``--out``), one record per cell, so an
interrupted matrix run resumes where it stopped (``--skip-done``).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --cell train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
  python -m repro.launch.dryrun --arch llama3-8b --cell train_4k \
      --execute 5 --tag calib
"""
import argparse
import functools
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import obs
from ..configs import all_configs, cells_for, get_config
from ..configs.base import ArchConfig, ShapeCell, SHAPE_CELLS
from ..distributed import sharding as shard_rules
from ..distributed.sharding import (batch_spec, cache_specs, spec_for_param,
                                    tree_shardings)
from ..runtime import compat
from ..models.transformer import decode_step, forward, init_cache, init_params, prefill
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.step import make_train_step

__all__ = ["input_specs", "lower_cell", "run_cell", "main"]


# ---------------------------------------------------------------------------
# ShapeDtypeStruct inputs (weak-type-correct, shardable, zero allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_struct(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    if cell.kind == "train":
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        if cfg.prefix_len:
            batch["prefix_embed"] = _sds((B, cfg.prefix_len, d), jnp.bfloat16)
        if cfg.enc_dec:
            batch["enc_embed"] = _sds((B, cfg.enc_seq, d), jnp.bfloat16)
        return {"batch": batch}
    if cell.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.prefix_len:
            out["prefix_embed"] = _sds((B, cfg.prefix_len, d), jnp.bfloat16)
        if cfg.enc_dec:
            out["enc_embed"] = _sds((B, cfg.enc_seq, d), jnp.bfloat16)
        return out
    # decode: one new token against a cache of length S
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, B, S, dtype=jnp.bfloat16))
    return {"tokens": _sds((B,), jnp.int32), "cache": cache}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh, *,
               multi_pod: bool, remat: bool = True,
               microbatches: int = 1, remat_policy: str = "minimal"):
    """Lower one cell on ``mesh``; returns the jax Lowered object."""
    params_t = param_struct(cfg)
    p_shard = tree_shardings(mesh, params_t)
    bsp = batch_spec(multi_pod=multi_pod)
    baxes = bsp[0]

    if cell.kind == "train":
        opt_t = jax.eval_shape(adamw_init, params_t)
        # ZeRO-1: optimizer m/v always take the fsdp=True (data-augmented)
        # specs — they are touched once per step, so the extra gather cost
        # is tiny next to the footprint win.
        o_shard = tree_shardings(
            mesh, opt_t,
            fsdp=True if shard_rules.get_options().zero1 else None)
        specs = input_specs(cfg, cell)
        bshard = {}
        for k, v in specs["batch"].items():
            nd = len(v.shape)
            bshard[k] = NamedSharding(mesh, P(*((baxes,) + (None,) * (nd - 1))))
        step = make_train_step(cfg, AdamWConfig(), remat=remat,
                               microbatches=microbatches,
                               remat_policy=remat_policy)
        with compat.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, bshard),
                donate_argnums=(0, 1),
            ).lower(params_t, opt_t, specs["batch"])
        return lowered

    if cell.kind == "prefill":
        specs = input_specs(cfg, cell)
        arg_shards = {}
        for k, v in specs.items():
            nd = len(v.shape)
            arg_shards[k] = NamedSharding(mesh, P(*((baxes,) + (None,) * (nd - 1))))

        def prefill_fn(params, inputs):
            kw = {k: v for k, v in inputs.items() if k != "tokens"}
            return prefill(params, inputs["tokens"], cfg, **kw)

        with compat.set_mesh(mesh):
            lowered = jax.jit(
                prefill_fn, in_shardings=(p_shard, arg_shards),
            ).lower(params_t, specs)
        return lowered

    # decode
    specs = input_specs(cfg, cell)
    c_specs = cache_specs(cfg, cell, multi_pod=multi_pod)
    cache_t = specs["cache"]
    c_shard = {}
    for k, v in cache_t.items():
        c_shard[k] = NamedSharding(mesh, c_specs.get(k, P()))
    data_size = 16 * (2 if multi_pod else 1)
    tok_spec = P(baxes) if cell.global_batch >= data_size else P(None)
    tok_shard = NamedSharding(mesh, tok_spec)

    def serve_step(params, tokens, cache):
        return decode_step(params, tokens, cfg, cache)

    with compat.set_mesh(mesh):
        lowered = jax.jit(
            serve_step,
            in_shardings=(p_shard, tok_shard, c_shard),
            donate_argnums=(2,),
        ).lower(params_t, specs["tokens"], cache_t)
    return lowered


# ---------------------------------------------------------------------------
# Collective-byte extraction from optimized HLO
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every typed shape in an HLO result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes from an (optimized) HLO dump."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (.+?) (\S+)\(", ls)
        if not m:
            continue
        sig, opname = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if opname == kind or opname.startswith(kind + "-start") \
               or opname == kind + "-done":
                if opname.endswith("-done"):
                    break  # counted at -start
                out[kind] += _shape_bytes(sig)
                out["count"] += 1
                break
    return out


# ---------------------------------------------------------------------------
# Cell execution + ledger
# ---------------------------------------------------------------------------

def _cost_of(compiled) -> Tuple[float, float, Dict[str, int]]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _timed_execute(compiled, args, *, repeats: int = 3,
                   refeed: Tuple[Tuple[int, int], ...] = (),
                   block=None, clock=time.perf_counter) -> Dict[str, float]:
    """Run ``compiled(*args)`` ``repeats`` times and report wall seconds.

    ``refeed`` maps output positions back onto donated argument slots
    (``(arg_idx, out_idx)``) — donated buffers are invalidated by the
    call, so repeats re-feed the step's own outputs (params/opt for
    train, the KV cache for decode), which is also what a real training
    loop does.  One extra warmup call absorbs transfer/dispatch warmup
    and is excluded from the stats.
    """
    if block is None:
        block = jax.block_until_ready
    args = list(args)
    times = []
    for _ in range(max(1, repeats) + 1):
        t0 = clock()
        out = compiled(*args)
        block(out)
        times.append(clock() - t0)
        for arg_idx, out_idx in refeed:
            args[arg_idx] = out[out_idx]
    timed = times[1:]
    timed_sorted = sorted(timed)
    mid = len(timed_sorted) // 2
    median = (timed_sorted[mid] if len(timed_sorted) % 2
              else 0.5 * (timed_sorted[mid - 1] + timed_sorted[mid]))
    return {"time_s": min(timed), "time_s_median": median,
            "execute_repeats": len(timed)}


# donated arg slot <- output position, per cell kind (train donates
# params+opt and returns them first; decode donates and returns the cache)
_REFEED = {"train": ((0, 0), (1, 1)), "prefill": (), "decode": ((2, 1),)}


def _zeros_like_structs(structs, shardings):
    """Materialise zero-filled device arrays for a struct tree, placed on
    the compiled executable's input shardings."""
    flat, treedef = jax.tree.flatten(structs)
    flat_sh = list(shardings)
    if len(flat_sh) != len(flat):       # some jax versions return a pytree
        flat_sh = jax.tree.flatten(shardings)[0]
    out = []
    for s, sh in zip(flat, flat_sh):
        out.append(jax.device_put(jnp.zeros(s.shape, s.dtype), sh))
    return jax.tree.unflatten(treedef, out)


def _execute_cell(compiled, structs, kind: str, repeats: int) -> Dict[str, float]:
    """Execute a compiled cell on zero inputs; returns timing fields."""
    args = _zeros_like_structs(structs, compiled.input_shardings[0])
    return _timed_execute(compiled, args, repeats=repeats,
                          refeed=_REFEED.get(kind, ()))


def run_cell(arch: str, cell_name: str, mesh_kind: str, *,
             remat: bool = True, microbatches: int = 1,
             extra_tag: str = "", remat_policy: str = "minimal",
             ffn_compress: float = 0.0, execute: int = 0) -> Dict[str, Any]:
    """Lower+compile one cell, plus the L=1/L=2 unrolled variants used to
    extrapolate exact per-layer FLOPs / bytes / collective traffic (XLA
    cost analysis counts a rolled scan body once, so the full-L program's
    raw numbers undercount by ~L×)."""
    import dataclasses as _dc

    from ..models import transformer as _tf

    cfg = get_config(arch)
    if ffn_compress > 0:
        # FullBlock row-compressed FFN execution: pruned rows of w_up/
        # w_gate (and cols of w_down) are removed entirely — on TPU the
        # static block indices fold into the weight layout at compile
        # time, so compressed execution IS a smaller dense matmul (the
        # alignment argument of paper §III-D).
        keep = 1.0 - ffn_compress
        cfg = _dc.replace(
            cfg, d_ff=max(256, int(round(cfg.d_ff * keep / 256)) * 256))
    cell = SHAPE_CELLS[cell_name]
    multi_pod = mesh_kind == "multi"
    from .mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256

    # --- full-size compile: THE dry-run proof + memory analysis -----------
    # (obs spans are no-ops unless recording is enabled; the ledger's
    # lower_s/compile_s fields below stay the source of truth)
    t0 = time.time()
    with obs.span("dryrun.lower", arch=arch, cell=cell_name, mesh=mesh_kind):
        lowered = lower_cell(cfg, cell, mesh, multi_pod=multi_pod,
                             remat=remat, microbatches=microbatches,
                             remat_policy=remat_policy)
    t_lower = time.time() - t0
    t0 = time.time()
    with obs.span("dryrun.compile", arch=arch, cell=cell_name,
                  mesh=mesh_kind):
        compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll_raw = _cost_of(compiled)

    # --- optional real execution: wall-clock for the calibration loop ------
    timing: Dict[str, float] = {}
    if execute > 0:
        params_t = param_struct(cfg)
        specs = input_specs(cfg, cell)
        if cell.kind == "train":
            opt_t = jax.eval_shape(adamw_init, params_t)
            structs = (params_t, opt_t, specs["batch"])
        elif cell.kind == "prefill":
            structs = (params_t, specs)
        else:
            structs = (params_t, specs["tokens"], specs["cache"])
        with obs.span("dryrun.execute", arch=arch, cell=cell_name,
                      mesh=mesh_kind, repeats=execute):
            timing = _execute_cell(compiled, structs, cell.kind, execute)

    # --- per-layer extrapolation via unrolled L=1 / L=2 variants -----------
    from ..models import layers as _ly

    def measure(n_layers: int):
        kw = dict(n_layers=n_layers)
        if cfg.enc_dec:
            kw["enc_layers"] = n_layers
        cfg_l = _dc.replace(cfg, **kw)
        with _tf.scan_unroll(max(2, n_layers)), _ly.chunk_unroll(8):
            low = lower_cell(cfg_l, cell, mesh, multi_pod=multi_pod,
                             remat=remat, microbatches=microbatches,
                             remat_policy=remat_policy)
            return _cost_of(low.compile())

    L = cfg.n_layers
    f1, b1, c1 = measure(1)
    f2, b2, c2 = measure(2)
    flops = f1 + (L - 1) * max(f2 - f1, 0.0)
    bytes_acc = b1 + (L - 1) * max(b2 - b1, 0.0)
    coll = {}
    for k in set(c1) | set(c2):
        coll[k] = int(c1.get(k, 0) + (L - 1) * max(c2.get(k, 0) - c1.get(k, 0), 0))

    rec = {
        "arch": arch,
        "cell": cell_name,
        "mesh": mesh_kind,
        "tag": extra_tag,
        "chips": n_chips,
        "kind": cell.kind,
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        # per-device program totals (extrapolated to all L layers)
        "flops": flops,
        "bytes_accessed": bytes_acc,
        "collective_bytes": coll,
        # raw rolled-scan numbers kept for reference
        "flops_raw": flops_raw,
        "bytes_raw": bytes_raw,
        "collective_raw": {k: int(v) for k, v in coll_raw.items()},
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": int(getattr(mem, "peak_memory_in_bytes",
                                  getattr(mem, "temp_size_in_bytes", 0))),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if timing:
        rec["executed"] = True
        rec.update(timing)      # time_s / time_s_median / execute_repeats
    return rec


def _emit_trace(arch: str, cell: ShapeCell, out: str) -> Dict[str, Any]:
    """Capture the modeling-plane traced DAG for this cell and save it
    next to the ledger (``<out dir>/trace/<arch>_<cell>.json``).

    The returned fields join the measured HLO row to its modeling-plane
    sibling by content: the TraceGraph digest keys explore-cache entries
    for ``--workload traced:<arch>`` sweeps, and the lowered MVM totals
    are the analytic counterpart of the record's XLA ``flops``.
    """
    from ..trace import lower_graph, trace_model
    from ..trace.diff import summarize

    step = {"train": "forward"}.get(cell.kind, cell.kind)
    graph = trace_model(get_config(arch), step=step, seq_len=cell.seq_len,
                        batch=cell.global_batch)
    tdir = os.path.join(os.path.dirname(out) or ".", "trace")
    os.makedirs(tdir, exist_ok=True)
    path = os.path.join(tdir, f"{arch}_{cell.name}.json")
    graph.save(path)
    wl = lower_graph(graph)
    # strict pre-flight: a broken lowered DAG fails this cell's record
    # (the per-cell try/except upstream turns it into a failure row)
    from ..analysis import preflight
    preflight(wl, strict=True, where="dryrun.emit_trace")
    s = summarize(wl)
    return {"trace_path": path, "trace_digest": graph.digest(),
            "trace_ops": len(wl), "trace_mvm_macs": s["mvm_macs"],
            "trace_mvm_weights": s["mvm_weights"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--cell", default=None, choices=list(SHAPE_CELLS))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run the full arch × cell matrix")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--execute", type=int, default=0, metavar="N",
                    help="additionally RUN each compiled cell N times on "
                         "zero inputs and record best wall-clock as "
                         "time_s (allocates the real footprint; feeds "
                         "repro.calibrate)")
    ap.add_argument("--emit-trace", action="store_true",
                    help="also capture the modeling-plane traced DAG "
                         "(repro.trace) per cell, save the graph JSON "
                         "under <out dir>/trace/, and stamp its content "
                         "digest + MVM totals into the ledger record")
    ap.add_argument("--tag", default="")
    # sharding-strategy knobs (§Perf hillclimb)
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params over 'data' too (FSDP/ZeRO-3)")
    ap.add_argument("--no-zero1", action="store_true",
                    help="disable ZeRO-1 optimizer-state sharding")
    ap.add_argument("--no-ep", action="store_true",
                    help="disable shard_map expert parallelism")
    ap.add_argument("--legacy-sharding", action="store_true",
                    help="legacy head_dim attention fallback sharding")
    ap.add_argument("--remat-policy", default="minimal",
                    choices=["minimal", "dots", "nothing"],
                    help="activation-checkpoint policy for train cells")
    ap.add_argument("--scores-bf16", action="store_true",
                    help="materialise attention score tiles in bf16 "
                         "(approximates the fused Pallas flash kernel's "
                         "HBM behaviour)")
    ap.add_argument("--ffn-compress", type=float, default=0.0,
                    help="execute with FullBlock row-compressed FFN at "
                         "this sparsity ratio (the paper's technique in "
                         "the execution plane): d_ff → (1-r)·d_ff")
    args = ap.parse_args(argv)

    if args.scores_bf16:
        from ..models.layers import set_scores_dtype
        set_scores_dtype(jnp.bfloat16)

    shard_rules.set_options(
        fsdp=args.fsdp,
        # ZeRO-1 rides with FSDP (matched layouts); standalone ZeRO-1
        # triggers GSPMD replicate-then-partition resharding (§Perf)
        zero1=args.fsdp and not args.no_zero1,
        ep_shardmap=not args.no_ep,
        attn_kv_fallback="head_dim" if args.legacy_sharding else "replicate",
    )

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["cell"], r["mesh"], r.get("tag", "")))
                except json.JSONDecodeError:
                    pass

    jobs = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for arch, cfg in all_configs().items():
            for cell_name in cells_for(cfg):
                for mk in meshes:
                    jobs.append((arch, cell_name, mk))
    else:
        if not args.arch or not args.cell:
            ap.error("--arch and --cell required unless --all")
        cfg = get_config(args.arch)
        if args.cell not in cells_for(cfg):
            print(f"SKIP {args.arch}/{args.cell}: long_500k needs "
                  "sub-quadratic attention (see DESIGN.md §3.2)")
            return 0
        jobs = [(args.arch, args.cell, mk) for mk in meshes]

    failures = 0
    for arch, cell_name, mk in jobs:
        if (arch, cell_name, mk, args.tag) in done:
            print(f"skip (done): {arch} {cell_name} {mk}")
            continue
        print(f"=== {arch} × {cell_name} × {mk} ===", flush=True)
        try:
            rec = run_cell(arch, cell_name, mk, remat=not args.no_remat,
                           extra_tag=args.tag, remat_policy=args.remat_policy,
                           ffn_compress=args.ffn_compress,
                           execute=args.execute)
            if args.emit_trace:
                rec.update(_emit_trace(arch, SHAPE_CELLS[cell_name], args.out))
                print(f"    trace: {rec['trace_path']} "
                      f"digest={rec['trace_digest'][:16]} "
                      f"mvm_macs={rec['trace_mvm_macs']:.3e}", flush=True)
            timed = (f" time={rec['time_s']:.3f}s" if "time_s" in rec else "")
            print(f"    flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
                  f"coll={sum(v for k, v in rec['collective_bytes'].items() if k != 'count'):.3e} "
                  f"peak/device={rec['peak_bytes']/2**30:.2f} GiB "
                  f"compile={rec['compile_s']}s{timed}", flush=True)
        except Exception as e:  # noqa: BLE001 — ledger records failures
            rec = {"arch": arch, "cell": cell_name, "mesh": mk,
                   "tag": args.tag, "error": f"{type(e).__name__}: {e}"}
            failures += 1
            print(f"    FAILED: {rec['error'][:300]}", flush=True)
        obs.event("dryrun.cell.done", arch=arch, cell=cell_name, mesh=mk,
                  ok="error" not in rec,
                  compile_s=rec.get("compile_s"), time_s=rec.get("time_s"))
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
