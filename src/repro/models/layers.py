"""Model-layer primitives shared by every assigned architecture.

Pure-functional JAX (no framework deps): RMSNorm, RoPE, chunked
(flash-style) attention with GQA / sliding windows / softcaps / qk-norm,
gated & plain MLPs, capacity-based MoE with scatter dispatch, and the
Mamba-2 SSD mixer (chunked state-space duality) with single-step decode.

All matmul-bearing layers accept an optional FlexBlock weight mask set
(applied multiplicatively), which is how the paper's pruning workflow
reaches the execution plane.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import maybe_shard
from ..runtime import compat

Params = Dict[str, Any]

# Measurement override: when >1, sequence-chunk scans (attention KV
# chunks, SSD inter-chunk recurrence) cap their chunk count at this value
# and fully unroll, so XLA cost analysis counts every chunk's FLOPs and
# bytes (a rolled scan body is counted once).  Enabled only by the
# dry-run's per-layer measurement variants via ``chunk_unroll``.
_CHUNK_UNROLL: int = 1

# A/B switch for the statically tiled attention path (perf ablations).
_TILED_ATTN: bool = True


def set_tiled_attn(on: bool) -> None:
    global _TILED_ATTN
    _TILED_ATTN = on


# Materialisation dtype for attention score tiles.  f32 (default) is the
# exact-softmax configuration; bf16 approximates what the fused Pallas
# flash kernel does on TPU (scores live in VMEM registers and never hit
# HBM at f32 width) — used by §Perf dry-run configurations.
_SCORES_DTYPE = jnp.float32


def set_scores_dtype(dtype) -> None:
    global _SCORES_DTYPE
    _SCORES_DTYPE = dtype


def chunk_unroll(n: int):
    """Context manager overriding the sequence-chunk unroll factor."""
    import contextlib

    @contextlib.contextmanager
    def _ctx():
        global _CHUNK_UNROLL
        prev = _CHUNK_UNROLL
        _CHUNK_UNROLL = n
        try:
            yield
        finally:
            _CHUNK_UNROLL = prev

    return _ctx()


# ---------------------------------------------------------------------------
# Norms / positions
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# Attention (chunked online-softmax; GQA; windows; caps)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q: (B,Sq,Hkv,G,hd), k: (B,Skv,Hkv,hd) → (B,Hkv,G,Sq,Skv) scores.

    The score tiles stay in ``_SCORES_DTYPE`` end-to-end through the
    softmax chain (only the small (…,Sq) running max/sum are f32) — in
    bf16 mode this halves every score-sized fusion boundary, matching
    what the fused TPU flash kernel keeps out of HBM entirely."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=_SCORES_DTYPE)
    return s * jnp.asarray(scale, s.dtype)


def _attn_bias(q_idx, k_idx, *, causal, window, prefix, kv_len, valid_kv,
               B, nonneg_k: bool = False):
    """Additive attention bias (B, Tq, Tk) f32: 0 attendable / -inf masked.

    Folding every mask condition into ONE additive tensor (instead of two
    ``where``s over the full (B,H,G,Sq,ck) score tensor) halves the number
    of score-sized f32 materialisations in the flash body — a direct
    HLO-bytes win on the memory-roofline term.
    """
    ok = jnp.ones((B, q_idx.shape[1], k_idx.shape[0]), bool)
    if causal:
        cm = k_idx[None, None, :] <= q_idx[:, :, None]
        if prefix > 0:
            # prefix-LM: bidirectional attention within the prefix
            cm |= ((q_idx[:, :, None] < prefix)
                   & (k_idx[None, None, :] < prefix))
        ok &= cm
    if window is not None:
        ok &= k_idx[None, None, :] > (q_idx[:, :, None] - window)
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        if kvl.ndim == 0:
            kvl = jnp.broadcast_to(kvl, (B,))
        ok &= k_idx[None, None, :] < kvl[:, None, None]
    if valid_kv is not None:
        ok &= (k_idx < valid_kv)[None, None, :]
    if nonneg_k:
        ok &= (k_idx >= 0)[None, None, :]
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _attn_tile(qg, k_i, v_i, bias, carry, *, scale, attn_cap):
    """One flash tile: online-softmax update of (m, l, acc).

    Score-sized tensors stay in ``_SCORES_DTYPE``; the running max/sum/
    accumulator (…,Tq[,hd]) carries stay f32."""
    m_prev, l_prev, acc_prev = carry
    s = _gqa_scores(qg, k_i, scale)               # (B,Hkv,G,Tq,Tk)
    s = softcap(s, attn_cap)
    s = s + bias[:, None, None].astype(s.dtype)   # -inf ⇒ exp → 0
    m_cur = jnp.maximum(m_prev, s.max(axis=-1).astype(jnp.float32))
    m_safe = jnp.where(jnp.isinf(m_cur), 0.0, m_cur)
    p = jnp.exp(s - m_safe[..., None].astype(s.dtype))
    corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_cur = l_prev * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_i.dtype), v_i,
                    preferred_element_type=jnp.float32)
    acc_cur = acc_prev * corr[..., None] + pv
    return m_cur, l_cur, acc_cur


def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, Hq, hd)
    k: jnp.ndarray,            # (B, Skv, Hkv, hd)
    v: jnp.ndarray,            # (B, Skv, Hkv, hd)
    *,
    causal: bool = True,
    window: Optional[Any] = None,  # None = unbounded; int or traced scalar
    q_offset: Any = 0,             # absolute position of q[0] (may be traced)
    kv_len: Optional[jnp.ndarray] = None,   # valid cache length (decode)
    attn_cap: float = 0.0,
    prefix: int = 0,               # bidirectional prefix length (prefix-LM)
    chunk: int = 1024,
) -> jnp.ndarray:
    """Flash-style attention: scan over KV chunks with online softmax.

    Never materialises the (Sq × Skv) score matrix — memory is
    O(Sq × chunk) — which is what makes the 32k-prefill and 500k-decode
    cells lowerable without TB-scale buffers.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    if _CHUNK_UNROLL > 1:
        # measurement mode: bound the chunk count and unroll the scans so
        # XLA cost analysis counts every tile
        chunk = max(chunk, -(-Skv // _CHUNK_UNROLL))
        chunk = -(-chunk // 128) * 128
    nchunks = max(1, math.ceil(Skv / chunk))
    pad = nchunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    # q_offset may be a scalar or a per-batch (B,) vector (serving slots
    # at heterogeneous positions) — normalise to (B, Sq).
    q_off = jnp.asarray(q_offset)
    static_q0 = isinstance(q_offset, int) and q_offset == 0
    if q_off.ndim == 0:
        q_off = jnp.broadcast_to(q_off, (B,))
    q_idx = q_off[:, None] + jnp.arange(Sq)[None, :]          # (B, Sq)
    static_window = window if isinstance(window, int) else None
    valid_kv = Skv if pad else None

    # ---- statically tiled path (training / prefill self-attention) -------
    # Tiles q as well as kv and SKIPS tiles that are fully masked by the
    # causal structure (triangular: ~2× fewer tiles) or by a static
    # sliding window (hymba w=1024 at 32k: ~16× fewer tiles).  This is
    # FullBlock sparsity applied to the attention score matrix — the same
    # block-skip idea the paper applies to CIM weight tiles.
    use_tiled = (_TILED_ATTN and causal and kv_len is None and static_q0
                 and Sq == Skv and Sq > chunk)
    if use_tiled:
        tq = chunk
        nq = math.ceil(Sq / tq)
        q_pad = nq * tq - Sq
        if q_pad:
            qg = jnp.pad(qg, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
        outs = []
        for qi in range(nq):
            lo, hi = 0, min(qi, nchunks - 1)
            if prefix > 0:
                # prefix-LM: kv tiles holding prefix columns stay visible
                hi = min(max(qi, -(-prefix // chunk) - 1), nchunks - 1)
            elif static_window is not None:
                lo = max(0, (qi * tq - static_window + 1) // chunk)
            q_tile_idx = q_idx[:, qi * tq:(qi + 1) * tq]
            if q_pad and qi == nq - 1:
                q_tile_idx = jnp.pad(q_tile_idx, ((0, 0), (0, q_pad)),
                                     constant_values=Sq)
            qt = qg[:, qi * tq:(qi + 1) * tq]
            m0 = jnp.full((B, Hkv, G, qt.shape[1]), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, Hkv, G, qt.shape[1]), jnp.float32)
            a0 = jnp.zeros((B, Hkv, G, qt.shape[1], hd), jnp.float32)
            n_tiles = hi - lo + 1
            if n_tiles <= max(4, _CHUNK_UNROLL):
                carry = (m0, l0, a0)
                for ki in range(lo, hi + 1):
                    k_tile_idx = ki * chunk + jnp.arange(chunk)
                    bias = _attn_bias(
                        q_tile_idx, k_tile_idx, causal=causal,
                        window=window, prefix=prefix, kv_len=None,
                        valid_kv=valid_kv, B=B)
                    carry = _attn_tile(qt, kc[ki], vc[ki], bias, carry,
                                       scale=scale, attn_cap=attn_cap)
                m, l, acc = carry
            else:
                # long kv range: rolled scan over the STATIC slice
                # [lo, hi] keeps HLO size bounded (one body per q-tile)
                def body(carry, inputs):
                    ki, k_i, v_i = inputs
                    k_tile_idx = ki * chunk + jnp.arange(chunk)
                    bias = _attn_bias(
                        q_tile_idx, k_tile_idx, causal=causal,
                        window=window, prefix=prefix, kv_len=None,
                        valid_kv=valid_kv, B=B)
                    return _attn_tile(qt, k_i, v_i, bias, carry,
                                      scale=scale, attn_cap=attn_cap), None
                (m, l, acc), _ = jax.lax.scan(
                    body, (m0, l0, a0),
                    (jnp.arange(lo, hi + 1), kc[lo:hi + 1], vc[lo:hi + 1]),
                    unroll=min(n_tiles, _CHUNK_UNROLL))
            o = acc / jnp.maximum(l[..., None], 1e-20)
            outs.append(o)
        out = jnp.concatenate(outs, axis=3)                 # (B,Hkv,G,Sq+,hd)
        if q_pad:
            out = out[:, :, :, :Sq]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd)
        return out.astype(q.dtype)

    # ---- generic scan path (decode / cross-attn / dynamic offsets) -------
    def body(carry, inputs):
        ci, k_i, v_i = inputs
        k_idx = ci * chunk + jnp.arange(chunk)
        bias = _attn_bias(q_idx, k_idx, causal=causal, window=window,
                          prefix=prefix, kv_len=kv_len, valid_kv=valid_kv,
                          B=B)
        return _attn_tile(qg, k_i, v_i, bias, carry,
                          scale=scale, attn_cap=attn_cap), None

    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nchunks), kc, vc),
        unroll=min(nchunks, _CHUNK_UNROLL))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd)   # (B,Sq,Hq,hd)
    return out.astype(q.dtype)


def _swa_seqpar_attention(x, p, cfg, mesh, *, window: int,
                          chunk: int = 1024):
    """Sequence-parallel sliding-window attention via shard_map.

    For archs whose head counts do not divide the "model" axis (hymba:
    25 q / 5 kv heads), plain SPMD replicates the whole attention block
    across all model ranks — 16× redundant score tensors dominate both
    the compute and memory roofline terms.  Here each model rank instead
    processes a contiguous 1/M slice of the QUERY sequence: with a static
    window the kv extent per rank is the STATIC size S/M + window at a
    rank-dependent offset, so every rank runs the same program on
    different sequence slices.  Per-device attention flops/bytes drop M×;
    the only collective is the output all-gather (tiny next to scores).

    Projections (q/k/v/o) run inside on the slice, so they parallelise
    too.  Returns (y, k_full, v_full) — the gathered k/v feed the prefill
    cache (DCE'd in training, where the cache is unused).
    """
    B, S, D = x.shape
    M = mesh.shape["model"]
    S_loc = S // M
    hd, Hq, Hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    G = Hq // Hkv
    W = window
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    scale = 1.0 / math.sqrt(hd)
    n_tiles = max(1, S_loc // chunk)
    tq = S_loc // n_tiles

    def body(xl, wq, wk, wv, wo):
        B_loc = xl.shape[0]
        mi = jax.lax.axis_index("model")
        start = mi * S_loc
        xq = jax.lax.dynamic_slice_in_dim(xl, start, S_loc, 1)
        xp = jnp.pad(xl, ((0, 0), (W, 0), (0, 0)))
        xkv = jax.lax.dynamic_slice_in_dim(xp, start, S_loc + W, 1)
        q = jnp.einsum("bsd,dhk->bshk", xq, wq).astype(xl.dtype)
        k = jnp.einsum("bsd,dhk->bshk", xkv, wk).astype(xl.dtype)
        v = jnp.einsum("bsd,dhk->bshk", xkv, wv).astype(xl.dtype)
        qpos = start + jnp.arange(S_loc)
        kpos = start - W + jnp.arange(S_loc + W)
        q = rope(q, jnp.broadcast_to(qpos, (B_loc, S_loc)), cfg.rope_theta)
        k = rope(k, jnp.broadcast_to(kpos, (B_loc, S_loc + W)),
                 cfg.rope_theta)
        outs = []
        for j in range(n_tiles):
            qt = q[:, j * tq:(j + 1) * tq].reshape(B_loc, tq, Hkv, G, hd)
            kt = k[:, j * tq:j * tq + W + tq]
            vt = v[:, j * tq:j * tq + W + tq]
            q_idx = jnp.broadcast_to(qpos[j * tq:(j + 1) * tq],
                                     (B_loc, tq))
            k_idx = start - W + j * tq + jnp.arange(W + tq)
            bias = _attn_bias(q_idx, k_idx, causal=True, window=W,
                              prefix=0, kv_len=None, valid_kv=None,
                              B=B_loc, nonneg_k=True)
            m0 = jnp.full((B_loc, Hkv, G, tq), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B_loc, Hkv, G, tq), jnp.float32)
            a0 = jnp.zeros((B_loc, Hkv, G, tq, hd), jnp.float32)
            m, l, acc = _attn_tile(qt, kt, vt, bias, (m0, l0, a0),
                                   scale=scale, attn_cap=cfg.attn_softcap)
            o = acc / jnp.maximum(l[..., None], 1e-20)
            outs.append(o)
        out = jnp.concatenate(outs, axis=3)            # (B,Hkv,G,S_loc,hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B_loc, S_loc, Hq, hd)
        y = jnp.einsum("bshk,hkd->bsd", out.astype(xl.dtype),
                       wo).astype(xl.dtype)
        y = jax.lax.all_gather(y, "model", axis=1, tiled=True)
        kc = jax.lax.all_gather(k[:, W:], "model", axis=1, tiled=True)
        vc = jax.lax.all_gather(v[:, W:], "model", axis=1, tiled=True)
        return y, kc, vc

    wspec = P(None, None, None)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(baxes, None, None), wspec, wspec, wspec, wspec),
        out_specs=(P(baxes, None, None), P(baxes, None, None, None),
                   P(baxes, None, None, None)),
        check_vma=False,
    )(x, p["wq"], p["wk"], p["wv"], p["wo"])


def attention_block(
    x: jnp.ndarray,             # (B, S, D)
    p: Params,                  # q/k/v/o (+ q_norm/k_norm)
    cfg,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    window: Optional[Any] = None,
    prefix: int = 0,
    cache_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """Full attention sub-block: projections + RoPE + chunked attention.

    * training/prefill: ``cache_kv=None`` → attends within ``x``.
    * decode: ``cache_kv=(K, V)`` buffers (B, Smax, Hkv, hd) and
      ``cache_len`` current length; new K/V are scattered in at
      ``cache_len`` and attention spans the valid prefix.
    * cross-attention (whisper decoder): ``cross_kv`` precomputed from
      the encoder; no cache update.
    """
    B, S, D = x.shape
    hd, Hq, Hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads

    # sequence-parallel path: static sliding window + non-divisible heads
    # (otherwise head sharding already parallelises over "model")
    mesh = compat.get_abstract_mesh()
    if (cache_kv is None and cross_kv is None and causal
            and isinstance(window, int) and not cfg.qk_norm
            and prefix == 0 and not mesh.empty
            and "model" in mesh.axis_names and mesh.shape["model"] > 1
            and Hq % mesh.shape["model"] != 0
            and S % (mesh.shape["model"] * 1024) == 0):
        y, kc, vc = _swa_seqpar_attention(x, p, cfg, mesh, window=window)
        return y, (kc, vc)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)

    if cross_kv is not None:
        k, v = cross_kv
        out = chunked_attention(q, k, v, causal=False, attn_cap=cfg.attn_softcap)
        new_cache = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(x.dtype)
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(x.dtype)
        if cfg.qk_norm:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        k = rope(k, positions, cfg.rope_theta)
        if cache_kv is None:
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    prefix=prefix, attn_cap=cfg.attn_softcap)
            new_cache = (k, v)
        else:
            K, V = cache_kv
            pos = jnp.asarray(cache_len)
            if pos.ndim == 0:
                # uniform position: cheap dynamic_update_slice
                K = jax.lax.dynamic_update_slice(K, k, (0, pos, 0, 0))
                V = jax.lax.dynamic_update_slice(V, v, (0, pos, 0, 0))
            else:
                # per-slot positions (serving): scatter one row per batch
                bidx = jnp.arange(K.shape[0])
                K = K.at[bidx, pos].set(k[:, 0])
                V = V.at[bidx, pos].set(v[:, 0])
            # q lives at absolute position cache_len; the causal mask also
            # masks the unwritten cache tail (k_idx > cache_len + S - 1).
            # Single-query decode uses ONE chunk spanning the whole cache:
            # scores are only (B,H,1,Skv), and XLA shards the sequence dim
            # cleanly (flash-decode: partial softmax per shard + small
            # cross-shard reductions), whereas a chunk scan would fight
            # the sequence sharding and replicate compute.
            out = chunked_attention(
                q, K, V, causal=True, window=window, q_offset=cache_len,
                attn_cap=cfg.attn_softcap, chunk=K.shape[1])
            new_cache = (K, V)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(x: jnp.ndarray, p: Params, cfg) -> jnp.ndarray:
    # gelu runs in the compute dtype: the (B,S,F) activation chain is the
    # largest per-layer tensor and f32 upcasting doubled its bytes (§Perf)
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"]).astype(x.dtype)
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"]).astype(x.dtype)
        h = jax.nn.gelu(g) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("bsd,df->bsf", x, p["w_up"]).astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity-bounded scatter dispatch
# ---------------------------------------------------------------------------

def _moe_dispatch(xt, w_router, E, K, capacity_factor, dtype):
    """Route tokens: returns (eb, top_p, keep, dest, tok_idx, C).

    Sort-based capacity dispatch into an (E·C+1, D) scatter buffer (no
    one-hot einsum: keeps HLO FLOPs ≈ active FLOPs so the roofline's
    useful-compute ratio stays honest).  Overflow beyond capacity C is
    dropped — standard GShard capacity semantics.
    """
    T, D = xt.shape
    logits = jnp.einsum("td,de->te", xt, w_router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                        # (T, K)
    top_p = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)).astype(dtype)

    C = max(1, math.ceil(T * K / E * capacity_factor))
    e_flat = top_e.reshape(-1)                                    # (T·K,)
    # position of each (token, slot) within its expert via sort
    order = jnp.argsort(e_flat)
    ranks = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.arange(T * K, dtype=jnp.int32))
    sorted_e = e_flat[order]
    # start offset of each expert group in the sorted order
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = ranks - starts[e_flat]                                  # (T·K,)
    keep = pos < C
    dest = jnp.where(keep, e_flat * C + pos, E * C)               # overflow bin

    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E * C + 1, D), dtype)
    buf = buf.at[dest].add(xt[tok_idx])
    return buf[:-1].reshape(E, C, D), top_p, keep, dest, tok_idx, C


def _moe_combine(eo, top_p, keep, dest, tok_idx, T, D, dtype):
    """Inverse of dispatch: gather expert outputs back per token."""
    E_C = eo.shape[0] * eo.shape[1]
    out_flat = jnp.concatenate([eo.reshape(E_C, D),
                                jnp.zeros((1, D), dtype)])
    gathered = out_flat[jnp.where(keep, dest, E_C)]               # (T·K, D)
    weighted = gathered * top_p.reshape(-1)[:, None]
    return jnp.zeros((T, D), dtype).at[tok_idx].add(weighted)


def _expert_ffn(eb, p, cfg, dtype):
    """(E, C, D) → (E, C, D) through per-expert (optionally gated) MLPs.

    gelu in compute dtype: the (E,C,F) expert activation chain dominated
    dbrx's memory roofline when upcast to f32 (§Perf it4)."""
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", eb, p["w_gate"]).astype(dtype)
        u = jnp.einsum("ecd,edf->ecf", eb, p["w_up"]).astype(dtype)
        h = jax.nn.gelu(g) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("ecd,edf->ecf", eb, p["w_up"]).astype(dtype))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"]).astype(dtype)


def _moe_block_global(x: jnp.ndarray, p: Params, cfg) -> jnp.ndarray:
    """Single-device / no-mesh MoE path (global dispatch)."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    eb, top_p, keep, dest, tok_idx, C = _moe_dispatch(
        xt, p["w_router"], cfg.n_experts, cfg.top_k, cfg.capacity_factor,
        x.dtype)
    eb = maybe_shard(eb, P("model", None, None))
    eo = _expert_ffn(eb, p, cfg, x.dtype)
    eo = maybe_shard(eo, P("model", None, None))
    y = _moe_combine(eo, top_p, keep, dest, tok_idx, T, D, x.dtype)
    return y.reshape(B, S, D)


def _moe_block_ep(x: jnp.ndarray, p: Params, cfg, mesh, baxes) -> jnp.ndarray:
    """Expert-parallel MoE via shard_map (the §Perf fix for MoE cells).

    The global-scatter path cannot be data-parallelised by SPMD (the
    argsort/scatter force a global token ordering, so every device
    re-dispatches ALL tokens and the expert einsums only parallelise over
    the "model" axis — a ~data×-inflation of expert FLOPs, plus an
    all-reduce of the whole (E·C·D) buffer per layer).  Here each device
    routes only its local token slice, exchanges capacity blocks with an
    all_to_all over "model", computes its resident experts, and reverses
    the exchange — per-device expert FLOPs = global/(data·model) and the
    only collectives are two a2a's + one output all-gather per layer.

    When FSDP weight sharding is on, expert weights arrive additionally
    sharded over "data" and are all-gathered per use (their transpose is
    a reduce-scatter, so weight grads come back ZeRO-2 style).
    """
    from ..distributed.sharding import get_options
    opts = get_options()
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    M = mesh.shape["model"]
    E_loc = E // M
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    fsdp = opts.fsdp

    w_gate = p.get("w_gate")
    gated = w_gate is not None
    # in_specs mirror spec_for_param's assignments for these leaves
    wspec_up = P("model", None, "data") if fsdp else P("model", None, None)
    wspec_dn = P("model", "data", None) if fsdp else P("model", None, None)

    def ep_body(xl, wr, wu, wd, wg):
        B_loc = xl.shape[0]
        T_loc = B_loc * S
        xt = xl.reshape(T_loc, D)
        # each model-rank routes a disjoint 1/M slice of the local tokens
        # (the slice is padded so T_loc need not divide M)
        Ts = -(-T_loc // M)
        pad = Ts * M - T_loc
        if pad:
            xt = jnp.concatenate([xt, jnp.zeros((pad, D), xt.dtype)])
        mi = jax.lax.axis_index("model")
        xs = jax.lax.dynamic_slice_in_dim(xt, mi * Ts, Ts, axis=0)
        eb, top_p, keep, dest, tok_idx, C = _moe_dispatch(
            xs, wr, E, K, cfg.capacity_factor, xl.dtype)

        # exchange capacity blocks: dim0 of the result = source rank
        ex = jax.lax.all_to_all(
            eb.reshape(M, E_loc, C, D), "model", 0, 0)    # (M, E_loc, C, D)
        ex = ex.transpose(1, 0, 2, 3).reshape(E_loc, M * C, D)

        if fsdp:
            wu = jax.lax.all_gather(wu, "data", axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)
            if gated:
                wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
        lp = {"w_up": wu, "w_down": wd}
        if gated:
            lp["w_gate"] = wg
        eo = _expert_ffn(ex, lp, cfg, xl.dtype)           # (E_loc, M·C, D)

        eo = eo.reshape(E_loc, M, C, D).transpose(1, 0, 2, 3)
        eo = jax.lax.all_to_all(eo, "model", 0, 0)        # back to sources
        eo = eo.reshape(E, C, D)

        ys = _moe_combine(eo, top_p, keep, dest, tok_idx, Ts, D, xl.dtype)
        # reassemble the full local token set on every model rank
        yt = jax.lax.all_gather(ys, "model", axis=0, tiled=True)
        if pad:
            yt = yt[:T_loc]
        return yt.reshape(B_loc, S, D)

    gate_arg = w_gate if gated else jnp.zeros((), x.dtype)
    gate_spec = wspec_up if gated else P()
    return compat.shard_map(
        ep_body, mesh=mesh,
        in_specs=(P(baxes, None, None), P(None, None),
                  wspec_up, wspec_dn, gate_spec),
        out_specs=P(baxes, None, None),
        check_vma=False,
    )(x, p["w_router"], p["w_up"], p["w_down"], gate_arg)


def moe_block(x: jnp.ndarray, p: Params, cfg) -> jnp.ndarray:
    """Capacity-based MoE.  Dispatches to the shard_map expert-parallel
    path on a mesh with a "model" axis that divides the expert count;
    falls back to the global-dispatch path otherwise (single device /
    smoke tests)."""
    from ..distributed.sharding import get_options
    mesh = compat.get_abstract_mesh()
    if (get_options().ep_shardmap and not mesh.empty
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nb = 1
        for a in baxes:
            nb *= mesh.shape[a]
        if x.shape[0] % max(nb, 1) == 0:
            return _moe_block_ep(x, p, cfg, mesh, baxes)
    return _moe_block_global(x, p, cfg)


# ---------------------------------------------------------------------------
# Mamba-2 SSD mixer (chunked state-space duality) + single-step decode
# ---------------------------------------------------------------------------

def _ssd_chunked(xh, dt, A, Bm, Cm, Q):
    """Chunked SSD (Dao & Gu 2024, alg. of §6): intra-chunk quadratic
    term + inter-chunk state recurrence.

    xh: (B,S,H,Pd); dt: (B,S,H) >0; A: (H,) <0; Bm/Cm: (B,S,N).
    Returns y: (B,S,H,Pd) and final state (B,H,Pd,N).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nc = S // Q
    xq = xh.reshape(Bsz, nc, Q, H, Pd)
    dtq = dt.reshape(Bsz, nc, Q, H)
    Bq = Bm.reshape(Bsz, nc, Q, N)
    Cq = Cm.reshape(Bsz, nc, Q, N)

    loga = dtq * A[None, None, None, :]                # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(loga, axis=2)                     # within-chunk cumsum
    total = cum[:, :, -1, :]                           # (B,nc,H)

    # intra-chunk: scores[i,j] = C_i·B_j · exp(cum_i - cum_j) for j ≤ i.
    # The (B,nc,Q,Q,H) tensors dominate SSD memory traffic — materialise
    # the masked scores directly in the compute dtype (bf16): halves the
    # bytes of the largest tensor chain with f32 kept only inside exp/cum.
    cb = jnp.einsum("bcqn,bckn->bcqk", Cq, Bq,
                    preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    scores = jnp.where(tri[None, None, :, :, None],
                       cb[..., None] * decay, 0.0).astype(xh.dtype)
    xdt = xq * dtq[..., None]                           # (B,nc,Q,H,Pd)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xdt,
                         preferred_element_type=jnp.float32)

    # chunk states: S_c = Σ_j exp(total - cum_j) · B_j ⊗ (x_j·dt_j)
    w = jnp.exp(total[:, :, None, :] - cum)             # (B,nc,Q,H)
    Sc = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bq, w.astype(xh.dtype), xdt,
                    preferred_element_type=jnp.float32)  # (B,nc,H,Pd,N)

    # inter-chunk recurrence: h_c = exp(total_c)·h_{c-1} + S_c
    def scan_fn(h_prev, inp):
        tot_c, S_c = inp
        h_new = h_prev * jnp.exp(tot_c)[:, :, None, None] + S_c
        return h_new, h_prev

    h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    hT, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (total.transpose(1, 0, 2), Sc.transpose(1, 0, 2, 3, 4)),
        unroll=min(nc, _CHUNK_UNROLL))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (B,nc,H,Pd,N)

    # inter-chunk output: y_i += C_i · h_{c-1} · exp(cum_i)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cq, h_prevs.astype(xh.dtype),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y.astype(xh.dtype), hT


def ssm_block(
    x: jnp.ndarray, p: Params, cfg, *,
    state: Optional[jnp.ndarray] = None,
    conv_state: Optional[jnp.ndarray] = None,
    chunk: Optional[int] = None,
):
    """Mamba-2 mixer.  Training/prefill: chunked SSD over the sequence.
    Decode (S==1 with ``state``): single-step recurrence.

    Layout: in_proj → [z (din), xs (din), B (N), C (N), dt (H)];
    4-tap depthwise causal conv on xs; SSD; gated output (z); out_proj.
    Returns (y, new_state, new_conv_state).
    """
    B, S, D = x.shape
    if chunk is None:
        chunk = getattr(cfg, "ssm_chunk", 256)
    din = cfg.ssm_inner(D)
    N, H = cfg.ssm_state, cfg.ssm_heads
    Pd = din // H
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"]).astype(x.dtype)
    z, xs, Bm, Cm, dt_raw = jnp.split(
        proj, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                     # (H,) < 0

    # depthwise causal conv (kernel 4) on xs
    kern = p["conv_w"]                                                # (4, din)
    if state is None or S > 1:
        xpad = jnp.pad(xs, ((0, 0), (3, 0), (0, 0)))
        xc = sum(xpad[:, i:i + S, :] * kern[3 - i] for i in range(4))
        new_conv = xpad[:, -3:, :]
    else:
        hist = jnp.concatenate([conv_state, xs], axis=1)              # (B,4,din)
        xc = (hist * kern[::-1].T[None].transpose(0, 2, 1)).sum(axis=1,
                                                                keepdims=True)
        new_conv = hist[:, 1:, :]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    xh = xc.reshape(B, S, H, Pd)

    if state is None or S > 1:
        pad = (-S) % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, min(chunk, xh.shape[1]))
        y = y[:, :S]
    else:
        # single-step: h' = exp(dt·A)·h + dt·(B ⊗ x);  y = C·h'
        a = jnp.exp(dt[:, 0, :] * A[None, :])                        # (B,H)
        upd = jnp.einsum("bn,bhp->bhpn", Bm[:, 0], xh[:, 0] * dt[:, 0, :, None])
        hT = state * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], hT)[:, None]        # (B,1,H,Pd)
    y = y + xh[:, :S] * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, din) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"]).astype(x.dtype)
    return out, hT, new_conv
