"""Unified model stack for all assigned architectures.

One parameterised decoder (+ optional encoder) covering: dense GQA
transformers (llama3, qwen3, gemma, gemma2 incl. local/global
alternation + softcaps + post-norms), MoE (dbrx, qwen3-moe), SSM
(mamba2 SSD), hybrid attn∥SSM (hymba), encoder-decoder (whisper stub
frontend), and prefix-VLM (paligemma stub frontend).

Layers are stacked on a leading L axis and driven by ``jax.lax.scan`` so
HLO size / compile time stay bounded for full-size dry-run cells.

Three entry modes share one layer body:

* ``forward``      — training / scoring over a full sequence → logits
* ``prefill``      — forward + emit per-layer KV / SSM states → cache
* ``decode_step``  — one token against a cache (serve_step)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..distributed.sharding import batch_axes, maybe_shard
from .layers import (attention_block, chunked_attention, mlp_block,
                     moe_block, rms_norm, rope, softcap, ssm_block)

Params = Dict[str, Any]
Cache = Dict[str, Any]

# Layer-scan unroll factor.  Default 1 = rolled (compact HLO, fast
# compiles).  The dry-run's FLOP-extrapolation pass sets this >= L so
# XLA cost analysis sees every layer (a rolled while-loop body is
# counted once by cost_analysis).  Set via `scan_unroll(n)`.
_SCAN_UNROLL: int = 1


def scan_unroll(n: int):
    """Context manager overriding the layer-scan unroll factor."""
    import contextlib

    @contextlib.contextmanager
    def _ctx():
        global _SCAN_UNROLL
        prev = _SCAN_UNROLL
        _SCAN_UNROLL = n
        try:
            yield
        finally:
            _SCAN_UNROLL = prev

    return _ctx()


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=_SCAN_UNROLL)


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------

def _layer_shapes(cfg: ArchConfig, *, encoder: bool = False) -> Dict[str, Tuple]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    shapes: Dict[str, Tuple] = {"ln1": (d,)}
    attn = cfg.attention != "none" or encoder
    if attn:
        shapes.update({
            "wq": (d, Hq, hd), "wk": (d, Hkv, hd), "wv": (d, Hkv, hd),
            "wo": (Hq, hd, d),
        })
        if cfg.qk_norm:
            shapes.update({"q_norm": (hd,), "k_norm": (hd,)})
    if encoder or cfg.d_ff > 0:
        shapes["ln2"] = (d,)
        ff = cfg.d_ff
        if not encoder and cfg.n_experts > 1:
            E = cfg.n_experts
            shapes.update({
                "w_router": (d, E),
                "w_up": (E, d, ff), "w_down": (E, ff, d),
            })
            if cfg.gated_mlp:
                shapes["w_gate"] = (E, d, ff)
        else:
            shapes.update({"w_up": (d, ff), "w_down": (ff, d)})
            if cfg.gated_mlp:
                shapes["w_gate"] = (d, ff)
    if not encoder and cfg.ssm_state > 0:
        din, N, H = cfg.ssm_inner(), cfg.ssm_state, cfg.ssm_heads
        e = 2 * din + 2 * N + H
        shapes.update({
            "w_in": (d, e), "w_out": (din, d), "conv_w": (4, din),
            "dt_bias": (H,), "A_log": (H,), "D_skip": (H,),
        })
        if cfg.family == "hybrid":
            shapes.update({"attn_branch_norm": (d,), "ssm_branch_norm": (d,)})
        elif cfg.attention == "none":
            pass  # pure SSM: ssm is the only mixer
    if cfg.post_norms and not encoder:
        shapes.update({"post_ln1": (d,), "post_ln2": (d,)})
    return shapes


def _init_stacked(key, shapes: Dict[str, Tuple], L: int, dtype, d_model: int):
    params = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shp), k in zip(sorted(shapes.items()), keys):
        full = (L,) + shp
        if name.startswith(("ln", "post_ln")) or name.endswith("_norm"):
            params[name] = jnp.zeros(full, dtype)
        elif name == "A_log":
            params[name] = jnp.zeros(full, dtype)          # A = -1
        elif name in ("dt_bias", "D_skip"):
            params[name] = jnp.full(full, 0.5 if name == "D_skip" else 0.0,
                                    dtype)
        else:
            fan_in = shp[0] if len(shp) == 1 else math.prod(shp[:-1])
            if name in ("wq", "wk", "wv"):
                fan_in = d_model
            std = 1.0 / math.sqrt(max(fan_in, 1))
            params[name] = (jax.random.normal(k, full, jnp.float32)
                            * std).astype(dtype)
    return params


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    k_emb, k_layers, k_enc, k_head = jax.random.split(key, 4)
    d = cfg.d_model
    params: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, d), jnp.float32)
                  / math.sqrt(d)).astype(dtype),
        "final_norm": jnp.zeros((d,), dtype),
        "layers": _init_stacked(k_layers, _layer_shapes(cfg), cfg.n_layers,
                                dtype, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (d, cfg.vocab_size), jnp.float32)
            / math.sqrt(d)).astype(dtype)
    if cfg.enc_dec:
        hd, Hq, Hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        ks = jax.random.split(k_enc, 3)
        params["enc_layers"] = _init_stacked(
            ks[0], _layer_shapes(cfg, encoder=True), cfg.enc_layers, dtype, d)
        params["enc_final_norm"] = jnp.zeros((d,), dtype)
        std = 1.0 / math.sqrt(d)
        params["enc_cross"] = {
            "wk": (jax.random.normal(ks[1], (cfg.n_layers, d, Hkv, hd),
                                     jnp.float32) * std).astype(dtype),
            "wv": (jax.random.normal(ks[1], (cfg.n_layers, d, Hkv, hd),
                                     jnp.float32) * std).astype(dtype),
        }
        params["dec_cross"] = {
            "wq": (jax.random.normal(ks[2], (cfg.n_layers, d, Hq, hd),
                                     jnp.float32) * std).astype(dtype),
            "wo": (jax.random.normal(ks[2], (cfg.n_layers, Hq, hd, d),
                                     jnp.float32) * std).astype(dtype),
            "ln": jnp.zeros((cfg.n_layers, d), dtype),
        }
    return params


def layer_flags(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer is-global-attention flags (gemma2 alternation)."""
    if cfg.attention == "local_global":
        return (jnp.arange(cfg.n_layers) % 2 == 1)
    if cfg.attention == "sliding":
        return jnp.zeros(cfg.n_layers, bool)
    return jnp.ones(cfg.n_layers, bool)


_BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _decoder_layer(x, lp, cfg: ArchConfig, *, positions, is_global,
                   mode: str, cache_slice=None, cross_slice=None,
                   cache_len=None, prefix: int = 0):
    """One decoder layer.  Returns (x, new_cache_slice)."""
    B, S, D = x.shape
    new_cache = {}
    window = None
    if cfg.attention == "sliding":
        window = cfg.window
    elif cfg.attention == "local_global":
        window = jnp.where(is_global, _BIG_WINDOW, cfg.window)

    def run_attn(xin):
        kwargs = dict(positions=positions, causal=True, window=window,
                      prefix=prefix)
        if mode == "decode":
            kwargs.update(cache_kv=(cache_slice["k"], cache_slice["v"]),
                          cache_len=cache_len)
        y, kv = attention_block(xin, lp, cfg, **kwargs)
        if kv is not None:
            new_cache["k"], new_cache["v"] = kv
        return y

    def run_ssm(xin):
        state = cache_slice["ssm"] if mode == "decode" else None
        conv = cache_slice["conv"] if mode == "decode" else None
        y, hT, convT = ssm_block(xin, lp, cfg, state=state, conv_state=conv)
        if mode in ("prefill", "decode"):
            new_cache["ssm"], new_cache["conv"] = hT, convT
        return y

    # ---- mixer(s) ----------------------------------------------------------
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        ya = run_attn(h)
        ys = run_ssm(h)
        mix = rms_norm(ya, lp["attn_branch_norm"], cfg.norm_eps) \
            + rms_norm(ys, lp["ssm_branch_norm"], cfg.norm_eps)
    elif cfg.attention == "none":
        mix = run_ssm(h)
    else:
        mix = run_attn(h)
    if cfg.post_norms:
        mix = rms_norm(mix, lp["post_ln1"], cfg.norm_eps)
    x = x + mix
    x = maybe_shard(x, P(("pod", "data"), None, None))

    # ---- cross-attention (whisper decoder) ----------------------------------
    if cfg.enc_dec and cross_slice is not None:
        hq = rms_norm(x, cross_slice["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hq, cross_slice["wq"]).astype(x.dtype)
        attn = chunked_attention(q, cross_slice["k"], cross_slice["v"],
                                 causal=False, chunk=512)
        x = x + jnp.einsum("bshk,hkd->bsd", attn,
                           cross_slice["wo"]).astype(x.dtype)

    # ---- FFN ------------------------------------------------------------------
    if cfg.d_ff > 0:
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        ff = moe_block(h2, lp, cfg) if cfg.n_experts > 1 else mlp_block(h2, lp, cfg)
        if cfg.post_norms:
            ff = rms_norm(ff, lp["post_ln2"], cfg.norm_eps)
        x = x + ff
        x = maybe_shard(x, P(("pod", "data"), None, None))
    return x, new_cache


def _encoder_stack(params, enc_embed, cfg: ArchConfig):
    """Bidirectional encoder over stub frontend embeddings."""
    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, _ = attention_block(
            h, lp, cfg, positions=jnp.arange(x.shape[1])[None], causal=False)
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_block(h2, lp, cfg)
        return x, None

    x, _ = _scan(body, enc_embed, params["enc_layers"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _cross_kv(params, enc_out, cfg: ArchConfig):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    k = jnp.einsum("bsd,ldhk->lbshk", enc_out, params["enc_cross"]["wk"])
    v = jnp.einsum("bsd,ldhk->lbshk", enc_out, params["enc_cross"]["wv"])
    return k.astype(enc_out.dtype), v.astype(enc_out.dtype)


def _embed(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return maybe_shard(x, P(("pod", "data"), None, None))


def _unembed(params, x, cfg: ArchConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    return maybe_shard(logits, P(("pod", "data"), None, "model"))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

REMAT_POLICIES = {
    # minimal saved state: recompute everything except weight-stationary
    # dots — smallest footprint, most recompute (legacy default)
    "minimal": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    # save every dot output: no matmul recompute in backward — the §Perf
    # winner whenever peak memory has headroom (it usually does after
    # ZeRO-1/FSDP)
    "dots": jax.checkpoint_policies.dots_saveable,
    # save nothing (maximum recompute)
    "nothing": jax.checkpoint_policies.nothing_saveable,
}


def forward(
    params: Params,
    tokens: jnp.ndarray,                 # (B, S) int32
    cfg: ArchConfig,
    *,
    prefix_embed: Optional[jnp.ndarray] = None,   # VLM stub (B, P, D)
    enc_embed: Optional[jnp.ndarray] = None,      # audio stub (B, Se, D)
    remat: bool = False,
    remat_policy: str = "minimal",
) -> jnp.ndarray:
    """Training / scoring forward pass → logits (B, S[, +P], V).

    ``remat=True`` checkpoints each scanned layer (activation
    rematerialisation): backward saves only what ``remat_policy`` allows,
    the standard memory/compute trade for full-size training cells.
    """
    x = _embed(params, tokens, cfg)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    flags = layer_flags(cfg)

    cross = None
    if cfg.enc_dec:
        if enc_embed is None:
            raise ValueError("enc-dec arch requires enc_embed")
        enc_out = _encoder_stack(params, enc_embed.astype(x.dtype), cfg)
        ck, cv = _cross_kv(params, enc_out, cfg)
        cross = {"k": ck, "v": cv, "wq": params["dec_cross"]["wq"],
                 "wo": params["dec_cross"]["wo"], "ln": params["dec_cross"]["ln"]}

    pfx = prefix_embed.shape[1] if prefix_embed is not None else 0

    def body(x, scanned):
        lp, flag = scanned[0], scanned[1]
        cs = scanned[2] if cfg.enc_dec else None
        x, _ = _decoder_layer(x, lp, cfg, positions=positions, is_global=flag,
                              mode="train", cross_slice=cs, prefix=pfx)
        return x, None

    if remat:
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat_policy])
    xs = (params["layers"], flags) + ((cross,) if cfg.enc_dec else ())
    x, _ = _scan(body, x, xs)
    return _unembed(params, x, cfg)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, *, enc_seq: int = 0) -> Cache:
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.attention != "none":
        cache["k"] = jnp.zeros((L, batch, max_len, Hkv, hd), dtype)
        cache["v"] = jnp.zeros((L, batch, max_len, Hkv, hd), dtype)
    if cfg.ssm_state > 0:
        din, N, H = cfg.ssm_inner(), cfg.ssm_state, cfg.ssm_heads
        cache["ssm"] = jnp.zeros((L, batch, H, din // H, N), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch, 3, din), dtype)
    if cfg.enc_dec:
        se = enc_seq or cfg.enc_seq
        cache["cross_k"] = jnp.zeros((L, batch, se, Hkv, hd), dtype)
        cache["cross_v"] = jnp.zeros((L, batch, se, Hkv, hd), dtype)
    return cache


def prefill(
    params: Params,
    tokens: jnp.ndarray,                 # (B, S)
    cfg: ArchConfig,
    *,
    prefix_embed: Optional[jnp.ndarray] = None,
    enc_embed: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Cache]:
    """Run the prompt, build the serving cache, return last-token logits."""
    x = _embed(params, tokens, cfg)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    flags = layer_flags(cfg)

    cross = None
    if cfg.enc_dec:
        enc_out = _encoder_stack(params, enc_embed.astype(x.dtype), cfg)
        ck, cv = _cross_kv(params, enc_out, cfg)
        cross = {"k": ck, "v": cv, "wq": params["dec_cross"]["wq"],
                 "wo": params["dec_cross"]["wo"], "ln": params["dec_cross"]["ln"]}

    pfx = prefix_embed.shape[1] if prefix_embed is not None else 0

    def body(x, scanned):
        lp, flag = scanned[0], scanned[1]
        cs = scanned[2] if cfg.enc_dec else None
        x, nc = _decoder_layer(x, lp, cfg, positions=positions, is_global=flag,
                               mode="prefill", cross_slice=cs, prefix=pfx)
        return x, nc

    xs = (params["layers"], flags) + ((cross,) if cfg.enc_dec else ())
    x, caches = _scan(body, x, xs)
    logits = _unembed(params, x[:, -1:], cfg)

    cache: Cache = {"pos": jnp.full((), S, jnp.int32)}
    if "k" in caches:
        cache["k"], cache["v"] = caches["k"], caches["v"]
    if "ssm" in caches:
        cache["ssm"], cache["conv"] = caches["ssm"], caches["conv"]
    if cfg.enc_dec:
        cache["cross_k"], cache["cross_v"] = cross["k"], cross["v"]
    return logits, cache


def decode_step(
    params: Params,
    tokens: jnp.ndarray,                 # (B,) or (B, 1) int32
    cfg: ArchConfig,
    cache: Cache,
) -> Tuple[jnp.ndarray, Cache]:
    """serve_step: one new token against the cache."""
    if tokens.ndim == 1:
        tokens = tokens[:, None]
    x = _embed(params, tokens, cfg)
    B = x.shape[0]
    pos = jnp.asarray(cache["pos"])          # scalar, or (B,) per-slot
    positions = jnp.broadcast_to(
        pos if pos.ndim == 0 else pos[:, None], (B, 1))
    flags = layer_flags(cfg)

    xs = [params["layers"], flags, {}]
    per_layer_cache = {}
    for key in ("k", "v", "ssm", "conv"):
        if key in cache:
            per_layer_cache[key] = cache[key]
    xs[2] = per_layer_cache
    if cfg.enc_dec:
        cross_stream = {"k": cache["cross_k"], "v": cache["cross_v"],
                        "wq": params["dec_cross"]["wq"],
                        "wo": params["dec_cross"]["wo"],
                        "ln": params["dec_cross"]["ln"]}
        xs.append(cross_stream)

    def body(x, scanned):
        lp, flag, cslice = scanned[0], scanned[1], scanned[2]
        cross_s = scanned[3] if cfg.enc_dec else None
        x, nc = _decoder_layer(x, lp, cfg, positions=positions, is_global=flag,
                               mode="decode", cache_slice=cslice,
                               cross_slice=cross_s, cache_len=pos)
        return x, nc

    x, new_caches = _scan(body, x, tuple(xs))
    logits = _unembed(params, x, cfg)

    new_cache = dict(cache)
    for key in new_caches:
        new_cache[key] = new_caches[key]
    new_cache["pos"] = pos + 1
    return logits[:, 0], new_cache
