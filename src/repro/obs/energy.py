"""Per-component energy attribution: tables and CSV/JSON artifacts.

The paper's headline analysis — "in-depth energy consumption analysis
at the level of individual components" — as a first-class artifact
instead of ad-hoc ``energy_pj`` dict spelunking.  Components are the
simulator's energy ledger keys (cim_array, adder_tree, …); groups are
the paper's Fig. 6(c) power-breakdown buckets, classified by the same
rules as :meth:`~repro.core.report.CostReport.grouped_energy` so the
two views always partition identically (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import csv
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

from ..core.report import CostReport

__all__ = ["component_group", "component_rows", "energy_table",
           "write_energy_csv", "append_energy_csv", "write_energy_json"]

GROUPS = ("cim_macro", "buffers", "pre_post", "sparsity", "static")


def component_group(component: str) -> str:
    """Fig. 6(c) group for one energy-ledger component — the single
    classification shared with ``CostReport.grouped_energy``."""
    if component in ("cim_array", "adder_tree", "shift_add", "accumulator",
                     "local_buf"):
        return "cim_macro"
    if component.endswith("_buf") or component == "global_buf":
        return "buffers"
    if component in ("pre_proc", "post_proc"):
        return "pre_post"
    if component in ("mux_index", "sparse_accum", "zero_detect", "index_mem"):
        return "sparsity"
    if component == "static":
        return "static"
    return "other"


def component_rows(report: CostReport,
                   meta: Optional[Dict] = None) -> List[Dict]:
    """One row per energy component: name, group, pJ, share of total.

    ``meta`` (grid-point coordinates: pattern, ratio, mapping, …) is
    prefixed onto every row so rows from a whole sweep concatenate into
    one long-format CSV."""
    total = max(sum(report.energy_pj.values()), 1e-12)
    rows: List[Dict] = []
    for comp, pj in report.energy_pj.items():
        row = dict(meta) if meta else {}
        row.update({
            "workload": report.workload,
            "arch": report.arch,
            "mapping": report.mapping,
            "component": comp,
            "group": component_group(comp),
            "energy_pj": pj,
            "share": pj / total,
            "latency_ms": report.latency_ms,
        })
        rows.append(row)
    return rows


def energy_table(report: CostReport) -> str:
    """Human-readable per-component breakdown with group subtotals."""
    total = max(sum(report.energy_pj.values()), 1e-12)
    lines = [f"{report.workload} on {report.arch} [{report.mapping}] — "
             f"{report.total_energy_uj:.3f} uJ, {report.latency_ms:.3f} ms",
             f"  {'component':<14}{'group':<11}{'energy_pj':>14}{'share':>9}"]
    by_group: Dict[str, float] = {}
    for comp, pj in sorted(report.energy_pj.items(),
                           key=lambda kv: -kv[1]):
        g = component_group(comp)
        by_group[g] = by_group.get(g, 0.0) + pj
        lines.append(f"  {comp:<14}{g:<11}{pj:>14.3e}{pj / total:>8.1%}")
    lines.append(f"  {'-' * 46}")
    for g, pj in sorted(by_group.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {'':<14}{g:<11}{pj:>14.3e}{pj / total:>8.1%}")
    return "\n".join(lines)


def _collect(rows_or_reports: Sequence) -> List[Dict]:
    rows: List[Dict] = []
    for item in rows_or_reports:
        if isinstance(item, CostReport):
            rows.extend(component_rows(item))
        else:
            rows.append(item)
    return rows


def write_energy_csv(rows_or_reports: Sequence,
                     path: Union[str, Path]) -> Path:
    """Write long-format component rows (or reports, expanded) to CSV."""
    rows = _collect(rows_or_reports)
    path = Path(path)
    fieldnames: List[str] = []
    for r in rows:
        for k in r:
            if k not in fieldnames:
                fieldnames.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fieldnames)
        w.writeheader()
        w.writerows(rows)
    return path


def append_energy_csv(rows_or_reports: Sequence,
                      path: Union[str, Path]) -> Path:
    """Append component rows to a (possibly existing) long-format CSV.

    Used by the sweep hook so every ``run_grid`` call of a recorded run
    lands in one ``energy_components.csv`` artifact.  The first write
    fixes the header; later rows are projected onto it (missing fields
    empty, unknown fields dropped)."""
    rows = _collect(rows_or_reports)
    if not rows:
        return Path(path)
    path = Path(path)
    if path.exists() and path.stat().st_size > 0:
        with open(path, newline="") as f:
            fieldnames = next(csv.reader(f))
        write_header = False
    else:
        fieldnames = []
        for r in rows:
            for k in r:
                if k not in fieldnames:
                    fieldnames.append(k)
        write_header = True
    with open(path, "a", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fieldnames,
                           extrasaction="ignore", restval="")
        if write_header:
            w.writeheader()
        w.writerows(rows)
    return path


def write_energy_json(rows_or_reports: Sequence,
                      path: Union[str, Path]) -> Path:
    path = Path(path)
    path.write_text(json.dumps({"rows": _collect(rows_or_reports)},
                               indent=1) + "\n")
    return path
