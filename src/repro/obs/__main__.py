"""``python -m repro.obs`` — observability CLI.

Subcommands:

* ``timeline`` — simulate a model (or load a ``CostReport`` JSON) under
  a scheduling policy and export the schedule as Chrome-trace JSON for
  chrome://tracing / https://ui.perfetto.dev::

      python -m repro.obs timeline --model resnet18 --policy partitioned \
          --out resnet18_partitioned.json

* ``energy`` — per-component energy attribution table (+ CSV/JSON
  artifacts) for one simulation or a report file::

      python -m repro.obs energy --model resnet18 --ratio 0.8 \
          --csv energy_components.csv

* ``report`` — summarise a recorded trace directory (manifest, sweep
  runs, heartbeats, counters)::

      python -m repro.obs report obs_runs/run-.../

* ``check`` — schema-validate an exported Chrome-trace JSON (CI's
  obs-smoke gate); exits non-zero on problems::

      python -m repro.obs check resnet18_partitioned.json
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter as _Counter
from pathlib import Path
from typing import List, Optional

from ..core.report import CostReport
from .core import iter_runs, read_events, read_manifest
from .energy import energy_table, write_energy_csv, write_energy_json
from .timeline import check_chrome_trace, chrome_trace, write_chrome_trace


def _build_report(args) -> CostReport:
    """Load ``--report`` JSON, or simulate the named model fresh."""
    if args.report:
        return CostReport.from_dict(json.loads(Path(args.report).read_text()))
    from ..core import MODEL_BUILDERS, TABLE_II_PATTERNS, usecase_arch
    from ..core.costmodel import simulate
    from ..core.mapping import default_mapping
    from ..core.presets import PRESET_ARCHS
    from ..core.schedule import SchedulePolicy
    arch = (PRESET_ARCHS[args.arch]() if args.arch
            else usecase_arch(args.macros))
    wl = MODEL_BUILDERS[args.model](args.img)
    if args.ratio is not None:
        pats = TABLE_II_PATTERNS(args.ratio, c_in=16)
        if args.pattern not in pats:
            raise SystemExit(f"unknown pattern {args.pattern!r}; choose "
                             f"from {sorted(pats)}")
        wl = wl.set_sparsity(pats[args.pattern])
    sched = SchedulePolicy(policy=args.policy,
                           invocations=args.invocations)
    return simulate(arch, wl, default_mapping(arch), schedule=sched)


def _add_model_args(sp: argparse.ArgumentParser) -> None:
    sp.add_argument("--report", default=None, metavar="FILE",
                    help="CostReport JSON (CostReport.to_json output) "
                         "instead of simulating")
    sp.add_argument("--model", default="resnet18",
                    help="workload model to simulate (default resnet18)")
    sp.add_argument("--img", type=int, default=32)
    sp.add_argument("--arch", default=None,
                    help="preset architecture name (default: use-case "
                         "arch with --macros macros)")
    sp.add_argument("--macros", type=int, default=16)
    sp.add_argument("--policy", default="partitioned",
                    choices=("monolithic", "partitioned", "resident"))
    sp.add_argument("--invocations", type=int, default=1)
    sp.add_argument("--ratio", type=float, default=None,
                    help="apply a Table-II sparsity pattern at this ratio")
    sp.add_argument("--pattern", default="row-block",
                    help="Table-II pattern name for --ratio")


def _cmd_timeline(args) -> int:
    rep = _build_report(args)
    doc = chrome_trace(rep)
    out = args.out or (f"{rep.workload}_{doc['otherData']['policy']}"
                       ".trace.json")
    write_chrome_trace(rep, out)
    meta = doc["otherData"]
    print(f"wrote {len(doc['traceEvents'])} events to {out}")
    print(f"  {meta['workload']} on {meta['arch']} [{meta['policy']}]: "
          f"{meta['n_macros']} macro tracks, "
          f"makespan {meta['makespan_cycles']:.0f} cyc, "
          f"critical path {meta['critical_path_cycles']:.0f} cyc, "
          f"macro-util {meta['macro_time_utilization']:.1%}, "
          f"concurrency {meta['concurrency']:.2f}x")
    print("  open in chrome://tracing or https://ui.perfetto.dev")
    return 0


def _cmd_energy(args) -> int:
    rep = _build_report(args)
    print(energy_table(rep))
    if args.csv:
        write_energy_csv([rep], args.csv)
        print(f"wrote component rows to {args.csv}")
    if args.json:
        write_energy_json([rep], args.json)
        print(f"wrote component rows to {args.json}")
    return 0


def _cmd_report(args) -> int:
    trace_dir = Path(args.trace_dir)
    manifest = read_manifest(trace_dir)
    if manifest is None:
        print(f"error: no manifest.json under {trace_dir}", file=sys.stderr)
        return 1
    print(f"run {manifest['run_id']} (obs schema "
          f"{manifest.get('obs_schema')}), argv: "
          f"{' '.join(manifest.get('argv', []))}")
    events = read_events(trace_dir)
    kinds = _Counter((r.get("type"), r.get("name")) for r in events)
    pids = {r.get("pid") for r in events}
    print(f"{len(events)} records from {len(pids)} process(es)")
    for (typ, name), n in sorted(kinds.items(),
                                 key=lambda kv: (-kv[1], str(kv[0]))):
        print(f"  {n:>6}  {typ:<8} {name}")
    runs = list(iter_runs(trace_dir))
    if runs:
        print(f"sweep runs ({len(runs)}):")
        for r in runs:
            print(f"  requested={r.get('requested')} "
                  f"unique={r.get('unique')} "
                  f"evaluated={r.get('evaluated')} "
                  f"cache_hits={r.get('cache_hits')} "
                  f"workers={r.get('workers')} "
                  f"wall_s={r.get('wall_s')}")
    beats = [r for r in events if str(r.get("name", "")).endswith(".heartbeat")]
    if beats:
        last = beats[-1]["attrs"]
        print(f"last heartbeat: {last.get('done')}/{last.get('total')} "
              f"@ {last.get('points_per_s')} points/s")
    return 0


def _cmd_check(args) -> int:
    doc = json.loads(Path(args.trace_json).read_text())
    problems = check_chrome_trace(doc)
    if problems:
        for p in problems:
            print(f"FAIL: {p}", file=sys.stderr)
        return 1
    n = len([e for e in doc["traceEvents"] if e.get("ph") == "X"])
    print(f"ok: {args.trace_json} is a loadable Chrome trace "
          f"({n} complete events)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("timeline", help="export a schedule as Chrome trace")
    _add_model_args(sp)
    sp.add_argument("--out", default=None, metavar="FILE")
    sp.set_defaults(fn=_cmd_timeline)

    sp = sub.add_parser("energy", help="per-component energy attribution")
    _add_model_args(sp)
    sp.add_argument("--csv", default=None, metavar="FILE")
    sp.add_argument("--json", default=None, metavar="FILE")
    sp.set_defaults(fn=_cmd_energy)

    sp = sub.add_parser("report", help="summarise a recorded trace dir")
    sp.add_argument("trace_dir")
    sp.set_defaults(fn=_cmd_report)

    sp = sub.add_parser("check", help="schema-validate a Chrome trace JSON")
    sp.add_argument("trace_json")
    sp.set_defaults(fn=_cmd_check)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
