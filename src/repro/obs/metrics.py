"""Serving metrics: counters and streaming histograms with exposition.

Pure-python, jax-free accumulators the serve engine updates inline
(:class:`ServeMetrics` is cheap enough to keep on unconditionally —
a histogram observe is one bisect + three adds).  The streaming
histogram uses fixed log-spaced buckets (1 µs … ~500 s, ~12% resolution)
so p50/p99 come from bucket interpolation without retaining samples —
the standard Prometheus-style trade.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Dict, List, Optional

__all__ = ["StreamingHistogram", "ServeMetrics"]


def _log_bounds(lo: float = 1e-6, hi: float = 512.0,
                per_decade: int = 20) -> List[float]:
    n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
    return [lo * 10 ** (i / per_decade) for i in range(n)]


_DEFAULT_BOUNDS = _log_bounds()


class StreamingHistogram:
    """Fixed-bucket streaming histogram over positive floats (seconds).

    ``percentile(p)`` interpolates linearly inside the winning bucket;
    exact min/max are tracked so p0/p100 are sample-exact and a
    single-sample histogram reports that sample for every percentile.
    """

    __slots__ = ("bounds", "counts", "count", "total", "min", "max")

    def __init__(self, bounds: Optional[List[float]] = None):
        self.bounds = bounds if bounds is not None else _DEFAULT_BOUNDS
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100]."""
        if self.count == 0:
            return 0.0
        if p <= 0:
            return self.min
        if p >= 100:
            return self.max
        rank = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= rank and c > 0:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo, hi = max(lo, self.min), min(max(hi, lo), self.max)
                frac = (rank - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return self.max

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


class ServeMetrics:
    """Request/latency accounting for :class:`repro.serve.engine.ServeEngine`.

    Cumulative across the engine's lifetime — ``snapshot()`` is a pure
    read, so repeated ``run()`` calls keep accumulating (mirroring the
    sweep engine's cumulative ``stats`` vs per-call ``last_stats``
    split).  All latencies are wall seconds from ``time.monotonic()``
    callers pass in; this module never reads a clock itself.
    """

    def __init__(self):
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_rejected = 0     # refused admission (queue full)
        self.requests_expired = 0      # deadline passed (queued or decoding)
        self.tokens_generated = 0
        self.steps = 0
        self.queue_depth = 0           # gauge: waiting, not yet in a slot
        self.active_slots = 0          # gauge: slots decoding right now
        self.busy_s = 0.0              # wall seconds inside step()
        self.ttft = StreamingHistogram()
        self.token_latency = StreamingHistogram()

    # -- update points (called by the engine) --------------------------------
    def on_submit(self) -> None:
        self.requests_submitted += 1
        self.queue_depth += 1

    def on_scheduled(self) -> None:
        self.queue_depth -= 1

    def on_reject(self) -> None:
        # a rejected request never entered the queue: no submit/depth
        self.requests_rejected += 1

    def on_expire(self, queued: bool = True) -> None:
        # ``queued``: expired while waiting (it held a queue_depth unit);
        # False = cut off mid-decode (its slot is released by the engine)
        self.requests_expired += 1
        if queued:
            self.queue_depth -= 1

    def on_first_token(self, ttft_s: float) -> None:
        self.ttft.observe(ttft_s)

    def on_tokens(self, n: int, step_s: float) -> None:
        # each of the n tokens (one per active slot) experienced the
        # full decode-step latency — that is the user-visible
        # inter-token latency, so it is what the histogram records
        self.tokens_generated += n
        if n > 0 and step_s > 0:
            for _ in range(n):
                self.token_latency.observe(step_s)

    def on_step(self, active: int, step_s: float) -> None:
        self.steps += 1
        self.active_slots = active
        self.busy_s += step_s

    def on_complete(self) -> None:
        self.requests_completed += 1

    # -- exposition ----------------------------------------------------------
    def snapshot(self) -> Dict:
        toks_per_s = (self.tokens_generated / self.busy_s
                      if self.busy_s > 0 else 0.0)
        return {
            "requests": {"submitted": self.requests_submitted,
                         "completed": self.requests_completed,
                         "queue_depth": self.queue_depth},
            # kept out of "requests" so long-standing consumers of that
            # sub-dict (and its exact shape) are unaffected
            "failures": {"rejected": self.requests_rejected,
                         "expired": self.requests_expired},
            "steps": self.steps,
            "active_slots": self.active_slots,
            "tokens_generated": self.tokens_generated,
            "tokens_per_s": toks_per_s,
            "busy_s": self.busy_s,
            "ttft_s": self.ttft.snapshot(),
            "token_latency_s": self.token_latency.snapshot(),
        }

    def render_json(self) -> str:
        return json.dumps(self.snapshot(), indent=1)

    def render_text(self) -> str:
        s = self.snapshot()
        t, tl = s["ttft_s"], s["token_latency_s"]
        f = s["failures"]
        return "\n".join([
            f"serve.requests submitted={s['requests']['submitted']} "
            f"completed={s['requests']['completed']} "
            f"queue_depth={s['requests']['queue_depth']} "
            f"rejected={f['rejected']} expired={f['expired']}",
            f"serve.steps {s['steps']} active_slots={s['active_slots']}",
            f"serve.tokens {s['tokens_generated']} "
            f"({s['tokens_per_s']:.1f} tok/s over {s['busy_s']:.3f}s busy)",
            f"serve.ttft_s count={t['count']} mean={t['mean']:.4f} "
            f"p50={t['p50']:.4f} p99={t['p99']:.4f}",
            f"serve.token_latency_s count={tl['count']} "
            f"mean={tl['mean']:.5f} p50={tl['p50']:.5f} p99={tl['p99']:.5f}",
        ])
