"""Schedule → Chrome-trace/Perfetto timeline export.

Turns a :class:`~repro.core.report.CostReport` carrying a resolved
:class:`~repro.core.schedule.ScheduleResult` into Chrome Trace Event
Format JSON (``{"traceEvents": [...]}``) that loads directly in
``chrome://tracing`` or https://ui.perfetto.dev.

Track layout (one process per report):

* ``tid 0 .. n_macros-1`` — one track per **macro**.  A scheduled op
  occupying ``k`` macros emits one ``X`` (complete) event on each of its
  ``k`` lanes, so monolithic serialisation, partitioned overlap on
  disjoint macro subsets, and idle macros are all directly visible.
* ``tid n_macros`` — the post-processing unit (ops with zero macro
  demand: pooling/elementwise on the shared post unit).
* ``tid n_macros+1`` — the critical path: the DAG's longest dependency
  chain re-drawn as one lane, the latency floor no allocation beats.

Lane assignment replays the scheduler's allocation deterministically:
ops sorted by (start cycle, DAG insertion index), each taking the
lowest-numbered free macro lanes; lanes free at the occupant's end
cycle.  The scheduler admitted every op against the same macro budget,
so the replay never runs out of lanes.

Timestamps are microseconds (the Chrome trace unit), converted from
cycles via the report's own ``latency_ms / latency_cycles`` ratio (the
arch clock), falling back to 1 ns/cycle when the report is zero-length.
"""
from __future__ import annotations

import heapq
import json
from pathlib import Path
from typing import Dict, List, Optional, Union

from ..core.report import CostReport
from ..core.schedule import ScheduleResult

__all__ = ["chrome_trace", "write_chrome_trace", "check_chrome_trace"]


def _ns_per_cycle(report: CostReport) -> float:
    if report.latency_cycles > 0 and report.latency_ms > 0:
        return report.latency_ms * 1e6 / report.latency_cycles
    return 1.0


def _infer_n_macros(sched: ScheduleResult) -> int:
    """Recover the organisation's macro count from any op with a macro
    share (``macro_share == macros / n_macros`` exactly, by
    construction in the scheduler)."""
    for op in sched.ops:
        if op.macros > 0 and op.macro_share > 0:
            return max(1, round(op.macros / op.macro_share))
    return 1


def _assign_lanes(sched: ScheduleResult, n_macros: int) -> Dict[str, List[int]]:
    """Replay macro allocation: op name → occupied macro lane ids."""
    order = [op for _, op in sorted(
        ((i, op) for i, op in enumerate(sched.ops) if op.macros > 0),
        key=lambda t: (t[1].start, t[0]))]
    free = list(range(n_macros))
    heapq.heapify(free)
    running: List[tuple] = []            # (end, [lanes])
    lanes: Dict[str, List[int]] = {}
    for op in order:
        while running and running[0][0] <= op.start:
            _, done = heapq.heappop(running)
            for lane in done:
                heapq.heappush(free, lane)
        take = [heapq.heappop(free) for _ in range(min(op.macros, len(free)))]
        lanes[op.name] = take
        heapq.heappush(running, (op.end, take))
    return lanes


def chrome_trace(report: CostReport, *,
                 title: Optional[str] = None) -> Dict:
    """Chrome Trace Event Format dict for ``report.schedule``.

    Raises ``ValueError`` when the report carries no schedule (the
    retained pre-scheduler reference path)."""
    sched = report.schedule
    if sched is None:
        raise ValueError(
            f"report for {report.workload!r} has no schedule; run "
            f"simulate() (not simulate_reference) to get one")
    n_macros = _infer_n_macros(sched)
    ns_cycle = _ns_per_cycle(report)
    us = ns_cycle / 1000.0               # cycles → microseconds

    name = title or (f"{report.workload} on {report.arch} "
                     f"[{sched.policy}]")
    events: List[Dict] = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": name}},
    ]
    for lane in range(n_macros):
        events.append({"ph": "M", "pid": 0, "tid": lane,
                       "name": "thread_name",
                       "args": {"name": f"macro {lane}"}})
    post_tid, cp_tid = n_macros, n_macros + 1
    events.append({"ph": "M", "pid": 0, "tid": post_tid,
                   "name": "thread_name", "args": {"name": "post-proc"}})
    events.append({"ph": "M", "pid": 0, "tid": cp_tid,
                   "name": "thread_name", "args": {"name": "critical path"}})

    lanes = _assign_lanes(sched, n_macros)
    for op in sched.ops:
        if op.end <= op.start:           # zero-length (out-of-scope) ops
            continue
        args = {"macros": op.macros,
                "macro_share": round(op.macro_share, 6),
                "start_cycle": op.start, "end_cycle": op.end}
        tids = lanes.get(op.name, [post_tid])
        for tid in tids:
            events.append({"ph": "X", "pid": 0, "tid": tid,
                           "name": op.name, "cat": "op",
                           "ts": op.start * us,
                           "dur": (op.end - op.start) * us,
                           "args": args})

    on_cp = set(sched.critical_path)
    for op in sched.ops:
        if op.name in on_cp and op.end > op.start:
            events.append({"ph": "X", "pid": 0, "tid": cp_tid,
                           "name": op.name, "cat": "critical-path",
                           "ts": op.start * us,
                           "dur": (op.end - op.start) * us,
                           "args": {"critical_path_cycles":
                                    sched.critical_path_cycles}})

    return {
        "traceEvents": events,
        "displayTimeUnit": "ns",
        "otherData": {
            "workload": report.workload,
            "arch": report.arch,
            "mapping": report.mapping,
            "policy": sched.policy,
            "invocations": sched.invocations,
            "n_macros": n_macros,
            "makespan_cycles": sched.makespan_cycles,
            "critical_path_cycles": sched.critical_path_cycles,
            "macro_time_utilization": sched.macro_time_utilization(),
            "concurrency": sched.concurrency,
            "ns_per_cycle": ns_cycle,
        },
    }


def write_chrome_trace(report: CostReport, path: Union[str, Path], *,
                       title: Optional[str] = None) -> Path:
    path = Path(path)
    path.write_text(json.dumps(chrome_trace(report, title=title)) + "\n")
    return path


def check_chrome_trace(doc: Dict) -> List[str]:
    """Schema check for an exported trace (the CI obs-smoke gate).

    Returns a list of problems; empty means the document is a loadable
    Chrome trace with at least one op event."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    x_events = [e for e in events if e.get("ph") == "X"]
    if not x_events:
        problems.append("no complete ('X') events")
    for i, e in enumerate(events):
        if "ph" not in e or "name" not in e:
            problems.append(f"event {i}: missing ph/name")
            continue
        if e["ph"] == "X":
            for fld in ("ts", "dur", "pid", "tid"):
                if fld not in e:
                    problems.append(f"event {i} ({e['name']}): missing {fld}")
            if e.get("dur", 0) < 0:
                problems.append(f"event {i} ({e['name']}): negative dur")
    op_tids = {e["tid"] for e in x_events if e.get("cat") == "op"}
    n_macros = doc.get("otherData", {}).get("n_macros")
    if n_macros and n_macros > 1 and len(op_tids) < 2:
        problems.append(
            f"{n_macros} macro tracks declared but ops occupy "
            f"{len(op_tids)} track(s)")
    return problems
