"""repro.obs — unified observability: timelines, energy attribution,
sweep telemetry, and serve metrics behind one zero-overhead core.

Jax-free and observational-only by contract: nothing here may alter a
:class:`~repro.core.report.CostReport` or enter an explore cache key
(machine-enforced by ``repro.analysis`` — import-boundary protects this
package, CIM205 keeps cache keys obs-free, and the determinism pass
waives its wall-clock rule here and only here).

See ``docs/observability.md`` for the trace schema and workflows.
"""
from .core import (OBS_SCHEMA, Heartbeat, Observer, counter, disable,
                   enable, enabled, event, get_observer, heartbeat,
                   is_enabled, read_events, read_manifest, span)
from .energy import (component_group, component_rows, energy_table,
                     write_energy_csv, write_energy_json)
from .metrics import ServeMetrics, StreamingHistogram
from .timeline import check_chrome_trace, chrome_trace, write_chrome_trace

__all__ = [
    "OBS_SCHEMA", "Observer", "Heartbeat",
    "enable", "disable", "enabled", "is_enabled", "get_observer",
    "span", "counter", "event", "heartbeat",
    "read_events", "read_manifest",
    "chrome_trace", "write_chrome_trace", "check_chrome_trace",
    "component_group", "component_rows", "energy_table",
    "write_energy_csv", "write_energy_json",
    "ServeMetrics", "StreamingHistogram",
]
