"""Observability core: spans, counters, events → process-safe JSONL sinks.

``repro.obs`` is the repo's *observational* plane: it records what
sweeps, schedules, and serving runs actually did, and must never change
what they compute.  Three contracts follow:

* **Zero overhead when disabled.**  Every recording entry point
  (:func:`span`, :func:`counter`, :func:`event`, :func:`heartbeat`)
  collapses to a module-global ``None`` check and returns a shared
  no-op object.  ``benchmarks/obs_overhead.py`` pins the disabled-mode
  cost below 2% of the sparsity exploration suite.
* **Observational only.**  Nothing here may enter an
  :class:`~repro.explore.job.ExploreJob` cache key or alter a
  :class:`~repro.core.report.CostReport` — machine-checked by the
  ``cache-key`` analysis pass (CIM205) and by the obs-on/off
  bit-identity tests in ``tests/test_obs.py``.
* **Monotonic-clock event time.**  Event timestamps come from
  ``time.monotonic()`` (CLOCK_MONOTONIC — comparable across the
  processes of one host, which is exactly the merge domain of a run's
  trace directory).  The one sanctioned wall-clock read is the run
  manifest's ``started_unix`` stamp — telemetry metadata, never a
  result — covered by the determinism pass's ``repro.obs`` waiver.

Enabling
--------
* ``REPRO_OBS=1`` in the environment — a default trace directory is
  created under ``obs_runs/``;
* ``REPRO_OBS_DIR=<dir>`` — record into ``<dir>`` (this is also how
  worker processes join the parent's run: :func:`enable` exports the
  variable, and a forked/spawned worker's first recording call attaches
  to the same directory);
* programmatically via :func:`enable` / :func:`disable` (tests use the
  :func:`enabled` context manager);
* ``--obs`` on the CLIs (``python -m repro.explore --obs``).

Trace directory layout
----------------------
``manifest.json``      run metadata (id, argv, schema, start time)
``events-<pid>.jsonl`` one file per writing process: spans/counters/events
``runs.jsonl``         one record per :meth:`SweepRunner.run` call
``energy_components.csv``  per-component energy rows (``repro.obs.energy``)
"""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path
from typing import Callable, Dict, IO, Iterator, List, Optional, Union

__all__ = [
    "OBS_SCHEMA", "Observer", "enable", "disable", "enabled", "is_enabled",
    "get_observer", "span", "counter", "event", "heartbeat", "Heartbeat",
    "read_events", "read_manifest",
]

# Bump when the JSONL event shape changes incompatibly; readers
# (``python -m repro.obs report`` and external tooling) key on it via
# the manifest.
OBS_SCHEMA = 1

_ENV_FLAG = "REPRO_OBS"
_ENV_DIR = "REPRO_OBS_DIR"


class Observer:
    """One run's recording sink: a trace directory of JSONL files.

    Process-safe by construction: every process writes its *own*
    ``events-<pid>.jsonl`` (append mode, line-buffered), so concurrent
    writers never interleave within a line.  A forked worker inherits
    the parent's ``Observer``; the pid check in :meth:`_file` reopens a
    fresh per-pid sink on first write after the fork.
    """

    def __init__(self, trace_dir: Union[str, Path], run_id: str, *,
                 echo: bool = False):
        self.dir = Path(trace_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.run_id = run_id
        self.echo = echo
        self._pid: Optional[int] = None
        self._fh: Optional[IO[str]] = None
        self._aux: Dict[str, IO[str]] = {}

    # -- sinks ---------------------------------------------------------------
    def _file(self) -> IO[str]:
        pid = os.getpid()
        if self._fh is None or pid != self._pid:
            self._pid = pid
            self._aux = {}                     # post-fork: never share handles
            self._fh = open(self.dir / f"events-{pid}.jsonl", "a",
                            buffering=1)
        return self._fh

    def emit(self, rec: Dict) -> None:
        rec.setdefault("t", time.monotonic())
        rec["pid"] = os.getpid()
        self._file().write(json.dumps(rec, separators=(",", ":")) + "\n")
        if self.echo and rec.get("type") == "event":
            attrs = rec.get("attrs") or {}
            flat = " ".join(f"{k}={v}" for k, v in attrs.items())
            print(f"[obs] {rec.get('name')} {flat}", file=sys.stderr)

    def append_jsonl(self, name: str, rec: Dict) -> None:
        """Append one record to an auxiliary JSONL artifact (e.g. the
        ``runs.jsonl`` sweep-run manifest)."""
        pid = os.getpid()
        if pid != self._pid:
            self._file()                       # resets _aux on pid change
        fh = self._aux.get(name)
        if fh is None:
            fh = self._aux[name] = open(self.dir / name, "a", buffering=1)
        fh.write(json.dumps(rec, separators=(",", ":")) + "\n")

    def artifact_path(self, name: str) -> Path:
        """Path for a named artifact inside the trace directory."""
        return self.dir / name

    def close(self) -> None:
        for fh in (self._fh, *self._aux.values()):
            if fh is not None:
                try:
                    fh.close()
                except OSError:
                    pass
        self._fh, self._aux = None, {}

    # -- manifest ------------------------------------------------------------
    def write_manifest(self, extra: Optional[Dict] = None) -> None:
        path = self.dir / "manifest.json"
        if path.exists():                      # one manifest per run dir
            return
        manifest = {
            "run_id": self.run_id,
            "obs_schema": OBS_SCHEMA,
            # telemetry metadata, not a result: the determinism pass
            # sanctions wall-clock reads inside repro.obs only
            "started_unix": time.time(),
            "argv": list(sys.argv),
            "python": sys.version.split()[0],
            "pid": os.getpid(),
        }
        if extra:
            manifest.update(extra)
        path.write_text(json.dumps(manifest, indent=2) + "\n")


# -- module state -------------------------------------------------------------

_OBSERVER: Optional[Observer] = None
_ENV_CHECKED = False
_OWNS_ENV = False


def get_observer() -> Optional[Observer]:
    """The active observer, or None.  First call per process consults
    ``REPRO_OBS``/``REPRO_OBS_DIR`` so workers auto-attach to the
    parent's run; after that the disabled fast path is one global read."""
    global _ENV_CHECKED
    if _OBSERVER is not None:
        return _OBSERVER
    if not _ENV_CHECKED:
        _ENV_CHECKED = True
        env_dir = os.environ.get(_ENV_DIR)
        if env_dir:
            return enable(env_dir, _export_env=False)
        if os.environ.get(_ENV_FLAG) == "1":
            return enable(_export_env=False)
    return None


def is_enabled() -> bool:
    return get_observer() is not None


def _default_run_id() -> str:
    # wall clock is sanctioned here: the id names a directory, it never
    # enters a result (see module docstring + determinism-pass waiver)
    return f"run-{int(time.time())}-{os.getpid()}"


def enable(trace_dir: Optional[Union[str, Path]] = None, *,
           run_id: Optional[str] = None, echo: bool = False,
           manifest: Optional[Dict] = None,
           _export_env: bool = True) -> Observer:
    """Turn recording on for this process (and, via ``REPRO_OBS_DIR``,
    for every worker process it spawns or forks).

    ``trace_dir`` defaults to ``obs_runs/<run-id>``.  Idempotent-ish:
    enabling while enabled replaces the observer (the previous one is
    closed)."""
    global _OBSERVER, _ENV_CHECKED, _OWNS_ENV
    if _OBSERVER is not None:
        _OBSERVER.close()
    rid = run_id or _default_run_id()
    if trace_dir is None:
        trace_dir = Path("obs_runs") / rid
    obs = Observer(trace_dir, rid, echo=echo)
    obs.write_manifest(manifest)
    _OBSERVER = obs
    _ENV_CHECKED = True
    if _export_env:
        os.environ[_ENV_DIR] = str(obs.dir)
        _OWNS_ENV = True
    return obs


def disable() -> None:
    """Turn recording off and drop the env hand-off (if we set it)."""
    global _OBSERVER, _ENV_CHECKED, _OWNS_ENV
    if _OBSERVER is not None:
        _OBSERVER.close()
    _OBSERVER = None
    _ENV_CHECKED = True                        # do not re-enable from env
    if _OWNS_ENV:
        os.environ.pop(_ENV_DIR, None)
        _OWNS_ENV = False


class enabled:
    """Context manager: record into ``trace_dir`` for the block."""

    def __init__(self, trace_dir: Union[str, Path], **kw):
        self._dir, self._kw = trace_dir, kw

    def __enter__(self) -> Observer:
        return enable(self._dir, **self._kw)

    def __exit__(self, *exc) -> None:
        disable()


# -- recording entry points ---------------------------------------------------

class _NullSpan:
    """Shared no-op span/heartbeat: the whole disabled-mode surface."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def set(self, **attrs) -> None:
        pass

    def tick(self, done: int, **attrs) -> None:
        pass


_NULL = _NullSpan()


class _Span:
    __slots__ = ("_obs", "_name", "_attrs", "_t0")

    def __init__(self, obs: Observer, name: str, attrs: Dict):
        self._obs, self._name, self._attrs = obs, name, attrs
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = time.monotonic()
        return self

    def set(self, **attrs) -> None:
        self._attrs.update(attrs)

    def __exit__(self, exc_type, *exc) -> None:
        t1 = time.monotonic()
        rec = {"type": "span", "name": self._name, "t": self._t0,
               "dur_s": t1 - self._t0, "attrs": self._attrs}
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        self._obs.emit(rec)


def span(name: str, **attrs):
    """Time a block: ``with obs.span("explore.evaluate", arch=...)``.
    No-op (shared null object) when disabled."""
    obs = get_observer()
    if obs is None:
        return _NULL
    return _Span(obs, name, attrs)


def counter(name: str, value: Union[int, float] = 1, **attrs) -> None:
    """Record a named numeric sample (monotonic totals or gauges)."""
    obs = get_observer()
    if obs is None:
        return
    rec: Dict = {"type": "counter", "name": name, "value": value}
    if attrs:
        rec["attrs"] = attrs
    obs.emit(rec)


def event(name: str, **attrs) -> None:
    """Record a point-in-time event with attributes."""
    obs = get_observer()
    if obs is None:
        return
    obs.emit({"type": "event", "name": name, "attrs": attrs})


class Heartbeat:
    """Rate-limited progress events for long loops.

    ``tick(done)`` emits at most one ``<name>.heartbeat`` event per
    ``min_interval_s`` (plus always the final tick where
    ``done == total``), carrying points/s, ETA, and any caller attrs.
    """

    __slots__ = ("_obs", "_name", "_total", "_min_interval", "_t0", "_last")

    def __init__(self, obs: Observer, name: str, total: int,
                 min_interval_s: float = 0.25):
        self._obs, self._name, self._total = obs, name, total
        self._min_interval = min_interval_s
        self._t0 = time.monotonic()
        self._last = 0.0                       # force an early first beat

    def tick(self, done: int, **attrs) -> None:
        now = time.monotonic()
        if done < self._total and now - self._last < self._min_interval:
            return
        self._last = now
        elapsed = max(now - self._t0, 1e-9)
        rate = done / elapsed
        eta = (self._total - done) / rate if rate > 0 else float("inf")
        payload = {"done": done, "total": self._total,
                   "elapsed_s": round(elapsed, 4),
                   "points_per_s": round(rate, 2),
                   "eta_s": round(eta, 3) if eta != float("inf") else None}
        payload.update(attrs)
        self._obs.emit({"type": "event", "name": f"{self._name}.heartbeat",
                        "attrs": payload})


def heartbeat(name: str, total: int, **kw):
    """A :class:`Heartbeat` when enabled, the shared no-op otherwise."""
    obs = get_observer()
    if obs is None:
        return _NULL
    return Heartbeat(obs, name, total, **kw)


# -- reading ------------------------------------------------------------------

def read_events(trace_dir: Union[str, Path],
                name: Optional[str] = None) -> List[Dict]:
    """Merge every process's events, ordered by monotonic timestamp
    (CLOCK_MONOTONIC is host-wide, so cross-process order is real).
    ``name`` filters to one event/span/counter name."""
    out: List[Dict] = []
    for path in sorted(Path(trace_dir).glob("events-*.jsonl")):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue                   # torn tail line: skip
                if name is None or rec.get("name") == name:
                    out.append(rec)
    out.sort(key=lambda r: (r.get("t", 0.0), r.get("pid", 0)))
    return out


def read_manifest(trace_dir: Union[str, Path]) -> Optional[Dict]:
    path = Path(trace_dir) / "manifest.json"
    if not path.exists():
        return None
    return json.loads(path.read_text())


def iter_runs(trace_dir: Union[str, Path]) -> Iterator[Dict]:
    """Records from the ``runs.jsonl`` sweep-run manifest, in order."""
    path = Path(trace_dir) / "runs.jsonl"
    if not path.exists():
        return
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    continue
