"""Training loop with fault tolerance.

Production behaviours implemented here:

* checkpoint/restart — atomic saves every ``ckpt_every`` steps including
  data-pipeline state; on start, auto-resume from the latest complete
  checkpoint.
* failure handling — a step that raises (device OOM, preemption signal,
  injected fault in tests) triggers restore-from-last-checkpoint and
  replay; after ``max_retries`` consecutive failures the trainer aborts
  with a clean error.
* straggler mitigation — per-step wall-clock deadline (EMA-based): steps
  exceeding ``straggler_factor ×`` the EMA are logged and counted; the
  hook is where a real deployment would trigger re-sharding away from a
  slow host.
* NaN/inf guard — non-finite loss skips the update (params/opt state of
  the previous step are kept) and is logged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..models.transformer import init_params
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .optimizer import AdamWConfig, adamw_init
from .step import make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    max_retries: int = 3
    straggler_factor: float = 3.0
    microbatches: int = 1
    seed: int = 0
    param_dtype: Any = jnp.float32


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, pipeline: TokenPipeline,
                 *, masks=None, extra_batch_fn: Optional[Callable] = None):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.pipeline = pipeline
        self.extra_batch_fn = extra_batch_fn
        self.step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, microbatches=tcfg.microbatches, masks=masks))
        self.metrics_log: List[Dict] = []
        self.straggler_events: List[int] = []
        self.skipped_nonfinite: int = 0
        self._init_state()

    # -- state ------------------------------------------------------------------
    def _init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        self.params = init_params(self.cfg, key, dtype=self.tcfg.param_dtype)
        self.opt_state = adamw_init(self.params)
        self.start_step = 0
        if self.tcfg.ckpt_dir and latest_step(self.tcfg.ckpt_dir) is not None:
            self._restore()

    def _restore(self):
        self.params, self.opt_state, meta = restore_checkpoint(
            self.tcfg.ckpt_dir, self.params, self.opt_state)
        self.start_step = int(meta["step"])
        ds = meta.get("data_state") or {}
        if ds:
            self.pipeline = TokenPipeline.from_state(self.pipeline.cfg, ds)

    def _save(self, step: int):
        if not self.tcfg.ckpt_dir:
            return
        save_checkpoint(self.tcfg.ckpt_dir, step, self.params, self.opt_state,
                        data_state=self.pipeline.state(),
                        keep=self.tcfg.keep_ckpts)

    # -- loop -----------------------------------------------------------------------
    def _one_step(self, batch) -> Dict[str, float]:
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.extra_batch_fn is not None:
            jb.update(self.extra_batch_fn(jb))
        new_params, new_opt, metrics = self.step_fn(
            self.params, self.opt_state, jb)
        loss = float(metrics["loss"])
        if not np.isfinite(loss):
            self.skipped_nonfinite += 1
            return {"loss": loss, "skipped": 1.0}
        self.params, self.opt_state = new_params, new_opt
        return {k: float(v) for k, v in metrics.items()}

    def train(self, fault_hook: Optional[Callable[[int], None]] = None
              ) -> List[Dict]:
        """Run to tcfg.steps.  ``fault_hook(step)`` (tests) may raise to
        simulate a node failure at a given step."""
        step = self.start_step
        retries = 0
        ema = None
        while step < self.tcfg.steps:
            batch = self.pipeline.next_batch()
            t0 = time.monotonic()
            try:
                if fault_hook is not None:
                    fault_hook(step)
                metrics = self._one_step(batch)
                retries = 0
            except Exception as e:  # noqa: BLE001 — deliberate catch-all
                retries += 1
                if retries > self.tcfg.max_retries:
                    raise RuntimeError(
                        f"step {step} failed {retries} times; aborting "
                        f"(root cause: {type(e).__name__}: {e})"
                    ) from e
                # failure recovery: restore last complete checkpoint
                if self.tcfg.ckpt_dir and latest_step(self.tcfg.ckpt_dir) is not None:
                    self._restore()
                    step = self.start_step
                continue
            dt = time.monotonic() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > self.tcfg.straggler_factor * ema and step > 5:
                self.straggler_events.append(step)
            metrics.update(step=step, wall_s=dt)
            self.metrics_log.append(metrics)
            step += 1
            if step % self.tcfg.ckpt_every == 0 or step == self.tcfg.steps:
                self._save(step)
        return self.metrics_log
