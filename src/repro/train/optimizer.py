"""AdamW with global-norm clipping, implemented directly on pytrees.

Written in-tree (no optax dependency) so the optimizer state layout is
fully under our control for ZeRO-1 sharding: ``m``/``v`` mirror the
parameter tree in f32 and receive their own PartitionSpecs (the trainer
shards them over the "data" axis on top of the tensor-parallel specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step_f - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def adamw_update(
    grads, opt_state: Dict[str, Any], params, cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
