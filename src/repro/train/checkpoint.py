"""Fault-tolerant checkpointing.

Atomic save (write to ``<dir>/tmp.<step>`` then ``os.replace``), step
metadata + data-pipeline state included, keep-last-N rotation, and
restore that validates tree structure against a template.  Arrays are
stored as one ``.npz`` per checkpoint with flattened key paths — no
external deps, deterministic, and fast enough for the CPU test scale
(production deployments would swap the array store for a tensorstore
backend without touching the interface).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    import ml_dtypes

    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:
            # npz has no native bfloat16: store widened, restore re-casts
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        if hasattr(leaf, "dtype"):
            import jax.numpy as jnp
            leaves.append(jnp.asarray(arr).astype(leaf.dtype))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    params,
    opt_state=None,
    *,
    data_state: Optional[Dict] = None,
    extra_meta: Optional[Dict] = None,
    keep: int = 3,
) -> str:
    """Atomically write checkpoint for ``step``; returns its path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        if opt_state is not None:
            np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
        meta = {"step": step, "data_state": data_state or {},
                "extra": extra_meta or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int) -> None:
    steps = list_checkpoints(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def list_checkpoints(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{10})", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    params_template,
    opt_template=None,
    *,
    step: Optional[int] = None,
) -> Tuple[Any, Any, Dict]:
    """Restore (params, opt_state, meta); ``step=None`` → latest.

    A checkpoint directory missing ``meta.json`` (crash mid-write before
    the atomic rename — impossible by construction — or manual damage)
    is skipped by ``list_checkpoints``, so restart always lands on the
    last *complete* step.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    p_flat = dict(np.load(os.path.join(path, "params.npz")))
    params = _unflatten_into(params_template, p_flat)
    opt_state = None
    if opt_template is not None:
        o_flat = dict(np.load(os.path.join(path, "opt_state.npz")))
        opt_state = _unflatten_into(opt_template, o_flat)
    return params, opt_state, meta
