"""Loss and train-step construction.

``make_train_step(cfg, opt_cfg)`` returns a pure
``(params, opt_state, batch) → (params, opt_state, metrics)`` suitable
for ``jax.jit`` with in/out shardings — the function the multi-pod
dry-run lowers.  Supports microbatch gradient accumulation (scan over
microbatches keeps the HLO compact), FlexBlock mask application
(sparse fine-tuning: masks re-applied after the optimizer step so pruned
weights stay pruned), and optional int8 gradient compression for the
cross-pod reduction.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..distributed.compress import compress_decompress_grads
from ..distributed.sharding import maybe_shard
from ..models.transformer import forward
from .optimizer import AdamWConfig, adamw_update

__all__ = ["cross_entropy_loss", "make_loss_fn", "make_train_step"]


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean softmax cross entropy; logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(cfg: ArchConfig) -> Callable:
    """Batch dict → scalar loss.  Batch keys: tokens, labels
    (+ prefix_embed / enc_embed for stub-frontend archs)."""

    def loss_fn(params, batch, *, remat: bool = False,
                remat_policy: str = "minimal"):
        kwargs = {}
        if cfg.prefix_len:
            kwargs["prefix_embed"] = batch["prefix_embed"]
        if cfg.enc_dec:
            kwargs["enc_embed"] = batch["enc_embed"]
        logits = forward(params, batch["tokens"], cfg, remat=remat,
                         remat_policy=remat_policy, **kwargs)
        if cfg.prefix_len:
            logits = logits[:, cfg.prefix_len:]
        return cross_entropy_loss(logits, batch["labels"],
                                  batch.get("loss_mask"))

    return loss_fn


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    masks: Optional[Any] = None,          # FlexBlock masks pytree (subset)
    compress_grads: bool = False,         # int8 cross-pod compression
    remat: bool = False,                  # activation rematerialisation
    remat_policy: str = "minimal",        # see transformer.REMAT_POLICIES
) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def single_grad(params, batch):
        return jax.value_and_grad(
            lambda p, b: loss_fn(p, b, remat=remat,
                                 remat_policy=remat_policy))(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = single_grad(params, mb)
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch)
            (loss_sum, grads), _ = jax.lax.scan(micro, (0.0, zeros), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = single_grad(params, batch)

        if compress_grads:
            grads = compress_decompress_grads(grads)
        if masks is not None:
            # sparse fine-tuning: zero grads of pruned weights
            grads = _apply_masks(grads, masks)
        new_params, new_opt, metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        if masks is not None:
            # keep pruned weights exactly zero after the update
            new_params = _apply_masks(new_params, masks)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def _apply_masks(tree, masks):
    """Multiply matching subtree leaves by their FlexBlock masks."""
    def apply(path, leaf):
        m = masks
        try:
            for k in path:
                m = m[k.key if hasattr(k, "key") else k]
        except (KeyError, TypeError):
            return leaf
        if m is None:
            return leaf
        return leaf * jnp.asarray(m, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(apply, tree)
