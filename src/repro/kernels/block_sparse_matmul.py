"""FullBlock block-sparse matmul Pallas TPU kernel.

TPU-native adaptation of the paper's CIM weight-sparsity execution
(§III-B): FullBlock-pruned weights are stored *densely* as the gathered
list of surviving (bm × bn) blocks per output-column group, plus a block
index that routes the right input slice to each block — the analogue of
the CIM accelerator's block-index memory directing inputs to array rows.

Layout (built by :func:`repro.kernels.ops.compress_fullblock`):

* ``w_comp``: (Gn, L, bm, bn) — for each of Gn output-column groups, its
  L surviving K-blocks (L = max over groups, padded).
* ``idx``:    (Gn, L) int32 — source K-block index per slot, -1 padding.

Grid: (B/TB, Gn).  Each program owns one (input-row tile × output-column
group) cell and loops its L blocks, dynamic-slicing the input from VMEM.
``bm``/``bn`` should be multiples of the MXU tile (128) in production;
interpret-mode tests exercise smaller shapes too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_sparse_matmul_pallas"]


def _kernel(idx_ref, x_ref, w_ref, o_ref):
    TB = x_ref.shape[0]
    L, bm, bn = w_ref.shape[1], w_ref.shape[2], w_ref.shape[3]

    def body(l, acc):
        i = idx_ref[0, l]
        valid = i >= 0
        start = jnp.maximum(i, 0) * bm
        xb = pl.load(x_ref, (slice(None), pl.dslice(start, bm)))
        part = jnp.dot(xb, w_ref[0, l], preferred_element_type=jnp.float32)
        return acc + jnp.where(valid, part, jnp.zeros_like(part))

    acc = jax.lax.fori_loop(
        0, L, body, jnp.zeros((TB, bn), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def block_sparse_matmul_pallas(
    x: jnp.ndarray,        # (B, K)
    w_comp: jnp.ndarray,   # (Gn, L, bm, bn)
    idx: jnp.ndarray,      # (Gn, L) int32
    *,
    tile_b: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, K = x.shape
    Gn, L, bm, bn = w_comp.shape
    if K % bm:
        raise ValueError(f"K={K} not a multiple of block rows {bm}")
    TB = min(tile_b, B)
    pad_b = (-B) % TB
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    Bp = x.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(Bp // TB, Gn),
        in_specs=[
            pl.BlockSpec((1, L), lambda b, j: (j, 0)),
            pl.BlockSpec((TB, K), lambda b, j: (b, 0)),
            pl.BlockSpec((1, L, bm, bn), lambda b, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TB, bn), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Gn * bn), x.dtype),
        interpret=interpret,
    )(idx, x, w_comp)
    return out[:B]
