"""IntraBlock (N:M column-sparse) matmul Pallas TPU kernel.

IntraBlock(m, 1) pruning keeps φ of every m consecutive K-rows; column-
wise compression stacks the survivors into a uniform (Kc = K·φ/m, N)
matrix.  At execution each compressed row must receive the input element
of its *original* row — in CIM hardware this is the mux-based indexing
unit between the pre-processor and the array (§IV-C ③); on TPU it is an
input gather feeding a dense MXU matmul.

Grid: (B/TB, N/TN).  The gather runs once per input-row tile and is
shared across all N tiles of that row via VMEM residency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["intrablock_gather_matmul_pallas"]


def _make_kernel(cast_f32: bool):
    def _kernel(idx_ref, x_ref, w_ref, o_ref):
        # x_ref: (TB, K); idx_ref: (1, Kc); w_ref: (Kc, TN); o_ref: (TB, TN)
        xg = jnp.take(x_ref[...], idx_ref[0, :], axis=1)      # (TB, Kc)
        w = w_ref[...]
        if cast_f32:
            # interpret-mode CPU thunks lack bf16×bf16→f32 dot support;
            # the TPU path keeps bf16 operands for native MXU accumulation
            xg, w = xg.astype(jnp.float32), w.astype(jnp.float32)
        o_ref[...] = jnp.dot(
            xg, w, preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)

    return _kernel


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_n", "interpret"))
def intrablock_gather_matmul_pallas(
    x: jnp.ndarray,        # (B, K)
    w_comp: jnp.ndarray,   # (Kc, N)
    row_idx: jnp.ndarray,  # (Kc,) int32
    *,
    tile_b: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, K = x.shape
    Kc, N = w_comp.shape
    TB, TN = min(tile_b, B), min(tile_n, N)
    pad_b, pad_n = (-B) % TB, (-N) % TN
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    if pad_n:
        w_comp = jnp.pad(w_comp, ((0, 0), (0, pad_n)))
    Bp, Np = x.shape[0], w_comp.shape[1]
    idx2 = row_idx.reshape(1, Kc).astype(jnp.int32)
    out = pl.pallas_call(
        _make_kernel(cast_f32=interpret and x.dtype == jnp.bfloat16),
        grid=(Bp // TB, Np // TN),
        in_specs=[
            pl.BlockSpec((1, Kc), lambda b, j: (0, 0)),
            pl.BlockSpec((TB, K), lambda b, j: (b, 0)),
            pl.BlockSpec((Kc, TN), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TB, TN), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), x.dtype),
        interpret=interpret,
    )(idx2, x, w_comp)
    return out[:B, :N]
