"""Public kernel API: layout builders + impl dispatch.

``impl`` semantics for every op:

* ``"auto"``   — compiled Pallas on TPU, jnp oracle elsewhere (CPU tests
  exercise the kernels explicitly via ``impl="pallas_interpret"``).
* ``"pallas"`` — compiled Pallas (TPU target).
* ``"pallas_interpret"`` — Pallas kernel body interpreted in Python on
  CPU; the correctness-validation path in this container.
* ``"ref"``    — the pure-jnp oracle.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import ref as _ref
from .bitserial_profile import bitserial_zero_profile_pallas
from .block_importance import block_importance_pallas
from .block_sparse_matmul import block_sparse_matmul_pallas
from .flash_attention import flash_attention_pallas
from .intrablock_matmul import intrablock_gather_matmul_pallas

__all__ = [
    "compress_fullblock",
    "compress_intrablock",
    "block_sparse_matmul",
    "intrablock_gather_matmul",
    "block_importance",
    "bitserial_zero_profile",
    "flash_attention",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# ---------------------------------------------------------------------------
# Layout builders (host-side, run once at deployment/pruning time)
# ---------------------------------------------------------------------------

def compress_fullblock(
    w: np.ndarray, keep: np.ndarray, bm: int, bn: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack a FullBlock-pruned matrix into the kernel layout.

    ``keep``: (K/bm, N/bn) bool block keep-grid.
    Returns ``w_comp`` (Gn, L, bm, bn) and ``idx`` (Gn, L) int32 with -1
    padding, where L = max surviving K-blocks over output-column groups
    (the ragged-compression shape the paper's rearrangement step
    equalises; L is the post-'pad'-rearrangement extent).
    """
    K, N = w.shape
    gk, gn = keep.shape
    if gk * bm != K or gn * bn != N:
        raise ValueError(f"keep grid {keep.shape} mismatches {w.shape}/({bm},{bn})")
    L = max(1, int(keep.sum(axis=0).max()))
    w_comp = np.zeros((gn, L, bm, bn), dtype=w.dtype)
    idx = np.full((gn, L), -1, dtype=np.int32)
    for j in range(gn):
        ks = np.nonzero(keep[:, j])[0]
        for l, kblk in enumerate(ks):
            w_comp[j, l] = w[kblk * bm:(kblk + 1) * bm, j * bn:(j + 1) * bn]
            idx[j, l] = kblk
    return w_comp, idx


def compress_intrablock(
    w: np.ndarray, mask: np.ndarray, m: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack a *row-aligned* IntraBlock(m,1)-pruned matrix.

    Hardware-adaptation note (see DESIGN.md §2.2): CIM muxes select a
    different surviving row per (block, column) cell; the TPU MXU has no
    sub-tile gather, so per-column N:M sparsity yields **no MXU FLOP
    saving** — the general case runs as masked-dense
    (:func:`decompress_intrablock`).  When survivor positions are shared
    across columns (row-aligned IntraBlock, produced by
    ``intrablock_mask(..., align_cols=True)``), compression is a pure
    row-subset selection that maps to an input row-gather + dense
    matmul, keeping both the storage and the compute saving.

    Returns ``w_comp`` (Kc, N) = ``w[row_idx]`` and ``row_idx`` (Kc,).
    Raises if the mask is not row-aligned or survivor counts are not
    uniform per block.
    """
    K, N = w.shape
    if K % m:
        raise ValueError(f"K={K} not a multiple of intra block m={m}")
    nblocks = K // m
    mb = mask.reshape(nblocks, m, N).astype(bool)
    if not np.all(mb == mb[:, :, :1]):
        raise ValueError(
            "mask is not row-aligned across columns; per-column IntraBlock "
            "has no TPU gather layout — use decompress_intrablock()")
    pattern = mb[:, :, 0]                       # (nblocks, m)
    counts = pattern.sum(axis=1)
    phi = int(counts.max())
    if phi == 0:
        raise ValueError("mask keeps nothing")
    if not np.all(counts == phi):
        raise ValueError(f"non-uniform survivors per block: {set(counts.tolist())}")
    row_idx = np.nonzero(pattern.reshape(-1))[0].astype(np.int32)   # (nblocks*phi,)
    w_comp = np.ascontiguousarray(w[row_idx, :])
    return w_comp, row_idx


def decompress_intrablock(w: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """General per-column IntraBlock fallback: masked-dense weights.

    On TPU the MXU processes the zeros anyway; the benefit of per-column
    N:M is storage/accuracy (and CIM rows), not MXU FLOPs.
    """
    return np.asarray(w) * np.asarray(mask, dtype=w.dtype)


# ---------------------------------------------------------------------------
# Dispatch wrappers
# ---------------------------------------------------------------------------

def block_sparse_matmul(x, w_comp, idx, *, impl: str = "auto", tile_b: int = 128):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.block_sparse_matmul_ref(x, w_comp, idx)
    return block_sparse_matmul_pallas(
        x, w_comp, idx, tile_b=tile_b,
        interpret=(impl == "pallas_interpret"))


def intrablock_gather_matmul(x, w_comp, row_idx, *, impl: str = "auto",
                             tile_b: int = 128, tile_n: int = 128):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.intrablock_gather_matmul_ref(x, w_comp, row_idx)
    return intrablock_gather_matmul_pallas(
        x, w_comp, row_idx, tile_b=tile_b, tile_n=tile_n,
        interpret=(impl == "pallas_interpret"))


def block_importance(w, bm: int, bn: int, criterion: str = "l1", *,
                     impl: str = "auto", tile_n: int = 0):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.block_importance_ref(w, bm, bn, criterion)
    return block_importance_pallas(
        w, bm, bn, criterion, tile_n=tile_n,
        interpret=(impl == "pallas_interpret"))


def bitserial_zero_profile(q, group_rows: int, n_bits: int = 8, *,
                           impl: str = "auto", tile_v: int = 128):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.bitserial_zero_profile_ref(q, group_rows, n_bits)
    return bitserial_zero_profile_pallas(
        q, group_rows, n_bits, tile_v=tile_v,
        interpret=(impl == "pallas_interpret"))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "auto",
                    tile_q: int = 128, tile_k: int = 128):
    """Fused attention over (B, S, H, hd) tensors with GQA broadcast.

    q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) with Hq % Hkv == 0.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, Skv, hd)
    impl = _resolve(impl)
    if impl == "ref":
        of = _ref.flash_attention_ref(qf, kf, vf, causal=causal,
                                      window=window)
    else:
        of = flash_attention_pallas(
            qf, kf, vf, causal=causal, window=window, tile_q=tile_q,
            tile_k=tile_k, interpret=(impl == "pallas_interpret"))
    return of.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
