"""Pallas TPU kernels for CIMinus compute hot-spots.

Each kernel ships three artefacts:

* ``<name>.py`` — the ``pl.pallas_call`` + BlockSpec kernel (TPU target);
* ``ops.py``    — jit'd dispatch wrappers + compressed-layout builders;
* ``ref.py``    — pure-jnp oracles (semantic ground truth + CPU path).

Validated in interpret mode on CPU; see tests/test_kernels.py.
"""
from .ops import (bitserial_zero_profile, block_importance,
                  block_sparse_matmul, compress_fullblock,
                  compress_intrablock, decompress_intrablock,
                  flash_attention, intrablock_gather_matmul)

__all__ = [
    "bitserial_zero_profile", "block_importance", "block_sparse_matmul",
    "compress_fullblock", "compress_intrablock", "decompress_intrablock",
    "flash_attention", "intrablock_gather_matmul",
]
