"""Blockwise importance reduction Pallas TPU kernel (pruning Eq. 1).

Computes ``L_FB[i, j] = Σ ρ(W[i·bm:(i+1)·bm, j·bn:(j+1)·bn])`` for the
FullBlock pruning workflow, tiled so one program owns one block-row
strip: grid = (M/bm,), block = (bm, N) in VMEM, output row (1, N/bn).

For very wide matrices the strip splits along N as well (tile_n), with
the partial block sums remaining exact because bn divides tile_n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_importance_pallas"]


def _make_kernel(bm: int, bn: int, criterion: str):
    def _kernel(w_ref, o_ref):
        w = w_ref[...].astype(jnp.float32)
        rho = jnp.abs(w) if criterion == "l1" else jnp.square(w)
        TN = w.shape[1]
        o_ref[...] = rho.reshape(bm, TN // bn, bn).sum(axis=(0, 2))[None, :]

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "criterion", "tile_n",
                                    "interpret"))
def block_importance_pallas(
    w: jnp.ndarray,
    bm: int,
    bn: int,
    criterion: str = "l1",
    *,
    tile_n: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    M, N = w.shape
    if M % bm or N % bn:
        raise ValueError(f"matrix {w.shape} not divisible by block ({bm},{bn})")
    TN = tile_n or N
    if TN % bn or N % TN:
        raise ValueError(f"tile_n={TN} must tile N={N} in whole blocks of {bn}")
    out = pl.pallas_call(
        _make_kernel(bm, bn, criterion),
        grid=(M // bm, N // TN),
        in_specs=[pl.BlockSpec((bm, TN), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, TN // bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M // bm, N // bn), jnp.float32),
        interpret=interpret,
    )(w)
    return out
