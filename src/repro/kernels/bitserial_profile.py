"""Bit-serial zero-plane profiling Pallas TPU kernel (§IV-B).

Digital CIM pre-processors detect, per bit position, whether every input
broadcast to an array's activated rows is zero (an OR-tree across the
group) and skip that bit-serial cycle.  CIMinus profiles activations to
estimate the skippable ratio; this kernel performs the bit-plane
group-OR reduction over int8 activation samples.

Grid: (V/TV,).  Each program reduces its vector tile to a partial count
of skippable (vector × group × bit) slots; the wrapper sums partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitserial_zero_profile_pallas"]


def _make_kernel(group_rows: int, n_bits: int):
    def _kernel(q_ref, o_ref):
        mag = jnp.abs(q_ref[...].astype(jnp.int32))      # (TV, Kp)
        TV, Kp = mag.shape
        grouped = mag.reshape(TV, Kp // group_rows, group_rows)
        count = jnp.zeros((), jnp.int32)
        for b in range(n_bits):
            plane = (grouped >> b) & 1
            group_or = plane.max(axis=-1)
            count += jnp.sum(group_or == 0, dtype=jnp.int32)
        o_ref[0, 0] = count

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("group_rows", "n_bits", "tile_v",
                                    "interpret"))
def bitserial_zero_profile_pallas(
    q: jnp.ndarray,          # (V, K) int8
    group_rows: int,
    n_bits: int = 8,
    *,
    tile_v: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns jnp.int32 [skippable, total] — identical contract to
    :func:`repro.kernels.ref.bitserial_zero_profile_ref`."""
    V, K = q.shape
    pad_k = (-K) % group_rows
    if pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_k)))
    TV = min(tile_v, V)
    pad_v = (-V) % TV
    if pad_v:
        # pad vectors with ones: a non-zero pad never counts as skippable,
        # so padded rows contribute zero to the count and we subtract their
        # group totals from `total` below by just not counting them.
        q = jnp.pad(q, ((0, pad_v), (0, 0)), constant_values=1)
    Vp, Kp = q.shape
    G = Kp // group_rows
    partials = pl.pallas_call(
        _make_kernel(group_rows, n_bits),
        grid=(Vp // TV,),
        in_specs=[pl.BlockSpec((TV, Kp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Vp // TV, 1), jnp.int32),
        interpret=interpret,
    )(q)
    skippable = partials.sum()
    # padded rows contain a 1-bit in plane 0 → bits 1..7 of an all-ones pad
    # row are zero and would inflate the count; remove their contribution.
    if pad_v:
        pad_contrib = pad_v * G * (n_bits - 1)
        skippable = skippable - jnp.int32(pad_contrib)
    total = jnp.int32(V * G * n_bits)
    return jnp.stack([skippable.astype(jnp.int32), total])
