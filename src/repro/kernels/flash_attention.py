"""Flash attention Pallas TPU kernel with causal/window tile skipping.

The execution-plane hot-spot: §Perf showed score-tile materialisation
dominating every attention-bearing cell's memory roofline term.  The
fused kernel keeps score tiles in VMEM (they never reach HBM) and skips
kv tiles that are fully masked — causal-triangular and sliding-window
skipping, i.e. the paper's FullBlock block-skip idea applied to the
attention score matrix.

Layout / grid:

* q: (BH, Sq, hd), k/v: (BH, Skv, hd) — GQA group broadcast happens in
  the ops.py wrapper.
* grid = (BH, Sq/TQ): each program owns one query tile and runs the
  online-softmax loop over its *live* kv tiles only:
  ``lo = (q_lo − window + 1) // TK`` (window) .. ``hi = q_hi // TK``
  (causal) — a dynamic fori_loop range from the program id.
* BlockSpec keeps the q tile + the running (m, l, acc) in VMEM; kv rows
  stream tile-by-tile via ``pl.dslice`` loads.  TQ/TK default to the
  MXU-aligned 128; hd is the lane dimension.  The BH grid dimension is
  squeezed out of every block (``None`` block dims) so refs are plain
  2-D (rows, hd) tiles — no scalar indices in the load/store paths
  (bare int indices break interpret-mode state discharge on the 0.4.x
  jax line).

Validated in interpret mode against the pure-jnp oracle
(:func:`repro.kernels.ref.flash_attention_ref`) across shapes, dtypes,
windows and masks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG = -1e30  # finite -inf stand-in: keeps exp()/max() NaN-free in bf16


def _kernel(q_ref, k_ref, v_ref, o_ref, *, causal, window, tile_k,
            seq_kv, scale):
    TQ, hd = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale            # (TQ, hd)
    q_lo = qi * TQ
    q_idx = q_lo + jax.lax.iota(jnp.int32, TQ)

    n_tiles = seq_kv // tile_k
    if causal:
        hi = jnp.minimum((q_lo + TQ - 1) // tile_k + 1, n_tiles)
    else:
        hi = jnp.int32(n_tiles)
    if window is not None:
        lo = jnp.maximum((q_lo - window + 1) // tile_k, 0)
    else:
        lo = jnp.int32(0)

    def body(ki, carry):
        m_prev, l_prev, acc_prev = carry
        start = ki * tile_k
        kt = pl.load(k_ref, (pl.dslice(start, tile_k), slice(None)))
        vt = pl.load(v_ref, (pl.dslice(start, tile_k), slice(None)))
        k_idx = start + jax.lax.iota(jnp.int32, tile_k)
        s = jnp.dot(q, kt.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)   # (TQ, TK)
        ok = jnp.ones((TQ, tile_k), bool)
        if causal:
            ok &= k_idx[None, :] <= q_idx[:, None]
        if window is not None:
            ok &= k_idx[None, :] > q_idx[:, None] - window
        s = jnp.where(ok, s, _NEG)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        corr = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * corr + p.sum(axis=-1)
        pv = jnp.dot(p.astype(vt.dtype), vt,
                     preferred_element_type=jnp.float32)
        acc_cur = acc_prev * corr[:, None] + pv
        return m_cur, l_cur, acc_cur

    m0 = jnp.full((TQ,), _NEG, jnp.float32)
    l0 = jnp.zeros((TQ,), jnp.float32)
    a0 = jnp.zeros((TQ, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "tile_q", "tile_k", "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,        # (BH, Sq, hd)
    k: jnp.ndarray,        # (BH, Skv, hd)
    v: jnp.ndarray,        # (BH, Skv, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    if Sq % tile_q or Skv % tile_k:
        raise ValueError(f"Sq={Sq}/Skv={Skv} must tile by {tile_q}/{tile_k}")
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, Sq // tile_q)
    return pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window,
                          tile_k=tile_k, seq_kv=Skv, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, tile_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Skv, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Skv, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, tile_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
