"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps
shapes/dtypes and asserts allclose against the function here.  They are
also the CPU fallback used by :mod:`repro.kernels.ops` outside TPU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "block_sparse_matmul_ref",
    "intrablock_gather_matmul_ref",
    "block_importance_ref",
    "bitserial_zero_profile_ref",
    "flash_attention_ref",
]


def flash_attention_ref(
    q: jnp.ndarray,          # (BH, Sq, hd)
    k: jnp.ndarray,          # (BH, Skv, hd)
    v: jnp.ndarray,          # (BH, Skv, hd)
    *,
    causal: bool = True,
    window=None,
) -> jnp.ndarray:
    """Dense softmax attention with causal/sliding-window masking."""
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    qi = jnp.arange(q.shape[1])[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones(s.shape[1:], bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def block_sparse_matmul_ref(
    x: jnp.ndarray,          # (B, K)
    w_comp: jnp.ndarray,     # (Gn, L, bm, bn) gathered non-zero K-blocks
    idx: jnp.ndarray,        # (Gn, L) int32 K-block indices, -1 = padding
) -> jnp.ndarray:
    """y[b, j*bn:(j+1)*bn] = Σ_l x[b, idx[j,l]*bm : +bm] @ w_comp[j, l].

    The FullBlock-compressed matmul: only surviving (bm × bn) weight
    blocks are stored; the block index directs which input slice feeds
    each block — the TPU analogue of CIM block-index input routing.
    """
    Gn, L, bm, bn = w_comp.shape
    B, K = x.shape

    def per_ncol(w_j, idx_j):
        def body(carry, li):
            acc = carry
            i = idx_j[li]
            valid = i >= 0
            start = jnp.maximum(i, 0) * bm
            xb = jax.lax.dynamic_slice(x, (0, start), (B, bm))
            part = jnp.dot(xb, w_j[li], preferred_element_type=jnp.float32)
            return acc + jnp.where(valid, part, 0.0), None

        acc0 = jnp.zeros((B, bn), jnp.float32)
        acc, _ = jax.lax.scan(body, acc0, jnp.arange(L))
        return acc

    out = jax.vmap(per_ncol, in_axes=(0, 0), out_axes=1)(w_comp, idx)  # (B, Gn, bn)
    return out.reshape(B, Gn * bn).astype(x.dtype)


def intrablock_gather_matmul_ref(
    x: jnp.ndarray,          # (B, K)
    w_comp: jnp.ndarray,     # (Kc, N) column-compressed weights
    row_idx: jnp.ndarray,    # (Kc,) int32: original K row of each compressed row
) -> jnp.ndarray:
    """y = x[:, row_idx] @ w_comp — the IntraBlock N:M column-sparse
    matmul; the row gather is the mux-based input selection (§IV-C ③)."""
    xg = jnp.take(x, row_idx, axis=1)
    return jnp.dot(xg, w_comp, preferred_element_type=jnp.float32).astype(x.dtype)


def block_importance_ref(
    w: jnp.ndarray, bm: int, bn: int, criterion: str = "l1"
) -> jnp.ndarray:
    """Eq. 1 block losses: (M/m, N/n) sums of ρ(w) per block."""
    M, N = w.shape
    assert M % bm == 0 and N % bn == 0, "ref expects whole blocks"
    rho = jnp.abs(w) if criterion == "l1" else jnp.square(w)
    rho = rho.astype(jnp.float32)
    return rho.reshape(M // bm, bm, N // bn, bn).sum(axis=(1, 3))


def bitserial_zero_profile_ref(
    q: jnp.ndarray, group_rows: int, n_bits: int = 8
) -> jnp.ndarray:
    """Count of all-zero (vector × group × bit) slots, as int32 scalar,
    plus the total slot count: returns (skippable, total).

    ``q`` int8 of shape (V, K); K padded up to a multiple of group_rows
    with zeros (paddings are genuinely skippable slots in hardware, and
    both the kernel and oracle count them identically).
    """
    V, K = q.shape
    pad = (-K) % group_rows
    mag = jnp.abs(q.astype(jnp.int32))
    if pad:
        mag = jnp.pad(mag, ((0, 0), (0, pad)))
    G = mag.shape[1] // group_rows
    grouped = mag.reshape(V, G, group_rows)
    skippable = jnp.int32(0)
    for b in range(n_bits):
        plane = (grouped >> b) & 1
        group_or = plane.max(axis=-1)
        skippable += jnp.sum(group_or == 0, dtype=jnp.int32)
    total = jnp.int32(V * G * n_bits)
    return jnp.stack([skippable, total])
