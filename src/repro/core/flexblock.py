"""FlexBlock sparsity abstraction (paper §III).

FlexBlock represents a sparsity pattern on a reshaped 2-D weight matrix
``W ∈ R^{M×N}`` as a composition of at most two block-based patterns:

* :class:`FullBlock` — entire ``m×n`` blocks are zeroed (Def. III.2).
* :class:`IntraBlock` — within each ``m×n`` block, a fixed count of
  elements survives, arranged per a binary pattern from a pattern set
  (Def. III.3).  For CIM mappability IntraBlock blocks must be
  column-wise one-dimensional, i.e. ``n == 1`` (§III-D).

Composition constraints (§III-D):

* at most two patterns;
* when two are composed, the finer one must be an IntraBlock and the
  coarser a FullBlock whose block size is an integral multiple of the
  finer block size (stacking two FullBlocks is a mathematical subset of
  the finer one; stacking IntraBlocks explodes routing complexity).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FullBlock",
    "IntraBlock",
    "FlexBlockSpec",
    "dense_spec",
    "row_wise",
    "row_block",
    "column_wise",
    "channel_wise",
    "column_block",
    "hybrid",
    "TABLE_II_PATTERNS",
]


def _check_block_dims(m: int, n: int) -> None:
    if m <= 0 or n <= 0:
        raise ValueError(f"block dims must be positive, got ({m}, {n})")
    if m * n <= 1:
        raise ValueError(f"block must contain >1 element, got ({m}, {n})")


def _check_ratio(r: float) -> None:
    if not (0.0 < r < 1.0):
        raise ValueError(f"sparsity ratio must be in (0, 1), got {r}")


@dataclasses.dataclass(frozen=True)
class FullBlock:
    """FullBlock sparsity pattern (Def. III.2).

    ``m``/``n`` may be the sentinel ``-1`` meaning "full extent of the
    matrix dimension" (used by row-wise / column-wise patterns whose block
    spans an entire row or column; resolved at bind time).
    """

    m: int
    n: int
    ratio: float

    def __post_init__(self):
        if self.m != -1 and self.n != -1:
            _check_block_dims(self.m, self.n)
        _check_ratio(self.ratio)

    def bind(self, shape: Tuple[int, int]) -> "FullBlock":
        """Resolve ``-1`` sentinels against a concrete matrix shape."""
        m = shape[0] if self.m == -1 else self.m
        n = shape[1] if self.n == -1 else self.n
        return FullBlock(m, n, self.ratio)

    @property
    def kind(self) -> str:
        return "full"

    def grid(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        """Number of blocks along each dim (matrix padded up if ragged)."""
        b = self.bind(shape)
        return (math.ceil(shape[0] / b.m), math.ceil(shape[1] / b.n))

    def nonzero_blocks(self, shape: Tuple[int, int]) -> int:
        """Φ = ⌊(1-r)·(M/m)·(N/n)⌋ (Def. III.2)."""
        gm, gn = self.grid(shape)
        return int(math.floor((1.0 - self.ratio) * gm * gn))


@dataclasses.dataclass(frozen=True)
class IntraBlock:
    """IntraBlock sparsity pattern (Def. III.3).

    ``pattern_set`` is an optional tuple of binary masks (each of shape
    ``(m, n)`` flattened to a tuple of 0/1 ints).  When ``None`` it
    defaults to *all* patterns with exactly ``phi`` non-zeros, which makes
    per-block pattern selection equivalent to magnitude top-k.
    """

    m: int
    n: int
    ratio: float
    pattern_set: Optional[Tuple[Tuple[int, ...], ...]] = None

    def __post_init__(self):
        _check_block_dims(self.m, self.n)
        _check_ratio(self.ratio)
        if self.n != 1:
            raise ValueError(
                "IntraBlock patterns must be column-wise one-dimensional "
                f"blocks (n == 1) for uniform compressed shapes, got n={self.n}"
            )
        if self.phi < 1:
            raise ValueError(
                f"IntraBlock({self.m},{self.n}) at ratio {self.ratio} would "
                "keep zero elements per block"
            )
        if self.pattern_set is not None:
            for p in self.pattern_set:
                if len(p) != self.m * self.n:
                    raise ValueError("pattern mask size must equal m*n")
                if sum(p) != self.phi:
                    raise ValueError(
                        f"pattern {p} keeps {sum(p)} elements, expected {self.phi}"
                    )

    @property
    def kind(self) -> str:
        return "intra"

    @property
    def phi(self) -> int:
        """Non-zero elements per block: φ = ⌊(1-r)·m·n⌋."""
        return int(math.floor((1.0 - self.ratio) * self.m * self.n))

    def bind(self, shape: Tuple[int, int]) -> "IntraBlock":
        return self

    def default_patterns(self) -> Tuple[Tuple[int, ...], ...]:
        """All C(m·n, φ) binary masks keeping exactly φ elements."""
        size, phi = self.m * self.n, self.phi
        pats = []
        for keep in itertools.combinations(range(size), phi):
            mask = [0] * size
            for k in keep:
                mask[k] = 1
            pats.append(tuple(mask))
        return tuple(pats)

    def patterns(self) -> Tuple[Tuple[int, ...], ...]:
        return self.pattern_set if self.pattern_set is not None else self.default_patterns()

    def patterns_array(self) -> np.ndarray:
        """Pattern set as a dense (P, m, n) uint8 array."""
        pats = self.patterns()
        return np.asarray(pats, dtype=np.uint8).reshape(len(pats), self.m, self.n)


Pattern = object  # FullBlock | IntraBlock


@dataclasses.dataclass(frozen=True)
class FlexBlockSpec:
    """A FlexBlock sparsity description: ordered composition of patterns.

    Order is fine→coarse by convention (the paper writes e.g.
    ``IntraBlock(2,1) + FullBlock(2,16)``).
    """

    patterns: Tuple[Pattern, ...] = ()
    name: str = ""

    def __post_init__(self):
        if len(self.patterns) > 2:
            raise ValueError(
                "FlexBlock composition is limited to two patterns (§III-D)"
            )
        kinds = [p.kind for p in self.patterns]
        if len(self.patterns) == 2:
            if kinds != ["intra", "full"]:
                raise ValueError(
                    "two-pattern composition must be IntraBlock (fine) + "
                    f"FullBlock (coarse), got {kinds}"
                )
            fine, coarse = self.patterns
            if coarse.m != -1 and coarse.m % fine.m != 0:
                raise ValueError(
                    f"coarse block rows ({coarse.m}) must be an integral "
                    f"multiple of fine block rows ({fine.m})"
                )
            if coarse.n != -1 and coarse.n % fine.n != 0:
                raise ValueError(
                    f"coarse block cols ({coarse.n}) must be an integral "
                    f"multiple of fine block cols ({fine.n})"
                )

    # -- introspection -----------------------------------------------------
    @property
    def is_dense(self) -> bool:
        return not self.patterns

    @property
    def intra(self) -> Optional[IntraBlock]:
        for p in self.patterns:
            if p.kind == "intra":
                return p
        return None

    @property
    def full(self) -> Optional[FullBlock]:
        for p in self.patterns:
            if p.kind == "full":
                return p
        return None

    def bind(self, shape: Tuple[int, int]) -> "FlexBlockSpec":
        return FlexBlockSpec(tuple(p.bind(shape) for p in self.patterns), self.name)

    def validate_for(self, shape: Tuple[int, int]) -> None:
        """Check the spec is applicable to a concrete matrix shape."""
        M, N = shape
        for p in self.patterns:
            b = p.bind(shape)
            if b.m > M or b.n > N:
                raise ValueError(
                    f"block ({b.m},{b.n}) exceeds matrix shape {shape}"
                )

    def overall_density(self, shape: Tuple[int, int]) -> float:
        """Expected fraction of surviving weights."""
        d = 1.0
        for p in self.patterns:
            b = p.bind(shape)
            if b.kind == "full":
                gm, gn = b.grid(shape)
                d *= b.nonzero_blocks(shape) / float(gm * gn)
            else:
                d *= b.phi / float(b.m * b.n)
        return d

    def describe(self) -> str:
        if self.is_dense:
            return "dense"
        parts = []
        for p in self.patterns:
            tag = "Intra" if p.kind == "intra" else "Full"
            parts.append(f"{tag}({p.m},{p.n})@{p.ratio:g}")
        return " + ".join(parts)

    # -- index storage overhead (Eq. 8) -------------------------------------
    def index_storage_bits(
        self, shape: Tuple[int, int], *, block_index_bits: Optional[int] = None,
        elem_index_bits: Optional[int] = None,
    ) -> int:
        """S_idx = N_nz_blocks × S_block + Σ_i N_nz(B_i) × S_elem  (Eq. 8).

        Block indices are stored in the finest-grained pattern; element
        indices only for IntraBlock blocks.
        """
        M, N = shape
        full, intra = self.full, self.intra
        if self.is_dense:
            return 0
        if full is not None:
            f = full.bind(shape)
            gm, gn = f.grid(shape)
            n_blocks_total = gm * gn
            n_nz_blocks = f.nonzero_blocks(shape)
            per_block_elems = f.m * f.n
        else:
            # IntraBlock only: every block is "non-zero" at the block level.
            gm, gn = math.ceil(M / intra.m), math.ceil(N / intra.n)
            n_blocks_total = gm * gn
            n_nz_blocks = n_blocks_total
            per_block_elems = intra.m * intra.n
        s_block = (
            block_index_bits
            if block_index_bits is not None
            else max(1, math.ceil(math.log2(max(2, n_blocks_total))))
        )
        bits = n_nz_blocks * s_block
        if intra is not None:
            # element index addresses a position inside the intra block
            s_elem = (
                elem_index_bits
                if elem_index_bits is not None
                else max(1, math.ceil(math.log2(max(2, intra.m * intra.n))))
            )
            if full is not None:
                n_intra_blocks = n_nz_blocks * (per_block_elems // (intra.m * intra.n))
            else:
                n_intra_blocks = n_nz_blocks
            bits += n_intra_blocks * intra.phi * s_elem
        return int(bits)


# ---------------------------------------------------------------------------
# Named constructors for the paper's Table II patterns.
# ---------------------------------------------------------------------------

def dense_spec() -> FlexBlockSpec:
    return FlexBlockSpec((), name="dense")


def row_wise(ratio: float) -> FlexBlockSpec:
    """Row-wise: FullBlock(1, N)."""
    return FlexBlockSpec((FullBlock(1, -1, ratio),), name="row-wise")


def row_block(ratio: float, width: int = 16) -> FlexBlockSpec:
    """Row-block: FullBlock(1, width) (Table II uses width=16)."""
    return FlexBlockSpec((FullBlock(1, width, ratio),), name=f"row-block{width}")


def column_wise(ratio: float) -> FlexBlockSpec:
    """Column (filter)-wise: FullBlock(M, 1)."""
    return FlexBlockSpec((FullBlock(-1, 1, ratio),), name="column-wise")


def channel_wise(ratio: float, c_in: int) -> FlexBlockSpec:
    """Channel-wise: FullBlock(C_in, 1) on a channel-innermost flattening."""
    return FlexBlockSpec((FullBlock(c_in, 1, ratio),), name="channel-wise")


def column_block(ratio: float, height: int = 16) -> FlexBlockSpec:
    """Column-block: FullBlock(height, 1) (Table II uses height=16)."""
    return FlexBlockSpec((FullBlock(height, 1, ratio),), name=f"column-block{height}")


def hybrid(
    intra_m: int,
    full_n: int,
    overall_ratio: float,
    *,
    full_m: Optional[int] = None,
) -> FlexBlockSpec:
    """Hybrid N:M + FullBlock pattern, e.g. ``1:2 + row-block`` =
    IntraBlock(2,1)@0.5 + FullBlock(2,16)@r_fb.

    The IntraBlock ratio is fixed so exactly one element per column block
    survives (φ=1, §VII-A); the FullBlock ratio is derived to hit
    ``overall_ratio``:  (1-r_overall) = (1/m)·(1-r_fb).
    """
    intra_ratio = (intra_m - 1) / intra_m  # keep exactly one of m
    intra_density = 1.0 / intra_m
    target_density = 1.0 - overall_ratio
    fb_density = target_density / intra_density
    if not (0.0 < fb_density < 1.0):
        raise ValueError(
            f"overall ratio {overall_ratio} unreachable with 1:{intra_m} intra "
            f"(intra alone gives density {intra_density})"
        )
    fb_ratio = 1.0 - fb_density
    fm = intra_m if full_m is None else full_m
    name = f"1:{intra_m}+" + ("row-wise" if full_n == -1 else f"row-block{full_n}")
    return FlexBlockSpec(
        (IntraBlock(intra_m, 1, intra_ratio), FullBlock(fm, full_n, fb_ratio)),
        name=name,
    )


def TABLE_II_PATTERNS(ratio: float, *, M: int = 0, N: int = 0, c_in: int = 16):
    """The eight patterns of Table II at a given overall sparsity ratio."""
    pats = {
        "row-wise": row_wise(ratio),
        "row-block": row_block(ratio, 16),
        "column-wise": column_wise(ratio),
        "channel-wise": channel_wise(ratio, c_in),
        "column-block": column_block(ratio, 16),
    }
    # Hybrids only exist where overall ratio exceeds the intra-only ratio.
    try:
        pats["1:2+row-block"] = hybrid(2, 16, ratio)
    except ValueError:
        pass
    try:
        pats["1:2+row-wise"] = hybrid(2, -1, ratio)
    except ValueError:
        pass
    try:
        pats["1:4+row-block"] = hybrid(4, 16, ratio)
    except ValueError:
        pass
    return pats
