"""Mapping description interface (paper §IV-C, Fig. 5(c)).

Two halves:

① *Data reshaping* — flattening the (multi-dim) weight into a 2-D matrix
  (flattening sequence), compressing it along the row or column
  orientation according to its FlexBlock mask, padding/aligning to the
  tile size, and optionally *rearranging* ragged compressed shapes
  (equalisation by padding or slicing with a user slice size).

② *Operation mapping* — a loopnest over the tiled matrix dims where each
  loop is temporal (sequential) or spatial (assigned to a macro-
  organisation dimension).  Spatially mapped weight loops *unroll* the
  matrix across macros; feature loops *duplicate* weights so macros chew
  different input vectors in parallel (§VII-C's two strategies).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import List, Optional, Tuple

import numpy as np

from .flexblock import FlexBlockSpec
from .hardware import CIMArch
from .workload import OpNode

__all__ = [
    "ReshapeSpec", "Loop", "MappingSpec", "TileGrid", "reshape_and_compress",
    "spatial_mapping", "duplicate_mapping", "default_mapping",
]


@dataclasses.dataclass(frozen=True)
class ReshapeSpec:
    """Data reshaping description (§IV-C ①)."""

    flatten_order: str = "channel_major"   # 'channel_major' | 'kernel_major'
    compress_orient: str = "auto"          # 'auto' | 'row' | 'col'
    tile: Optional[Tuple[int, int]] = None  # defaults to macro (rows, cols)
    rearrange: Optional[str] = None        # None | 'pad' | 'slice'
    slice_size: int = 0                    # for rearrange='slice'
    slice_axis: str = "row"


@dataclasses.dataclass(frozen=True)
class Loop:
    """One loopnest level."""

    dim: str           # 'k_tile' | 'n_tile' | 'v_tile'
    extent: int
    kind: str          # 'temporal' | 'spatial'
    org_axis: int = -1  # for spatial loops: macro-organisation axis (0|1)


@dataclasses.dataclass(frozen=True)
class MappingSpec:
    """Full mapping description for MVM ops."""

    reshape: ReshapeSpec
    strategy: str = "spatial"   # 'spatial' (unroll weights) | 'duplicate'
    # which org axes serve weight-K, weight-N, and feature duplication
    k_axis: int = 0
    n_axis: int = 1
    mapping_dict: Tuple[Tuple[str, str], ...] = (
        ("conv", "cim_macro"), ("fc", "cim_macro"), ("matmul", "cim_macro"),
        ("dwconv", "cim_macro"),
        ("pool", "post_proc"), ("act", "post_proc"), ("add", "post_proc"),
        ("norm", "post_proc"), ("embed", "post_proc"),
    )

    def target_of(self, kind: str) -> str:
        for k, v in self.mapping_dict:
            if k == kind:
                return v
        return "post_proc"


@dataclasses.dataclass
class TileGrid:
    """Result of reshape+compress+tile for one MVM op.

    ``occupancy[kt, nt]`` = fraction of the (tile_k × tile_n) tile that
    holds real (non-padding) weight rows×cols; drives utilisation and
    energy.  ``row_lengths[nt]`` = compressed K extent per column tile
    (ragged when FullBlock pruning removes different row counts per
    column group).
    """

    K: int                      # original contraction extent
    N: int                      # original output extent
    k_eff: np.ndarray           # per-column-tile compressed row count
    n_eff: int                  # compressed output extent
    tile_k: int
    tile_n: int
    occupancy: np.ndarray       # (kt, nt) in [0,1]
    intra_fanin: int = 1        # inputs broadcast per array row (IntraBlock m)
    misaligned: bool = False    # FullBlock boundaries cross sub-array rows

    @property
    def grid(self) -> Tuple[int, int]:
        return self.occupancy.shape

    @property
    def n_tiles(self) -> int:
        return int(np.prod(self.occupancy.shape))

    @property
    def mean_occupancy(self) -> float:
        if self.occupancy.size == 0:
            return 0.0
        return float(self.occupancy.mean())


def _block_keep_grid(op: OpNode, spec: FlexBlockSpec) -> Optional[np.ndarray]:
    """Deterministic pseudo-random block keep-grid for costing.

    The cost model needs *which* blocks survive to measure raggedness.
    When real masks are supplied (from the pruning workflow) the caller
    passes them in; otherwise we synthesise a seeded random grid with the
    exact block keep-count Φ — the paper's auto-generated randomised
    sparsity mask path (§IV-C).
    """
    full = spec.full
    if full is None:
        return None
    shape = (op.K, op.N)
    f = full.bind(shape)
    gm, gn = f.grid(shape)
    n_keep = f.nonzero_blocks(shape)
    # content-stable seed: Python's hash() is salted per process, which
    # would make parallel sweep workers disagree with sequential runs
    seed_src = f"{op.name}|{f.m}|{f.n}|{round(f.ratio, 6)}"
    seed = int.from_bytes(
        hashlib.blake2b(seed_src.encode(), digest_size=4).digest(), "little")
    rng = np.random.default_rng(seed)
    keep = np.zeros(gm * gn, dtype=bool)
    keep[rng.permutation(gm * gn)[:n_keep]] = True
    return keep.reshape(gm, gn)


def reshape_and_compress(
    op: OpNode,
    arch: CIMArch,
    reshape: ReshapeSpec,
    *,
    block_keep: Optional[np.ndarray] = None,
) -> TileGrid:
    """① Data reshaping: compress the op's K×N weight view per its
    FlexBlock spec, align to the tile size, optionally rearrange."""
    spec = op.sparsity.bind((op.K, op.N))
    tile_k, tile_n = reshape.tile or (arch.macro.rows, arch.macro.cols)
    intra = spec.intra
    full = spec.full

    # Resolve 'auto' compression orientation from the pattern structure:
    # IntraBlock always compresses column-wise along K ('row' profile);
    # FullBlock patterns spanning every matrix row (column/filter-wise
    # pruning) compress out whole output columns instead.
    orient = reshape.compress_orient
    if orient == "auto":
        if intra is not None or full is None:
            orient = "row"
        else:
            fb = full.bind((op.K, op.N))
            orient = "col" if fb.m >= op.K else "row"
    reshape = dataclasses.replace(reshape, compress_orient=orient)

    # --- IntraBlock: uniform column-wise compression of the K dim ---------
    intra_fanin = 1
    k_base = op.K
    if intra is not None:
        intra_fanin = intra.m
        k_base = math.ceil(op.K * intra.phi / intra.m)

    # --- FullBlock: block-grid compression (possibly ragged) --------------
    n_eff = op.N
    misaligned = False
    if full is not None:
        f = full.bind((op.K, op.N))
        keep = block_keep if block_keep is not None else _block_keep_grid(op, spec)
        gm, gn = keep.shape
        # row-extent removed per block-column group
        if reshape.compress_orient == "row":
            # compress along K: per block-column, count surviving block rows
            rows_per_block = f.m if intra is None else max(1, round(f.m * intra.phi / intra.m))
            k_per_bcol = keep.sum(axis=0) * rows_per_block          # (gn,)
            # expand block columns to element columns
            n_groups = gn
            col_width = f.n if f.n > 0 else op.N
            # ragged: element-columns in group j have k_per_bcol[j] rows
            k_cols = np.repeat(k_per_bcol, col_width)[: op.N]
            misaligned = (f.m % arch.macro.sub_rows != 0) and (f.m != 1) \
                and (intra is None)
        else:
            # compress along N: per block-row, surviving block columns
            n_keep_cols = int(keep.sum(axis=1).max()) if keep.size else 0
            n_eff = n_keep_cols * f.n
            k_cols = np.full(max(n_eff, 1), k_base)
            misaligned = (f.n % arch.macro.sub_cols != 0) and (f.n != 1)
    else:
        k_cols = np.full(op.N, k_base)

    # --- rearrangement: equalise ragged compressed shapes ------------------
    if reshape.rearrange == "pad" and k_cols.size:
        k_cols = np.full_like(k_cols, int(k_cols.max()))
    elif reshape.rearrange == "slice" and reshape.slice_size > 0 and k_cols.size:
        # slice long columns into chunks of slice_size and restack: the
        # effective profile flattens toward the mean, at the cost of extra
        # tiles when max length exceeds the slice size.
        total = int(k_cols.sum())
        width = len(k_cols)
        mean_len = total / width
        lvl = max(reshape.slice_size, int(math.ceil(mean_len)))
        k_cols = np.full(width, lvl)

    # --- tiling -------------------------------------------------------------
    n_eff = len(k_cols)
    kt = max(1, math.ceil((int(k_cols.max()) if k_cols.size else k_base) / tile_k))
    nt = max(1, math.ceil(n_eff / tile_n))
    occ = np.zeros((kt, nt))
    for j in range(nt):
        cols = k_cols[j * tile_n:(j + 1) * tile_n]
        width_frac = len(cols) / tile_n
        for i in range(kt):
            lo, hi = i * tile_k, (i + 1) * tile_k
            rows = np.clip(cols - lo, 0, tile_k)
            if len(cols):
                occ[i, j] = float(rows.mean()) / tile_k * width_frac
    return TileGrid(K=op.K, N=op.N, k_eff=k_cols, n_eff=n_eff,
                    tile_k=tile_k, tile_n=tile_n, occupancy=occ,
                    intra_fanin=intra_fanin, misaligned=misaligned)


def spatial_mapping(arch: CIMArch, *, rearrange: Optional[str] = None,
                    slice_size: int = 0) -> MappingSpec:
    """Unroll weight tiles across the macro organisation (SP in §VII-C)."""
    return MappingSpec(
        reshape=ReshapeSpec(rearrange=rearrange, slice_size=slice_size),
        strategy="spatial",
    )


def duplicate_mapping(arch: CIMArch, *, rearrange: Optional[str] = None,
                      slice_size: int = 0) -> MappingSpec:
    """Duplicate weights across one org axis; macros split input vectors
    (DP in §VII-C)."""
    return MappingSpec(
        reshape=ReshapeSpec(rearrange=rearrange, slice_size=slice_size),
        strategy="duplicate",
    )


def default_mapping(arch: CIMArch, strategy: str = "spatial",
                    **kw) -> MappingSpec:
    if strategy == "spatial":
        return spatial_mapping(arch, **kw)
    if strategy == "duplicate":
        return duplicate_mapping(arch, **kw)
    raise ValueError(f"unknown mapping strategy {strategy!r}")
