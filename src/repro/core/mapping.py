"""Mapping description interface (paper §IV-C, Fig. 5(c)).

Two halves:

① *Data reshaping* — flattening the (multi-dim) weight into a 2-D matrix
  (flattening sequence), compressing it along the row or column
  orientation according to its FlexBlock mask, padding/aligning to the
  tile size, and optionally *rearranging* ragged compressed shapes
  (equalisation by padding or slicing with a user slice size).

② *Operation mapping* — a loopnest over the tiled matrix dims where each
  loop is temporal (sequential) or spatial (assigned to a macro-
  organisation dimension).  Spatially mapped weight loops *unroll* the
  matrix across macros; feature loops *duplicate* weights so macros chew
  different input vectors in parallel (§VII-C's two strategies).

Performance note (Fig. 7): :func:`reshape_and_compress` is the analytic
hot path — every simulated MVM op tiles through it.  The occupancy and
band reductions are vectorised (``np.add.reduceat`` over the compressed
column profile) and the resulting :class:`TileGrid` is memoised in a
content-addressed :class:`TileGridCache`, so repeated layer shapes — the
common case in CNN stages and transformer stacks, and across every grid
point of a sweep — pay for one grid computation.  The scalar loop
implementations are retained (``_occupancy_loop`` / ``_band_stats_loop``)
as the reference the equivalence tests replay via
:func:`reference_loops`; vectorised results are bit-for-bit identical.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from .flexblock import FlexBlockSpec
from .hardware import CIMArch
from .workload import OpNode

__all__ = [
    "ReshapeSpec", "Loop", "MappingSpec", "TileGrid", "TileGridCache",
    "reshape_and_compress", "precompute_tile_grids", "reference_loops",
    "default_tile_cache", "spatial_mapping", "duplicate_mapping",
    "default_mapping",
]


@dataclasses.dataclass(frozen=True)
class ReshapeSpec:
    """Data reshaping description (§IV-C ①)."""

    flatten_order: str = "channel_major"   # 'channel_major' | 'kernel_major'
    compress_orient: str = "auto"          # 'auto' | 'row' | 'col'
    tile: Optional[Tuple[int, int]] = None  # defaults to macro (rows, cols)
    rearrange: Optional[str] = None        # None | 'pad' | 'slice'
    slice_size: int = 0                    # for rearrange='slice'
    slice_axis: str = "row"


@dataclasses.dataclass(frozen=True)
class Loop:
    """One loopnest level."""

    dim: str           # 'k_tile' | 'n_tile' | 'v_tile'
    extent: int
    kind: str          # 'temporal' | 'spatial'
    org_axis: int = -1  # for spatial loops: macro-organisation axis (0|1)


@dataclasses.dataclass(frozen=True)
class MappingSpec:
    """Full mapping description for MVM ops."""

    reshape: ReshapeSpec
    strategy: str = "spatial"   # 'spatial' (unroll weights) | 'duplicate'
    # which org axes serve weight-K, weight-N, and feature duplication
    k_axis: int = 0
    n_axis: int = 1
    mapping_dict: Tuple[Tuple[str, str], ...] = (
        ("conv", "cim_macro"), ("fc", "cim_macro"), ("matmul", "cim_macro"),
        ("dwconv", "cim_macro"),
        ("pool", "post_proc"), ("act", "post_proc"), ("add", "post_proc"),
        ("norm", "post_proc"), ("embed", "post_proc"),
    )

    def target_of(self, kind: str) -> str:
        for k, v in self.mapping_dict:
            if k == kind:
                return v
        return "post_proc"


@dataclasses.dataclass
class TileGrid:
    """Result of reshape+compress+tile for one MVM op.

    ``occupancy[kt, nt]`` = fraction of the (tile_k × tile_n) tile that
    holds real (non-padding) weight rows×cols; drives utilisation and
    energy.  ``row_lengths[nt]`` = compressed K extent per column tile
    (ragged when FullBlock pruning removes different row counts per
    column group).

    Instances may come out of a shared :class:`TileGridCache` — treat
    them (and their arrays) as immutable.
    """

    K: int                      # original contraction extent
    N: int                      # original output extent
    k_eff: np.ndarray           # per-column-tile compressed row count
    n_eff: int                  # compressed output extent
    tile_k: int
    tile_n: int
    occupancy: np.ndarray       # (kt, nt) in [0,1]
    intra_fanin: int = 1        # inputs broadcast per array row (IntraBlock m)
    misaligned: bool = False    # FullBlock boundaries cross sub-array rows

    @property
    def grid(self) -> Tuple[int, int]:
        return self.occupancy.shape

    @property
    def n_tiles(self) -> int:
        return int(np.prod(self.occupancy.shape))

    @property
    def mean_occupancy(self) -> float:
        if self.occupancy.size == 0:
            return 0.0
        return float(self.occupancy.mean())

    def band_stats(self, sub_rows: int) -> Tuple[int, int, float, bool]:
        """Per-N-tile band accounting for the cost model (memoised).

        Returns ``(bands_sum, n_tiles, row_demand, ragged)`` — see
        :func:`_band_stats_vectorized`.  The result depends only on the
        grid's column profile and ``sub_rows``, so it is computed once
        per (grid, sub_rows) pair however many ops share the grid.
        """
        memo = self.__dict__.setdefault("_band_stats_memo", {})
        hit = memo.get(sub_rows)
        if hit is None:
            hit = _band_stats_vectorized(self.k_eff, self.K, self.tile_n,
                                         sub_rows)
            memo[sub_rows] = hit
        return hit


# ---------------------------------------------------------------------------
# Reference (scalar-loop) ↔ vectorized implementations of the hot path.
#
# The loop variants are the original per-tile formulation and are kept as
# the ground truth for the equivalence tests; `reference_loops()` routes
# the whole simulator through them (and past every cache).
# ---------------------------------------------------------------------------

_REFERENCE = False


@contextlib.contextmanager
def reference_loops():
    """Route the cost-model hot path through the retained scalar-loop
    reference implementations, bypassing every memo/cache.  Test-only —
    results must be bit-for-bit identical to the vectorized default."""
    global _REFERENCE
    prev = _REFERENCE
    _REFERENCE = True
    try:
        yield
    finally:
        _REFERENCE = prev


def _tile_counts(k_cols: np.ndarray, k_base: int,
                 tile_k: int, tile_n: int) -> Tuple[int, int]:
    kt = max(1, math.ceil((int(k_cols.max()) if k_cols.size else k_base)
                          / tile_k))
    nt = max(1, math.ceil(len(k_cols) / tile_n))
    return kt, nt


def _occupancy_loop(k_cols: np.ndarray, k_base: int,
                    tile_k: int, tile_n: int) -> np.ndarray:
    """Reference: per-(kt, nt) tile occupancy via the original loop."""
    kt, nt = _tile_counts(k_cols, k_base, tile_k, tile_n)
    occ = np.zeros((kt, nt))
    for j in range(nt):
        cols = k_cols[j * tile_n:(j + 1) * tile_n]
        width_frac = len(cols) / tile_n
        for i in range(kt):
            lo, hi = i * tile_k, (i + 1) * tile_k
            rows = np.clip(cols - lo, 0, tile_k)
            if len(cols):
                occ[i, j] = float(rows.mean()) / tile_k * width_frac
    return occ


def _occupancy_vectorized(k_cols: np.ndarray, k_base: int,
                          tile_k: int, tile_n: int) -> np.ndarray:
    """Vectorized occupancy: clip the whole column profile against every
    K-tile at once, then segment-sum per N-tile with ``np.add.reduceat``.

    Column counts are integers, so the segment sums are exact and the
    final float expression replays the loop's association order —
    ``(mean / tile_k) * width_frac`` — making the result bit-identical
    to :func:`_occupancy_loop`.
    """
    kt, nt = _tile_counts(k_cols, k_base, tile_k, tile_n)
    if not k_cols.size:
        return np.zeros((kt, nt))
    n = len(k_cols)
    starts = np.arange(nt) * tile_n
    lo = np.arange(kt, dtype=np.int64) * tile_k
    rows = np.clip(k_cols[None, :].astype(np.int64, copy=False)
                   - lo[:, None], 0, tile_k)
    sums = np.add.reduceat(rows, starts, axis=1)          # (kt, nt) exact
    lens = np.diff(np.append(starts, n))                  # per-tile widths
    return (sums / lens / tile_k) * (lens / tile_n)


def _band_stats_loop(k_cols: np.ndarray, K: int, tile_n: int,
                     sub_rows: int) -> Tuple[int, int, float, bool]:
    """Reference: the original per-N-tile band-demand loop.

    Returns ``(bands_sum, n_tiles, row_demand, ragged)`` where
    ``bands_sum`` is the total band demand Σ ceil(k_max / sub_rows) over
    non-empty tiles, ``n_tiles`` the count of non-empty tiles,
    ``row_demand`` the Σ over tiles of the tile's mean real rows per
    column (the op's total real array-row demand — each tile's columns
    share band rows, so the per-column mean is that tile's row
    footprint), and ``ragged`` whether any tile mixes column lengths.
    """
    kc = k_cols if len(k_cols) else np.array([K])
    nt = max(1, math.ceil(len(k_cols) / tile_n))
    tile_bands: List[int] = []
    tile_rows: List[float] = []
    for j in range(nt):
        cols = kc[j * tile_n:(j + 1) * tile_n]
        k_max = int(cols.max()) if len(cols) else 0
        if k_max <= 0:
            continue
        tile_bands.append(math.ceil(k_max / sub_rows))
        tile_rows.append(float(cols.sum()) / max(len(cols), 1))
    ragged = any(
        len(set(int(c) for c in kc[j * tile_n:(j + 1) * tile_n])) > 1
        for j in range(nt))
    return (int(sum(tile_bands)), len(tile_bands),
            float(sum(r for r in tile_rows)), ragged)


def _band_stats_vectorized(k_cols: np.ndarray, K: int, tile_n: int,
                           sub_rows: int) -> Tuple[int, int, float, bool]:
    """Vectorized band accounting via segmented reduceat reductions.

    Bit-for-bit contract with :func:`_band_stats_loop`: segment sums /
    maxima are exact integer reductions; ``row_demand`` replays the
    loop's left-to-right Python float summation so no pairwise-summation
    reassociation can creep in.
    """
    kc = k_cols if len(k_cols) else np.array([K])
    nt = max(1, math.ceil(len(k_cols) / tile_n))
    n = len(kc)
    starts = np.arange(nt) * tile_n
    maxs = np.maximum.reduceat(kc, starts)
    mins = np.minimum.reduceat(kc, starts)
    sums = np.add.reduceat(kc.astype(np.int64, copy=False), starts)
    lens = np.diff(np.append(starts, n))
    sel = maxs > 0
    bands = -(-maxs[sel].astype(np.int64) // sub_rows)    # exact int ceil
    tile_rows = sums[sel] / np.maximum(lens[sel], 1)
    # left-to-right like the reference's Python sum (not np pairwise)
    row_demand = float(sum(tile_rows.tolist()))
    return (int(bands.sum()), int(sel.sum()), row_demand,
            bool(np.any(mins != maxs)))


# ---------------------------------------------------------------------------
# Content-addressed memoisation: synthesised keep-grids + tile grids.
# ---------------------------------------------------------------------------

class TileGridCache:
    """LRU cache of :class:`TileGrid` results keyed by content.

    Key: ``(K, N, bound sparsity spec, reshape, tile, sub-array dims,
    mask identity)`` — everything :func:`reshape_and_compress` reads.
    Synthesised keep-grids are themselves content-addressed (seeded by
    shape + pattern), so the sentinel ``('synth',)`` suffices for them;
    externally supplied masks key by a blake2b digest of their bytes.

    One module-level instance (:func:`default_tile_cache`) serves a whole
    process: sequential sweeps share it across jobs and each ProcessPool
    worker of :class:`repro.explore.runner.SweepRunner` warms its own
    copy once.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._d: "OrderedDict[tuple, TileGrid]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple) -> Optional[TileGrid]:
        grid = self._d.get(key)
        if grid is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return grid

    def put(self, key: tuple, grid: TileGrid) -> None:
        if self.capacity <= 0:
            return
        self._d[key] = grid
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def resize(self, capacity: int) -> None:
        """Change the entry budget in place, evicting LRU overflow —
        keeps warm entries and the stats object identity intact."""
        self.capacity = capacity
        if capacity <= 0:
            self._d.clear()
            return
        while len(self._d) > capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._d),
                "capacity": self.capacity}


_DEFAULT_TILE_CACHE = TileGridCache()
# synthesised keep-grids are tiny relative to their permutation cost;
# bounded separately so huge row-wise grids can't evict tile grids
_KEEP_GRID_CACHE: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
_KEEP_GRID_CAPACITY = 2048


def default_tile_cache() -> TileGridCache:
    """The process-wide :class:`TileGridCache` ``simulate()`` uses unless
    handed an explicit one."""
    return _DEFAULT_TILE_CACHE


def set_default_tile_cache(cache: TileGridCache) -> TileGridCache:
    """Swap the process-wide tile cache (e.g. to resize it in explore
    workers); returns the previous one."""
    global _DEFAULT_TILE_CACHE
    prev = _DEFAULT_TILE_CACHE
    _DEFAULT_TILE_CACHE = cache
    return prev


def _synth_keep_grid(seed_src: str, gm: int, gn: int,
                     n_keep: int) -> np.ndarray:
    """blake2b-seeded random keep-grid with exactly ``n_keep`` survivors,
    memoised on its full content address (the permutation dominates the
    synthesis cost for large grids)."""
    key = (seed_src, gm, gn, n_keep)
    if not _REFERENCE:
        hit = _KEEP_GRID_CACHE.get(key)
        if hit is not None:
            _KEEP_GRID_CACHE.move_to_end(key)
            return hit
    seed = int.from_bytes(
        hashlib.blake2b(seed_src.encode(), digest_size=4).digest(), "little")
    rng = np.random.default_rng(seed)
    keep = np.zeros(gm * gn, dtype=bool)
    keep[rng.permutation(gm * gn)[:n_keep]] = True
    keep = keep.reshape(gm, gn)
    keep.setflags(write=False)                 # cached: treat as immutable
    if not _REFERENCE:
        _KEEP_GRID_CACHE[key] = keep
        while len(_KEEP_GRID_CACHE) > _KEEP_GRID_CAPACITY:
            _KEEP_GRID_CACHE.popitem(last=False)
    return keep


def _block_keep_grid(op: OpNode, spec: FlexBlockSpec) -> Optional[np.ndarray]:
    """Deterministic pseudo-random block keep-grid for costing.

    The cost model needs *which* blocks survive to measure raggedness.
    When real masks are supplied (from the pruning workflow) the caller
    passes them in; otherwise we synthesise a seeded random grid with the
    exact block keep-count Φ — the paper's auto-generated randomised
    sparsity mask path (§IV-C).

    The seed is content-addressed by the matrix shape and the bound
    pattern (NOT the op name): Python's ``hash()`` is salted per process
    — which would make parallel sweep workers disagree with sequential
    runs — and same-shape ops repeat dozens of times per workload, so
    one synthesised grid (and the tile grid derived from it) serves all
    of them.
    """
    full = spec.full
    if full is None:
        return None
    shape = (op.K, op.N)
    f = full.bind(shape)
    gm, gn = f.grid(shape)
    n_keep = f.nonzero_blocks(shape)
    seed_src = f"{op.K}x{op.N}|{f.m}|{f.n}|{round(f.ratio, 6)}"
    return _synth_keep_grid(seed_src, gm, gn, n_keep)


def _mask_identity(block_keep: Optional[np.ndarray],
                   spec: FlexBlockSpec) -> Optional[tuple]:
    if block_keep is not None:
        arr = np.ascontiguousarray(block_keep)
        digest = hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest()
        return ("mask", str(arr.dtype), arr.shape, digest)
    if spec.full is not None:
        return ("synth",)      # fully determined by (K, N, bound spec)
    return None


def reshape_and_compress(
    op: OpNode,
    arch: CIMArch,
    reshape: ReshapeSpec,
    *,
    block_keep: Optional[np.ndarray] = None,
    cache: Optional[TileGridCache] = None,
) -> TileGrid:
    """① Data reshaping: compress the op's K×N weight view per its
    FlexBlock spec, align to the tile size, optionally rearrange.

    Memoised in ``cache`` (default: the process-wide
    :func:`default_tile_cache`): the returned :class:`TileGrid` may be
    shared between ops/calls — callers must not mutate it.
    """
    spec = op.sparsity.bind((op.K, op.N))
    tile_k, tile_n = reshape.tile or (arch.macro.rows, arch.macro.cols)

    key = None
    if not _REFERENCE:
        if cache is None:
            cache = _DEFAULT_TILE_CACHE
        key = _grid_key(op, arch, reshape, spec, tile_k, tile_n, block_keep)
        hit = cache.get(key)
        if hit is not None:
            return hit

    k_cols, k_base, intra_fanin, misaligned = _column_profile(
        op, arch, reshape, spec, block_keep)

    # --- tiling -------------------------------------------------------------
    n_eff = len(k_cols)
    if _REFERENCE:
        occ = _occupancy_loop(k_cols, k_base, tile_k, tile_n)
    else:
        occ = _occupancy_vectorized(k_cols, k_base, tile_k, tile_n)
    k_cols.setflags(write=False)
    occ.setflags(write=False)
    grid = TileGrid(K=op.K, N=op.N, k_eff=k_cols, n_eff=n_eff,
                    tile_k=tile_k, tile_n=tile_n, occupancy=occ,
                    intra_fanin=intra_fanin, misaligned=misaligned)
    if key is not None:
        cache.put(key, grid)
    return grid


def _grid_key(op: OpNode, arch: CIMArch, reshape: ReshapeSpec,
              spec: FlexBlockSpec, tile_k: int, tile_n: int,
              block_keep: Optional[np.ndarray]) -> tuple:
    """The :class:`TileGridCache` content key for one tiling request —
    everything :func:`reshape_and_compress` reads (the *incoming*
    reshape, before orientation resolution)."""
    return (op.K, op.N, spec, reshape, tile_k, tile_n,
            arch.macro.sub_rows, arch.macro.sub_cols,
            _mask_identity(block_keep, spec))


def _column_profile(
    op: OpNode, arch: CIMArch, reshape: ReshapeSpec, spec: FlexBlockSpec,
    block_keep: Optional[np.ndarray],
) -> Tuple[np.ndarray, int, int, bool]:
    """② The compressed column profile of one MVM op: the cheap half of
    :func:`reshape_and_compress` (no tiling reductions).

    Returns ``(k_cols, k_base, intra_fanin, misaligned)``.
    """
    intra = spec.intra
    full = spec.full

    # Resolve 'auto' compression orientation from the pattern structure:
    # IntraBlock always compresses column-wise along K ('row' profile);
    # FullBlock patterns spanning every matrix row (column/filter-wise
    # pruning) compress out whole output columns instead.
    orient = reshape.compress_orient
    if orient == "auto":
        if intra is not None or full is None:
            orient = "row"
        else:
            fb = full.bind((op.K, op.N))
            orient = "col" if fb.m >= op.K else "row"
    reshape = dataclasses.replace(reshape, compress_orient=orient)

    # --- IntraBlock: uniform column-wise compression of the K dim ---------
    intra_fanin = 1
    k_base = op.K
    if intra is not None:
        intra_fanin = intra.m
        k_base = math.ceil(op.K * intra.phi / intra.m)

    # --- FullBlock: block-grid compression (possibly ragged) --------------
    misaligned = False
    if full is not None:
        f = full.bind((op.K, op.N))
        keep = block_keep if block_keep is not None else _block_keep_grid(op, spec)
        gm, gn = keep.shape
        # row-extent removed per block-column group
        if reshape.compress_orient == "row":
            # compress along K: per block-column, count surviving block rows
            rows_per_block = f.m if intra is None else max(1, round(f.m * intra.phi / intra.m))
            k_per_bcol = keep.sum(axis=0) * rows_per_block          # (gn,)
            # expand block columns to element columns
            col_width = f.n if f.n > 0 else op.N
            # ragged: element-columns in group j have k_per_bcol[j] rows
            k_cols = np.repeat(k_per_bcol, col_width)[: op.N]
            misaligned = (f.m % arch.macro.sub_rows != 0) and (f.m != 1) \
                and (intra is None)
        else:
            # compress along N: per block-row, surviving block columns
            n_keep_cols = int(keep.sum(axis=1).max()) if keep.size else 0
            n_eff = n_keep_cols * f.n
            k_cols = np.full(max(n_eff, 1), k_base)
            misaligned = (f.n % arch.macro.sub_cols != 0) and (f.n != 1)
    else:
        k_cols = np.full(op.N, k_base)

    # --- rearrangement: equalise ragged compressed shapes ------------------
    if reshape.rearrange == "pad" and k_cols.size:
        k_cols = np.full_like(k_cols, int(k_cols.max()))
    elif reshape.rearrange == "slice" and reshape.slice_size > 0 and k_cols.size:
        # slice long columns into chunks of slice_size and restack: the
        # effective profile flattens toward the mean, at the cost of extra
        # tiles when max length exceeds the slice size.
        total = int(k_cols.sum())
        width = len(k_cols)
        mean_len = total / width
        lvl = max(reshape.slice_size, int(math.ceil(mean_len)))
        k_cols = np.full(width, lvl)

    return k_cols, k_base, intra_fanin, misaligned


def precompute_tile_grids(
    requests: List[Tuple[OpNode, CIMArch, ReshapeSpec,
                         Optional[np.ndarray]]],
    *,
    cache: Optional[TileGridCache] = None,
) -> Dict[tuple, TileGrid]:
    """Batch-tile many MVM ops in stacked segment-reduction passes.

    ``requests`` is a list of ``(op, arch, reshape, block_keep)``
    tuples — exactly the arguments each per-op
    :func:`reshape_and_compress` call would receive.  Requests are
    deduped on the tile-grid content key, cache hits are skipped, and
    the remaining cold grids are computed together: column profiles
    sharing a ``(tile_k, tile_n, kt)`` shape concatenate into ONE
    ``np.add.reduceat`` occupancy pass, and ALL new profiles share one
    stacked ``maximum/minimum/add.reduceat`` band-stats pass whose
    per-grid results seed each grid's ``band_stats`` memo.  Every
    reduction is an exact integer segment reduction and every float
    expression is elementwise, so the resulting grids are bit-identical
    to per-op calls — the batched explore plane relies on that.

    Under :func:`reference_loops` this is a no-op (the reference path
    bypasses every cache by design).  Returns ``{key: TileGrid}`` for
    every request (hits included) keyed by the content key.
    """
    if _REFERENCE:
        return {}
    if cache is None:
        cache = _DEFAULT_TILE_CACHE

    # -- dedupe + cache probe ------------------------------------------------
    out: Dict[tuple, TileGrid] = {}
    cold: "OrderedDict[tuple, tuple]" = OrderedDict()   # key -> request
    for op, arch, reshape, block_keep in requests:
        spec = op.sparsity.bind((op.K, op.N))
        tile_k, tile_n = reshape.tile or (arch.macro.rows, arch.macro.cols)
        key = _grid_key(op, arch, reshape, spec, tile_k, tile_n, block_keep)
        if key in out or key in cold:
            continue
        hit = cache.get(key)
        if hit is not None:
            out[key] = hit
        else:
            cold[key] = (op, arch, reshape, spec, tile_k, tile_n, block_keep)
    if not cold:
        return out

    # -- column profiles (cheap) ----------------------------------------------
    profiles: List[tuple] = []      # (key, request..., k_cols, k_base, ...)
    for key, (op, arch, reshape, spec, tile_k, tile_n, bk) in cold.items():
        k_cols, k_base, intra_fanin, misaligned = _column_profile(
            op, arch, reshape, spec, bk)
        profiles.append((key, op, arch, tile_k, tile_n,
                         k_cols, k_base, intra_fanin, misaligned))

    # -- stacked occupancy, grouped by (tile_k, tile_n, kt) --------------------
    groups: "OrderedDict[tuple, List[int]]" = OrderedDict()
    occs: Dict[int, np.ndarray] = {}
    for i, (key, op, arch, tile_k, tile_n,
            k_cols, k_base, *_rest) in enumerate(profiles):
        if not k_cols.size:
            # the vectorized kernel's empty-profile path; rare
            occs[i] = _occupancy_vectorized(k_cols, k_base, tile_k, tile_n)
            continue
        kt, _ = _tile_counts(k_cols, k_base, tile_k, tile_n)
        groups.setdefault((tile_k, tile_n, kt), []).append(i)
    for (tile_k, tile_n, kt), idxs in groups.items():
        cols = [profiles[i][5] for i in idxs]
        nts = [max(1, math.ceil(len(c) / tile_n)) for c in cols]
        offs = np.cumsum([0] + [len(c) for c in cols])
        kc = np.concatenate([c.astype(np.int64, copy=False) for c in cols])
        starts = np.concatenate(
            [np.arange(nt) * tile_n + off
             for nt, off in zip(nts, offs[:-1])])
        lo = np.arange(kt, dtype=np.int64) * tile_k
        rows = np.clip(kc[None, :] - lo[:, None], 0, tile_k)
        sums = np.add.reduceat(rows, starts, axis=1)       # exact int sums
        lens = np.diff(np.append(starts, len(kc)))
        occ_all = (sums / lens / tile_k) * (lens / tile_n)
        tile_offs = np.cumsum([0] + nts)
        for i, a, b in zip(idxs, tile_offs[:-1], tile_offs[1:]):
            occs[i] = occ_all[:, a:b]

    # -- stacked band stats across ALL cold grids ------------------------------
    # maxs/mins/sums are sub_rows-independent; the per-grid finish below
    # applies each request's own macro.sub_rows, replaying
    # _band_stats_vectorized's expressions elementwise (bit-identical).
    band_cols: List[np.ndarray] = []
    band_nts: List[int] = []
    for key, op, arch, tile_k, tile_n, k_cols, k_base, *_rest in profiles:
        kc = k_cols if len(k_cols) else np.array([op.K])
        band_cols.append(kc.astype(np.int64, copy=False))
        band_nts.append(max(1, math.ceil(len(k_cols) / tile_n)))
    b_offs = np.cumsum([0] + [len(c) for c in band_cols])
    b_kc = np.concatenate(band_cols)
    b_starts_per = []
    for (key, op, arch, tile_k, tile_n, k_cols, *_r), nt, off in zip(
            profiles, band_nts, b_offs[:-1]):
        b_starts_per.append(np.arange(nt) * tile_n + off)
    b_starts = np.concatenate(b_starts_per)
    maxs_all = np.maximum.reduceat(b_kc, b_starts)
    mins_all = np.minimum.reduceat(b_kc, b_starts)
    sums_all = np.add.reduceat(b_kc, b_starts)
    lens_all = np.diff(np.append(b_starts, len(b_kc)))
    t_offs = np.cumsum([0] + band_nts)

    # -- assemble, seed memos, publish ------------------------------------------
    for i, (key, op, arch, tile_k, tile_n,
            k_cols, k_base, intra_fanin, misaligned) in enumerate(profiles):
        occ = occs[i]
        k_cols.setflags(write=False)
        occ.setflags(write=False)
        grid = TileGrid(K=op.K, N=op.N, k_eff=k_cols, n_eff=len(k_cols),
                        tile_k=tile_k, tile_n=tile_n, occupancy=occ,
                        intra_fanin=intra_fanin, misaligned=misaligned)
        a, b = t_offs[i], t_offs[i + 1]
        maxs, mins = maxs_all[a:b], mins_all[a:b]
        sums, lens = sums_all[a:b], lens_all[a:b]
        sel = maxs > 0
        sub_rows = arch.macro.sub_rows
        bands = -(-maxs[sel].astype(np.int64) // sub_rows)
        tile_rows = sums[sel] / np.maximum(lens[sel], 1)
        row_demand = float(sum(tile_rows.tolist()))
        stats = (int(bands.sum()), int(sel.sum()), row_demand,
                 bool(np.any(mins != maxs)))
        grid.__dict__["_band_stats_memo"] = {sub_rows: stats}
        cache.put(key, grid)
        out[key] = grid
    return out


def spatial_mapping(arch: CIMArch, *, rearrange: Optional[str] = None,
                    slice_size: int = 0) -> MappingSpec:
    """Unroll weight tiles across the macro organisation (SP in §VII-C)."""
    return MappingSpec(
        reshape=ReshapeSpec(rearrange=rearrange, slice_size=slice_size),
        strategy="spatial",
    )


def duplicate_mapping(arch: CIMArch, *, rearrange: Optional[str] = None,
                      slice_size: int = 0) -> MappingSpec:
    """Duplicate weights across one org axis; macros split input vectors
    (DP in §VII-C)."""
    return MappingSpec(
        reshape=ReshapeSpec(rearrange=rearrange, slice_size=slice_size),
        strategy="duplicate",
    )


def default_mapping(arch: CIMArch, strategy: str = "spatial",
                    **kw) -> MappingSpec:
    if strategy == "spatial":
        return spatial_mapping(arch, **kw)
    if strategy == "duplicate":
        return duplicate_mapping(arch, **kw)
    raise ValueError(f"unknown mapping strategy {strategy!r}")
