"""Hardware description interface (paper §IV-C, Fig. 5(b)).

A CIM architecture is a collection of *compute units* and *memory units*
organised around multi-macro CIM arrays.  Users provide per-access /
per-cycle energies (from synthesis flows or tools like PCACTI); CIMinus
infers unit counts from the array size, unit size, and the organisation
parameter, and tracks access counts during simulation.

Units modelled (digital SRAM-CIM paradigm):

* ``cim_array``     — the bit-serial MAC array (per sub-array per cycle)
* ``adder_tree``    — column-wise partial-sum reduction across sub-arrays
* ``shift_add``     — bit-significance accumulation per output column
* ``accumulator``   — cross-tile partial-sum accumulation
* ``pre_proc``      — bit-serial conversion of inputs (+ zero-bit detect)
* ``post_proc``     — activation / pooling / residual etc.
* ``mux_index``     — IntraBlock input-select multiplexers (§IV-C ③)
* ``sparse_accum``  — misaligned partial-sum accumulation for FullBlock

Memory units: weight/input/output global buffers (optionally ping-pong),
per-macro local buffers, and index memories for sparsity support.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

__all__ = [
    "ComputeUnit",
    "MemoryUnit",
    "MacroSpec",
    "CIMArch",
]


@dataclasses.dataclass(frozen=True)
class ComputeUnit:
    """A compute unit type with per-access dynamic energy.

    ``energy_pj``: dynamic energy per access (pJ).
    ``static_pw_mw``: static power (mW) — charged for the whole runtime.
    ``width``: elements processed per access.
    ``location``: 'macro' (instanced per macro) or 'system'.
    """

    name: str
    energy_pj: float
    static_pw_mw: float = 0.0
    width: int = 1
    location: str = "macro"


@dataclasses.dataclass(frozen=True)
class MemoryUnit:
    """A memory unit with per-access read/write energies.

    ``width_bits``: access width (bits per read/write).
    ``capacity_bytes``: storage capacity; simulation checks footprints.
    ``ping_pong``: double-buffered — loads overlap compute (§IV-C ②).
    """

    name: str
    capacity_bytes: int
    width_bits: int
    read_pj: float
    write_pj: float
    static_pw_mw: float = 0.0
    ping_pong: bool = False
    location: str = "system"


@dataclasses.dataclass(frozen=True)
class MacroSpec:
    """Geometry of one CIM macro.

    ``rows × cols`` weight elements, tiled into ``sub_rows × sub_cols``
    sub-arrays.  Digital CIM: all rows activate simultaneously.

    ``load_rows_per_cycle``: SRAM write parallelism when loading weights.
    ``mac_cycles_per_bit``: array cycles per input bit position (1 for
    fully-pipelined bit-serial digital CIM).
    """

    rows: int
    cols: int
    sub_rows: int
    sub_cols: int
    weight_bits: int = 8
    input_bits: int = 8
    load_rows_per_cycle: int = 1
    mac_cycles_per_bit: int = 1
    # row-serial digital CIM (SDP-style row-granular macros with a shared
    # per-column MAC): compute time scales with RESIDENT weight rows, so
    # row pruning shortens execution even when the whole workload fits in
    # one wave.  Fully row-parallel macros (False) activate all rows at
    # once and only save whole waves.
    row_serial: bool = False

    def __post_init__(self):
        if self.rows % self.sub_rows or self.cols % self.sub_cols:
            raise ValueError(
                f"macro {self.rows}x{self.cols} not divisible into "
                f"sub-arrays {self.sub_rows}x{self.sub_cols}"
            )

    @property
    def n_subarrays(self) -> int:
        return (self.rows // self.sub_rows) * (self.cols // self.sub_cols)

    @property
    def weight_capacity_bits(self) -> int:
        return self.rows * self.cols * self.weight_bits


@dataclasses.dataclass(frozen=True)
class CIMArch:
    """A complete multi-macro CIM architecture description."""

    name: str
    macro: MacroSpec
    org: Tuple[int, int]                      # macro organisation (rows, cols)
    compute_units: Dict[str, ComputeUnit]
    memory_units: Dict[str, MemoryUnit]
    clock_ghz: float = 1.0
    weight_sparsity_support: bool = True
    input_sparsity_support: bool = False
    eval_scope: str = "all"                   # 'all' | 'conv_only' (Table I)

    # -- derived -----------------------------------------------------------
    @property
    def n_macros(self) -> int:
        return self.org[0] * self.org[1]

    @property
    def total_rows(self) -> int:
        return self.macro.rows * self.n_macros

    @property
    def cycle_ns(self) -> float:
        return 1.0 / self.clock_ghz

    def unit(self, name: str) -> ComputeUnit:
        return self.compute_units[name]

    def mem(self, name: str) -> MemoryUnit:
        return self.memory_units[name]

    def has_unit(self, name: str) -> bool:
        return name in self.compute_units

    def has_mem(self, name: str) -> bool:
        return name in self.memory_units

    def validate(self) -> None:
        required = ["cim_array", "shift_add", "adder_tree", "accumulator",
                    "pre_proc", "post_proc"]
        for r in required:
            if r not in self.compute_units:
                raise ValueError(f"architecture {self.name} missing unit {r!r}")
        if not any(m.name.startswith(("weight", "global", "input"))
                   for m in self.memory_units.values()):
            raise ValueError(f"architecture {self.name} has no input-side buffer")
        if self.weight_sparsity_support and not self.has_mem("index_mem"):
            raise ValueError(
                f"{self.name}: weight sparsity support requires an index_mem"
            )

    def replace(self, **kw) -> "CIMArch":
        return dataclasses.replace(self, **kw)

    def with_org(self, org: Tuple[int, int]) -> "CIMArch":
        return dataclasses.replace(self, org=org)

    # convenience: index memory sizing check for a workload (Eq. 8 totals)
    def index_capacity_bits(self) -> int:
        if not self.has_mem("index_mem"):
            return 0
        return self.mem("index_mem").capacity_bytes * 8

    def static_power_mw(self) -> float:
        """Aggregate static power across all instanced units (mW)."""
        p = 0.0
        for cu in self.compute_units.values():
            inst = self.n_macros if cu.location == "macro" else 1
            p += cu.static_pw_mw * inst
        for mu in self.memory_units.values():
            inst = self.n_macros if mu.location == "macro" else 1
            p += mu.static_pw_mw * inst
        return p
