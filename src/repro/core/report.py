"""Cost report dataclasses produced by the CIMinus simulator."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from .schedule import ScheduledOp, ScheduleResult

__all__ = ["OpCost", "CostReport"]


@dataclasses.dataclass
class OpCost:
    name: str
    kind: str
    latency_cycles: float
    macs: int
    tiles: int
    waves: int
    utilization: float
    index_bits: int
    occupancy: float
    # scheduling layer (repro.core.schedule): the op's resident band
    # footprint (bands × duplication replicas), its per-wave weight-load
    # cycles, the macros those bands occupy (count + org fraction — the
    # partitioned scheduler's demand, computed once at costing time),
    # and its placement in the resolved schedule (cycles within one
    # invocation; for serial policies starts are simply cumulative).
    bands: int = 0
    load_cycles: float = 0.0
    macros: int = 0
    macro_share: float = 0.0
    start_cycle: float = 0.0
    end_cycle: float = 0.0


@dataclasses.dataclass
class CostReport:
    """System-level CIMinus output: overall latency + energy breakdown."""

    arch: str
    workload: str
    mapping: str
    latency_cycles: float
    latency_ms: float
    energy_pj: Dict[str, float]
    total_energy_uj: float
    utilization: float
    op_costs: List[OpCost]
    index_storage_bits: int
    index_capacity_ok: bool
    # Resolved multi-macro schedule (None for the retained pre-scheduler
    # reference path): per-op start/end cycles, critical path, macro
    # shares, resident/preload accounting.  ``latency_cycles`` above is
    # its ``total_cycles``.
    schedule: Optional[ScheduleResult] = None

    # -- views ---------------------------------------------------------------
    def energy_shares(self) -> Dict[str, float]:
        tot = max(sum(self.energy_pj.values()), 1e-12)
        return {k: v / tot for k, v in self.energy_pj.items() if v > 0}

    def grouped_energy(self) -> Dict[str, float]:
        """Power-breakdown groups used in the paper's Fig. 6(c)."""
        groups = {"cim_macro": 0.0, "buffers": 0.0, "pre_post": 0.0,
                  "sparsity": 0.0, "static": 0.0}
        for k, v in self.energy_pj.items():
            if k in ("cim_array", "adder_tree", "shift_add", "accumulator",
                     "local_buf"):
                groups["cim_macro"] += v
            elif k.endswith("_buf") or k == "global_buf":
                groups["buffers"] += v
            elif k in ("pre_proc", "post_proc"):
                groups["pre_post"] += v
            elif k in ("mux_index", "sparse_accum", "zero_detect", "index_mem"):
                groups["sparsity"] += v
            elif k == "static":
                groups["static"] += v
        return groups

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "CostReport":
        """Rebuild a report from :meth:`to_dict` output (e.g. a JSON
        artifact handed to ``python -m repro.obs timeline``)."""
        d = dict(d)
        d["op_costs"] = [OpCost(**oc) for oc in d.get("op_costs", [])]
        sched = d.get("schedule")
        if sched is not None:
            sched = dict(sched)
            sched["ops"] = [ScheduledOp(**so) for so in sched.get("ops", [])]
            d["schedule"] = ScheduleResult(**sched)
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def summary(self) -> str:
        g = self.grouped_energy()
        sched = ""
        sched_line = ""
        if self.schedule is not None:
            s = self.schedule
            if s.policy != "monolithic" or s.invocations != 1:
                sched = f"/{s.policy}x{s.invocations}"
            sched_line = (
                f"\n  schedule[{s.policy}]: "
                f"critical-path={s.critical_path_cycles:.0f} cyc "
                f"({s.critical_path_cycles / max(s.makespan_cycles, 1e-12):.0%}"
                f" of makespan), "
                f"macro-util={s.macro_time_utilization():.1%}, "
                f"concurrency={s.concurrency:.2f}x")
        return (f"{self.workload} on {self.arch} [{self.mapping}{sched}]: "
                f"{self.latency_ms:.3f} ms, {self.total_energy_uj:.2f} uJ, "
                f"util={self.utilization:.2%}, "
                f"idx={self.index_storage_bits/8/1024:.1f} KiB, "
                f"E[macro/buf/prepost/sparse/static]="
                f"{g['cim_macro']:.2e}/{g['buffers']:.2e}/{g['pre_post']:.2e}/"
                f"{g['sparsity']:.2e}/{g['static']:.2e} pJ" + sched_line)
