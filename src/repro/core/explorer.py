"""Design-space exploration sweeps (paper §VII use-cases).

Compatibility layer: the sweep logic lives in :mod:`repro.explore`, a
job-based engine with content-addressed result caching and process
fan-out.  These wrappers keep the original signatures and row schema;
they run the engine sequentially (``workers=1``) so callers that never
opted into parallelism see identical behaviour, while still getting
baseline deduplication for free.

Pass ``workers``/``runner`` to fan a sweep out or to share a result
cache across sweeps — or use :mod:`repro.explore` directly for Pareto
frontiers, top-k tables, and CSV/JSON export.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .flexblock import FlexBlockSpec
from .hardware import CIMArch
from .mapping import MappingSpec
from .workload import Workload

__all__ = ["sweep_sparsity", "sweep_mappings", "sweep_orgs"]


def sweep_sparsity(
    arch: CIMArch,
    workload_fn: Callable[[], Workload],
    patterns: Dict[str, FlexBlockSpec],
    *,
    ratios: Sequence[float] = (0.5, 0.6, 0.7, 0.8, 0.9),
    mapping: Optional[MappingSpec] = None,
    pattern_factory: Optional[Callable[[float], Dict[str, FlexBlockSpec]]] = None,
    input_sparsity: Optional[Dict[str, float]] = None,
    schedule=None,
    workers: Optional[int] = 1,
    runner=None,
) -> List[Dict]:
    """§VII-B: sparsity pattern × ratio grid on one architecture."""
    from ..explore import sparsity_sweep

    return sparsity_sweep(
        arch, workload_fn, patterns, ratios=ratios, mapping=mapping,
        pattern_factory=pattern_factory, input_sparsity=input_sparsity,
        schedule=schedule, workers=workers, runner=runner,
    ).rows


def sweep_mappings(
    arch_fn: Callable[[Tuple[int, int]], CIMArch],
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    *,
    orgs: Sequence[Tuple[int, int]] = ((8, 2), (4, 4), (2, 8)),
    strategies: Sequence[str] = ("spatial", "duplicate"),
    rearrange: Sequence[Optional[str]] = (None,),
    schedule=None,
    workers: Optional[int] = 1,
    runner=None,
) -> List[Dict]:
    """§VII-C: mapping strategy × macro organisation (× rearrangement)."""
    from ..explore import mapping_sweep

    return mapping_sweep(
        arch_fn, workload_fn, spec, orgs=orgs, strategies=strategies,
        rearrange=rearrange, schedule=schedule, workers=workers,
        runner=runner,
    ).rows


def sweep_orgs(
    arch_fn: Callable[[Tuple[int, int]], CIMArch],
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    orgs: Sequence[Tuple[int, int]],
    strategy: str = "spatial",
    **kw,
) -> List[Dict]:
    return sweep_mappings(arch_fn, workload_fn, spec, orgs=orgs,
                          strategies=(strategy,), **kw)
