"""Design-space exploration sweeps (paper §VII use-cases).

Thin orchestration over the simulator: evaluate grids of
(sparsity pattern × ratio × macro organisation × mapping strategy) and
tabulate speedup / energy saving / utilisation against the dense
baseline.  Rows are plain dicts so benchmarks can CSV them directly.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .costmodel import compare, dense_baseline, simulate
from .flexblock import FlexBlockSpec
from .hardware import CIMArch
from .mapping import MappingSpec, default_mapping
from .workload import Workload

__all__ = ["sweep_sparsity", "sweep_mappings", "sweep_orgs"]


def _row(arch, wl, spec_name, ratio, mapping, rep, cmp) -> Dict:
    return {
        "arch": arch.name,
        "workload": wl.name,
        "pattern": spec_name,
        "ratio": ratio,
        "mapping": mapping,
        "latency_ms": rep.latency_ms,
        "energy_uj": rep.total_energy_uj,
        "utilization": rep.utilization,
        "speedup": cmp["speedup"],
        "energy_saving": cmp["energy_saving"],
        "index_kib": rep.index_storage_bits / 8 / 1024,
    }


def sweep_sparsity(
    arch: CIMArch,
    workload_fn: Callable[[], Workload],
    patterns: Dict[str, FlexBlockSpec],
    *,
    ratios: Sequence[float] = (0.5, 0.6, 0.7, 0.8, 0.9),
    mapping: Optional[MappingSpec] = None,
    pattern_factory: Optional[Callable[[float], Dict[str, FlexBlockSpec]]] = None,
    input_sparsity: Optional[Dict[str, float]] = None,
) -> List[Dict]:
    """§VII-B: sparsity pattern × ratio grid on one architecture."""
    mapping = mapping or default_mapping(arch)
    base_wl = workload_fn()
    dense = dense_baseline(arch, base_wl, mapping)
    rows: List[Dict] = []
    for ratio in ratios:
        pats = pattern_factory(ratio) if pattern_factory else patterns
        for name, spec in pats.items():
            wl = workload_fn().set_sparsity(spec)
            rep = simulate(arch, wl, mapping, input_sparsity=input_sparsity)
            rows.append(_row(arch, wl, name, ratio, mapping.strategy,
                             rep, compare(rep, dense)))
    return rows


def sweep_mappings(
    arch_fn: Callable[[Tuple[int, int]], CIMArch],
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    *,
    orgs: Sequence[Tuple[int, int]] = ((8, 2), (4, 4), (2, 8)),
    strategies: Sequence[str] = ("spatial", "duplicate"),
    rearrange: Sequence[Optional[str]] = (None,),
) -> List[Dict]:
    """§VII-C: mapping strategy × macro organisation (× rearrangement)."""
    rows: List[Dict] = []
    for org, strat, rr in itertools.product(orgs, strategies, rearrange):
        arch = arch_fn(org)
        mapping = default_mapping(arch, strat, rearrange=rr)
        wl = workload_fn().set_sparsity(spec)
        dense = dense_baseline(arch, wl, mapping)
        rep = simulate(arch, wl, mapping)
        row = _row(arch, wl, spec.name, None, strat, rep, compare(rep, dense))
        row["org"] = f"{org[0]}x{org[1]}"
        row["rearrange"] = rr or "none"
        rows.append(row)
    return rows


def sweep_orgs(
    arch_fn: Callable[[Tuple[int, int]], CIMArch],
    workload_fn: Callable[[], Workload],
    spec: FlexBlockSpec,
    orgs: Sequence[Tuple[int, int]],
    strategy: str = "spatial",
) -> List[Dict]:
    return sweep_mappings(arch_fn, workload_fn, spec, orgs=orgs,
                          strategies=(strategy,))
