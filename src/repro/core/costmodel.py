"""CIMinus cost model (paper §V).

Latency: pipelined load / compute / write-back schedule per Eq. 3 —
``L_total = L1_load + Σ P_i(L_i^load, L_{i-1}^comp, L_{i-1}^wb) + L_n^comp
+ L_n^wb`` where ``P_i`` resolves to the pipeline bottleneck stage given
buffer double-buffering capabilities.

Energy: Eq. 4–7 — Σ per-access·access-count over compute units, read /
write energies over memory units, plus static power × total latency
(mW × ns ≡ pJ).

Sparsity-support overhead (§V-B): index-memory traffic and capacity
(Eq. 8), IntraBlock input-select multiplexers, misaligned partial-sum
accumulators, and pre-processing zero-bit detection for input sparsity.

The simulation tiles each MVM op via
:func:`repro.core.mapping.reshape_and_compress`, schedules tiles over the
macro organisation per the mapping strategy, and accumulates unit access
counts exactly (cycle-accurate at tile granularity, the level the paper
validates at).  How the *ops* share the organisation in time is the
scheduling layer's job (:mod:`repro.core.schedule`): :func:`simulate`
costs every op, builds scheduler-facing execution profiles, resolves the
:class:`~repro.core.schedule.SchedulePolicy` into a
:class:`~repro.core.schedule.ScheduleResult` (per-op start/end cycles,
critical path, macro shares), and reports the schedule's total.  The
default ``"monolithic"`` policy reproduces the historical op-serial walk
bit-for-bit (:func:`simulate_reference` retains that walk as the test
ground truth).
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..calibrate.profile import CalibrationProfile
from . import mapping as _mapping
from .flexblock import FlexBlockSpec
from .hardware import CIMArch
from .mapping import (MappingSpec, TileGridCache, _band_stats_loop,
                      reshape_and_compress)
from .report import CostReport, OpCost
from .schedule import OpExec, SchedulePolicy, build_schedule
from . import workload as _workload
from .workload import OpNode, Workload

__all__ = ["simulate", "simulate_variants", "simulate_reference",
           "dense_baseline", "dense_twin", "compare", "op_class"]


def op_class(op: OpNode) -> str:
    """Map a workload op to a calibration op class.

    These are the classes the harvest plane measures
    (:func:`repro.calibrate.microbench_kernels`): attention-shaped MVMs
    (the ``attn_*`` *score/context* matmuls, ``kind="matmul"`` in
    :func:`~repro.core.workload.lm_workload` → the flash-attention
    kernel), every other MVM — including the ``attn_{q,k,v,o}``
    projections, which are plain ``fc`` GEMMs executed by the matmul
    kernels — and everything else on the post-processing unit.

    Traced workloads (:mod:`repro.trace`) emit activation×activation
    matmuls under generated names, so weight-free matmuls classify as
    attention regardless of naming — in the hand DAGs the only
    weight-free matmuls are the ``attn_*`` score/context GEMMs, so this
    is a pure generalisation.
    """
    if op.is_mvm or op.kind == "dwconv":
        if op.kind == "matmul" and (op.weights == 0
                                    or op.name.startswith("attn")):
            return "attention"
        return "matmul"
    return "post_proc"


@dataclasses.dataclass
class _Step:
    """One pipeline step: a tile-group loaded, computed, written back."""

    load: float
    comp: float
    wb: float


def _pipeline(steps: List[_Step], overlap: bool) -> float:
    """Eq. 3.  With double buffering (ping-pong weight buffer), step i's
    load overlaps step i-1's compute+write-back:
    ``P_i = max(L_i^load, L_{i-1}^comp + L_{i-1}^wb)``.  Without, stages
    serialise: ``P_i = L_i^load + L_{i-1}^comp + L_{i-1}^wb``."""
    if not steps:
        return 0.0
    if not overlap:
        return float(sum(s.load + s.comp + s.wb for s in steps))
    lat = steps[0].load
    for i in range(1, len(steps)):
        lat += max(steps[i].load, steps[i - 1].comp + steps[i - 1].wb)
    lat += steps[-1].comp + steps[-1].wb
    return float(lat)


_ACC, _READ, _WRITE = 0, 1, 2


class _OpLedger:
    """Per-op access-event buffer (same recording interface as
    :class:`_Accounting`).

    Per-op costing appends events here and :meth:`_Accounting.commit`
    absorbs them in one pass, so the shared ledger dicts see O(1) traffic
    per op instead of one guarded dict lookup per recording call.  Events
    apply in recorded order — float accumulation order (and therefore the
    energy breakdown) is bit-identical to calling the accounting methods
    directly.

    ``pinned`` marks traffic that a resident schedule pays once across
    repeated invocations (weight fill/loads, stored-once index
    metadata).  The flag rides on the event itself — keying on buffer
    *names* would misclassify activation traffic on unified-buffer
    arches, where weights and activations share one ``global_buf``.
    """

    __slots__ = ("events",)

    def __init__(self):
        self.events: List[tuple] = []

    def acc(self, unit: str, n: float) -> None:
        self.events.append((_ACC, unit, n, False))

    def read(self, mem: str, bits: float, *, pinned: bool = False) -> None:
        self.events.append((_READ, mem, bits, pinned))

    def write(self, mem: str, bits: float, *, pinned: bool = False) -> None:
        self.events.append((_WRITE, mem, bits, pinned))


class _Accounting:
    """Access-count ledger across all units."""

    def __init__(self, arch: CIMArch):
        self.arch = arch
        self.compute_acc: Dict[str, float] = {k: 0.0 for k in arch.compute_units}
        self.mem_rd: Dict[str, float] = {k: 0.0 for k in arch.memory_units}
        self.mem_wr: Dict[str, float] = {k: 0.0 for k in arch.memory_units}

    def acc(self, unit: str, n: float) -> None:
        if unit in self.compute_acc and n > 0:
            self.compute_acc[unit] += n

    def read(self, mem: str, bits: float) -> None:
        if mem in self.mem_rd and bits > 0:
            self.mem_rd[mem] += bits / self.arch.mem(mem).width_bits

    def write(self, mem: str, bits: float) -> None:
        if mem in self.mem_wr and bits > 0:
            self.mem_wr[mem] += bits / self.arch.mem(mem).width_bits

    def commit(self, ledger: _OpLedger, *, scale: float = 1.0,
               honor_pins: bool = False) -> None:
        """Absorb one op's buffered events in a single pass.

        ``scale`` multiplies every event — the schedule's invocation
        count (repeated DAG executions repeat every access).  With
        ``honor_pins`` (set for ops a resident schedule keeps loaded),
        events the ledger recorded as ``pinned`` commit once regardless:
        weight fill/loads and stored-once index metadata amortise across
        invocations while activation traffic keeps scaling.
        ``scale == 1.0`` leaves every value bit-for-bit untouched.
        """
        comp, rd, wr = self.compute_acc, self.mem_rd, self.mem_wr
        mems = self.arch.memory_units
        for kind, unit, val, pinned in ledger.events:
            if val <= 0:
                continue
            s = 1.0 if (pinned and honor_pins) else scale
            v = val if s == 1.0 else val * s
            if kind == _ACC:
                if unit in comp:
                    comp[unit] += v
            elif kind == _READ:
                if unit in rd:
                    rd[unit] += v / mems[unit].width_bits
            else:
                if unit in wr:
                    wr[unit] += v / mems[unit].width_bits

    def energy_breakdown(self, latency_cycles: float) -> Dict[str, float]:
        """Eq. 4–7, in pJ."""
        arch = self.arch
        out: Dict[str, float] = {}
        for name, cu in arch.compute_units.items():
            out[name] = cu.energy_pj * self.compute_acc[name]
        for name, mu in arch.memory_units.items():
            out[name] = (mu.read_pj * self.mem_rd[name]
                         + mu.write_pj * self.mem_wr[name])
        # Eq. 7: static energy = P_stat × L_total.  mW × ns = pJ.
        t_ns = latency_cycles * arch.cycle_ns
        out["static"] = arch.static_power_mw() * t_ns
        return out


def _input_buffer(arch: CIMArch) -> str:
    for cand in ("input_buf", "global_buf", "weight_buf"):
        if arch.has_mem(cand):
            return cand
    return next(iter(arch.memory_units))


def _weight_buffer(arch: CIMArch) -> str:
    for cand in ("weight_buf", "global_buf"):
        if arch.has_mem(cand):
            return cand
    return next(iter(arch.memory_units))


def _output_buffer(arch: CIMArch) -> str:
    for cand in ("output_buf", "global_buf", "input_buf"):
        if arch.has_mem(cand):
            return cand
    return next(iter(arch.memory_units))


def _macro_demand(bands: int, waves: int, n_macros: int,
                  bands_per_macro: int) -> int:
    """Macros an op's resident bands (incl. duplication replicas) occupy.

    Multi-wave ops cycle the whole organisation; single-wave ops occupy
    exactly the macros their bands pack into — the subset the
    partitioned scheduler may hand them without changing their cost.
    """
    if waves > 1:
        return n_macros
    return min(n_macros, max(1, math.ceil(bands / bands_per_macro)))


def _mvm_op_cost(
    op: OpNode,
    arch: CIMArch,
    mapping: MappingSpec,
    acct: _OpLedger,
    *,
    input_skip_ratio: float = 0.0,
    block_keep: Optional[np.ndarray] = None,
    tile_cache: Optional[TileGridCache] = None,
) -> OpCost:
    """Cost one MVM op with a *band-packing* schedule.

    Digital CIM accumulates partial sums per sub-array, so the placement
    granularity is a **band** of ``sub_rows`` array rows.  Each N-tile
    (``macro.cols`` output columns) with compressed row extent ``k_eff``
    demands ``ceil(k_eff / sub_rows)`` bands; bands from different tiles
    pack into the same macro (the adder tree + extra accumulators route
    their partial sums separately — §V-B's misaligned-aggregation
    support).  This is where CIM sparsity speedup actually comes from:

    * fewer bands ⇒ fewer waves when weights exceed array capacity;
    * leftover bands ⇒ weight-duplication headroom, splitting the input
      vectors across replicas (§VII-C weight duplication);
    * input sparsity ⇒ shorter effective bit-serial length.
    """
    macro = arch.macro
    grid = reshape_and_compress(op, arch, mapping.reshape,
                                block_keep=block_keep, cache=tile_cache)
    n_macros = arch.n_macros
    org_r, org_c = arch.org
    bands_per_macro = macro.rows // macro.sub_rows

    # ---- effective bit-serial length (input sparsity, §IV-C ③) ------------
    V = max(op.V, 1)
    eff_bits = float(macro.input_bits)
    if arch.input_sparsity_support and input_skip_ratio > 0.0:
        eff_bits = macro.input_bits * (1.0 - input_skip_ratio)
        # OR-tree zero detection scans every input element once per bit
        acct.acc("zero_detect", float(V) * grid.K)
    comp_cycles_per_vec = max(1.0, eff_bits * macro.mac_cycles_per_bit)

    # ---- band demand ---------------------------------------------------------
    # Per N-tile (width = macro.cols), the compressed row profile of its
    # columns sets its band demand; ragged profiles are charged at the
    # tile's max column (fragmentation — unless rearrangement equalised).
    tile_n = grid.tile_n
    nt = max(1, math.ceil(grid.n_eff / tile_n))
    k_cols = grid.k_eff if len(grid.k_eff) else np.array([grid.K])
    if _mapping._REFERENCE:
        bands_sum, n_band_tiles, row_demand, ragged = _band_stats_loop(
            grid.k_eff, grid.K, tile_n, macro.sub_rows)
    else:
        bands_sum, n_band_tiles, row_demand, ragged = grid.band_stats(
            macro.sub_rows)
    B = max(1, bands_sum)                     # total band demand
    # row_demand = Σ over N-tiles of the tile's mean real rows per column:
    # a tile's columns share its band rows, so the per-column mean is that
    # tile's real array-row footprint and the sum is the op's total real
    # row demand — the numerator of row-granular utilisation below.

    # ---- schedule -------------------------------------------------------------
    # spatial:   all macros hold distinct bands; no duplication.
    # duplicate: one org row's worth of macros holds the weights; the
    #            org[0] rows replicate them and split V.  Leftover bands
    #            within a wave add intra-wave duplication headroom.
    if mapping.strategy == "spatial":
        slots = n_macros * bands_per_macro
        waves = math.ceil(B / slots)
        dup = 1
    else:
        row_slots = org_c * bands_per_macro
        waves = math.ceil(B / row_slots)
        dup = org_r
        if waves == 1:
            dup = min(V, org_r * max(1, row_slots // B))
    v_eff = math.ceil(V / dup)

    # ---- per-wave latency (Eq. 3 inner pipeline) --------------------------------
    bands_this_wave = min(B, bands_per_macro)  # per macro, upper bound
    load_cycles = math.ceil(bands_this_wave * macro.sub_rows
                            / macro.load_rows_per_cycle)
    serial_rows = 1.0
    if macro.row_serial:
        # row-serial macros: each resident band is processed in sequence
        # by the shared per-column MAC → compute scales with resident
        # band count (this is where SDP's row-pruning speedup comes from).
        # IntraBlock column compression keeps the row count low but each
        # compressed row streams its ``intra_fanin`` broadcast candidates
        # bit-serially — the mux picks per-cell — so intra compression
        # saves ENERGY (fewer array rows) but not broadcast TIME.
        holders = n_macros if mapping.strategy == "spatial" else org_c
        serial_rows = float(min(bands_per_macro,
                                max(1, math.ceil(B / (waves * holders)))))
        serial_rows *= grid.intra_fanin
    comp_cycles = v_eff * comp_cycles_per_vec * serial_rows
    # partial sums accumulate on-chip (adder tree / accumulators); the
    # output buffer receives post-processed activation-quantized values
    out_bits_per_vec = tile_n * macro.input_bits
    wb_bus = arch.mem(_output_buffer(arch)).width_bits
    wb_cycles = math.ceil(v_eff * out_bits_per_vec / wb_bus)
    overlap = arch.mem(_weight_buffer(arch)).ping_pong
    steps = [_Step(load_cycles, comp_cycles, wb_cycles) for _ in range(waves)]
    lat = _pipeline(steps, overlap)

    # ---- compute-unit access counting --------------------------------------------
    # cim_array fires per (band × vector × bit); all V vectors pass through
    # some replica, so totals are duplication-invariant (dup trades time
    # for parallel energy) — but fragmentation (ceil to bands) costs real
    # energy, matching Fig. 9's alignment findings.
    subs_per_band = macro.cols // macro.sub_cols
    band_vec_cycles = float(B) * subs_per_band * V * comp_cycles_per_vec
    acct.acc("cim_array", band_vec_cycles)
    acct.acc("adder_tree", float(B) * V * comp_cycles_per_vec)
    acct.acc("shift_add", float(n_band_tiles or 1) * V)
    # cross-wave / cross-macro partial-sum accumulation
    k_span = max(1, math.ceil((int(k_cols.max()) if len(k_cols) else grid.K)
                              / macro.rows))
    if k_span > 1 or waves > 1:
        acct.acc("accumulator", float(max(k_span - 1, waves - 1)) * V
                 * max(grid.n_eff, 1) / max(nt, 1))

    # pre-processing: each input element bit-serial converted once per wave
    acct.acc("pre_proc", float(V) * grid.K)

    # ---- memory traffic -------------------------------------------------------------
    ibuf, wbuf, obuf = _input_buffer(arch), _weight_buffer(arch), _output_buffer(arch)
    w_bits = float(np.sum(grid.k_eff)) * macro.weight_bits
    # weight traffic is pinned: a resident schedule pays it once however
    # many invocations run (the activation traffic below always recurs)
    acct.write(wbuf, w_bits, pinned=True)         # filled once (off-chip DMA)
    acct.read(wbuf, w_bits * dup, pinned=True)    # array loads, × replicas
    # inputs: FullBlock row compression cuts traffic; IntraBlock does not
    # (each compressed row receives its intra_fanin broadcast candidates).
    mean_k = float(np.mean(k_cols)) if len(k_cols) else float(grid.K)
    in_bits = float(V) * mean_k * grid.intra_fanin * macro.input_bits
    acct.read(ibuf, in_bits)
    o_bits = float(V) * max(grid.n_eff, 1) * float(macro.input_bits)
    acct.write(obuf, o_bits)
    if k_span > 1:  # partial-sum spill/refill across K spans (32b wide)
        spill = float(V) * max(grid.n_eff, 1) * 32.0 * (k_span - 1)
        acct.read(obuf, spill)
        acct.write(obuf, spill)

    # ---- sparsity support (§V-B) ------------------------------------------------------
    spec: FlexBlockSpec = op.sparsity.bind((op.K, op.N))
    idx_bits = 0
    if not spec.is_dense and arch.weight_sparsity_support:
        idx_bits = spec.index_storage_bits((op.K, op.N))          # Eq. 8
        acct.write("index_mem", float(idx_bits), pinned=True)     # stored once
        acct.read("index_mem", float(idx_bits))                   # streamed once/op
        if grid.intra_fanin > 1 and len(k_cols):
            # mux select: every compressed row picks 1-of-fanin per vector
            acct.acc("mux_index", mean_k * V)
        if grid.misaligned or ragged:
            acct.acc("sparse_accum", float(V) * max(grid.n_eff, 1))

    # utilisation: real weight rows (× replicas) over provisioned capacity
    provisioned = waves * (n_macros * bands_per_macro) * macro.sub_rows
    util = min(1.0, row_demand * dup / max(provisioned, 1))
    # scheduling metadata: the op's resident band footprint (replicas
    # included) and the macros those bands actually occupy — the demand
    # the partitioned scheduler packs disjoint subsets from (an op never
    # benefited from macros its bands don't touch, so granting exactly
    # this share leaves latency and access counts untouched).
    bands_resident = B * dup
    m_need = _macro_demand(bands_resident, waves, n_macros, bands_per_macro)
    return OpCost(name=op.name, kind=op.kind, latency_cycles=lat,
                  macs=op.macs, tiles=n_band_tiles or 1, waves=waves,
                  utilization=util, index_bits=idx_bits,
                  occupancy=grid.mean_occupancy,
                  bands=bands_resident, load_cycles=float(load_cycles),
                  macros=m_need, macro_share=m_need / n_macros)


def _other_op_cost(op: OpNode, arch: CIMArch, acct: _OpLedger) -> OpCost:
    """Non-MVM ops (pool / act / add / norm / embed / …) run on post_proc.

    Buffer traffic is priced at the macro's activation width
    (``macro.input_bits``) — post-processing consumes/produces the same
    quantised activations the arrays chew, so 4-bit / 16-bit arch sweeps
    see consistently scaled post-proc traffic.

    Kinds outside :data:`repro.core.workload.OTHER_KINDS` (traced graphs
    surface fused elementwise primitives the hand DAGs never emit) warn
    once and are priced exactly like plain elementwise work — an
    explicit, visible fallback rather than a crash or silent zero.
    """
    _workload.warn_unknown_kind(op.kind)
    post = arch.unit("post_proc")
    act_bits = float(arch.macro.input_bits)
    n = max(op.elements, 1)
    cycles = math.ceil(n / max(post.width, 1))
    acct.acc("post_proc", float(n))
    acct.read(_input_buffer(arch), float(n) * act_bits)
    acct.write(_output_buffer(arch), float(n) * act_bits)
    if op.kind == "embed":
        acct.read(_weight_buffer(arch), float(n) * act_bits)
    return OpCost(name=op.name, kind=op.kind, latency_cycles=float(cycles),
                  macs=0, tiles=0, waves=0, utilization=0.0, index_bits=0,
                  occupancy=0.0)


def _cost_ops(
    arch: CIMArch,
    workload: Workload,
    mapping: MappingSpec,
    *,
    input_sparsity: Optional[Dict[str, float]],
    masks: Optional[Dict[str, np.ndarray]],
    profile: Optional[CalibrationProfile],
    tile_cache: Optional[TileGridCache],
) -> List[Tuple[OpNode, Optional[OpCost], _OpLedger]]:
    """Per-op costing pass, shared by :func:`simulate` and
    :func:`simulate_reference` so the scheduling layer can be proved
    behavior-preserving against the retained op-serial aggregation.

    Returns ``(op, OpCost | None, ledger)`` triples in DAG insertion
    order; ``None`` marks ops outside the arch's ``eval_scope`` (Table
    I's conv-only setups), which carry zero cost and only convey
    dependencies.
    """
    scoped = {o.name for o in workload.mvm_ops(arch.eval_scope)}
    out: List[Tuple[OpNode, Optional[OpCost], _OpLedger]] = []
    for op in workload.nodes.values():
        led = _OpLedger()
        if (op.is_mvm or op.kind == "dwconv") and op.name in scoped:
            oc = _mvm_op_cost(op, arch, mapping, led,
                              input_skip_ratio=(input_sparsity or {}).get(op.name, 0.0),
                              block_keep=(masks or {}).get(op.name),
                              tile_cache=tile_cache)
        elif arch.eval_scope == "conv_only":
            # Table I: MARS evaluates conv layers only — everything else
            # is outside the measured scope entirely.
            oc = None
        else:
            oc = _other_op_cost(op, arch, led)
        if oc is not None and profile is not None:
            eff = profile.efficiency_for(op_class(op))
            if eff != 1.0:
                oc.latency_cycles /= eff
                oc.load_cycles /= eff
        out.append((op, oc, led))
    return out


def _op_execs(arch: CIMArch,
              costed: List[Tuple[OpNode, Optional[OpCost], _OpLedger]],
              ) -> Dict[str, OpExec]:
    """Scheduler-facing execution profiles for every DAG node."""
    execs: Dict[str, OpExec] = {}
    for op, oc, _ in costed:
        if oc is None:
            execs[op.name] = OpExec(name=op.name, duration=0.0)
        elif oc.tiles > 0:                   # MVM on the CIM organisation
            # single-wave pipelines are load+comp+wb, so hoisting the
            # load (resident steady state) subtracts it exactly
            steady = (oc.latency_cycles - oc.load_cycles
                      if oc.waves <= 1 else oc.latency_cycles)
            execs[op.name] = OpExec(
                name=op.name, duration=oc.latency_cycles, steady=steady,
                load_cycles=oc.load_cycles, macros=oc.macros,
                bands=oc.bands, waves=oc.waves)
        else:                                # post-processing unit
            execs[op.name] = OpExec(name=op.name,
                                    duration=oc.latency_cycles,
                                    steady=oc.latency_cycles,
                                    uses_post=True)
    return execs


def _finish_report(
    arch: CIMArch,
    workload: Workload,
    mapping: MappingSpec,
    policy: SchedulePolicy,
    costed: List[Tuple[OpNode, Optional[OpCost], _OpLedger]],
) -> CostReport:
    """Schedule + aggregate one costed op list into a :class:`CostReport`.

    This is :func:`simulate`'s tail, factored out so
    :func:`simulate_variants` can re-aggregate ONE ``_cost_ops`` pass
    under several ``(profile, schedule)`` variants.  Every float
    operation happens in the same order as the historical inline code,
    so the extraction is bit-identical by construction.  Mutates the
    ``OpCost`` objects in ``costed`` (start/end cycles) — variant
    callers must pass per-variant copies.
    """
    bands_per_macro = arch.macro.rows // arch.macro.sub_rows
    sched = build_schedule(workload, policy, _op_execs(arch, costed),
                           n_macros=arch.n_macros,
                           band_slots=arch.n_macros * bands_per_macro)

    # mirror placements onto the per-op costs (steady-state invocation;
    # the resident preload sits before cycle 0 of this timeline)
    placed = {s.name: s for s in sched.ops}
    op_costs: List[OpCost] = []
    for op, oc, _ in costed:
        if oc is None:
            continue
        s = placed[op.name]
        oc.start_cycle, oc.end_cycle = s.start, s.end
        op_costs.append(oc)

    # commit access ledgers in DAG order, scaled by the invocation count;
    # a resident schedule honors the ledger's pinned events (MVM weight
    # fill/loads, stored-once index metadata) so only the first
    # invocation pays them — activation traffic recurs either way
    acct = _Accounting(arch)
    n_inv = float(policy.invocations)
    for op, oc, led in costed:
        acct.commit(led, scale=n_inv,
                    honor_pins=sched.resident and oc is not None
                    and oc.tiles > 0)

    total_cycles = sched.total_cycles
    energy = acct.energy_breakdown(total_cycles)
    mvm_costs = [c for c in op_costs if c.tiles > 0]
    util = (sum(c.utilization * c.macs for c in mvm_costs)
            / max(sum(c.macs for c in mvm_costs), 1)) if mvm_costs else 0.0
    idx_bits = sum(c.index_bits for c in op_costs)
    cap = arch.index_capacity_bits()
    return CostReport(
        arch=arch.name,
        workload=workload.name,
        mapping=mapping.strategy,
        latency_cycles=total_cycles,
        latency_ms=total_cycles * arch.cycle_ns * 1e-6,
        energy_pj=energy,
        total_energy_uj=sum(energy.values()) * 1e-6,
        utilization=util,
        op_costs=op_costs,
        index_storage_bits=idx_bits,
        # index_capacity_bits() already returns bits — the historical
        # `cap * 64` slack silently passed workloads 64x over capacity
        index_capacity_ok=(cap == 0 or idx_bits <= cap),
        schedule=sched,
    )


def simulate(
    arch: CIMArch,
    workload: Workload,
    mapping: MappingSpec,
    *,
    input_sparsity: Optional[Dict[str, float]] = None,
    masks: Optional[Dict[str, np.ndarray]] = None,
    profile: Optional[CalibrationProfile] = None,
    tile_cache: Optional[TileGridCache] = None,
    schedule: Optional[SchedulePolicy] = None,
) -> CostReport:
    """Run the CIMinus cost simulation.

    ``input_sparsity`` maps op name → skippable-bit ratio (from
    :mod:`repro.core.input_sparsity` profiling).
    ``masks`` maps op name → FullBlock block keep-grid from the pruning
    workflow; otherwise seeded random grids with exact Φ are synthesised
    (the paper's auto-generated mask path).
    ``profile`` is an optional measured :class:`CalibrationProfile`
    (see :mod:`repro.calibrate`): each op's latency is divided by the
    profile's efficiency factor for its :func:`op_class` — a class
    achieving half the fitted roofline takes twice the analytic latency
    — and the static-energy term follows the stretched schedule.
    Dynamic energy is access-count-based and therefore unchanged.
    ``profile=None`` (and any profile with all-1.0 efficiencies, like
    the bundled default) reproduces the analytic model bit-for-bit.
    ``tile_cache`` overrides the process-wide
    :class:`~repro.core.mapping.TileGridCache` the tiling hot path
    memoises into (``None`` = share the module default, which is what
    sweep workers rely on to warm once per process).
    ``schedule`` selects the multi-macro scheduling policy
    (:mod:`repro.core.schedule`): ``None`` (= the default
    ``SchedulePolicy()``) is the historical op-serial walk on the whole
    organisation, bit-for-bit; ``"partitioned"`` overlaps independent
    DAG branches on disjoint macro subsets; ``"resident"`` pins weights
    across ``invocations`` repeated executions.  The resolved
    :class:`~repro.core.schedule.ScheduleResult` is attached to the
    report and mirrored into each op's ``start_cycle`` / ``end_cycle``.
    """
    arch.validate()
    policy = schedule if schedule is not None else SchedulePolicy()
    costed = _cost_ops(arch, workload, mapping,
                       input_sparsity=input_sparsity, masks=masks,
                       profile=profile, tile_cache=tile_cache)
    return _finish_report(arch, workload, mapping, policy, costed)


def _apply_profile(
    costed: List[Tuple[OpNode, Optional[OpCost], _OpLedger]],
    profile: Optional[CalibrationProfile],
) -> List[Tuple[OpNode, Optional[OpCost], _OpLedger]]:
    """Copy a profile-less ``_cost_ops`` result and apply ``profile``.

    A profile only ever divides each op's ``latency_cycles`` /
    ``load_cycles`` at the very end of :func:`_cost_ops`, so dividing
    the same floats here produces bit-identical values.  OpCosts are
    shallow-copied even for ``profile=None`` because
    :func:`_finish_report` mutates their start/end cycles per variant;
    ledgers are immutable under aggregation and shared.
    """
    out: List[Tuple[OpNode, Optional[OpCost], _OpLedger]] = []
    for op, oc, led in costed:
        if oc is not None:
            oc = copy.copy(oc)
            if profile is not None:
                eff = profile.efficiency_for(op_class(op))
                if eff != 1.0:
                    oc.latency_cycles /= eff
                    oc.load_cycles /= eff
        out.append((op, oc, led))
    return out


def simulate_variants(
    arch: CIMArch,
    workload: Workload,
    mapping: MappingSpec,
    *,
    input_sparsity: Optional[Dict[str, float]] = None,
    masks: Optional[Dict[str, np.ndarray]] = None,
    tile_cache: Optional[TileGridCache] = None,
    variants: List[Tuple[Optional[CalibrationProfile],
                         Optional[SchedulePolicy]]],
) -> List[CostReport]:
    """Evaluate one grid point under several ``(profile, schedule)``
    variants, paying the per-op costing pass (tiling, band packing,
    access ledgers — the dominant cost) exactly once.

    Returns one :class:`CostReport` per variant, in order, each
    bit-identical to ``simulate(..., profile=p, schedule=s)`` — the
    batched-evaluation contract the explore plane's differential tests
    pin down.  Profiles are applied as a post-pass (see
    :func:`_apply_profile`) because :func:`_cost_ops` itself only
    touches profile efficiencies after all costing is done.
    """
    arch.validate()
    costed = _cost_ops(arch, workload, mapping,
                       input_sparsity=input_sparsity, masks=masks,
                       profile=None, tile_cache=tile_cache)
    reports: List[CostReport] = []
    for prof, sched_pol in variants:
        policy = sched_pol if sched_pol is not None else SchedulePolicy()
        reports.append(_finish_report(arch, workload, mapping, policy,
                                      _apply_profile(costed, prof)))
    return reports


def simulate_reference(
    arch: CIMArch,
    workload: Workload,
    mapping: MappingSpec,
    *,
    input_sparsity: Optional[Dict[str, float]] = None,
    masks: Optional[Dict[str, np.ndarray]] = None,
    profile: Optional[CalibrationProfile] = None,
    tile_cache: Optional[TileGridCache] = None,
) -> CostReport:
    """The pre-scheduler op-serial simulator, retained as ground truth.

    Per-op costing is shared with :func:`simulate`; the *aggregation*
    replays the historical loop verbatim — every op on the whole
    organisation, serialised in DAG insertion order, total latency the
    plain left-to-right sum, ledgers committed per op, no schedule
    built.  ``tests/test_schedule.py`` asserts the ``"monolithic"``
    policy reproduces this bit-for-bit across patterns × strategies ×
    workloads (the PR-4 ``reference_loops`` discipline).  Test-only —
    production callers use :func:`simulate`.
    """
    arch.validate()
    acct = _Accounting(arch)
    op_costs: List[OpCost] = []
    cum = 0.0
    for op, oc, led in _cost_ops(arch, workload, mapping,
                                 input_sparsity=input_sparsity, masks=masks,
                                 profile=profile, tile_cache=tile_cache):
        if oc is None:
            continue
        acct.commit(led)
        oc.start_cycle = cum
        cum = cum + oc.latency_cycles
        oc.end_cycle = cum
        op_costs.append(oc)

    # Ops are data-dependent along the DAG, so they serialise at op
    # granularity; intra-op load/compute/wb overlap is already inside the
    # per-op Eq. 3 pipeline.
    total_cycles = float(sum(c.latency_cycles for c in op_costs))

    energy = acct.energy_breakdown(total_cycles)
    mvm_costs = [c for c in op_costs if c.tiles > 0]
    util = (sum(c.utilization * c.macs for c in mvm_costs)
            / max(sum(c.macs for c in mvm_costs), 1)) if mvm_costs else 0.0
    idx_bits = sum(c.index_bits for c in op_costs)
    cap = arch.index_capacity_bits()
    return CostReport(
        arch=arch.name,
        workload=workload.name,
        mapping=mapping.strategy,
        latency_cycles=total_cycles,
        latency_ms=total_cycles * arch.cycle_ns * 1e-6,
        energy_pj=energy,
        total_energy_uj=sum(energy.values()) * 1e-6,
        utilization=util,
        op_costs=op_costs,
        index_storage_bits=idx_bits,
        index_capacity_ok=(cap == 0 or idx_bits <= cap),
        schedule=None,
    )


def dense_twin(arch: CIMArch, workload: Workload) -> tuple:
    """The dense counterpart of a (arch, workload) pair: sparsity
    stripped from every op, sparsity-support hardware disabled.

    Shared by :func:`dense_baseline` and the exploration engine's
    baseline jobs (``repro.explore.job.ExploreJob.dense``) so the two
    can never diverge."""
    dense_wl = Workload(workload.name + "-dense")
    for n in workload.nodes.values():
        dn = copy.copy(n)
        dn.sparsity = FlexBlockSpec()
        dense_wl.nodes[dn.name] = dn
    dense_arch = arch.replace(weight_sparsity_support=False,
                              input_sparsity_support=False)
    return dense_arch, dense_wl


def dense_baseline(arch: CIMArch, workload: Workload,
                   mapping: MappingSpec,
                   profile: Optional[CalibrationProfile] = None,
                   schedule: Optional[SchedulePolicy] = None) -> CostReport:
    """The paper's dense baseline: same architecture configuration, no
    sparsity-support hardware engaged, dense weights.  ``schedule``
    follows the sparse evaluation's policy so comparisons stay
    like-for-like."""
    dense_arch, dense_wl = dense_twin(arch, workload)
    return simulate(dense_arch, dense_wl, mapping, profile=profile,
                    schedule=schedule)


def compare(sparse: CostReport, dense: CostReport) -> Dict[str, float]:
    """Speedup & energy saving vs. the dense baseline (paper Fig. 6/8)."""
    return {
        "speedup": dense.latency_cycles / max(sparse.latency_cycles, 1e-9),
        "energy_saving": sum(dense.energy_pj.values())
        / max(sum(sparse.energy_pj.values()), 1e-9),
        "utilization": sparse.utilization,
    }
