"""Workload description interface (paper §IV-C, Fig. 5(a)).

A sparse DNN workload is a DAG whose nodes are operations and whose edges
carry producer→consumer relationships.  MVM-backed ops (conv / fc /
matmul) carry a reshaped-matrix view (K contraction rows × N output
columns × V input vectors) that the mapper tiles onto CIM arrays; other
ops (pool / act / add / norm) are routed to the post-processing unit.

Builders are provided for the paper's evaluation models (VGG16,
ResNet18/50, MobileNetV2 at CIFAR or ImageNet resolutions) and for
lowering the repo's LM architecture configs into MVM DAGs
(:func:`lm_workload`).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

from .flexblock import FlexBlockSpec, dense_spec

__all__ = ["OpNode", "Workload", "WorkloadIssue", "vgg16", "resnet18",
           "resnet50", "mobilenet_v2", "lm_workload", "MODEL_BUILDERS",
           "MVM_KINDS", "OTHER_KINDS", "warn_unknown_kind"]

MVM_KINDS = ("conv", "fc", "matmul")

# Non-MVM kinds the cost model prices on the post-processing unit.  The
# hand builders emit the first five; traced graphs (repro.trace) also
# surface the rest.  Kinds outside this vocabulary are priced as plain
# elementwise work after a one-time warning (see warn_unknown_kind) —
# an explicit fallback instead of mispricing or crashing.
OTHER_KINDS = frozenset({
    "pool", "act", "add", "norm", "embed",
    "softmax", "reduce", "sort", "gather", "scatter", "elementwise",
})

_warned_kinds: set = set()


def warn_unknown_kind(kind: str) -> bool:
    """True (with a once-per-kind RuntimeWarning) for op kinds outside
    the priced vocabulary; callers fall back to elementwise pricing."""
    import warnings

    if kind in MVM_KINDS or kind == "dwconv" or kind in OTHER_KINDS:
        return False
    if kind not in _warned_kinds:
        _warned_kinds.add(kind)
        warnings.warn(
            f"unknown op kind {kind!r}: pricing as elementwise on the "
            "post-processing unit", RuntimeWarning, stacklevel=3)
    return True


@dataclasses.dataclass
class OpNode:
    """One operation node.

    For MVM kinds, the reshaped two-dimensional weight matrix view is
    ``K × N`` with ``V`` input vectors pushed through it (im2col for
    convs: K = Cin·Kh·Kw, N = Cout, V = Hout·Wout·batch).
    ``c_in`` is retained so channel-wise FlexBlock patterns can bind.
    """

    name: str
    kind: str                        # conv|dwconv|fc|matmul|pool|act|add|norm|embed
    inputs: Tuple[str, ...] = ()
    K: int = 0
    N: int = 0
    V: int = 0
    c_in: int = 0
    kernel: Tuple[int, int] = (1, 1)
    elements: int = 0                # for non-MVM ops: elements processed
    sparsity: FlexBlockSpec = dataclasses.field(default_factory=dense_spec)
    weight_count: Optional[int] = None
    prunable: bool = True            # e.g. depthwise convs may be excluded

    @property
    def is_mvm(self) -> bool:
        return self.kind in MVM_KINDS

    @property
    def macs(self) -> int:
        if self.is_mvm:
            return self.K * self.N * self.V
        return 0

    @property
    def weights(self) -> int:
        if self.weight_count is not None:
            return self.weight_count
        return self.K * self.N if self.is_mvm else 0


class WorkloadIssue(NamedTuple):
    """One structural problem found by :meth:`Workload.validate`.

    ``kind`` is one of ``dangling-edge`` / ``name-mismatch`` / ``cycle``
    / ``isolated``; ``path`` is an object path relative to the workload
    (e.g. ``nodes['s0b0_add'].inputs[1]``).  Kept dependency-free so the
    core stays importable without :mod:`repro.analysis`.
    """

    kind: str
    path: str
    message: str


class Workload:
    """An ordered DAG of :class:`OpNode`."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, OpNode] = {}
        # content digest of the traced program this DAG was lowered from
        # (repro.trace); None for hand-built workloads.  Part of the
        # explore-cache key so traced DAGs are addressed by program.
        self.source_digest: Optional[str] = None

    # -- construction --------------------------------------------------------
    def add(self, node: OpNode) -> OpNode:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name!r}")
        for inp in node.inputs:
            if inp not in self.nodes:
                raise ValueError(f"{node.name}: unknown input {inp!r}")
        self.nodes[node.name] = node
        return node

    def conv(self, name, cin, cout, hw, k=3, stride=1, inputs=(),
             depthwise=False, prunable=True):
        """Add a conv; returns (node, out_hw)."""
        out_hw = math.ceil(hw / stride)
        v = out_hw * out_hw
        if depthwise:
            node = OpNode(name=name, kind="dwconv", inputs=tuple(inputs),
                          K=k * k, N=cout, V=v, c_in=cin, kernel=(k, k),
                          weight_count=k * k * cout, prunable=False)
        else:
            node = OpNode(name=name, kind="conv", inputs=tuple(inputs),
                          K=cin * k * k, N=cout, V=v, c_in=cin,
                          kernel=(k, k), prunable=prunable)
        self.add(node)
        return node, out_hw

    def fc(self, name, cin, cout, inputs=(), v=1, prunable=True):
        return self.add(OpNode(name=name, kind="fc", inputs=tuple(inputs),
                               K=cin, N=cout, V=v, c_in=cin,
                               prunable=prunable))

    def simple(self, name, kind, elements, inputs=()):
        return self.add(OpNode(name=name, kind=kind, elements=elements,
                               inputs=tuple(inputs)))

    # -- DAG structure --------------------------------------------------------
    def successors(self) -> Dict[str, List[str]]:
        """Producer → consumers adjacency (insertion-ordered)."""
        succ: Dict[str, List[str]] = {name: [] for name in self.nodes}
        for node in self.nodes.values():
            for inp in node.inputs:
                if inp not in succ:
                    raise ValueError(
                        f"{node.name}: unknown input {inp!r}")
                succ[inp].append(node.name)
        return succ

    def topo_order(self) -> List[str]:
        """Topological op order (Kahn), stable w.r.t. insertion order.

        :meth:`add` already forbids forward references, so workloads built
        through the public API are topologically ordered by construction —
        but the scheduler (:mod:`repro.core.schedule`) must not trust
        callers that splice ``nodes`` directly, so cycles raise
        ``ValueError`` here.
        """
        succ = self.successors()
        indeg = {name: len(node.inputs) for name, node in self.nodes.items()}
        ready = deque(name for name, d in indeg.items() if d == 0)
        order: List[str] = []
        while ready:
            name = ready.popleft()
            order.append(name)
            for s in succ[name]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(order) != len(self.nodes):
            stuck = sorted(n for n, d in indeg.items() if d > 0)
            raise ValueError(
                f"workload {self.name!r} has a dependency cycle "
                f"involving {stuck}")
        return order

    def levels(self) -> List[List[str]]:
        """ASAP dependency levels: ``levels()[i]`` holds the ops whose
        longest input chain has exactly ``i`` predecessors — ops within a
        level are mutually independent and may run concurrently (the
        grouping the partitioned scheduler exploits).  Raises on cycles.
        """
        depth: Dict[str, int] = {}
        for name in self.topo_order():
            node = self.nodes[name]
            depth[name] = (max(depth[i] for i in node.inputs) + 1
                           if node.inputs else 0)
        out: List[List[str]] = [[] for _ in range(max(depth.values(), default=-1) + 1)]
        for name in self.nodes:              # insertion order within levels
            out[depth[name]].append(name)
        return out

    def validate(self) -> List["WorkloadIssue"]:
        """Exhaustive structural audit of the DAG.

        Unlike :meth:`topo_order`, which raises on the first cycle, this
        reports *every* problem at once — dangling edge targets, dict-key /
        node-name mismatches (the splice hazard duplicate detection in
        :meth:`add` cannot see), cycle members, and isolated ops — as
        :class:`WorkloadIssue` records.  ``repro.analysis`` converts these
        into coded diagnostics (CIM301–CIM304); library callers can treat a
        non-empty ``[i for i in w.validate() if i.kind != "isolated"]`` as
        fatal.
        """
        issues: List[WorkloadIssue] = []
        for key, node in self.nodes.items():
            if key != node.name:
                issues.append(WorkloadIssue(
                    "name-mismatch", f"nodes[{key!r}]",
                    f"dict key {key!r} != node.name {node.name!r}"))
            for i, inp in enumerate(node.inputs):
                if inp not in self.nodes:
                    issues.append(WorkloadIssue(
                        "dangling-edge", f"nodes[{key!r}].inputs[{i}]",
                        f"{key!r} consumes unknown op {inp!r}"))
        # Kahn over the *resolvable* edges so cycles are reported even
        # when dangling edges coexist; stuck nodes are the cycle members.
        indeg = {k: sum(1 for i in n.inputs if i in self.nodes)
                 for k, n in self.nodes.items()}
        consumers: Dict[str, List[str]] = {k: [] for k in self.nodes}
        for k, n in self.nodes.items():
            for inp in n.inputs:
                if inp in self.nodes:
                    consumers[inp].append(k)
        ready = deque(k for k, d in indeg.items() if d == 0)
        visited = 0
        while ready:
            k = ready.popleft()
            visited += 1
            for c in consumers[k]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if visited != len(self.nodes):
            stuck = [k for k, d in indeg.items() if d > 0]
            for k in stuck:
                issues.append(WorkloadIssue(
                    "cycle", f"nodes[{k!r}]",
                    f"{k!r} is part of a dependency cycle "
                    f"(members: {sorted(stuck)})"))
        if len(self.nodes) > 1:
            for k, n in self.nodes.items():
                if not n.inputs and not consumers[k]:
                    issues.append(WorkloadIssue(
                        "isolated", f"nodes[{k!r}]",
                        f"{k!r} has no inputs and no consumers — "
                        f"disconnected from the DAG"))
        return issues

    # -- queries --------------------------------------------------------------
    def mvm_ops(self, scope: str = "all") -> List[OpNode]:
        ops = [n for n in self.nodes.values() if n.is_mvm or n.kind == "dwconv"]
        if scope == "conv_only":
            ops = [n for n in ops if n.kind in ("conv", "dwconv")]
        return ops

    def other_ops(self) -> List[OpNode]:
        return [n for n in self.nodes.values()
                if not n.is_mvm and n.kind != "dwconv"]

    def total_macs(self, scope: str = "all") -> int:
        return sum(n.macs for n in self.mvm_ops(scope))

    def total_weights(self) -> int:
        return sum(n.weights for n in self.nodes.values())

    def set_sparsity(self, spec, *,
                     kinds: Iterable[str] = ("conv", "fc", "matmul")) -> "Workload":
        """Assign a FlexBlock spec to every prunable MVM op (in place).

        ``spec`` is either a :class:`FlexBlockSpec` or a callable
        ``op -> FlexBlockSpec`` for per-op binding (e.g. channel-wise
        patterns whose block height is the op's own ``c_in``).
        """
        for n in self.nodes.values():
            if n.kind in kinds and n.prunable:
                n.sparsity = spec(n) if callable(spec) else spec
        return self

    def __len__(self):
        return len(self.nodes)

    def __repr__(self):
        return (f"Workload({self.name!r}, ops={len(self.nodes)}, "
                f"macs={self.total_macs():.3e}, weights={self.total_weights():.3e})")


# ---------------------------------------------------------------------------
# Paper evaluation models.
# ---------------------------------------------------------------------------

def vgg16(img: int = 32, num_classes: int = 100) -> Workload:
    """VGG16 (CIFAR variant when img=32, ImageNet when img=224)."""
    w = Workload(f"vgg16-{img}")
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    hw, cin, prev, i = img, 3, (), 0
    for v in cfg:
        if v == "M":
            hw //= 2
            node = w.simple(f"pool{i}", "pool", cin * hw * hw, inputs=prev)
            prev = (node.name,)
        else:
            node, hw = w.conv(f"conv{i}", cin, v, hw, k=3, inputs=prev)
            act = w.simple(f"relu{i}", "act", v * hw * hw, inputs=(node.name,))
            prev, cin = (act.name,), v
            i += 1
    flat = cin * hw * hw
    if img >= 224:
        f1 = w.fc("fc1", flat, 4096, inputs=prev)
        f2 = w.fc("fc2", 4096, 4096, inputs=(f1.name,))
        w.fc("fc3", 4096, num_classes, inputs=(f2.name,))
    else:
        f1 = w.fc("fc1", flat, 512, inputs=prev)
        w.fc("fc2", 512, num_classes, inputs=(f1.name,))
    return w


def _resnet(name: str, blocks, bottleneck: bool, img: int,
            num_classes: int) -> Workload:
    w = Workload(f"{name}-{img}")
    stem_stride = 2 if img >= 224 else 1
    node, hw = w.conv("stem", 3, 64, img, k=7 if img >= 224 else 3,
                      stride=stem_stride)
    prev = (node.name,)
    if img >= 224:
        hw //= 2
        p = w.simple("stem_pool", "pool", 64 * hw * hw, inputs=prev)
        prev = (p.name,)
    cin = 64
    expansion = 4 if bottleneck else 1
    for stage, (n_blocks, width) in enumerate(zip(blocks, (64, 128, 256, 512))):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            tag = f"s{stage}b{b}"
            if bottleneck:
                c1, hw1 = w.conv(f"{tag}_c1", cin, width, hw, k=1, inputs=prev)
                c2, hw2 = w.conv(f"{tag}_c2", width, width, hw1, k=3,
                                 stride=stride, inputs=(c1.name,))
                c3, hw3 = w.conv(f"{tag}_c3", width, width * 4, hw2, k=1,
                                 inputs=(c2.name,))
                out_c, out_hw, last = width * 4, hw3, c3
            else:
                c1, hw1 = w.conv(f"{tag}_c1", cin, width, hw, k=3,
                                 stride=stride, inputs=prev)
                c2, hw2 = w.conv(f"{tag}_c2", width, width, hw1, k=3,
                                 inputs=(c1.name,))
                out_c, out_hw, last = width, hw2, c2
            sc_inputs = [last.name]
            if stride != 1 or cin != out_c:
                sc, _ = w.conv(f"{tag}_sc", cin, out_c, hw, k=1,
                               stride=stride, inputs=prev)
                sc_inputs.append(sc.name)
            add = w.simple(f"{tag}_add", "add", out_c * out_hw * out_hw,
                           inputs=tuple(sc_inputs))
            prev, cin, hw = (add.name,), out_c, out_hw
    gap = w.simple("gap", "pool", cin, inputs=prev)
    w.fc("fc", cin, num_classes, inputs=(gap.name,))
    return w


def resnet18(img: int = 32, num_classes: int = 100) -> Workload:
    return _resnet("resnet18", (2, 2, 2, 2), False, img, num_classes)


def resnet50(img: int = 32, num_classes: int = 100) -> Workload:
    return _resnet("resnet50", (3, 4, 6, 3), True, img, num_classes)


def mobilenet_v2(img: int = 32, num_classes: int = 100) -> Workload:
    """MobileNetV2: inverted residuals; depthwise convs are not prunable
    (§VII-B restricts pruning to standard convs)."""
    w = Workload(f"mobilenetv2-{img}")
    node, hw = w.conv("stem", 3, 32, img, k=3, stride=2 if img >= 224 else 1)
    prev, cin = (node.name,), 32
    # (expansion t, out channels c, repeats n, stride s)
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for i, (t, c, n, s) in enumerate(cfg):
        for j in range(n):
            stride = s if j == 0 else 1
            tag = f"ir{i}_{j}"
            hidden = cin * t
            cur = prev
            if t != 1:
                e, _ = w.conv(f"{tag}_exp", cin, hidden, hw, k=1, inputs=cur)
                cur = (e.name,)
            d, hw2 = w.conv(f"{tag}_dw", hidden, hidden, hw, k=3,
                            stride=stride, inputs=cur, depthwise=True)
            p, _ = w.conv(f"{tag}_pw", hidden, c, hw2, k=1, inputs=(d.name,))
            if stride == 1 and cin == c:
                a = w.simple(f"{tag}_add", "add", c * hw2 * hw2,
                             inputs=(p.name, prev[0]))
                prev = (a.name,)
            else:
                prev = (p.name,)
            cin, hw = c, hw2
    head, _ = w.conv("head", cin, 1280, hw, k=1, inputs=prev)
    gap = w.simple("gap", "pool", 1280, inputs=(head.name,))
    w.fc("fc", 1280, num_classes, inputs=(gap.name,))
    return w


# ---------------------------------------------------------------------------
# LM architecture lowering: turn a repro model config into an MVM DAG so the
# modeling plane can cost LM inference on CIM hardware.
# ---------------------------------------------------------------------------

def lm_workload(cfg, *, seq_len: int = 128, batch: int = 1) -> Workload:
    """Lower an :class:`repro.configs.base.ArchConfig` into per-layer MVM ops.

    One representative layer block is emitted per *distinct* layer kind and
    scaled by its repeat count via ``V`` (the simulator costs are linear in
    V, so folding repeats keeps the DAG compact — the paper's Fig. 7 notes
    runtime scales with op count).
    """
    w = Workload(f"lm-{cfg.name}")
    v = seq_len * batch
    d = cfg.d_model
    head_dim = cfg.head_dim
    q_out = cfg.n_heads * head_dim
    kv_out = cfg.n_kv_heads * head_dim
    L = cfg.n_layers
    emb = w.add(OpNode(name="embed", kind="embed", elements=v * d,
                       weight_count=cfg.vocab_size * d))
    prev = (emb.name,)
    if cfg.attention != "none":
        q = w.fc("attn_q", d, q_out, inputs=prev, v=v * L)
        k = w.fc("attn_k", d, kv_out, inputs=prev, v=v * L)
        vv = w.fc("attn_v", d, kv_out, inputs=prev, v=v * L)
        # score/context matmuls: activation×activation, costed as matmul.
        # Per head and layer the score GEMM pushes one seq_len-long vector
        # batch of head_dim-deep queries against the K^T matrix, so
        # V = heads × layers × batch × seq_len — spelled out explicitly
        # (the old `n_heads * v * L // seq_len * seq_len` relied on
        # left-to-right // precedence to cancel the seq_len factor).
        sc = w.add(OpNode(name="attn_scores", kind="matmul",
                          inputs=(q.name, k.name),
                          K=head_dim, N=seq_len,
                          V=cfg.n_heads * batch * L * seq_len,
                          prunable=False, weight_count=0))
        # context matmul (probs·V): same volume shape as the score GEMM
        # transposed — seq_len-deep reduction producing head_dim columns
        # for every (head, layer, batch, query) vector.  The historical
        # DAG omitted it, undercounting attention MACs by half; the
        # traced-model differential harness (repro.trace) pinned it.
        ctx = w.add(OpNode(name="attn_ctx", kind="matmul",
                           inputs=(sc.name, vv.name),
                           K=seq_len, N=head_dim,
                           V=cfg.n_heads * batch * L * seq_len,
                           prunable=False, weight_count=0))
        o = w.fc("attn_o", q_out, d, inputs=(ctx.name,), v=v * L)
        prev = (o.name,)
    if cfg.n_experts > 1:
        # MoE: top-k experts active per token; V scales by top_k
        g = w.fc("moe_gate", d, cfg.n_experts, inputs=prev, v=v * L)
        n_up = 2 if cfg.gated_mlp else 1
        up = w.fc("expert_up", d, cfg.d_ff * n_up, inputs=(g.name,),
                  v=v * L * cfg.top_k)
        down = w.fc("expert_down", cfg.d_ff, d, inputs=(up.name,),
                    v=v * L * cfg.top_k)
        # expert weights replicated n_experts times for storage accounting
        up.weight_count = d * cfg.d_ff * n_up * cfg.n_experts
        down.weight_count = cfg.d_ff * d * cfg.n_experts
        prev = (down.name,)
    elif cfg.d_ff > 0:
        n_up = 2 if cfg.gated_mlp else 1
        up = w.fc("mlp_up", d, cfg.d_ff * n_up, inputs=prev, v=v * L)
        down = w.fc("mlp_down", cfg.d_ff, d, inputs=(up.name,), v=v * L)
        prev = (down.name,)
    if cfg.ssm_state > 0:
        din = cfg.ssm_inner(d)
        xp = w.fc("ssm_in_proj", d, din * 2, inputs=prev, v=v * L)
        op = w.fc("ssm_out_proj", din, d, inputs=(xp.name,), v=v * L)
        prev = (op.name,)
    norm = w.simple("final_norm", "norm", v * d, inputs=prev)
    w.fc("lm_head", d, cfg.vocab_size, inputs=(norm.name,), v=v)
    return w


MODEL_BUILDERS = {
    "vgg16": vgg16,
    "resnet18": resnet18,
    "resnet50": resnet50,
    "mobilenetv2": mobilenet_v2,
}
