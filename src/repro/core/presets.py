"""Preset energy parameters and validation-target architectures.

The paper obtains unit energies from ASIC synthesis (Design Compiler +
PTPX) and PCACTI; those flows are unavailable offline, so this module
ships a preset table consistent with the 28 nm digital-CIM literature the
paper builds on (the CIM array power model follows [24] Yan et al.,
ISSCC'22; buffer energies are PCACTI-class SRAM numbers).  Exactly like
the paper's own preset path ("CIMinus also provides a preset of energy
parameters ... for preliminary software-level explorations"), every value
is overridable by the user.

Energy unit: pJ per access.  Static power: mW.  Clock: 1 GHz.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .hardware import CIMArch, ComputeUnit, MacroSpec, MemoryUnit

__all__ = [
    "default_compute_units",
    "default_memory_units",
    "mars_arch",
    "sdp_arch",
    "usecase_arch",
    "PRESET_ARCHS",
]


# ---------------------------------------------------------------------------
# Per-access energies (pJ), 28nm-class digital CIM.
# cim_array: one bit-serial MAC cycle of one sub-array (all rows active).
# Scaled with sub-array size by the builders below.
# ---------------------------------------------------------------------------
_SRAM_RD_PJ_PER_BIT = 0.012     # PCACTI-class 28nm SRAM read, per bit
_SRAM_WR_PJ_PER_BIT = 0.014
_MAC_PJ_PER_CELL_BIT = 0.0023   # digital CIM MAC cell toggle energy [24]
_ADDER_PJ_PER_BIT = 0.003
_MUX_PJ = 0.0018                # per 8-bit input select
_PRE_PJ_PER_ELEM = 0.020        # bit-serial conversion, per 8b element
_POST_PJ_PER_ELEM = 0.032       # act/pool/residual per element


def default_compute_units(macro: MacroSpec) -> Dict[str, ComputeUnit]:
    sub_cells = macro.sub_rows * macro.sub_cols
    cols = macro.cols
    return {
        "cim_array": ComputeUnit(
            "cim_array",
            energy_pj=_MAC_PJ_PER_CELL_BIT * sub_cells,
            # static leakage scales with CELL count (4.4 nW/cell at 28nm
            # digital CIM), not sub-array count — row-granular macros
            # (SDP's 1×64) would otherwise be charged 64× too much
            static_pw_mw=4.4e-6 * macro.rows * macro.cols,
            width=sub_cells,
            location="macro",
        ),
        "adder_tree": ComputeUnit(
            "adder_tree",
            energy_pj=_ADDER_PJ_PER_BIT * 16 * (macro.rows // macro.sub_rows),
            static_pw_mw=0.006,
            width=cols,
            location="macro",
        ),
        "shift_add": ComputeUnit(
            "shift_add",
            energy_pj=_ADDER_PJ_PER_BIT * 24,
            static_pw_mw=0.004,
            width=cols,
            location="macro",
        ),
        "accumulator": ComputeUnit(
            "accumulator",
            energy_pj=_ADDER_PJ_PER_BIT * 32,
            static_pw_mw=0.003,
            width=cols,
            location="macro",
        ),
        "pre_proc": ComputeUnit(
            "pre_proc", energy_pj=_PRE_PJ_PER_ELEM, static_pw_mw=0.010,
            width=1, location="system",
        ),
        "post_proc": ComputeUnit(
            # 64-lane SIMD post-processing datapath; energy is per element.
            "post_proc", energy_pj=_POST_PJ_PER_ELEM, static_pw_mw=0.015,
            width=64, location="system",
        ),
        # sparsity-support units (§IV-C ③)
        "mux_index": ComputeUnit(
            "mux_index", energy_pj=_MUX_PJ, static_pw_mw=0.002,
            width=1, location="macro",
        ),
        "sparse_accum": ComputeUnit(
            "sparse_accum", energy_pj=_ADDER_PJ_PER_BIT * 32,
            static_pw_mw=0.002, width=1, location="macro",
        ),
        "zero_detect": ComputeUnit(
            "zero_detect", energy_pj=0.0009, static_pw_mw=0.001,
            width=1, location="system",
        ),
    }


def default_memory_units(
    *,
    weight_kb: int = 128,
    input_kb: Optional[int] = None,
    output_kb: Optional[int] = None,
    unified: bool = False,
    ping_pong: bool = False,
    index_kb: int = 16,
    local_kb: int = 4,
    width_bits: int = 256,
) -> Dict[str, MemoryUnit]:
    def sram(name, kb, pp=False, loc="system"):
        cap = kb * 1024
        return MemoryUnit(
            name,
            capacity_bytes=cap,
            width_bits=width_bits,
            read_pj=_SRAM_RD_PJ_PER_BIT * width_bits * (1.0 + 0.08 * (kb / 64)),
            write_pj=_SRAM_WR_PJ_PER_BIT * width_bits * (1.0 + 0.08 * (kb / 64)),
            static_pw_mw=0.020 * kb / 16,
            ping_pong=pp,
            location=loc,
        )

    mems: Dict[str, MemoryUnit] = {}
    if unified:
        mems["global_buf"] = sram("global_buf", weight_kb, pp=ping_pong)
    else:
        mems["weight_buf"] = sram("weight_buf", weight_kb, pp=ping_pong)
        mems["input_buf"] = sram("input_buf", input_kb or weight_kb)
        mems["output_buf"] = sram("output_buf", output_kb or weight_kb // 2)
    mems["local_buf"] = sram("local_buf", local_kb, loc="macro")
    mems["index_mem"] = sram("index_mem", index_kb)
    return mems


# ---------------------------------------------------------------------------
# Validation targets (paper Table I)
# ---------------------------------------------------------------------------

def mars_arch() -> CIMArch:
    """MARS [19]: 1024×64 macro, 64×64 sub-arrays, 8 macros (2×4),
    128 KB ping-pong global buffer, FullBlock(1,16) sparsity, Conv layers
    only."""
    macro = MacroSpec(rows=1024, cols=64, sub_rows=64, sub_cols=64,
                      weight_bits=8, input_bits=8, load_rows_per_cycle=4)
    arch = CIMArch(
        name="mars",
        macro=macro,
        org=(2, 4),
        compute_units=default_compute_units(macro),
        memory_units=default_memory_units(
            weight_kb=128, unified=True, ping_pong=True, index_kb=8),
        clock_ghz=0.2,
        weight_sparsity_support=True,
        input_sparsity_support=False,
        eval_scope="conv_only",
    )
    arch.validate()
    return arch


def sdp_arch() -> CIMArch:
    """SDP [20]: 32×64 macro, 1×64 sub-arrays (row-granular digital CIM),
    512 macros (16×32), 256 KB input + 128 KB output buffers,
    IntraBlock(2,1)+FullBlock(2,8) sparsity, entire NN."""
    macro = MacroSpec(rows=32, cols=64, sub_rows=1, sub_cols=64,
                      weight_bits=8, input_bits=8, load_rows_per_cycle=2,
                      row_serial=True)
    arch = CIMArch(
        name="sdp",
        macro=macro,
        org=(16, 32),
        compute_units=default_compute_units(macro),
        memory_units=default_memory_units(
            weight_kb=128, input_kb=256, output_kb=128,
            unified=False, ping_pong=True, index_kb=32),
        clock_ghz=0.5,
        weight_sparsity_support=True,
        input_sparsity_support=True,
        eval_scope="all",
    )
    arch.validate()
    return arch


def usecase_arch(n_macros: int = 4, org: Optional[Tuple[int, int]] = None,
                 *, input_sparsity: bool = False) -> CIMArch:
    """§VII-A exploration architecture: 8-bit, 1024×32 macro with 32×32
    sub-arrays, weight-stationary; 4 macros (sparsity study) or 16 macros
    (mapping study) with configurable organisation."""
    if org is None:
        org = {4: (2, 2), 16: (4, 4)}.get(n_macros, (1, n_macros))
    if org[0] * org[1] != n_macros:
        raise ValueError(f"org {org} != n_macros {n_macros}")
    macro = MacroSpec(rows=1024, cols=32, sub_rows=32, sub_cols=32,
                      weight_bits=8, input_bits=8, load_rows_per_cycle=4)
    arch = CIMArch(
        name=f"usecase-{n_macros}m",
        macro=macro,
        org=org,
        compute_units=default_compute_units(macro),
        memory_units=default_memory_units(
            weight_kb=256, input_kb=128, output_kb=64,
            unified=False, ping_pong=True, index_kb=32),
        clock_ghz=0.5,
        weight_sparsity_support=True,
        input_sparsity_support=input_sparsity,
        eval_scope="all",
    )
    arch.validate()
    return arch


PRESET_ARCHS = {
    "mars": mars_arch,
    "sdp": sdp_arch,
    "usecase4": lambda: usecase_arch(4),
    "usecase16": lambda: usecase_arch(16),
}
