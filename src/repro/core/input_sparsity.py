"""Input (activation) sparsity profiling (paper §IV-B pre-simulation).

Digital CIM processes inputs bit-serially; a bit position can be skipped
only when it is zero across *all* inputs broadcast to the activated rows
of an array (§III-B).  CIMinus therefore profiles sample activations
before simulation:

1. quantise activations to symmetric int8 (the paper's 8-bit precision);
2. decompose into bit planes;
3. for each group of ``group_rows`` inputs (one CIM array's row
   broadcast), a bit position is skippable iff the OR across the group's
   bit plane is zero;
4. the skippable ratio feeds the cost model's effective bit-serial
   length.

The bit-plane reduction is the :mod:`repro.kernels.bitserial` Pallas
kernel's job on TPU; this module is the host-side (numpy) profiling
path — small reductions where eager jax dispatch cost used to dominate
the benchmark wall clock.  jax arrays are accepted and pulled to host.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = [
    "quantize_int8",
    "skippable_bit_ratio",
    "profile_activations",
    "analytic_skip_ratio",
]


def quantize_int8(x, *, per_tensor_scale: Optional[float] = None
                  ) -> np.ndarray:
    """Symmetric int8 quantisation (round-to-nearest, saturating)."""
    x = np.asarray(x)
    scale = per_tensor_scale
    if scale is None:
        scale = max(float(np.max(np.abs(x))), 1e-8) / 127.0
    q = np.clip(np.round(x / scale), -128, 127).astype(np.int8)
    return q


def skippable_bit_ratio(q, group_rows: int, n_bits: int = 8) -> float:
    """Fraction of (group × bit) slots whose bit plane is all-zero.

    ``q`` is an int8 activation tensor reshaped to (n_vectors, K): each
    row is one input vector; contraction elements split into groups of
    ``group_rows`` (the array's broadcast span).  Sign-magnitude bit
    planes are used, matching bit-serial digital CIM datapaths.
    """
    q = np.asarray(q)
    if q.ndim == 1:
        q = q[None, :]
    mag = np.abs(q.astype(np.int32))
    V, K = mag.shape
    pad = (-K) % group_rows
    if pad:
        mag = np.pad(mag, ((0, 0), (0, pad)))
    G = mag.shape[1] // group_rows
    # OR of each broadcast group's magnitudes: bit b's plane is all-zero
    # within a group iff bit b of the group-OR is zero
    group_or = np.bitwise_or.reduce(mag.reshape(V, G, group_rows), axis=-1)
    planes_skippable = 0
    for b in range(n_bits):
        planes_skippable += int(np.sum(((group_or >> b) & 1) == 0))
    total = V * G * n_bits
    return float(planes_skippable) / max(total, 1)


def profile_activations(
    acts: Dict[str, np.ndarray],
    group_rows: int,
    n_bits: int = 8,
) -> Dict[str, float]:
    """Per-layer skippable-bit ratios from captured activation samples."""
    out = {}
    for name, a in acts.items():
        q = quantize_int8(np.asarray(a).reshape(-1, a.shape[-1]))
        out[name] = skippable_bit_ratio(q, group_rows, n_bits)
    return out


def analytic_skip_ratio(zero_rate: float, group_rows: int,
                        n_bits: int = 8, mean_mag_bits: float = 4.0) -> float:
    """Closed-form estimate when no activation samples are available.

    Models each activation as zero w.p. ``zero_rate`` (post-ReLU) and,
    when non-zero, each magnitude bit above ``mean_mag_bits`` decaying
    geometrically.  A (group, bit) slot skips iff every element's bit is
    zero.  Used for the CNN modeling plane where pretrained weights are
    unavailable offline; empirical profiling supersedes it when samples
    exist.
    """
    ratio = 0.0
    for b in range(n_bits):
        # P(bit b set | non-zero) — geometric decay above the mean MSB
        p_set = min(0.5, 0.5 * 2.0 ** (-(max(b - mean_mag_bits, 0.0))))
        p_elem_zero = zero_rate + (1.0 - zero_rate) * (1.0 - p_set)
        ratio += p_elem_zero ** group_rows
    return ratio / n_bits


def capture_mlp_activations(
    apply_fn: Callable,
    params,
    sample_inputs,
    layer_names: List[str],
) -> Dict[str, np.ndarray]:
    """Helper: run a model that returns (out, intermediates-dict) and
    collect the named intermediate activations for profiling."""
    _, inter = apply_fn(params, sample_inputs)
    return {k: np.asarray(v) for k, v in inter.items() if k in layer_names}
