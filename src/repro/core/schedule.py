"""Multi-macro DAG scheduling layer (paper §IV, use-case 2).

The paper's pitch is that sparse-DNN cost modeling must account for "the
flexibility of a multi-macro CIM structure"; this module makes that
flexibility a first-class, sweepable modeling axis sitting between the
workload DAG and the per-op cost kernels.  The cost model prices each op
in isolation (:mod:`repro.core.costmodel`); a :class:`SchedulePolicy`
decides how those ops share the macro organisation **in time**:

* ``"monolithic"`` — the historical behaviour: every op maps onto the
  whole organisation and ops serialise in DAG (insertion) order.  Total
  latency is the plain sum of per-op latencies, bit-for-bit identical to
  the pre-scheduler simulator (asserted by ``tests/test_schedule.py``
  against :func:`repro.core.costmodel.simulate_reference`).
* ``"partitioned"`` — a greedy list scheduler over the DAG: independent
  ready ops run concurrently on **disjoint macro subsets** (ResNet
  shortcut convs, attention Q/K/V projections, MoE experts overlap in
  time).  Each op's macro demand is its *actual* band footprint — an op
  occupying 30 of 128 band slots never benefited from the idle macros,
  so its per-op latency and access counts are unchanged and total
  dynamic energy is identical to monolithic (the accounting identity);
  only the time arrangement (and therefore static energy) changes.
* ``"resident"`` — when the aggregate band demand of every MVM op fits
  the organisation, weights are pinned across the whole inference: load
  waves are paid once up-front (``preload_cycles``) and the steady-state
  per-op latency drops its load stage.  Combined with
  ``SchedulePolicy.invocations > 1`` (repeated DAG executions: decode
  steps, batched re-inference) the preload and the weight-buffer traffic
  amortise while compute scales — the classic weight-stationary CIM
  win.  Workloads that do not fit fall back to monolithic timing
  (``ScheduleResult.resident`` is False).

The scheduler consumes :class:`OpExec` records (built by the cost model
from its per-op :class:`~repro.core.report.OpCost`) so this module stays
free of energy/accounting concerns and imports nothing but the workload
DAG utilities (:meth:`~repro.core.workload.Workload.topo_order`).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from .workload import Workload

__all__ = [
    "POLICIES", "SchedulePolicy", "OpExec", "ScheduledOp",
    "ScheduleResult", "build_schedule", "critical_path",
]

POLICIES = ("monolithic", "partitioned", "resident")


@dataclasses.dataclass(frozen=True)
class SchedulePolicy:
    """How the workload DAG shares the macro organisation.

    ``policy``: one of :data:`POLICIES` (see module docstring).
    ``invocations``: how many times the whole DAG executes (autoregressive
    decode steps, repeated batches).  Latency and dynamic energy scale
    linearly for every policy; under ``"resident"`` the weight
    preload/traffic is paid once and amortised across invocations.
    """

    policy: str = "monolithic"
    invocations: int = 1

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown schedule policy {self.policy!r}; "
                f"choose from {POLICIES}")
        if self.invocations < 1:
            raise ValueError(
                f"invocations must be >= 1, got {self.invocations}")


@dataclasses.dataclass(frozen=True)
class OpExec:
    """Scheduler-facing execution profile of one op.

    Built by the cost model from the op's :class:`OpCost`: how long it
    runs and which resources it occupies.  ``duration`` is the full
    per-invocation pipeline latency (loads included); ``steady`` is the
    same latency with weight loads hoisted (what a resident invocation
    costs); ``macros`` is the op's macro demand — the number of macros
    its bands (× duplication replicas) actually occupy, 0 for ops that
    run on the post-processing unit instead.
    """

    name: str
    duration: float
    steady: float = 0.0
    load_cycles: float = 0.0
    macros: int = 0
    bands: int = 0
    waves: int = 0
    uses_post: bool = False


@dataclasses.dataclass
class ScheduledOp:
    """Placement of one op in the schedule (cycles, one invocation)."""

    name: str
    start: float
    end: float
    macros: int
    macro_share: float


@dataclasses.dataclass
class ScheduleResult:
    """A fully resolved schedule for one (workload, arch, policy) triple.

    ``makespan_cycles`` covers one steady-state invocation;
    ``total_cycles`` = ``preload_cycles + invocations × makespan_cycles``
    and is what :class:`~repro.core.report.CostReport.latency_cycles`
    reports.  ``critical_path`` is the longest dependency chain through
    the DAG under the scheduled per-op durations — the latency floor no
    macro allocation can beat.  ``concurrency`` is the average
    parallelism achieved (Σ per-op durations / makespan; 1.0 for serial
    policies).
    """

    policy: str
    invocations: int
    makespan_cycles: float
    total_cycles: float
    preload_cycles: float
    resident: bool
    ops: List[ScheduledOp]
    critical_path: List[str]
    critical_path_cycles: float
    concurrency: float

    def op(self, name: str) -> ScheduledOp:
        for s in self.ops:
            if s.name == name:
                return s
        raise KeyError(name)

    def macro_time_utilization(self) -> float:
        """Fraction of the organisation's macro-time actually occupied:
        Σ(macro_share × op duration) / makespan.  1.0 would mean every
        macro busy for the whole invocation; serial policies on small
        ops sit far below.  0.0 for an empty/zero-length schedule."""
        if self.makespan_cycles <= 0:
            return 0.0
        busy = sum(s.macro_share * (s.end - s.start) for s in self.ops)
        return busy / self.makespan_cycles


def critical_path(workload: Workload,
                  durations: Dict[str, float]) -> Tuple[List[str], float]:
    """Longest dependency chain through the DAG.

    ``durations`` maps op name → cycles (missing names count as 0, e.g.
    ops outside the arch's ``eval_scope``).  Returns ``(path, cycles)``;
    ties break deterministically toward earlier-inserted ops.
    """
    order = workload.topo_order()
    dist: Dict[str, float] = {}
    pred: Dict[str, Optional[str]] = {}
    for name in order:
        best, best_pred = 0.0, None
        for inp in workload.nodes[name].inputs:
            if dist[inp] > best:
                best, best_pred = dist[inp], inp
        dist[name] = best + durations.get(name, 0.0)
        pred[name] = best_pred
    if not order:
        return [], 0.0
    end = max(order, key=lambda n: dist[n])
    path: List[str] = []
    cur: Optional[str] = end
    while cur is not None:
        path.append(cur)
        cur = pred[cur]
    path.reverse()
    return path, dist[end]


def _serial_walk(workload: Workload, execs: Dict[str, OpExec],
                 n_macros: int, *, steady: bool) -> Tuple[List[ScheduledOp],
                                                          float]:
    """Op-serial timeline in DAG insertion order.

    Accumulates left-to-right exactly like the pre-scheduler simulator's
    ``sum(latency for op in nodes)`` so the monolithic policy's makespan
    is bit-for-bit the historical total.
    """
    t = 0.0
    ops: List[ScheduledOp] = []
    for name in workload.nodes:
        ex = execs[name]
        dur = ex.steady if steady else ex.duration
        start = t
        t = t + dur
        share = ex.macros / n_macros if ex.macros else 0.0
        ops.append(ScheduledOp(name=name, start=start, end=t,
                               macros=ex.macros, macro_share=share))
    return ops, t


def _list_schedule(workload: Workload, execs: Dict[str, OpExec],
                   n_macros: int) -> Tuple[List[ScheduledOp], float]:
    """Greedy list scheduler: independent ready ops run concurrently on
    disjoint macro subsets; post-processing ops serialise on their (one)
    unit but overlap with CIM work.  Deterministic: ready ops start in
    DAG insertion order, completions break ties the same way."""
    idx = {name: i for i, name in enumerate(workload.nodes)}
    succ: Dict[str, List[str]] = {name: [] for name in workload.nodes}
    indeg: Dict[str, int] = {name: 0 for name in workload.nodes}
    workload.topo_order()                     # validates DAG (cycle check)
    for node in workload.nodes.values():
        for inp in node.inputs:
            succ[inp].append(node.name)
            indeg[node.name] += 1

    ready: List[Tuple[int, str]] = [
        (idx[n], n) for n in workload.nodes if indeg[n] == 0]
    heapq.heapify(ready)
    running: List[Tuple[float, int, str]] = []
    free = n_macros
    post_free = True
    t = 0.0
    placed: Dict[str, ScheduledOp] = {}

    def _finish(name: str) -> None:
        nonlocal free, post_free
        ex = execs[name]
        free += ex.macros
        if ex.uses_post:
            post_free = True
        for s in succ[name]:
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(ready, (idx[s], s))

    while ready or running:
        deferred: List[Tuple[int, str]] = []
        while ready:
            i, name = heapq.heappop(ready)
            ex = execs[name]
            if (ex.macros > free) or (ex.uses_post and not post_free):
                deferred.append((i, name))
                continue
            free -= ex.macros
            if ex.uses_post:
                post_free = False
            end = t + ex.duration
            share = ex.macros / n_macros if ex.macros else 0.0
            placed[name] = ScheduledOp(name=name, start=t, end=end,
                                       macros=ex.macros, macro_share=share)
            heapq.heappush(running, (end, i, name))
        for item in deferred:
            heapq.heappush(ready, item)
        if not running:
            if ready:      # every demand is capped at n_macros, so an
                # idle machine can always start the next ready op
                raise RuntimeError(
                    f"schedule deadlock in {workload.name!r}: "
                    f"{[n for _, n in ready]} cannot be placed")
            break
        end, _, name = heapq.heappop(running)
        t = end
        _finish(name)
        while running and running[0][0] == end:
            _, _, other = heapq.heappop(running)
            _finish(other)

    ops = [placed[name] for name in workload.nodes]
    makespan = max((s.end for s in ops), default=0.0)
    return ops, makespan


def build_schedule(workload: Workload, policy: SchedulePolicy,
                   execs: Dict[str, OpExec], *, n_macros: int,
                   band_slots: int) -> ScheduleResult:
    """Resolve ``policy`` into per-op start/end cycles and the totals.

    ``execs`` must cover every node of ``workload`` (ops outside the
    measured scope carry zero duration/demand and only convey
    dependencies).  ``band_slots`` is the organisation's total band
    capacity (``n_macros × rows/sub_rows``) the resident fit is checked
    against.
    """
    mvm = [ex for ex in execs.values() if ex.macros > 0]
    resident = False
    preload = 0.0
    if policy.policy == "partitioned":
        ops, makespan = _list_schedule(workload, execs, n_macros)
        durations = {name: execs[name].duration for name in workload.nodes}
    elif policy.policy == "resident":
        fits = (bool(mvm) and all(ex.waves <= 1 for ex in mvm)
                and sum(ex.bands for ex in mvm) <= band_slots)
        if fits:
            resident = True
            for name in workload.nodes:      # nodes order, like the walk
                preload += execs[name].load_cycles
            ops, makespan = _serial_walk(workload, execs, n_macros,
                                         steady=True)
            durations = {name: execs[name].steady for name in workload.nodes}
        else:
            ops, makespan = _serial_walk(workload, execs, n_macros,
                                         steady=False)
            durations = {name: execs[name].duration
                         for name in workload.nodes}
    else:                                    # monolithic
        ops, makespan = _serial_walk(workload, execs, n_macros, steady=False)
        durations = {name: execs[name].duration for name in workload.nodes}

    if policy.invocations == 1 and preload == 0.0:
        total = makespan                     # bit-exact monolithic total
    else:
        total = preload + policy.invocations * makespan
    cp_path, cp_cycles = critical_path(workload, durations)
    busy = sum(durations.values())
    concurrency = busy / makespan if makespan > 0 else 0.0
    return ScheduleResult(
        policy=policy.policy, invocations=policy.invocations,
        makespan_cycles=makespan, total_cycles=total,
        preload_cycles=preload, resident=resident, ops=ops,
        critical_path=cp_path, critical_path_cycles=cp_cycles,
        concurrency=concurrency)
