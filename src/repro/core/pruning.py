"""CIMinus pruning workflow (paper §IV-D).

Generates FlexBlock-conformant binary masks for 2-D weight matrices:

* FullBlock: block loss ``L_FB(W,i,j) = Σ ρ(W[x,y])`` over the block
  (Eq. 1); the ``r·n_blocks`` blocks with the lowest loss are pruned.
* IntraBlock: per block, the pattern ``P ∈ 𝒫`` minimising the pruned
  loss ``L_IB`` (Eq. 2) is selected — equivalently, the pattern that
  *keeps* the most importance.

Criteria ρ: ``l1`` (|w|) and ``l2`` (w²) as in the paper.

All mask generation is pure-functional and runs on **numpy** — the
reductions are tiny next to model weights, eager jax dispatch used to
dominate benchmark wall time with op-by-op compiles, and mask consumers
(the cost model, the compression helpers) want host arrays anyway.  jax
arrays are accepted (pulled to host) and jax is only imported when a
mask is *applied* to a device array.  The heavy block-loss reduction can
still be routed through the Pallas ``block_importance`` kernel (see
:mod:`repro.kernels.ops`).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .flexblock import FlexBlockSpec, FullBlock, IntraBlock

__all__ = [
    "CRITERIA",
    "block_losses",
    "fullblock_mask",
    "intrablock_mask",
    "flexblock_mask",
    "prune_matrix",
    "PruningResult",
]

CRITERIA: Dict[str, Callable] = {
    "l1": lambda w: np.abs(w),
    "l2": lambda w: np.square(w),
}


def _pad_to_blocks(w: np.ndarray, m: int, n: int) -> np.ndarray:
    M, N = w.shape
    pm = (-M) % m
    pn = (-N) % n
    if pm or pn:
        w = np.pad(w, ((0, pm), (0, pn)))
    return w


def block_losses(w, m: int, n: int, criterion: str = "l1") -> np.ndarray:
    """Eq. 1: per-block aggregated importance, shape (M/m, N/n).

    The matrix is zero-padded up to a whole number of blocks; padding
    contributes zero loss so it never protects a block from pruning.
    """
    rho = CRITERIA[criterion]
    wp = _pad_to_blocks(np.asarray(w), m, n)
    Mp, Np = wp.shape
    blocks = rho(wp).reshape(Mp // m, m, Np // n, n)
    return blocks.sum(axis=(1, 3))


def fullblock_mask(
    w,
    pattern: FullBlock,
    criterion: str = "l1",
    *,
    eligible: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Binary keep-mask (1 = keep) for FullBlock sparsity.

    ``eligible`` is an optional block-grid bool array; ineligible blocks
    (already fully zero from a prior pattern) are treated as pruned for
    free and do not consume the pruning budget.
    """
    w = np.asarray(w)
    p = pattern.bind(w.shape)
    losses = np.asarray(block_losses(w, p.m, p.n, criterion))
    gm, gn = losses.shape
    n_blocks = gm * gn
    n_keep = p.nonzero_blocks(w.shape)
    flat = losses.reshape(-1)
    if eligible is not None:
        flat = np.where(np.asarray(eligible).reshape(-1), flat, -np.inf)
    # keep the n_keep highest-loss blocks (stable: ties broken by index)
    order = np.argsort(-flat, kind="stable")
    keep_idx = order[:n_keep]
    keep = np.zeros(n_blocks, dtype=bool)
    keep[keep_idx] = True
    keep = keep.reshape(gm, gn)
    mask = np.repeat(np.repeat(keep, p.m, axis=0), p.n, axis=1)
    return mask[: w.shape[0], : w.shape[1]].astype(np.uint8)


def intrablock_mask(
    w,
    pattern: IntraBlock,
    criterion: str = "l1",
    *,
    align_cols: bool = False,
) -> np.ndarray:
    """Binary keep-mask for IntraBlock sparsity via Eq. 2 pattern selection.

    For the default (exhaustive) pattern set this reduces to top-φ
    magnitude selection per block; for a restricted pattern set each
    block picks ``argmax_P Σ_{P=1} ρ(w)`` — identical to
    ``argmin_P L_IB`` since block total importance is constant.

    ``align_cols=True`` selects one pattern per block *row-group shared
    by every column* (importance aggregated across columns).  Aligned
    masks compress to a pure row-subset, which is the layout the TPU
    block-sparse kernels require (see kernels/ops.py); CIM hardware
    with per-element muxes does not need the restriction.
    """
    m, n = pattern.m, pattern.n
    rho = CRITERIA[criterion]
    wp = _pad_to_blocks(np.asarray(w), m, n)
    Mp, Np = wp.shape
    imp = np.asarray(rho(wp)).reshape(Mp // m, m, Np // n, n)
    # (gm, gn, m*n) per-block element importances
    imp = imp.transpose(0, 2, 1, 3).reshape(Mp // m, Np // n, m * n)
    if align_cols:
        # aggregate across the column grid → one shared pattern per row-block
        imp = np.broadcast_to(imp.sum(axis=1, keepdims=True), imp.shape)
    if pattern.pattern_set is None:
        phi = pattern.phi
        # top-φ per block == optimal over the full pattern set
        thresh_idx = np.argsort(-imp, axis=-1, kind="stable")[..., :phi]
        keep = np.zeros_like(imp, dtype=bool)
        np.put_along_axis(keep, thresh_idx, True, axis=-1)
    else:
        pats = np.asarray(pattern.patterns(), dtype=np.float64)  # (P, m*n)
        kept_importance = imp @ pats.T  # (gm, gn, P)
        best = np.argmax(kept_importance, axis=-1)
        keep = pats[best].astype(bool)  # (gm, gn, m*n)
    gm, gn = keep.shape[:2]
    mask = keep.reshape(gm, gn, m, n).transpose(0, 2, 1, 3).reshape(gm * m, gn * n)
    return mask[: w.shape[0], : w.shape[1]].astype(np.uint8)


class PruningResult:
    """Mask + bookkeeping produced by :func:`prune_matrix`."""

    __slots__ = ("mask", "spec", "block_keep", "density")

    def __init__(self, mask: np.ndarray, spec: FlexBlockSpec,
                 block_keep: Optional[np.ndarray], density: float):
        self.mask = mask          # (M, N) uint8 keep-mask
        self.spec = spec
        self.block_keep = block_keep  # coarse block-grid keep map (or None)
        self.density = density

    def apply(self, w):
        if isinstance(w, np.ndarray):
            return w * self.mask.astype(w.dtype)
        try:
            # device arrays: mask moves to the weight (lazy site — the
            # modeling plane must stay importable without jax)
            import jax.numpy as jnp
        except ImportError:
            arr = np.asarray(w)
            return arr * self.mask.astype(arr.dtype)
        return w * jnp.asarray(self.mask, dtype=w.dtype)


def flexblock_mask(
    w, spec: FlexBlockSpec, criterion: str = "l1",
    *, align_cols: bool = False,
) -> np.ndarray:
    """Compose the spec's patterns into a single keep-mask.

    Order of application: coarse FullBlock first (removing whole blocks),
    then IntraBlock within the surviving region — matching the §IV-D
    workflow where block-level pruning precedes element-level pruning.
    """
    w = np.asarray(w)
    spec = spec.bind(w.shape)
    spec.validate_for(w.shape)
    if spec.is_dense:
        return np.ones(w.shape, dtype=np.uint8)
    full, intra = spec.full, spec.intra
    mask = np.ones(w.shape, dtype=np.uint8)
    if full is not None:
        mask &= fullblock_mask(w, full, criterion)
    if intra is not None:
        w_eff = w * mask
        mask &= intrablock_mask(w_eff, intra, criterion,
                                align_cols=align_cols)
    return mask


def prune_matrix(
    w, spec: FlexBlockSpec, criterion: str = "l1",
    *, align_cols: bool = False,
) -> PruningResult:
    w = np.asarray(w)
    mask = flexblock_mask(w, spec, criterion, align_cols=align_cols)
    spec_b = spec.bind(w.shape)
    block_keep = None
    if spec_b.full is not None:
        f = spec_b.full
        gm, gn = f.grid(w.shape)
        mp = _pad_to_blocks(mask, f.m, f.n)
        bk = mp.reshape(gm, f.m, gn, f.n).sum(axis=(1, 3)) > 0
        block_keep = bk
    density = float(mask.mean())
    return PruningResult(mask, spec_b, block_keep, density)
