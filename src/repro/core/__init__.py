"""CIMinus core: the paper's contribution as a composable library.

Public API:

* FlexBlock sparsity abstraction (§III): :mod:`repro.core.flexblock`
* Pruning workflow (§IV-D):            :mod:`repro.core.pruning`
* Hardware description (§IV-C):        :mod:`repro.core.hardware` / presets
* Workload DAG (§IV-C):                :mod:`repro.core.workload`
* Mapping description (§IV-C):         :mod:`repro.core.mapping`
* Multi-macro DAG scheduling (§IV):    :mod:`repro.core.schedule`
* Cost model (§V):                     :mod:`repro.core.costmodel`
* Input-sparsity profiling (§IV-B):    :mod:`repro.core.input_sparsity`
* Exploration sweeps (§VII):           :mod:`repro.core.explorer`
  (compatibility wrappers; the parallel engine with result caching and
  Pareto post-processing lives in :mod:`repro.explore`)
"""
from .flexblock import (FlexBlockSpec, FullBlock, IntraBlock, TABLE_II_PATTERNS,
                        channel_wise, column_block, column_wise, dense_spec,
                        hybrid, row_block, row_wise)
from .hardware import CIMArch, ComputeUnit, MacroSpec, MemoryUnit
from .mapping import (MappingSpec, ReshapeSpec, default_mapping,
                      duplicate_mapping, reshape_and_compress, spatial_mapping)
from .costmodel import (compare, dense_baseline, dense_twin, simulate,
                        simulate_reference)
from .report import CostReport, OpCost
from .schedule import (POLICIES, OpExec, SchedulePolicy, ScheduledOp,
                       ScheduleResult, build_schedule, critical_path)
from .workload import (MODEL_BUILDERS, OpNode, Workload, lm_workload,
                       mobilenet_v2, resnet18, resnet50, vgg16)
from .presets import mars_arch, sdp_arch, usecase_arch, PRESET_ARCHS
from .explorer import sweep_mappings, sweep_orgs, sweep_sparsity

# Mask generation (§IV-D) and input-sparsity profiling (§IV-B) are
# numpy-native host paths (the Pallas kernels cover the device side), so
# the whole modeling plane imports without jax.
from .pruning import (block_losses, flexblock_mask, fullblock_mask,
                      intrablock_mask, prune_matrix)
from .input_sparsity import (analytic_skip_ratio, profile_activations,
                             quantize_int8, skippable_bit_ratio)

__all__ = [
    # flexblock
    "FlexBlockSpec", "FullBlock", "IntraBlock", "TABLE_II_PATTERNS",
    "channel_wise", "column_block", "column_wise", "dense_spec", "hybrid",
    "row_block", "row_wise",
    # hardware
    "CIMArch", "ComputeUnit", "MacroSpec", "MemoryUnit",
    "mars_arch", "sdp_arch", "usecase_arch", "PRESET_ARCHS",
    # mapping
    "MappingSpec", "ReshapeSpec", "default_mapping", "duplicate_mapping",
    "reshape_and_compress", "spatial_mapping",
    # cost model
    "compare", "dense_baseline", "dense_twin", "simulate",
    "simulate_reference", "CostReport", "OpCost",
    # scheduling
    "POLICIES", "OpExec", "SchedulePolicy", "ScheduledOp", "ScheduleResult",
    "build_schedule", "critical_path",
    # pruning
    "block_losses", "flexblock_mask", "fullblock_mask", "intrablock_mask",
    "prune_matrix",
    # workload
    "MODEL_BUILDERS", "OpNode", "Workload", "lm_workload", "mobilenet_v2",
    "resnet18", "resnet50", "vgg16",
    # input sparsity
    "analytic_skip_ratio", "profile_activations", "quantize_int8",
    "skippable_bit_ratio",
    # explorer
    "sweep_mappings", "sweep_orgs", "sweep_sparsity",
]
