"""CLI for the static invariant checker.

  python -m repro.analysis                    # --all
  python -m repro.analysis --all --format json --out diagnostics.json
  python -m repro.analysis --pass import-boundary --pass cache-key
  python -m repro.analysis --list

Exit status: 0 when no error-severity diagnostics survive suppression,
1 otherwise (2 for usage errors).  Runs entirely without jax — the CI
``analysis`` job executes this on a jax-free interpreter.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .diagnostics import Severity, render_json, render_text
from .framework import all_passes, run_passes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--all", action="store_true",
                    help="run every registered pass (default)")
    ap.add_argument("--pass", dest="passes", action="append", default=[],
                    metavar="NAME", help="run one pass (repeatable)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the rendered report to FILE")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="analyse the tree at DIR instead of this repo")
    ap.add_argument("--list", action="store_true",
                    help="list registered passes and their codes")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="include suppressed diagnostics in text output")
    args = ap.parse_args(argv)

    passes = all_passes()
    if args.list:
        for name, cls in passes.items():
            codes = ", ".join(cls.codes)
            print(f"{name:16s} [{codes}]\n    {cls.description}")
        return 0

    if args.passes and args.all:
        ap.error("--pass and --all are mutually exclusive")
    for name in args.passes:
        if name not in passes:
            ap.error(f"unknown pass {name!r} "
                     f"(known: {', '.join(sorted(passes))})")

    selected = args.passes or None            # None -> all, in order
    root = Path(args.root) if args.root else None
    diags = run_passes(selected, root=root)

    if args.format == "json":
        report = render_json(diags, passes=selected or list(passes))
    else:
        report = render_text(diags, show_suppressed=args.show_suppressed)
    print(report)
    if args.out:
        Path(args.out).write_text(report + "\n")

    failed = any(d.severity == Severity.ERROR and not d.suppressed
                 for d in diags)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
