"""Pass 4 — determinism lint: the modeling plane must be a pure
function of its inputs.

Explore results are memoised under content keys and compared across
hosts and commits; any hidden source of nondeterminism — unseeded RNG,
wall-clock reads feeding results, per-process ``hash()`` salting,
filesystem-order iteration — silently breaks that contract (the PR 1
mask-seed bug was exactly this class).  This pass AST-scans the
result-producing packages (``core``, ``explore``, ``trace``,
``analysis``) for the known shapes of the bug.

Codes
-----
* ``CIM401`` (error) — unseeded RNG: legacy ``numpy.random.*`` global
  state, argument-less ``default_rng()``, or stdlib ``random.*``.
  Seeded construction (``default_rng(content_seed)``) is fine.
* ``CIM402`` (error) — wall-clock reads: ``time.time``,
  ``datetime.now``/``utcnow``/``today``.  Monotonic timers
  (``perf_counter`` etc.) are fine — they time work, they don't enter
  results.  **Sanctioned waiver:** modules under ``repro.obs`` may read
  the wall clock — the observability plane stamps run manifests and
  run ids (telemetry metadata), it never produces results, and its
  output is barred from cache keys by CIM205.  The waiver is exactly
  this prefix; wall-clock reads anywhere else in the scanned packages
  still fail.
* ``CIM403`` (error) — builtin ``hash()`` outside ``__hash__``/
  ``__eq__``: salted per process since PEP 456, so never content-stable.
  Use ``hashlib`` digests.
* ``CIM404`` (error) — filesystem enumeration (``os.listdir``,
  ``scandir``, ``glob``, ``Path.iterdir``/``glob``/``rglob``) not
  wrapped directly in ``sorted(...)``: directory order is
  filesystem-dependent.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .diagnostics import Diagnostic, Severity
from .framework import AnalysisPass, PassContext, register

__all__ = ["DeterminismPass", "SCANNED_PREFIXES",
           "WALL_CLOCK_WAIVED_PREFIXES"]

SCANNED_PREFIXES: Tuple[str, ...] = (
    "repro.core", "repro.explore", "repro.trace", "repro.analysis",
    "repro.obs",
)

# Module prefixes where CIM402 (wall-clock reads) is sanctioned: the
# observability plane stamps manifests/run-ids with wall time but is
# observational-only (CIM205 keeps its output away from cache keys).
WALL_CLOCK_WAIVED_PREFIXES: Tuple[str, ...] = ("repro.obs",)

# numpy.random attributes that are deterministic constructors, not
# legacy global-state draws
_NP_RANDOM_OK = frozenset({
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "MT19937", "Philox", "SFC64", "RandomState",
})

_WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "datetime.datetime.now",
    "datetime.datetime.utcnow", "datetime.date.today", "datetime.now",
    "datetime.utcnow", "date.today",
})

_FS_ENUM_DOTTED = frozenset({"os.listdir", "os.scandir", "glob.glob",
                             "glob.iglob"})
_FS_ENUM_METHODS = frozenset({"iterdir", "glob", "rglob", "scandir",
                              "listdir"})


def _alias_map(tree: ast.Module) -> Dict[str, str]:
    """name-in-scope -> real dotted prefix, from every import in the
    module (function-local imports included — usage follows them)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                aliases[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            base = node.module or ""
            for alias in node.names:
                if alias.name == "*":
                    continue
                aliases[alias.asname or alias.name] = f"{base}.{alias.name}"
    return aliases


def _dotted(func: ast.AST) -> Optional[str]:
    """'np.random.rand' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _resolve(dotted: str, aliases: Dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    real = aliases.get(head, head)
    return f"{real}.{rest}" if rest else real


class _Scanner(ast.NodeVisitor):
    def __init__(self, aliases: Dict[str, str]):
        self.aliases = aliases
        self.findings: List[Tuple[str, int, str, str]] = []
        self._func_stack: List[str] = []
        self._sorted_args: set = set()   # id() of calls wrapped in sorted()

    def _flag(self, code: str, lineno: int, message: str,
              hint: str) -> None:
        self.findings.append((code, lineno, message, hint))

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        # record direct arguments of sorted(...) so fs-enumeration calls
        # wrapped in it aren't flagged
        dotted = _dotted(node.func)
        if isinstance(node.func, ast.Name) and node.func.id == "sorted":
            for a in node.args:
                self._sorted_args.add(id(a))
        if dotted is not None:
            self._check(node, _resolve(dotted, self.aliases))
        self.generic_visit(node)

    def _check(self, node: ast.Call, name: str) -> None:
        # CIM401 — RNG
        if name.startswith("numpy.random."):
            leaf = name.rsplit(".", 1)[1]
            if leaf not in _NP_RANDOM_OK:
                self._flag("CIM401", node.lineno,
                           f"legacy global-state RNG call {name}()",
                           "draw from a content-seeded "
                           "np.random.default_rng(seed) instead")
            elif leaf in ("default_rng", "RandomState") and not node.args:
                self._flag("CIM401", node.lineno,
                           f"{name}() without a seed is entropy-seeded",
                           "derive the seed from content (e.g. blake2b "
                           "of the inputs) so reruns reproduce")
        elif name.startswith("random.") and name.count(".") == 1:
            leaf = name.rsplit(".", 1)[1]
            if not (leaf in ("Random", "SystemRandom") and node.args):
                self._flag("CIM401", node.lineno,
                           f"stdlib RNG call {name}()",
                           "use a content-seeded np.random.default_rng "
                           "(or random.Random(seed))")
        # CIM402 — wall clock
        elif name in _WALL_CLOCK:
            self._flag("CIM402", node.lineno,
                       f"wall-clock read {name}()",
                       "use time.perf_counter() for timing; results "
                       "must not depend on the clock")
        # CIM403 — salted builtin hash
        elif name == "hash" and isinstance(node.func, ast.Name):
            if not self._func_stack or self._func_stack[-1] not in (
                    "__hash__", "__eq__"):
                self._flag("CIM403", node.lineno,
                           "builtin hash() is salted per process "
                           "(PEP 456) — not content-stable",
                           "use hashlib (sha256/blake2b) over a "
                           "canonical byte form")
        # CIM404 — unsorted filesystem enumeration
        elif (name in _FS_ENUM_DOTTED
              or (isinstance(node.func, ast.Attribute)
                  and node.func.attr in _FS_ENUM_METHODS)):
            if id(node) not in self._sorted_args:
                self._flag("CIM404", node.lineno,
                           f"filesystem enumeration {name}() without "
                           f"sorted(...) — directory order is "
                           f"filesystem-dependent",
                           "wrap the call directly in sorted()")


@register
class DeterminismPass(AnalysisPass):
    name = "determinism"
    codes = ("CIM401", "CIM402", "CIM403", "CIM404")
    description = ("core/explore/trace/analysis must not read entropy, "
                   "the wall clock, salted hashes, or directory order")

    def run(self, ctx: PassContext) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for module, path in ctx.iter_modules():
            if not any(module == p or module.startswith(p + ".")
                       for p in SCANNED_PREFIXES):
                continue
            wall_waived = any(module == p or module.startswith(p + ".")
                              for p in WALL_CLOCK_WAIVED_PREFIXES)
            tree = ctx.tree(path)
            scanner = _Scanner(_alias_map(tree))
            # visit sorted() wrappers before their arguments: NodeVisitor
            # already walks parents first, which is what _sorted_args needs
            scanner.visit(tree)
            rel = ctx.rel(path)
            for code, lineno, msg, hint in scanner.findings:
                if code == "CIM402" and wall_waived:
                    continue        # sanctioned: obs stamps telemetry only
                diags.append(self.diag(code, Severity.ERROR, msg,
                                       file=rel, line=lineno, hint=hint))
        return diags
