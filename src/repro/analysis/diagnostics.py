"""Structured diagnostics for the static invariant-verification layer.

Every check in :mod:`repro.analysis` reports through one shape: a
:class:`Diagnostic` with a stable error code (``CIM1xx``–``CIM4xx``,
catalogued in ``docs/analysis.md``), a severity, and a *location* —
either ``file:line`` for source-level findings or an object path
(``workload.nodes['s0b0_add'].inputs[1]``) for semantic findings over
live model-plane objects.

Source-level diagnostics honour inline suppressions::

    import jax  # ciminus: ignore[CIM101] -- capture shim, guarded by CI

The marker may sit on the flagged line or on the line directly above it,
and may list several codes (``ignore[CIM101,CIM402]``) or ``ignore[*]``
for a blanket waiver.  Suppressed diagnostics are counted, not shown
(``--format json`` still carries them with ``suppressed: true`` so CI
artifacts record every waiver).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence

__all__ = ["Severity", "Diagnostic", "AnalysisError", "suppressed_codes",
           "apply_suppressions", "render_text", "render_json"]


class Severity:
    """Diagnostic severities, most severe first."""

    ERROR = "error"      # CI-blocking: the invariant is violated
    WARNING = "warning"  # suspicious but not contract-breaking
    NOTE = "note"        # informational (fix-it context, statistics)

    ORDER = (ERROR, WARNING, NOTE)

    @staticmethod
    def rank(sev: str) -> int:
        return Severity.ORDER.index(sev) if sev in Severity.ORDER else 99


@dataclasses.dataclass
class Diagnostic:
    """One finding: stable code, severity, location, message, fix-it hint."""

    code: str                       # e.g. "CIM101"
    severity: str                   # Severity.*
    message: str
    pass_name: str = ""
    file: Optional[str] = None      # repo-relative path for source findings
    line: Optional[int] = None      # 1-based
    obj: Optional[str] = None       # object path for semantic findings
    hint: Optional[str] = None      # how to fix (or how to suppress)
    suppressed: bool = False

    @property
    def location(self) -> str:
        if self.file is not None:
            return f"{self.file}:{self.line}" if self.line else self.file
        return self.obj or "<global>"

    def as_dict(self) -> Dict[str, object]:
        d = {"code": self.code, "severity": self.severity,
             "message": self.message, "pass": self.pass_name,
             "location": self.location, "suppressed": self.suppressed}
        for k in ("file", "line", "obj", "hint"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d


class AnalysisError(RuntimeError):
    """Raised by strict pre-flights when error-severity diagnostics exist."""

    def __init__(self, diags: Sequence[Diagnostic], where: str = "pre-flight"):
        self.diagnostics = list(diags)
        lines = [f"{where}: {len(self.diagnostics)} blocking diagnostic(s)"]
        lines += [f"  {d.code} [{d.location}] {d.message}"
                  for d in self.diagnostics]
        super().__init__("\n".join(lines))


# -- suppression -------------------------------------------------------------

_IGNORE_RE = re.compile(r"#\s*ciminus:\s*ignore\[([^\]]*)\]")


def suppressed_codes(source_line: str) -> Optional[List[str]]:
    """Codes waived by a ``# ciminus: ignore[...]`` marker (None = no
    marker; ``["*"]`` = blanket)."""
    m = _IGNORE_RE.search(source_line)
    if not m:
        return None
    return [c.strip() for c in m.group(1).split(",") if c.strip()]


def _line_suppresses(lines: Sequence[str], lineno: int, code: str) -> bool:
    if not (1 <= lineno <= len(lines)):
        return False
    codes = suppressed_codes(lines[lineno - 1])
    return codes is not None and ("*" in codes or code in codes)


def apply_suppressions(diags: List[Diagnostic],
                       sources: Dict[str, Sequence[str]]) -> List[Diagnostic]:
    """Mark file:line diagnostics whose line (or the line directly above)
    carries a matching ``ciminus: ignore`` marker.  Mutates and returns
    ``diags``; ``sources`` maps repo-relative path → source lines."""
    for d in diags:
        if d.file is None or d.line is None:
            continue
        lines = sources.get(d.file)
        if lines is None:
            continue
        if (_line_suppresses(lines, d.line, d.code)
                or _line_suppresses(lines, d.line - 1, d.code)):
            d.suppressed = True
    return diags


# -- rendering ---------------------------------------------------------------

def _sorted(diags: Sequence[Diagnostic]) -> List[Diagnostic]:
    return sorted(diags, key=lambda d: (Severity.rank(d.severity),
                                        d.code, d.location))


def render_text(diags: Sequence[Diagnostic], *, show_suppressed: bool = False
                ) -> str:
    shown = [d for d in diags if show_suppressed or not d.suppressed]
    n_sup = sum(1 for d in diags if d.suppressed)
    out = []
    for d in _sorted(shown):
        tag = " (suppressed)" if d.suppressed else ""
        out.append(f"{d.severity}[{d.code}]{tag} {d.location}: {d.message}")
        if d.hint:
            out.append(f"    hint: {d.hint}")
    errors = sum(1 for d in shown if not d.suppressed
                 and d.severity == Severity.ERROR)
    warns = sum(1 for d in shown if not d.suppressed
                and d.severity == Severity.WARNING)
    out.append(f"{errors} error(s), {warns} warning(s), "
               f"{n_sup} suppressed")
    return "\n".join(out)


def render_json(diags: Sequence[Diagnostic], *,
                passes: Sequence[str] = ()) -> str:
    active = [d for d in diags if not d.suppressed]
    payload = {
        "passes": list(passes),
        "counts": {
            "error": sum(1 for d in active
                         if d.severity == Severity.ERROR),
            "warning": sum(1 for d in active
                           if d.severity == Severity.WARNING),
            "note": sum(1 for d in active if d.severity == Severity.NOTE),
            "suppressed": sum(1 for d in diags if d.suppressed),
        },
        "ok": not any(d.severity == Severity.ERROR for d in active),
        "diagnostics": [d.as_dict() for d in _sorted(diags)],
    }
    return json.dumps(payload, indent=2)
