"""Pass 3 — model-plane validation: semantic checks over live
``Workload`` / ``OpNode`` / ``MappingSpec`` / ``CIMArch`` instances.

Two entry points share the checks:

* :func:`validate` — the library API (also re-exported as
  ``repro.analysis.validate``).  Called as a pre-flight by explore
  sweeps, ``trace.lower``, ``dryrun`` and ``serve.engine`` so a
  million-point sweep rejects ill-formed inputs in microseconds instead
  of burning hours (CIMFlow/AccelCIM-style front-end rejection).
* :class:`ModelPlanePass` — the ``--all`` repo self-check: every preset
  arch × every hand-built model, ``lm_workload`` over every
  ``configs/*`` entry, and the golden trace fixtures lowered and
  validated.  All jax-free.

Codes
-----
* ``CIM301`` (error) — dangling DAG edge (input names no op).
* ``CIM302`` (error) — dict-key / node-name mismatch (splice hazard).
* ``CIM303`` (error) — dependency cycle.
* ``CIM304`` (warning) — isolated op, disconnected from the DAG.
* ``CIM305`` (error) — zero/negative dims (K/N/V on MVM-shaped ops,
  negative ``elements``/``weight_count`` anywhere).
* ``CIM306`` (error) — sparsity spec incompatible with the op's matrix
  (block exceeds the K×N view, pattern cannot bind).
* ``CIM307`` — index-capacity feasibility (Eq. 8): per-op index
  footprint above ``index_capacity_bits`` is an error; declared weight
  sparsity on an arch without support is a warning.
* ``CIM308`` — macro-org feasibility: non-positive org axes (error);
  weight-side staging buffer smaller than one macro fill (warning).
* ``CIM309`` (error) — arch contract violations (missing required
  units, sparsity support without an index memory), surfaced from
  ``CIMArch.validate()`` as diagnostics.
* ``CIM310`` (error) — mapping contract violations (unknown strategy /
  flatten order / rearrange mode, ``slice`` without a positive
  ``slice_size``, bad org-axis assignment).
"""
from __future__ import annotations

from typing import List, Optional

from .diagnostics import Diagnostic, Severity
from .framework import AnalysisPass, PassContext, register

__all__ = ["validate", "ModelPlanePass"]

_ISSUE_CODES = {
    "dangling-edge": ("CIM301", Severity.ERROR),
    "name-mismatch": ("CIM302", Severity.ERROR),
    "cycle": ("CIM303", Severity.ERROR),
    "isolated": ("CIM304", Severity.WARNING),
}

_PASS_NAME = "model-plane"


def _diag(code: str, severity: str, message: str, obj: str,
          hint: Optional[str] = None) -> Diagnostic:
    return Diagnostic(code=code, severity=severity, message=message,
                      pass_name=_PASS_NAME, obj=obj, hint=hint)


def _mvm_shaped(op) -> bool:
    from ..core.workload import MVM_KINDS
    return op.kind in MVM_KINDS or op.kind == "dwconv"


def _validate_structure(workload, prefix: str) -> List[Diagnostic]:
    out = []
    for issue in workload.validate():
        code, sev = _ISSUE_CODES[issue.kind]
        out.append(_diag(code, sev, issue.message,
                         obj=f"{prefix}.{issue.path}"))
    return out


def _validate_ops(workload, arch, prefix: str) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    index_cap = arch.index_capacity_bits() if arch is not None else 0
    for key, op in workload.nodes.items():
        obj = f"{prefix}.nodes[{key!r}]"
        # CIM305 — dims
        if _mvm_shaped(op):
            for dim in ("K", "N", "V"):
                v = getattr(op, dim)
                if v <= 0:
                    out.append(_diag(
                        "CIM305", Severity.ERROR,
                        f"{op.kind} op {key!r} has {dim}={v} "
                        f"(must be positive)", obj=f"{obj}.{dim}"))
        elif op.elements < 0:
            out.append(_diag(
                "CIM305", Severity.ERROR,
                f"{op.kind} op {key!r} has negative elements "
                f"({op.elements})", obj=f"{obj}.elements"))
        if op.weight_count is not None and op.weight_count < 0:
            out.append(_diag(
                "CIM305", Severity.ERROR,
                f"op {key!r} has negative weight_count "
                f"({op.weight_count})", obj=f"{obj}.weight_count"))

        spec = op.sparsity
        if spec is None or not _mvm_shaped(op) or op.K <= 0 or op.N <= 0:
            continue
        shape = (op.K, op.N)
        # CIM306 — spec must bind to the op's matrix view
        try:
            spec.bind(shape)
            spec.validate_for(shape)
        except (ValueError, ZeroDivisionError) as e:
            out.append(_diag(
                "CIM306", Severity.ERROR,
                f"sparsity spec incompatible with {key!r} "
                f"({op.K}x{op.N}): {e}", obj=f"{obj}.sparsity",
                hint="bind block sizes to the op shape (e.g. "
                     "channel_wise with the op's own c_in) or drop the "
                     "spec for this op"))
            continue
        # CIM307 — index-capacity feasibility (Eq. 8)
        if not spec.is_dense and arch is not None:
            if not arch.weight_sparsity_support:
                out.append(_diag(
                    "CIM307", Severity.WARNING,
                    f"op {key!r} declares weight sparsity but arch "
                    f"{arch.name!r} has no weight-sparsity support "
                    f"(weights will be stored dense)",
                    obj=f"{obj}.sparsity"))
            elif index_cap > 0:
                bits = spec.index_storage_bits(shape)
                if bits > index_cap:
                    out.append(_diag(
                        "CIM307", Severity.ERROR,
                        f"op {key!r} needs {bits} index bits but "
                        f"{arch.name!r} index_mem holds {index_cap}",
                        obj=f"{obj}.sparsity",
                        hint="coarsen the block pattern (fewer, larger "
                             "blocks) or grow index_mem"))
    return out


def _validate_arch(arch, prefix: str) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    try:
        arch.validate()
    except ValueError as e:
        out.append(_diag("CIM309", Severity.ERROR, str(e),
                         obj=f"{prefix}",
                         hint="see CIMArch.validate for the required "
                              "compute/memory unit set"))
    if arch.org[0] <= 0 or arch.org[1] <= 0:
        out.append(_diag(
            "CIM308", Severity.ERROR,
            f"arch {arch.name!r} has non-positive macro org {arch.org}",
            obj=f"{prefix}.org"))
    weight_bufs = [m for m in arch.memory_units.values()
                   if m.name.startswith("weight")]
    if weight_bufs:
        cap_bits = max(m.capacity_bytes for m in weight_bufs) * 8
        need = arch.macro.weight_capacity_bits
        if cap_bits < need:
            out.append(_diag(
                "CIM308", Severity.WARNING,
                f"arch {arch.name!r} weight buffer ({cap_bits} bits) "
                f"cannot stage one macro fill ({need} bits) — loads "
                f"will stall mid-wave", obj=f"{prefix}.memory_units"))
    return out


def _validate_mapping(mapping, prefix: str) -> List[Diagnostic]:
    out: List[Diagnostic] = []

    def bad(msg: str, path: str, hint: Optional[str] = None) -> None:
        out.append(_diag("CIM310", Severity.ERROR, msg,
                         obj=f"{prefix}.{path}", hint=hint))

    if mapping.strategy not in ("spatial", "duplicate"):
        bad(f"unknown mapping strategy {mapping.strategy!r}", "strategy",
            "valid strategies: 'spatial', 'duplicate'")
    if {mapping.k_axis, mapping.n_axis} != {0, 1}:
        bad(f"k_axis/n_axis must cover org axes 0 and 1, got "
            f"({mapping.k_axis}, {mapping.n_axis})", "k_axis")
    r = mapping.reshape
    if r.flatten_order not in ("channel_major", "kernel_major"):
        bad(f"unknown flatten_order {r.flatten_order!r}",
            "reshape.flatten_order")
    if r.compress_orient not in ("auto", "row", "col"):
        bad(f"unknown compress_orient {r.compress_orient!r}",
            "reshape.compress_orient")
    if r.rearrange not in (None, "pad", "slice"):
        bad(f"unknown rearrange mode {r.rearrange!r}", "reshape.rearrange")
    if r.rearrange == "slice" and r.slice_size <= 0:
        bad(f"rearrange='slice' needs a positive slice_size "
            f"(got {r.slice_size})", "reshape.slice_size")
    if r.tile is not None and (r.tile[0] <= 0 or r.tile[1] <= 0):
        bad(f"non-positive reshape tile {r.tile}", "reshape.tile")
    return out


def validate(workload, arch=None, mapping=None, *,
             prefix: str = "workload") -> List[Diagnostic]:
    """Semantic pre-flight over live model-plane objects.

    Returns all diagnostics (CIM301–CIM310); callers decide strictness —
    :func:`repro.analysis.preflight` wraps the common raise/warn policy.
    Cost is O(ops): safe on the explore hot path (tracked by the
    ``analysis`` benchmark suite).
    """
    diags = _validate_structure(workload, prefix)
    diags += _validate_ops(workload, arch, prefix)
    if arch is not None:
        diags += _validate_arch(arch, prefix="arch")
    if mapping is not None:
        diags += _validate_mapping(mapping, prefix="mapping")
    return diags


@register
class ModelPlanePass(AnalysisPass):
    name = "model-plane"
    codes = ("CIM301", "CIM302", "CIM303", "CIM304", "CIM305",
             "CIM306", "CIM307", "CIM308", "CIM309", "CIM310")
    description = ("validate every preset arch x hand-built model, every "
                   "configs/* LM workload, and the golden trace fixtures")

    def run(self, ctx: PassContext) -> List[Diagnostic]:
        # live imports stay inside run() so `import repro.analysis` is
        # cheap and the source-only passes never need the package to be
        # importable from the analysed tree
        from ..configs import all_configs
        from ..core.mapping import MappingSpec, ReshapeSpec
        from ..core.presets import PRESET_ARCHS
        from ..core.workload import MODEL_BUILDERS, lm_workload

        mapping = MappingSpec(reshape=ReshapeSpec())
        archs = {name: mk() for name, mk in sorted(PRESET_ARCHS.items())}
        diags: List[Diagnostic] = []

        workloads = {name: mk() for name, mk in sorted(MODEL_BUILDERS.items())}
        for cfg_name, cfg in sorted(all_configs().items()):
            workloads[f"lm:{cfg_name}"] = lm_workload(cfg, seq_len=32)

        fixtures = sorted((ctx.root / "tests" / "fixtures" / "trace")
                          .glob("*.json"))
        if fixtures:
            from ..trace.ir import TraceGraph
            from ..trace.lower import lower_graph
            for fx in fixtures:
                graph = TraceGraph.load(fx)
                workloads[f"trace:{fx.stem}"] = lower_graph(graph)

        for wname, workload in workloads.items():
            for aname, arch in archs.items():
                for d in validate(workload, arch, mapping,
                                  prefix=f"{wname}[{aname}]"):
                    d.pass_name = self.name
                    diags.append(d)
        return diags
