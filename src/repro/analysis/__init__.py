"""repro.analysis — static invariant verification for the modeling plane.

A pass-based checker (CIMFlow/AccelCIM-style compiler front end) that
keeps the repo's load-bearing conventions machine-enforced:

* ``import-boundary`` (CIM1xx) — core/explore/trace/configs/calibrate
  stay jax-free; jax only through in-function lazy sites.
* ``cache-key`` (CIM2xx) — every ``simulate()`` knob participates in
  ``ExploreJob``'s content key and the CACHE_SCHEMA history.
* ``model-plane`` (CIM3xx) — semantic validation of live
  Workload/arch/mapping objects, also exposed as :func:`validate` /
  :func:`preflight` for pre-flight use on hot paths.
* ``determinism`` (CIM4xx) — no entropy, wall clock, salted ``hash()``,
  or directory-order dependence in result-producing code.

CLI: ``python -m repro.analysis [--all | --pass NAME] [--format
text|json]`` — exits non-zero on error-severity diagnostics.  The whole
package imports and runs without jax (it is itself part of the
protected plane it checks).  See ``docs/analysis.md``.
"""
from __future__ import annotations

import os
import warnings
from typing import List, Optional

from .diagnostics import (AnalysisError, Diagnostic, Severity,
                          render_json, render_text)
from .framework import (AnalysisPass, PassContext, all_passes, get_pass,
                        run_passes)
from .modelplane_pass import validate

__all__ = ["AnalysisError", "AnalysisPass", "Diagnostic", "PassContext",
           "Severity", "all_passes", "get_pass", "preflight",
           "render_json", "render_text", "run_passes", "validate"]

# set REPRO_ANALYSIS_PREFLIGHT=0 to disable library pre-flights (e.g.
# when intentionally simulating ill-formed inputs in experiments)
_PREFLIGHT_ENV = "REPRO_ANALYSIS_PREFLIGHT"

_warned: set = set()


def preflight(workload, arch=None, mapping=None, *, strict: bool = False,
              where: str = "pre-flight") -> List[Diagnostic]:
    """Validate model-plane inputs before expensive work.

    ``strict=True`` (CLI entry points) raises :class:`AnalysisError` on
    error-severity diagnostics; ``strict=False`` (library paths) emits
    one ``RuntimeWarning`` per offending workload and lets the caller
    proceed.  Returns the diagnostics either way.
    """
    if os.environ.get(_PREFLIGHT_ENV, "1") == "0":
        return []
    diags = validate(workload, arch, mapping)
    errors = [d for d in diags if d.severity == Severity.ERROR]
    if errors:
        if strict:
            raise AnalysisError(errors, where=where)
        key = (where, getattr(workload, "name", "?"),
               tuple(d.code for d in errors))
        if key not in _warned:
            _warned.add(key)
            head = "; ".join(f"{d.code} {d.message}" for d in errors[:3])
            more = f" (+{len(errors) - 3} more)" if len(errors) > 3 else ""
            warnings.warn(
                f"{where}: workload {getattr(workload, 'name', '?')!r} "
                f"failed model-plane validation: {head}{more}",
                RuntimeWarning, stacklevel=3)
    return diags
